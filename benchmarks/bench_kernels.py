"""Kernel benchmarks: Pallas (interpret) vs pure-jnp ref vs numpy host, plus
the analytic MXU roofline of the byte-limb gf_matmul formulation.

NOTE wall times here are CPU-interpret times (correctness harness), NOT TPU
times; the derived column carries the analytic TPU-side numbers
(16 int8-MXU passes per mod-matmul → peak_eff ≈ 197/4 TFLOP/s-equivalents
for the 62-bit exact product, see DESIGN §7).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.field import M31, NTT, shoup_precompute
from repro.kernels.butterfly.ops import butterfly_mac, butterfly_mac_reference
from repro.kernels.gf_matmul.ops import gf_matmul
from repro.kernels.gf_matmul.ref import gf_matmul_host, gf_matmul_ref

from .common import emit, time_fn


def run():
    rng = np.random.default_rng(0)
    q = M31
    M, K, N = 128, 512, 128
    a = jnp.asarray(rng.integers(0, q, size=(M, K), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, q, size=(K, N), dtype=np.uint32))
    us_pallas = time_fn(lambda: gf_matmul(a, b, q=q), iters=3, metric="bench.gf_matmul_us")
    # analytic: 16 uint8 dot passes of M*N*K MACs on the 197 TFLOP/s int8 MXU
    macs = M * N * K
    tpu_us = 16 * 2 * macs / 197e12 * 1e6
    emit("gf_matmul_128x512x128_pallas_interp", us_pallas, f"analytic_tpu_us={tpu_us:.2f}")
    us_ref = time_fn(lambda: gf_matmul_ref(a, b, q), iters=3, metric="bench.gf_matmul_ref_us")
    emit("gf_matmul_128x512x128_jnp_ref", us_ref, "oracle")
    import time as _t

    t0 = _t.perf_counter()
    gf_matmul_host(np.asarray(a), np.asarray(b), q)
    emit("gf_matmul_128x512x128_numpy_host", ( _t.perf_counter() - t0) * 1e6, "host_oracle")

    # butterfly fused MAC vs unfused ref
    radix, B, P = 2, 256, 4096
    parts = jnp.asarray(rng.integers(0, NTT, size=(radix, B, P), dtype=np.uint32))
    tw = jnp.asarray(rng.integers(0, NTT, size=(B, radix), dtype=np.uint32))
    tw_sh = jnp.asarray(np.asarray(shoup_precompute(np.asarray(tw), NTT)))
    us_fused = time_fn(
        lambda: butterfly_mac(parts, tw, tw_sh, q=NTT),
        iters=3,
        metric="bench.butterfly_mac_us",
    )
    us_unfused = time_fn(
        lambda: butterfly_mac_reference(parts, tw, tw_sh, q=NTT),
        iters=3,
        metric="bench.butterfly_mac_ref_us",
    )
    # analytic HBM traffic: fused reads radix·B·P + writes B·P once (vs
    # unfused writing radix intermediate rounds): bytes ratio (radix+1)/(2radix)
    emit(
        "butterfly_mac_r2_256x4096_fused_interp",
        us_fused,
        f"unfused_us={us_unfused:.1f},hbm_bytes_fused={(radix + 1) * B * P * 4}",
    )

    # the executor's fused LocalOp contraction (ISSUE 8): the exact shape
    # ir_encode_jit lowers per device — n_out×n_in coefficient rows over a
    # ≥64k payload — as the madd-folded row-batched Shoup fold ("fused"
    # kernels mode) vs the legacy per-(i,j) loop ("jnp" mode)
    import jax

    from repro.core.field import madd, shoup_mul

    n_out, n_in, pay = 15, 8, 1 << 16
    c = rng.integers(0, q, size=(n_out, n_in), dtype=np.uint32)
    csh = np.asarray(shoup_precompute(c, q))
    xs = jnp.asarray(rng.integers(0, q, size=(n_in, pay), dtype=np.uint32))
    cj, cshj = jnp.asarray(c), jnp.asarray(csh)

    @jax.jit
    def contraction_fused(xs):
        acc = None
        for j in range(n_in):
            term = shoup_mul(xs[j][None], cj[:, j, None], cshj[:, j, None], q)
            acc = term if acc is None else madd(acc, term, q)
        return acc

    @jax.jit
    def contraction_loop(xs):
        outs = []
        for i in range(n_out):
            acc = None
            for j in range(n_in):
                t = shoup_mul(xs[j], cj[i, j], cshj[i, j], q)
                acc = t if acc is None else madd(acc, t, q)
            outs.append(acc)
        return jnp.stack(outs)

    np.testing.assert_array_equal(
        np.asarray(contraction_fused(xs)), np.asarray(contraction_loop(xs))
    )
    us_f = time_fn(contraction_fused, xs, iters=5, metric="bench.localop_fused_us")
    us_l = time_fn(contraction_loop, xs, iters=5, metric="bench.localop_jnp_us")
    emit(
        f"localop_contraction_{n_out}x{n_in}x{pay}_fused",
        us_f,
        f"jnp_loop_us={us_l:.1f},speedup={us_l / us_f:.2f}x",
    )


if __name__ == "__main__":
    run()
