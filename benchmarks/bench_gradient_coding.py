"""Straggler-mitigation benchmark: coded gradient aggregation — decode
succeeds for every ≤s-straggler pattern; overhead = replication factor r."""

from __future__ import annotations

import itertools

import numpy as np

import jax.numpy as jnp

from repro.coded import aggregate, build_grad_coding, worker_combine

from .common import emit


def run():
    K, s = 8, 2
    plan = build_grad_coding(K, s, seed=0)
    rng = np.random.default_rng(1)
    shard_grads = {
        j: {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        for j in range(K)
    }
    want = sum(np.asarray(shard_grads[j]["w"]) for j in range(K))
    sent = {i: worker_combine(plan, i, shard_grads) for i in range(K)}
    worst = 0.0
    n_patterns = 0
    for drop in itertools.combinations(range(K), s):
        received = {i: c for i, c in sent.items() if i not in drop}
        got = np.asarray(aggregate(plan, received)["w"])
        worst = max(worst, float(np.abs(got - want).max() / np.abs(want).max()))
        n_patterns += 1
    emit(
        f"grad_coding_K{K}_s{s}_all_patterns",
        0.0,
        f"patterns={n_patterns},worst_rel_err={worst:.2e},replication={plan.r}",
    )


if __name__ == "__main__":
    run()
