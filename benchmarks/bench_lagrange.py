"""Theorem 4: Lagrange matrices = inverse-Vandermonde + forward-Vandermonde;
cost is the sum of the two draw-and-loose passes. Exactness vs the Lagrange
matrix oracle + wall time; plus the LCC coded-matmul application."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.coded import build_lcc, lcc_compute_and_decode, lcc_encode
from repro.core import bounds
from repro.core.draw_loose import encode_lagrange
from repro.core.field import NTT, Field
from repro.core.matrices import lagrange_matrix, random_vector
from repro.core.prepare_shoot import encode_oracle
from repro.core.schedule import plan_draw_loose

from .common import emit, time_fn


def run():
    f = Field(NTT)
    K = 16
    pw = plan_draw_loose(K, 1, NTT, seed=11)
    pa = plan_draw_loose(K, 1, NTT, seed=22)
    x = random_vector(f, K, seed=4)
    out = encode_lagrange(jnp.asarray(x.astype(np.uint32)), pw, pa)
    L = lagrange_matrix(f, pa.points, pw.points)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, L, NTT))
    c1 = 2 * bounds.theorem3_c1_c2(K, 1, pw.M, pw.H)[0]
    c2 = 2 * bounds.theorem3_c1_c2(K, 1, pw.M, pw.H)[1]
    print(f"# Theorem4 K={K}: C1={c1} C2={c2} (2x draw-and-loose), exact=True")
    fn = jax.jit(lambda xx: encode_lagrange(xx, pw, pa))
    us = time_fn(
        fn,
        jnp.asarray(random_vector(f, (K, 512), seed=5).astype(np.uint32)),
        metric="bench.lagrange_us",
    )
    emit("lagrange_K16_payload512", us, f"C1={c1}_C2={c2}")

    # LCC application (the paper's §VI motivation)
    plan = build_lcc(8, p=1, q=NTT)
    rng = np.random.default_rng(0)
    X = rng.integers(0, 1000, size=(8, 16, 8), dtype=np.uint32)
    W = rng.integers(0, 1000, size=(8, 4), dtype=np.uint64)
    enc = lcc_encode(plan, jnp.asarray(X))
    outs = lcc_compute_and_decode(plan, np.asarray(enc), W, list(range(8)))
    ok = all(
        np.array_equal(outs[i], f.matmul(X[i].astype(np.uint64), W)) for i in range(8)
    )
    emit("lcc_coded_matmul_K8", 0.0, f"exact={ok}")


if __name__ == "__main__":
    run()
