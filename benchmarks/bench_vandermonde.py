"""Theorem 3 / Remark 5: draw-and-loose for general Vandermonde — C2 = H+Ψ(M)
vs the universal algorithm, across K with different radix structure."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.core.draw_loose import encode_draw_loose
from repro.core.field import NTT, Field
from repro.core.matrices import random_vector
from repro.core.schedule import plan_draw_loose
from repro.core.simulator import simulate_draw_loose

from .common import emit, time_fn


def run():
    f = Field(NTT)
    print("# K,p,M,H,C1_sim,C2_sim,C2_thm3,C2_universal")
    for K in (8, 12, 16, 24, 48, 64, 96, 128, 7):
        plan = plan_draw_loose(K, 1, NTT, seed=3)
        x = random_vector(f, K, seed=K)
        _, st = simulate_draw_loose(x, plan, f)
        c1t, c2t = bounds.theorem3_c1_c2(K, 1, plan.M, plan.H)
        print(
            f"# {K},1,{plan.M},{plan.H},{st.C1},{st.C2},{c2t},{bounds.theorem1_c2(K, 1)}"
        )
        assert st.C2 == c2t or plan.M == 1
    K, payload = 64, 1024
    plan = plan_draw_loose(K, 1, NTT)
    x = jnp.asarray(random_vector(f, (K, payload), seed=1).astype(np.uint32))
    fn = jax.jit(lambda xx: encode_draw_loose(xx, plan))
    us = time_fn(fn, x, metric="bench.vandermonde_us")
    emit("draw_loose_K64_payload1024", us, f"M={plan.M}_H={plan.H}_C2={plan.c2}")


if __name__ == "__main__":
    run()
