"""Theorem 1 / Lemmas 1-3 (and Fig. 1): universal prepare-and-shoot.

Columns: simulator-counted C1/C2, closed forms, lower bounds, baseline C2's
(all-gather, direct), and wall time of the array-level executor.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.core.field import M31, Field
from repro.core.matrices import random_matrix, random_vector
from repro.core.prepare_shoot import encode_universal
from repro.core.schedule import counted_c2, plan_prepare_shoot
from repro.core.simulator import simulate_prepare_shoot

from .common import emit, time_fn


def run():
    f = Field(M31)
    print("# K,p,C1_sim,C1_lower,C2_sim,C2_thm1,C2_lower,C2_allgather,C2_direct")
    for p in (1, 2, 3):
        for K in (8, 16, 32, 64, 128, 256, 512):
            plan = plan_prepare_shoot(K, p)
            A = random_matrix(f, K, seed=K)
            x = random_vector(f, K, seed=K + 1)
            out, st = simulate_prepare_shoot(x, A, plan, f)
            ag = bounds.allgather_baseline_c1_c2(K, p)[1]
            di = bounds.direct_baseline_c1_c2(K, p)[1]
            print(
                f"# {K},{p},{st.C1},{bounds.lemma1_c1_lower(K, p)},{st.C2},"
                f"{bounds.theorem1_c2(K, p)},{bounds.lemma2_c2_lower(K, p):.1f},{ag},{di}"
            )
            assert st.C1 == bounds.lemma1_c1_lower(K, p)
            assert st.C2 == counted_c2(plan)
    # executor wall time (K=64, payload 1024, runtime-A path)
    K, payload = 64, 1024
    A = jnp.asarray(random_matrix(f, K, seed=0).astype(np.uint32))
    x = jnp.asarray(random_vector(f, (K, payload), seed=1).astype(np.uint32))
    fn = jax.jit(lambda xx, aa: encode_universal(xx, aa, p=1, q=M31))
    us = time_fn(fn, x, A, metric="bench.universal_us")
    emit("universal_ps_K64_payload1024", us, f"C2={bounds.theorem1_c2(K, 1)}")


if __name__ == "__main__":
    run()
