"""Roofline bench: renders the three-term roofline table from the cached
dry-run artifacts (results/dryrun/*.json). One row per (arch × shape × mesh)
— deliverable (g)'s machine-readable form."""

from __future__ import annotations

import os

from repro.launch.roofline import load_all, render_table

from .common import emit


def run():
    if not os.path.isdir("results/dryrun"):
        emit("dryrun_roofline", 0.0, "no results/dryrun — run repro.launch.dryrun first")
        return
    rows = load_all("results/dryrun")
    print(render_table(rows))
    ok = [r for r in rows if r.status == "ok"]
    emit("dryrun_roofline_cells", 0.0, f"ok={len(ok)},total={len(rows)}")


if __name__ == "__main__":
    run()
