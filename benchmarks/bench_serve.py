"""Serving harness: fixed-batch vs continuous-batching on one Poisson trace.

The workload is a seeded ``repro.serve.traffic.poisson_trace`` (exponential
arrivals, mixed prompt lengths, staggered generation budgets). Both engines
serve the SAME trace:

* fixed-batch baseline: requests are grouped into arrival-order batches of
  ``n_slots``; a batch starts when its last member has arrived and every
  result is delivered at batch completion (TTFT == E2E — the stall the
  continuous engine removes). Throughput counts only each request's
  requested tokens; the baseline's padding overshoot is wasted work.
* continuous: ``repro.serve.ContinuousEngine`` with the same slot count —
  bucketed compiled prefill + mid-decode slot refill.

Emits ``bench.serve.*`` CSV rows (micro-timings routed into
``bench.serve.prefill_us`` / ``bench.serve.decode_step_us`` histograms via
``benchmarks.common.time_fn``) and writes ``results/BENCH_serve.json`` —
schema-gated by ``tools/check_trace.py --kind serve``.

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import ContinuousEngine, Engine, LengthBand, Request, poisson_trace
from repro.serve.engine import _percentiles_ms
from repro.train.train_loop import make_decode_step, make_prefill_step

from .common import emit, time_fn

#: short-prompt-heavy mix sized for the smoke model's max_len
MIX = (
    LengthBand(2, 6, 0.5),
    LengthBand(7, 14, 0.35),
    LengthBand(15, 28, 0.15),
)


def _fixed_batch_serve(model, params, reqs, n_slots, max_len, eos_id=None):
    """Measure the fixed-batch engine on the trace: arrival-order groups of
    n_slots, batch starts once its last member arrived, per-request TTFT ==
    E2E == batch completion − arrival."""
    eng = Engine(model, params, max_len=max_len)
    groups = [reqs[i : i + n_slots] for i in range(0, len(reqs), n_slots)]
    # warmup: compile the decode step at batch size n_slots outside timing
    warm = [reqs[0].prompt] * n_slots
    eng.generate(warm, max_new_tokens=2, eos_id=eos_id)
    ttfts, e2es = [], []
    gen_total = 0
    t0 = time.perf_counter()

    def now():
        return time.perf_counter() - t0

    for g in groups:
        start = max(r.arrival_s for r in g)
        if start > now():
            time.sleep(start - now())
        prompts = [r.prompt for r in g]
        # pad the trailing partial group so the compiled step's batch size
        # (and so its compilation) is reused; padded rows are discarded
        while len(prompts) < n_slots:
            prompts.append(g[-1].prompt)
        res = eng.generate(
            prompts,
            max_new_tokens=max(r.max_new_tokens for r in g),
            eos_id=eos_id,
        )
        end = now()
        gens = res.lengths - res.prompt_lens
        for j, r in enumerate(g):
            ttfts.append(end - r.arrival_s)
            e2es.append(end - r.arrival_s)
            # only the tokens the request asked for count as useful output
            gen_total += int(min(gens[j], r.max_new_tokens))
    wall_s = now()
    return {
        "tokens_per_s": (gen_total / wall_s) if wall_s > 0 else 0.0,
        "ttft_ms": _percentiles_ms(ttfts),
        "e2e_ms": _percentiles_ms(e2es),
        "n_requests": len(reqs),
        "wall_s": wall_s,
    }


def run(smoke: bool = True, out: str = os.path.join("results", "BENCH_serve.json")):
    n_requests = 16 if smoke else 32
    rate_rps = 60.0
    n_slots = 4
    max_new = 8
    buckets = (8, 16, 32)
    max_len = 48
    seed = 0

    cfg = smoke_config("qwen3-1.7b").replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = poisson_trace(
        n_requests,
        rate_rps,
        mix=MIX,
        max_new_tokens=max_new,
        vocab_size=cfg.vocab_size,
        seed=seed,
    )

    # continuous engine: compile every bucket + the decode tick on a warmup
    # trace, then measure — recompiles stay bounded by the bucket set
    ceng = ContinuousEngine(
        model, params, n_slots=n_slots, max_len=max_len,
        buckets=buckets, max_new_tokens=max_new,
    )
    warm = [
        Request(id=f"warm-{b}", prompt=list(range(1, b + 1)), max_new_tokens=2)
        for b in buckets
        if b + 2 <= max_len
    ]
    ceng.serve(warm, greedy=True)
    creport = ceng.serve(reqs, greedy=True, sync_every=4)

    fixed = _fixed_batch_serve(model, params, reqs, n_slots, max_len)

    # micro-timings of the two compiled graphs behind the engine
    pf = jax.jit(make_prefill_step(model, into_cache=True))
    dec = jax.jit(make_decode_step(model))
    cache1 = model.init_cache(1, max_len)
    tok_b = jnp.zeros((1, buckets[0]), jnp.int32)
    us_pf = time_fn(
        lambda: pf(params, cache1, tok_b, jnp.int32(0), jnp.int32(buckets[0]))[0],
        metric="bench.serve.prefill_us",
    )
    cache_s = model.init_cache(n_slots, max_len)
    toks = jnp.ones((n_slots, 1), jnp.int32)
    pos = jnp.zeros((n_slots,), jnp.int32)
    us_dec = time_fn(
        lambda: dec(params, cache_s, toks, pos)[0],
        metric="bench.serve.decode_step_us",
    )

    record = {
        "model": cfg.name,
        "n_layers": cfg.n_layers,
        "workload": {
            "n_requests": n_requests,
            "rate_rps": rate_rps,
            "max_new_tokens": max_new,
            "seed": seed,
            "mix": [[b.lo, b.hi, b.weight] for b in MIX],
        },
        "n_slots": n_slots,
        "buckets": list(buckets),
        "engines": {
            "fixed_batch": fixed,
            "continuous": creport.to_record(),
        },
        "speedup": {
            "tokens_per_s": (
                creport.tokens_per_s / fixed["tokens_per_s"]
                if fixed["tokens_per_s"] > 0
                else 0.0
            ),
            "ttft_p99": (
                fixed["ttft_ms"]["p99"] / creport.ttft_ms["p99"]
                if creport.ttft_ms["p99"] > 0
                else 0.0
            ),
        },
    }
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)

    emit("serve_fixed_tokens_per_s", fixed["wall_s"] * 1e6,
         f"tok/s={fixed['tokens_per_s']:.1f}")
    emit("serve_continuous_tokens_per_s", creport.wall_s * 1e6,
         f"tok/s={creport.tokens_per_s:.1f}")
    emit("serve_prefill", us_pf, f"bucket={buckets[0]}")
    emit("serve_decode_step", us_dec, f"slots={n_slots}")
    emit(
        "serve_speedup",
        0.0,
        f"tok/s x{record['speedup']['tokens_per_s']:.2f} "
        f"ttft_p99 x{record['speedup']['ttft_p99']:.2f} "
        f"compiles={creport.prefill_compiles}",
    )
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small CI-sized run")
    ap.add_argument("--out", default=os.path.join("results", "BENCH_serve.json"))
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
