"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
