"""Shared benchmark utilities: timing + CSV emission.

``time_fn`` is THE timing helper for every benchmark (bench_topology's
subprocess child included — no local re-implementations): warmup calls,
``iters`` timed calls with ``block_until_ready``, median µs returned.
Passing ``metric=`` routes every individual sample through the
``repro.obs.metrics`` histogram of that name, so a benchmark run leaves a
queryable latency distribution (count/p50/p90/p99) behind in the registry
snapshot instead of only the median on stdout.
"""

from __future__ import annotations

import time

import jax


def time_fn(
    fn,
    *args,
    warmup: int = 1,
    iters: int = 5,
    metric: str | None = None,
    registry=None,
) -> float:
    """Median wall time (µs) of fn(*args) with block_until_ready. With
    ``metric``, each sample is also observed in that histogram of
    ``registry`` (default: the process-local ``repro.obs`` one)."""
    hist = None
    if metric is not None:
        if registry is None:
            from repro.obs.metrics import get_registry

            registry = get_registry()
        hist = registry.histogram(metric)
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    if hist is not None:
        for t in ts:
            hist.observe(t)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
