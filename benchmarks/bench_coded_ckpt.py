"""Coded-checkpoint benchmark (Remark 1 application): parity encode
throughput, recovery latency, and the collective cost C1·β + C2·τ of the
prepare-and-shoot schedule vs the all-gather baseline on the production
mesh's DP axis (K=16)."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.coded import build_parity_plan, encode_parity, recover_lost
from repro.core.bounds import CostModel, allgather_baseline_c1_c2
from repro.core.schedule import counted_c2

from .common import emit, time_fn


def run():
    K = 16
    S = 1 << 16  # limbs per replica shard
    plan = build_parity_plan(K, p=1)
    rng = np.random.default_rng(0)
    shards = jnp.asarray(rng.integers(0, 1 << 16, size=(K, S), dtype=np.uint32))
    fn = jax.jit(lambda x: encode_parity(x, plan))
    us = time_fn(fn, shards, iters=3, metric="bench.coded_ckpt_us")
    mb = K * S * 2 / 1e6  # 16-bit payload per limb
    emit("coded_ckpt_encode_K16_64Klimbs", us, f"MB={mb:.1f},MBps={mb / (us / 1e6):.0f}")

    parity = np.asarray(fn(shards), dtype=np.uint64)
    sn = np.asarray(shards, dtype=np.uint64)
    t0 = time.perf_counter()
    lost = [2, 7, 11]
    rec = recover_lost(
        plan,
        lost,
        {k: sn[k] for k in range(K) if k not in lost},
        {k: parity[k] for k in range(K) if k not in lost},
    )
    us_rec = (time.perf_counter() - t0) * 1e6
    ok = all(np.array_equal(rec[k], sn[k]) for k in lost)
    emit("coded_ckpt_recover_3of16", us_rec, f"bit_exact={ok}")

    # collective cost model on the DP axis (v5e ICI): paper vs baseline
    cm = CostModel()
    c1, c2 = plan.c1, counted_c2(plan.ps_plan)
    payload = S  # field elements
    t_ps = cm.time(c1, c2, payload)
    ag_c1, ag_c2 = allgather_baseline_c1_c2(K, 1)
    t_ag = cm.time(ag_c1, ag_c2, payload)
    emit(
        "coded_ckpt_collective_model_K16",
        t_ps * 1e6,
        f"C1={c1},C2={c2},allgather_us={t_ag * 1e6:.1f},speedup={t_ag / t_ps:.2f}x",
    )


if __name__ == "__main__":
    run()
