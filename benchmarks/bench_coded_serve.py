"""Coded straggler-tolerant serving: overhead + recovery latency.

One seeded Poisson trace served three ways by the continuous engine:

* **uncoded** — the PR-9 baseline, no guard;
* **coded, no faults** — ``serve.coded.CodedServeGuard`` LCC-encodes the
  decode-path state to N = K + R simulated hosts before every decode
  chunk: the pure snapshot/encode overhead (tokens/s, p99 TTFT);
* **fault scenarios** — the same coded run with 1 and 2 scheduled host
  kills mid-trace: every in-flight request recovered from K surviving
  shards, the token streams re-checked bit-identical against the
  unfailed baseline, recovery latency (``serve.recovery_us``) reported.

Writes ``results/BENCH_coded_serve.json`` — schema- and semantics-gated
by ``tools/check_trace.py --kind coded-serve`` (recoveries ≥ injected
faults, ordered recovery percentiles, ``tokens_identical`` true).

Run:  PYTHONPATH=src python -m benchmarks.bench_coded_serve [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import (
    CodedServeGuard,
    ContinuousEngine,
    FaultInjector,
    LengthBand,
    Request,
    poisson_trace,
)

from .common import emit

#: short-prompt-heavy mix sized for the smoke model's max_len
MIX = (
    LengthBand(2, 6, 0.5),
    LengthBand(7, 14, 0.35),
    LengthBand(15, 28, 0.15),
)

K, R = 3, 2  # N = 5 simulated hosts, any 3 survive


def _tokens(report) -> dict:
    return {r.id: tuple(r.tokens) for r in report.results}


def run(
    smoke: bool = True,
    out: str = os.path.join("results", "BENCH_coded_serve.json"),
):
    n_requests = 12 if smoke else 24
    rate_rps = 60.0
    n_slots = 4
    max_new = 8
    buckets = (8, 16, 32)
    max_len = 48
    seed = 0
    sync_every = 2

    cfg = smoke_config("qwen3-1.7b").replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def trace():
        return poisson_trace(
            n_requests,
            rate_rps,
            mix=MIX,
            max_new_tokens=max_new,
            vocab_size=cfg.vocab_size,
            seed=seed,
        )

    eng = ContinuousEngine(
        model, params, n_slots=n_slots, max_len=max_len,
        buckets=buckets, max_new_tokens=max_new,
    )
    warm = [
        Request(id=f"warm-{b}", prompt=list(range(1, b + 1)), max_new_tokens=2)
        for b in buckets
        if b + 2 <= max_len
    ]
    eng.serve(warm, greedy=True)

    # unfailed runs: the uncoded baseline and the pure coding overhead
    base = eng.serve(trace(), greedy=True, sync_every=sync_every)
    base_toks = _tokens(base)
    coded_clean = eng.serve(
        trace(), greedy=True, sync_every=sync_every,
        guard=CodedServeGuard(K=K, R=R),
    )
    assert _tokens(coded_clean) == base_toks  # guard must be a no-op on tokens

    # fault scenarios: 1 and 2 host kills mid-trace; tokens must still
    # match the unfailed baseline bit-for-bit
    scenarios = []
    for kills in ((3, 0),), ((3, 0), (7, 4)):
        guard = CodedServeGuard(K=K, R=R, injector=FaultInjector(kills=kills))
        rep = eng.serve(
            trace(), greedy=True, sync_every=sync_every, guard=guard
        )
        scenarios.append(
            {
                "kills": len(kills),
                "kill_schedule": [list(k) for k in kills],
                "tokens_identical": _tokens(rep) == base_toks,
                "tokens_per_s": rep.tokens_per_s,
                "coded": rep.coded,
            }
        )

    record = {
        "model": cfg.name,
        "n_layers": cfg.n_layers,
        "workload": {
            "n_requests": n_requests,
            "rate_rps": rate_rps,
            "max_new_tokens": max_new,
            "seed": seed,
            "mix": [[b.lo, b.hi, b.weight] for b in MIX],
        },
        "n_slots": n_slots,
        "buckets": list(buckets),
        "sync_every": sync_every,
        "coded": {"K": K, "R": R, "n_hosts": K + R},
        "engines": {
            "uncoded": base.to_record(),
            "coded": coded_clean.to_record(),
        },
        "fault_scenarios": scenarios,
        "overhead": {
            "tokens_per_s_ratio": (
                coded_clean.tokens_per_s / base.tokens_per_s
                if base.tokens_per_s > 0
                else 0.0
            ),
            "ttft_p99_ratio": (
                coded_clean.ttft_ms["p99"] / base.ttft_ms["p99"]
                if base.ttft_ms["p99"] > 0
                else 0.0
            ),
        },
    }
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)

    emit("coded_serve_uncoded_tokens_per_s", base.wall_s * 1e6,
         f"tok/s={base.tokens_per_s:.1f}")
    emit("coded_serve_coded_tokens_per_s", coded_clean.wall_s * 1e6,
         f"tok/s={coded_clean.tokens_per_s:.1f} "
         f"x{record['overhead']['tokens_per_s_ratio']:.2f}")
    for sc in scenarios:
        c = sc["coded"]
        emit(
            f"coded_serve_recovery_{sc['kills']}kill",
            c["recovery_us"]["p99"],
            f"recoveries={c['recoveries']} identical={sc['tokens_identical']}",
        )
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small CI-sized run")
    ap.add_argument(
        "--out", default=os.path.join("results", "BENCH_coded_serve.json")
    )
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
