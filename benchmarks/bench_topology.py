"""Topology benchmark: flat vs. two-level vs. three-level encode on 8
forced-host devices, plus the calibration sweep the α/β fitter consumes.

Times ``ps_encode_jit`` (1D mesh), ``hierarchical_encode_jit`` (4×2
inter×intra mesh), ``multilevel_encode_jit`` (2×2×2 pod×slice×chip mesh —
the recursive three-level schedule) and the ``allgather_encode_jit`` foil on
the same Vandermonde encode ACROSS A PAYLOAD SWEEP, in a subprocess with
``--xla_force_host_platform_device_count=8`` (the override must not leak
into sibling benchmarks). All timing goes through ``benchmarks.common.
time_fn`` (samples routed into ``bench.topology.*_us`` metrics
histograms), and the child ALSO runs the three-level encode through
``ir_encode_jit(tracer=...)`` — the traced per-round dispatch path — so
every CommRound leaves a span with measured wall µs next to the α-β
model's prediction. Emits ``results/BENCH_topology.json`` with:

* the measured wall times next to the autotuner's α-β predictions on the
  matching two-level topology (``measured_s`` feeds straight back into
  ``autotune(..., measured=...)`` / ``resolve_profile(measured=...)``);
* a ``three_level`` block with the same sweep priced on the
  ``Hierarchy(levels=(2, 2, 2))`` model;
* a ``calibration`` block — offline aggregate ``samples`` (one per
  (algorithm, payload): whole-encode seconds + analytic per-round
  ``{level, msgs, elems}`` rows) AND a ``live`` sub-block fitted from the
  traced per-round spans (the ROADMAP "feed the fit from LIVE sweep
  telemetry" item — ``repro.obs.feed``). The persisted
  ``fitted_level_costs`` come from the live fit when it succeeds, and are
  verified to round-trip through ``topo.calibrate.load_fitted_costs`` —
  the exact loader ``launch.profiles.resolve_profile`` uses;
* a ``fused_kernels`` block — the same flat schedule timed with the three
  ``kernels=`` LocalOp lowerings (legacy ``jnp`` loop vs the batched
  ``fused`` contraction, plus ``fused`` with the ``pipeline`` overlap
  rewrite) at the ≥64k-element payloads, with measured fused-vs-jnp
  speedups (the ISSUE 8 wall-clock acceptance);
* the child's metrics-registry snapshot under ``metrics``.

The traced spans are also persisted under ``results/traces/
bench_topology.{jsonl,trace.json}`` (Perfetto-loadable);
``launch/perf_report.py`` renders the predicted-vs-measured tables and
``render_drift`` renders the per-round drift from the trace.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PAYLOADS = (1 << 12, 1 << 14, 1 << 16)

_CHILD = """
    import json
    import numpy as np, jax, jax.numpy as jnp
    from benchmarks.common import time_fn
    from repro.launch.mesh import make_mesh
    from repro.core.field import M31, Field
    from repro.core.matrices import distinct_points, vandermonde, random_vector
    from repro.dist.collectives import (
        allgather_encode_jit, hierarchical_encode_jit, ir_encode_jit,
        multilevel_encode_jit, ps_encode_jit)
    from repro.obs import Tracer, get_registry
    from repro.topo import Hierarchy, plan_multilevel

    K = 8
    PAYLOADS = %(payloads)r
    f = Field(M31)
    A = np.asarray(vandermonde(f, distinct_points(f, K, seed=0)))

    mesh1 = make_mesh((8,), ("enc",))
    mesh2 = make_mesh((4, 2), ("inter", "intra"))
    mesh3 = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
    fn_ps, _ = ps_encode_jit(mesh1, "enc", A, p=1)
    fn_h, _ = hierarchical_encode_jit(mesh2, "inter", "intra", A, p=1)
    fn_m, _ = multilevel_encode_jit(mesh3, ("pod", "slice", "chip"), A, p=1)
    fn_ag = allgather_encode_jit(mesh1, "enc", A)
    fns = {"prepare-shoot": fn_ps, "hierarchical": fn_h,
           "multilevel": fn_m, "allgather": fn_ag}
    # the traced per-round dispatch of the SAME three-level schedule:
    # every CommRound becomes one span with measured wall vs predicted us
    topo3 = Hierarchy(levels=(2, 2, 2))
    ir3 = plan_multilevel(K, 1, (2, 2, 2)).to_ir(A)
    tracer = Tracer()
    fn_traced = ir_encode_jit(
        mesh3, ("pod", "slice", "chip"), ir3, tracer=tracer, topo=topo3)
    sweep = {alg: {} for alg in fns}
    live_windows = []
    for pay in PAYLOADS:
        x = jnp.asarray(random_vector(f, (K, pay), seed=1).astype(np.uint32))
        outs = {alg: np.asarray(fn(x)) for alg, fn in fns.items()}
        ref = outs["prepare-shoot"]
        for alg, o in outs.items():
            assert np.array_equal(ref, o), f"flat and {alg} disagree"
        for alg, fn in fns.items():
            sweep[alg][str(pay)] = time_fn(
                fn, x, warmup=1, iters=5,
                metric=f"bench.topology.{alg}_us")
        # traced run: first call compiles the per-round dispatches; only
        # the second call's spans are calibration-grade measurements
        assert np.array_equal(ref, np.asarray(fn_traced(x)))
        n0 = len(tracer.spans)
        assert np.array_equal(ref, np.asarray(fn_traced(x)))
        live_windows.append((n0, len(tracer.spans)))
    measured_spans = []
    for n0, n1 in live_windows:
        measured_spans += [s.to_dict() for s in tracer.spans[n0:n1]]
    print(json.dumps({
        "sweep": sweep,
        "spans": measured_spans,
        "metrics": get_registry().snapshot(),
    }))
"""

# Fused-kernel / pipelined-rounds comparison (ISSUE 8): its own 16-device
# child — K=16, p=2 prepare-shoot is the contraction-heaviest flat schedule
# the forced host can carry (a 3×9 shoot-init contraction per device), so the
# LocalOp lowering (kernels=) and the comm/compute-overlap rewrite
# (pipeline="pipeline") are visible over the emulated wire time at the
# ISSUE's ≥64k-element payloads. All variants are bit-exact by construction
# (asserted below and in tests/test_fused_encode.py).
_CHILD_FUSED = """
    import json
    import numpy as np, jax, jax.numpy as jnp
    from benchmarks.common import time_fn
    from repro.launch.mesh import make_mesh
    from repro.core.field import M31, Field
    from repro.core.matrices import distinct_points, vandermonde, random_vector
    from repro.dist.collectives import ps_encode_jit

    K = 16
    PAYLOADS = %(payloads)r
    f = Field(M31)
    A = np.asarray(vandermonde(f, distinct_points(f, K, seed=0)))
    mesh = make_mesh((16,), ("enc",))
    variants = {
        "jnp": ps_encode_jit(mesh, "enc", A, p=2, kernels="jnp")[0],
        "fused": ps_encode_jit(mesh, "enc", A, p=2, kernels="fused")[0],
        "fused+pipeline": ps_encode_jit(
            mesh, "enc", A, p=2, kernels="fused", pipeline="pipeline")[0],
    }
    rows = {}
    for pay in PAYLOADS:
        x = jnp.asarray(random_vector(f, (K, pay), seed=2).astype(np.uint32))
        ref, row = None, {}
        for name, fn in variants.items():
            o = np.asarray(fn(x))
            ref = o if ref is None else ref
            assert np.array_equal(ref, o), f"kernels={name} disagrees"
            row[name] = time_fn(
                fn, x, warmup=2, iters=9,
                metric=f"bench.topology.kernels_{name.replace('+', '_')}_us")
        rows[str(pay)] = row
    print(json.dumps(rows))
"""

FUSED_PAYLOADS = (1 << 16, 1 << 17)


def _run_fused_child():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.pathsep.join([REPO, os.path.join(REPO, "src")])
    r = subprocess.run(
        [
            sys.executable,
            "-c",
            textwrap.dedent(_CHILD_FUSED % {"payloads": FUSED_PAYLOADS}),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_topology fused child failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # repo root (for benchmarks.common) + src (for repro)
    env["PYTHONPATH"] = os.pathsep.join([REPO, os.path.join(REPO, "src")])
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD % {"payloads": PAYLOADS})],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_topology child failed:\n{r.stdout}\n{r.stderr}")
    child = json.loads(r.stdout.strip().splitlines()[-1])
    sweep = child["sweep"]
    spans = child["spans"]

    # α-β predictions for the same scenario on the matching topologies
    from repro.core.schedule import plan_prepare_shoot
    from repro.obs import drift_rows, round_measurements, write_chrome_trace, write_spans_jsonl
    from repro.topo import (
        Hierarchy,
        TwoLevel,
        autotune,
        fit_level_costs,
        lower,
        lower_allgather,
        plan_hierarchical,
        plan_multilevel,
        round_features,
    )

    K, PAY = 8, 1 << 14
    measured_us = {alg: times[str(PAY)] for alg, times in sweep.items()}
    topo = TwoLevel(k_intra=2, k_inter=4)
    result = autotune(K, 1, PAY * 4, topo, generator="vandermonde")

    def predicted_rows(res):
        return {
            c.algorithm: {
                "us": c.predicted_time * 1e6,
                "c1": c.c1,
                "c2": c.c2,
                "pipeline": c.pipeline,
            }
            for c in res.candidates
        }

    predicted = predicted_rows(result)
    two_level_us = {a: u for a, u in measured_us.items() if a != "multilevel"}
    record = {
        "K": K,
        "p": 1,
        "payload_elems": PAY,
        "mesh": "4x2 (inter x intra), forced-host",
        "topology": "two-level k_intra=2 k_inter=4",
        "autotuner_choice": result.algorithm,
        "autotuner_choice_pipeline": result.chosen.pipeline,
        "measured_us": two_level_us,
        # seconds, the unit autotune(..., measured=...) compares against
        "measured_s": {alg: us * 1e-6 for alg, us in two_level_us.items()},
        "predicted": predicted,
        "metrics": child["metrics"],
    }
    # three-level sweep: the same encode priced on the recursive hierarchy
    topo3 = Hierarchy(levels=(2, 2, 2))
    result3 = autotune(K, 1, PAY * 4, topo3, generator="vandermonde")
    # only multilevel actually ran on the 2×2×2 mesh — the flat/two-level
    # numbers above were measured on their own meshes and stay in the
    # top-level block (a measured_s map must match its stated mesh)
    three_level_us = {a: u for a, u in measured_us.items() if a == "multilevel"}
    record["three_level"] = {
        "mesh": "2x2x2 (pod x slice x chip), forced-host",
        "topology": "hierarchy levels=(2, 2, 2)",
        "autotuner_choice": result3.algorithm,
        "measured_us": three_level_us,
        "measured_s": {alg: us * 1e-6 for alg, us in three_level_us.items()},
        "predicted": predicted_rows(result3),
    }
    # calibration block: offline aggregate samples (whole-encode seconds ×
    # analytic round features) + the live per-round span fit
    rounds_by_alg = {
        "prepare-shoot": lower(plan_prepare_shoot(K, 1)).rounds,
        "hierarchical": lower(plan_hierarchical(K, 1, 2)).rounds,
        "multilevel": lower(plan_multilevel(K, 1, (2, 2, 2))).rounds,
        "allgather": lower_allgather(K, 1).rounds,
    }
    samples = []
    for alg, rounds in rounds_by_alg.items():
        feats = round_features(rounds, topo3)
        for pay_str, us in sweep[alg].items():
            samples.append(
                {
                    "algorithm": alg,
                    "payload_elems": int(pay_str),
                    "wall_s": us * 1e-6,
                    "rounds": feats,
                }
            )
    offline_fit = fit_level_costs(samples, n_levels=3)
    live_samples = round_measurements(spans)
    try:
        live_fit = fit_level_costs(live_samples, n_levels=3)
    except ValueError:
        live_fit = None
    # the persisted (load_fitted_costs-visible) costs are the LIVE fit when
    # the traced sweep produced one — telemetry-fed calibration; the offline
    # aggregate fit stays alongside for comparison
    fitted = live_fit if live_fit is not None else offline_fit
    record["calibration"] = {
        "model": "hierarchy levels=(2, 2, 2)",
        "samples": samples,
        "fitted_level_costs": [
            {"level": j, "alpha_s": c.alpha, "beta_s_per_elem": c.beta}
            for j, c in enumerate(fitted)
        ],
        "source": "live-trace" if live_fit is not None else "offline-aggregate",
        "offline_fitted_level_costs": [
            {"level": j, "alpha_s": c.alpha, "beta_s_per_elem": c.beta}
            for j, c in enumerate(offline_fit)
        ],
        "live": {
            "samples": live_samples,
            "fitted_level_costs": None
            if live_fit is None
            else [
                {"level": j, "alpha_s": c.alpha, "beta_s_per_elem": c.beta}
                for j, c in enumerate(live_fit)
            ],
            "note": "per-round spans from ir_encode_jit(tracer=...) on the "
            "2x2x2 forced-host mesh (repro.obs.feed)",
        },
        "note": "forced-host CPU emulation — the fit demonstrates the "
        "measured→α/β path; run on real ICI/DCI hardware for usable costs",
    }
    # fused/pipelined vs unfused lowering at >=64k payloads (ISSUE 8
    # acceptance: a measured wall-clock improvement over the unfused path,
    # not just a predicted-us delta)
    fused_rows = _run_fused_child()
    record["fused_kernels"] = {
        "mesh": "16 (enc), forced-host",
        "algorithm": "prepare-shoot",
        "K": 16,
        "p": 2,
        "measured_us": fused_rows,
        "speedup_fused_vs_jnp": {
            pay: row["jnp"] / row["fused"] for pay, row in fused_rows.items()
        },
        "speedup_fused_pipeline_vs_jnp": {
            pay: row["jnp"] / row["fused+pipeline"]
            for pay, row in fused_rows.items()
        },
        "note": "same ps_encode_jit schedule; jnp = legacy per-(i,j) loop "
        "kept behind the flag, fused = madd-folded row-batched Shoup "
        "contraction, fused+pipeline adds the pipeline-rounds overlap "
        "rewrite. On forced-host CPU the contraction folds are XLA-fused "
        "either way, so the fused delta is modest; the pipelined row is "
        "the measured win (and the Pallas lowering targets real TPUs).",
    }
    # per-round predicted-vs-measured drift from the traced sweep
    record["drift"] = drift_rows(spans)
    os.makedirs(os.path.join(REPO, "results"), exist_ok=True)
    out_path = os.path.join(REPO, "results", "BENCH_topology.json")
    with open(out_path, "w") as fh:
        json.dump(record, fh, indent=2)
    # persist the trace itself (Perfetto-loadable + machine-readable)
    traces = os.path.join(REPO, "results", "traces")
    write_spans_jsonl(spans, os.path.join(traces, "bench_topology.jsonl"))
    write_chrome_trace(
        spans,
        os.path.join(traces, "bench_topology.trace.json"),
        process_name="bench_topology",
    )
    # the persisted block must round-trip through the loader resolve_profile
    # uses — the calibration loop is only closed if this re-reads exactly
    from repro.topo import load_fitted_costs

    reloaded = load_fitted_costs(out_path)
    assert reloaded == fitted, f"calibration round-trip failed: {reloaded}"
    for alg, us in measured_us.items():
        pred = (
            record["three_level"]["predicted"]
            if alg == "multilevel"
            else predicted
        ).get(alg, {})
        emit(
            f"topology_encode_{alg}_K8",
            us,
            f"pred_us={pred.get('us', float('nan')):.1f},C1={pred.get('c1', '-')}",
        )
    for pay, row in fused_rows.items():
        for name, us in row.items():
            emit(
                f"topology_kernels_{name}_K16_{pay}",
                us,
                f"speedup_vs_jnp={row['jnp'] / us:.2f}x",
            )


if __name__ == "__main__":
    run()
