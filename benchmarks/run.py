# One function per paper table/figure. Print ``name,us_per_call,derived`` CSV.
#
# The paper is theory-only; its "tables" are Theorems 1-4 + Figures 1-4, each
# of which gets a benchmark module; the coded-system applications (Remark 1,
# §VI) and the dry-run roofline get their own.
#
# ``--trace`` wraps every module's run() in a ``repro.obs`` span and writes
# the whole-suite Chrome trace (results/traces/bench_suite.trace.json —
# Perfetto-loadable) plus the metrics-registry snapshot
# (results/bench_metrics.json: the per-sample latency histograms
# ``benchmarks.common.time_fn`` fed) after the run.
import sys
import traceback


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    trace = "--trace" in argv
    from . import (
        bench_universal,      # Theorem 1 / Lemmas 1-3 / Fig. 1-3
        bench_dft,            # Theorem 2 / Fig. 4
        bench_vandermonde,    # Theorem 3 / Remark 5
        bench_lagrange,       # Theorem 4 + LCC (§VI)
        bench_kernels,        # DESIGN §7 kernels
        bench_coded_ckpt,     # Remark 1 application (coded checkpointing)
        bench_gradient_coding,# straggler mitigation application
        bench_dryrun_roofline,# deliverable (g) table
        bench_topology,       # repro.topo: flat vs hierarchical on 8 devices
        bench_serve,          # continuous-batching vs fixed-batch serving
        bench_coded_serve,    # LCC fault-tolerant serving overhead + recovery
    )

    tracer = None
    if trace:
        from repro.obs import Tracer, set_tracer

        tracer = Tracer()
        set_tracer(tracer)

    print("name,us_per_call,derived")
    failures = []
    for mod in (
        bench_universal,
        bench_dft,
        bench_vandermonde,
        bench_lagrange,
        bench_kernels,
        bench_coded_ckpt,
        bench_gradient_coding,
        bench_dryrun_roofline,
        bench_topology,
        bench_serve,
        bench_coded_serve,
    ):
        name = mod.__name__.rsplit(".", 1)[-1]
        try:
            if tracer is not None:
                with tracer.span(f"bench.{name}"):
                    mod.run()
            else:
                mod.run()
        except Exception:
            failures.append(mod.__name__)
            traceback.print_exc()
    if tracer is not None:
        import os

        from repro.obs import get_registry, write_chrome_trace

        out = write_chrome_trace(
            tracer.spans,
            "results/traces/bench_suite.trace.json",
            process_name="bench_suite",
        )
        get_registry().write_json(os.path.join("results", "bench_metrics.json"))
        print(f"trace: {out}", file=sys.stderr)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
