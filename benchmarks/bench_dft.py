"""Theorem 2 / Fig. 4: DFT butterfly — strictly optimal C1 = C2 = log_{p+1}K
and the exponential C2 gain over the universal algorithm (Remark 4)."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.core.draw_loose import encode_dft
from repro.core.field import NTT, Field
from repro.core.matrices import random_vector
from repro.core.schedule import plan_butterfly
from repro.core.simulator import simulate_butterfly

from .common import emit, time_fn


def run():
    f = Field(NTT)
    print("# K,p,C1_sim,C2_sim,H,C2_universal (Remark 4 gain)")
    for K in (16, 64, 256, 1024):
        plan = plan_butterfly(K, 1, NTT)
        x = random_vector(f, K, seed=K)
        _, st = simulate_butterfly(x, plan, f)
        print(f"# {K},1,{st.C1},{st.C2},{plan.H},{bounds.theorem1_c2(K, 1)}")
        assert st.C1 == st.C2 == plan.H
    K, payload = 256, 1024
    plan = plan_butterfly(K, 1, NTT)
    x = jnp.asarray(random_vector(f, (K, payload), seed=1).astype(np.uint32))
    fn = jax.jit(lambda xx: encode_dft(xx, plan))
    us = time_fn(fn, x, metric="bench.dft_us")
    emit("butterfly_K256_payload1024", us, f"C2={plan.H}_vs_universal={bounds.theorem1_c2(K, 1)}")


if __name__ == "__main__":
    run()
