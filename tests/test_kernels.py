"""Pallas kernel validation: interpret=True vs pure-jnp/host oracles, with
shape and prime sweeps + hypothesis property tests."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax.numpy as jnp

from repro.core.field import M31, NTT, Field, shoup_precompute
from repro.kernels.butterfly.ops import butterfly_mac, butterfly_mac_reference
from repro.kernels.gf_matmul.ops import gf_matmul, gf_matmul_batched
from repro.kernels.gf_matmul.ref import gf_matmul_host, gf_matmul_ref

PRIMES = [M31, NTT, 65537, 97]


def rand_u32(shape, q, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, q, size=shape, dtype=np.uint32)


@pytest.mark.parametrize("q", PRIMES)
@pytest.mark.parametrize(
    "M,K,N",
    [
        (8, 8, 128),     # single small block
        (128, 512, 128), # exactly one default block
        (256, 1024, 256),# multi-block in every dim
        (130, 70, 200),  # ragged (padding path)
        (1, 16, 1),      # degenerate
    ],
)
def test_gf_matmul_vs_host_oracle(q, M, K, N):
    a = rand_u32((M, K), q, seed=M + K)
    b = rand_u32((K, N), q, seed=N + K)
    out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    want = gf_matmul_host(a, b, q)
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("q", [M31, NTT])
def test_gf_matmul_vs_jnp_ref(q):
    a = rand_u32((16, 24), q, seed=0)
    b = rand_u32((24, 8), q, seed=1)
    out = gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q)
    ref = gf_matmul_ref(jnp.asarray(a), jnp.asarray(b), q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gf_matmul_extreme_values():
    """q-1 everywhere: worst-case limb magnitudes."""
    for q in (M31, NTT):
        a = np.full((64, 512), q - 1, dtype=np.uint32)
        b = np.full((512, 128), q - 1, dtype=np.uint32)
        out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
        want = gf_matmul_host(a, b, q)
        np.testing.assert_array_equal(out, want)


def test_gf_matmul_batched():
    q = M31
    a = rand_u32((6, 9, 17), q, seed=3)
    b = rand_u32((6, 17, 5), q, seed=4)
    out = np.asarray(gf_matmul_batched(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    for i in range(6):
        np.testing.assert_array_equal(out[i], gf_matmul_host(a[i], b[i], q))


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 60),
    n=st.integers(1, 40),
    qi=st.integers(0, len(PRIMES) - 1),
    seed=st.integers(0, 10000),
)
@settings(max_examples=15, deadline=None)
def test_gf_matmul_property(m, k, n, qi, seed):
    q = PRIMES[qi]
    a = rand_u32((m, k), q, seed)
    b = rand_u32((k, n), q, seed + 1)
    out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    np.testing.assert_array_equal(out, gf_matmul_host(a, b, q))


@pytest.mark.parametrize("q", [M31, NTT])
@pytest.mark.parametrize("radix,B,P", [(2, 8, 16), (2, 256, 512), (3, 9, 100), (4, 64, 1000)])
def test_butterfly_mac_vs_ref(q, radix, B, P):
    rng = np.random.default_rng(B + P)
    parts = rng.integers(0, q, size=(radix, B, P), dtype=np.uint32)
    tw = rng.integers(0, q, size=(B, radix), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    ref = butterfly_mac_reference(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # independent host check
    f = Field(q)
    want = np.zeros((B, P), dtype=np.uint64)
    for r in range(radix):
        want = f.add(want, f.mul(parts[r], tw[:, r : r + 1]))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), want)


def test_butterfly_mac_payload_dims():
    q = NTT
    rng = np.random.default_rng(0)
    parts = rng.integers(0, q, size=(2, 16, 3, 5, 7), dtype=np.uint32)
    tw = rng.integers(0, q, size=(16, 2), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    assert out.shape == (16, 3, 5, 7)
    ref = butterfly_mac_reference(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# ISSUE 8: block-size grids, padding pins, zero-size guards, interpret plumb
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 8), (16, 128, 32), (64, 256, 64)])
@pytest.mark.parametrize("M,K,N", [(8, 8, 128), (13, 21, 130), (40, 100, 257)])
def test_gf_matmul_block_size_grid(bm, bn, bk, M, K, N):
    """The wrapper is exact for every (block_m, block_n, block_k) choice,
    including shapes that are NOT multiples of the blocks (the _pad_to /
    _round_up path) — padding with zeros is absorbing mod q."""
    q = M31
    a = rand_u32((M, K), q, seed=bm + M)
    b = rand_u32((K, N), q, seed=bn + N)
    out = np.asarray(
        gf_matmul(
            jnp.asarray(a), jnp.asarray(b), q=q, block_m=bm, block_n=bn, block_k=bk
        ),
        dtype=np.uint64,
    )
    np.testing.assert_array_equal(out, gf_matmul_host(a, b, q))


@pytest.mark.parametrize(
    "M,K,N", [(0, 8, 8), (8, 0, 8), (8, 8, 0), (0, 0, 0)]
)
def test_gf_matmul_zero_size_guard(M, K, N):
    """Empty operands (e.g. a slot emptied by fuse_trivial_rounds) must
    short-circuit to an empty/zero result instead of padding up into the
    kernel. K == 0 is a sum over zero terms: an all-zeros (M, N) result."""
    q = M31
    a = jnp.zeros((M, K), dtype=jnp.uint32)
    b = jnp.zeros((K, N), dtype=jnp.uint32)
    out = gf_matmul(a, b, q=q)
    assert out.shape == (M, N) and out.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(out), np.zeros((M, N), np.uint32))


def test_gf_matmul_batched_zero_size_guard():
    q = M31
    out = gf_matmul_batched(
        jnp.zeros((3, 0, 7), dtype=jnp.uint32),
        jnp.zeros((3, 7, 5), dtype=jnp.uint32),
        q=q,
    )
    assert out.shape == (3, 0, 5)
    out = gf_matmul_batched(
        jnp.zeros((2, 4, 0), dtype=jnp.uint32),
        jnp.zeros((2, 0, 5), dtype=jnp.uint32),
        q=q,
    )
    assert out.shape == (2, 4, 5)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((2, 4, 5), np.uint32))


def _pad_roundtrip_shapes():
    # non-multiple shapes around each tiling boundary the wrappers pin
    return [(1, 1), (7, 127), (8, 128), (9, 129), (17, 300)]


@pytest.mark.parametrize("r,c", _pad_roundtrip_shapes())
def test_pad_to_and_round_up_pins(r, c):
    """_pad_to pads up to multiples with zeros, never truncates; _round_up
    is the exact ceiling multiple (the kernels' 8×128 uint32 tile floor)."""
    from repro.kernels.gf_matmul.ops import _pad_to, _round_up

    x = jnp.arange(r * c, dtype=jnp.uint32).reshape(r, c)
    p = _pad_to(x, 8, 128)
    assert p.shape == (_round_up(r, 8), _round_up(c, 128))
    assert p.shape[0] % 8 == 0 and p.shape[1] % 128 == 0
    np.testing.assert_array_equal(np.asarray(p[:r, :c]), np.asarray(x))
    assert int(np.asarray(p).sum()) == int(np.asarray(x, dtype=np.uint64).sum())
    assert _round_up(r, 8) - r < 8 and _round_up(c, 128) - c < 128


@pytest.mark.parametrize("B,P", [(1, 1), (7, 100), (8, 128), (9, 513)])
def test_butterfly_mac_ragged_shapes(B, P):
    """Non-multiple (B, P) — the wrapper's pad/slice path — stays exact for
    every radix against the host field arithmetic."""
    q = M31
    for radix in (2, 3):
        rng = np.random.default_rng(radix * 1000 + B + P)
        parts = rng.integers(0, q, size=(radix, B, P), dtype=np.uint32)
        tw = rng.integers(0, q, size=(B, radix), dtype=np.uint32)
        tw_sh = np.asarray(shoup_precompute(tw, q))
        out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
        f = Field(q)
        want = np.zeros((B, P), dtype=np.uint64)
        for r in range(radix):
            want = f.add(want, f.mul(parts[r], tw[:, r : r + 1]))
        np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), want)


def test_butterfly_mac_forwards_interpret_flag():
    """Regression: butterfly_mac must pass interpret= through to the Pallas
    kernel (it was silently dropped once — on a TPU-less host the explicit
    interpret=True call is the only one that can run)."""
    import inspect

    from repro.kernels.butterfly import ops as bops

    src = inspect.getsource(bops.butterfly_mac.__wrapped__)
    assert "interpret=interpret" in src
    q = NTT
    rng = np.random.default_rng(9)
    parts = rng.integers(0, q, size=(2, 8, 16), dtype=np.uint32)
    tw = rng.integers(0, q, size=(8, 2), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(
        jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q, interpret=True
    )
    ref = butterfly_mac_reference(
        jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(
    b=st.integers(1, 20),
    p_=st.integers(1, 80),
    radix=st.integers(2, 4),
    seed=st.integers(0, 10000),
)
@settings(max_examples=10, deadline=None)
def test_butterfly_mac_property(b, p_, radix, seed):
    q = M31
    rng = np.random.default_rng(seed)
    parts = rng.integers(0, q, size=(radix, b, p_), dtype=np.uint32)
    tw = rng.integers(0, q, size=(b, radix), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    f = Field(q)
    want = np.zeros((b, p_), dtype=np.uint64)
    for r in range(radix):
        want = f.add(want, f.mul(parts[r], tw[:, r : r + 1]))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), want)
