"""Pallas kernel validation: interpret=True vs pure-jnp/host oracles, with
shape and prime sweeps + hypothesis property tests."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax.numpy as jnp

from repro.core.field import M31, NTT, Field, shoup_precompute
from repro.kernels.butterfly.ops import butterfly_mac, butterfly_mac_reference
from repro.kernels.gf_matmul.ops import gf_matmul, gf_matmul_batched
from repro.kernels.gf_matmul.ref import gf_matmul_host, gf_matmul_ref

PRIMES = [M31, NTT, 65537, 97]


def rand_u32(shape, q, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, q, size=shape, dtype=np.uint32)


@pytest.mark.parametrize("q", PRIMES)
@pytest.mark.parametrize(
    "M,K,N",
    [
        (8, 8, 128),     # single small block
        (128, 512, 128), # exactly one default block
        (256, 1024, 256),# multi-block in every dim
        (130, 70, 200),  # ragged (padding path)
        (1, 16, 1),      # degenerate
    ],
)
def test_gf_matmul_vs_host_oracle(q, M, K, N):
    a = rand_u32((M, K), q, seed=M + K)
    b = rand_u32((K, N), q, seed=N + K)
    out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    want = gf_matmul_host(a, b, q)
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("q", [M31, NTT])
def test_gf_matmul_vs_jnp_ref(q):
    a = rand_u32((16, 24), q, seed=0)
    b = rand_u32((24, 8), q, seed=1)
    out = gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q)
    ref = gf_matmul_ref(jnp.asarray(a), jnp.asarray(b), q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gf_matmul_extreme_values():
    """q-1 everywhere: worst-case limb magnitudes."""
    for q in (M31, NTT):
        a = np.full((64, 512), q - 1, dtype=np.uint32)
        b = np.full((512, 128), q - 1, dtype=np.uint32)
        out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
        want = gf_matmul_host(a, b, q)
        np.testing.assert_array_equal(out, want)


def test_gf_matmul_batched():
    q = M31
    a = rand_u32((6, 9, 17), q, seed=3)
    b = rand_u32((6, 17, 5), q, seed=4)
    out = np.asarray(gf_matmul_batched(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    for i in range(6):
        np.testing.assert_array_equal(out[i], gf_matmul_host(a[i], b[i], q))


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 60),
    n=st.integers(1, 40),
    qi=st.integers(0, len(PRIMES) - 1),
    seed=st.integers(0, 10000),
)
@settings(max_examples=15, deadline=None)
def test_gf_matmul_property(m, k, n, qi, seed):
    q = PRIMES[qi]
    a = rand_u32((m, k), q, seed)
    b = rand_u32((k, n), q, seed + 1)
    out = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), q=q), dtype=np.uint64)
    np.testing.assert_array_equal(out, gf_matmul_host(a, b, q))


@pytest.mark.parametrize("q", [M31, NTT])
@pytest.mark.parametrize("radix,B,P", [(2, 8, 16), (2, 256, 512), (3, 9, 100), (4, 64, 1000)])
def test_butterfly_mac_vs_ref(q, radix, B, P):
    rng = np.random.default_rng(B + P)
    parts = rng.integers(0, q, size=(radix, B, P), dtype=np.uint32)
    tw = rng.integers(0, q, size=(B, radix), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    ref = butterfly_mac_reference(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # independent host check
    f = Field(q)
    want = np.zeros((B, P), dtype=np.uint64)
    for r in range(radix):
        want = f.add(want, f.mul(parts[r], tw[:, r : r + 1]))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), want)


def test_butterfly_mac_payload_dims():
    q = NTT
    rng = np.random.default_rng(0)
    parts = rng.integers(0, q, size=(2, 16, 3, 5, 7), dtype=np.uint32)
    tw = rng.integers(0, q, size=(16, 2), dtype=np.uint32)
    tw_sh = np.asarray(shoup_precompute(tw, q))
    out = butterfly_mac(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    assert out.shape == (16, 3, 5, 7)
    ref = butterfly_mac_reference(jnp.asarray(parts), jnp.asarray(tw), jnp.asarray(tw_sh), q=q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
