"""Device-tier uint32 field arithmetic vs python-int oracle (+ hypothesis)."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax.numpy as jnp

from repro.core.field import (
    M31,
    NTT,
    Field,
    barrett32,
    madd,
    mmul,
    mmul_m31,
    msub,
    shoup_mul,
    shoup_precompute,
    umulhi32,
    umulhi32_full,
)

PRIMES = [M31, NTT, 97, 65537, 2**30 + 3]  # 2^30+3 is prime


def test_group_factorizations():
    for q, factors in [(M31, (2, 3, 7, 11, 31, 151, 331)), (NTT, (2, 3, 5))]:
        n = q - 1
        for f in factors:
            assert n % f == 0
            while n % f == 0:
                n //= f
        assert n == 1


@pytest.mark.parametrize("q", [M31, NTT])
def test_generator_is_primitive(q):
    f = Field(q)
    g = f.generator
    for fac in f._factor_group_order():
        assert pow(g, (q - 1) // fac, q) != 1


def test_root_of_unity_orders():
    f = Field(NTT)
    for n in [2, 4, 16, 256, 2**20]:
        b = f.root_of_unity(n)
        assert pow(b, n, NTT) == 1
        assert pow(b, n // 2, NTT) != 1  # primitive


@given(a=st.integers(0, 2**31 - 1), b=st.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_umulhi32(a, b):
    got = int(umulhi32(jnp.uint32(a), jnp.uint32(b)))
    assert got == (a * b) >> 32


@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_umulhi32_full(a, b):
    got = int(umulhi32_full(jnp.uint32(a), jnp.uint32(b)))
    assert got == (a * b) >> 32


@given(x=st.integers(0, 2**32 - 1), qi=st.integers(0, len(PRIMES) - 1))
@settings(max_examples=200, deadline=None)
def test_barrett32(x, qi):
    q = PRIMES[qi]
    assert int(barrett32(jnp.uint32(x), q)) == x % q


@given(data=st.data(), qi=st.integers(0, len(PRIMES) - 1))
@settings(max_examples=300, deadline=None)
def test_mod_ops(data, qi):
    q = PRIMES[qi]
    a = data.draw(st.integers(0, q - 1))
    b = data.draw(st.integers(0, q - 1))
    assert int(madd(jnp.uint32(a), jnp.uint32(b), q)) == (a + b) % q
    assert int(msub(jnp.uint32(a), jnp.uint32(b), q)) == (a - b) % q
    assert int(mmul(jnp.uint32(a), jnp.uint32(b), q)) == (a * b) % q


@given(a=st.integers(0, M31 - 1), b=st.integers(0, M31 - 1))
@settings(max_examples=300, deadline=None)
def test_mmul_m31(a, b):
    assert int(mmul_m31(jnp.uint32(a), jnp.uint32(b))) == (a * b) % M31


@given(data=st.data(), qi=st.integers(0, len(PRIMES) - 1))
@settings(max_examples=200, deadline=None)
def test_shoup_mul(data, qi):
    q = PRIMES[qi]
    a = data.draw(st.integers(0, q - 1))
    c = data.draw(st.integers(0, q - 1))
    c_pre = int(shoup_precompute(c, q))
    assert int(shoup_mul(jnp.uint32(a), jnp.uint32(c), jnp.uint32(c_pre), q)) == (a * c) % q


def test_vectorized_mod_ops_match_numpy():
    rng = np.random.default_rng(0)
    for q in (M31, NTT):
        a = rng.integers(0, q, size=(64,), dtype=np.uint32)
        b = rng.integers(0, q, size=(64,), dtype=np.uint32)
        want = (a.astype(np.uint64) * b.astype(np.uint64)) % q
        np.testing.assert_array_equal(np.asarray(mmul(a, b, q), dtype=np.uint64), want)
        want = (a.astype(np.uint64) + b.astype(np.uint64)) % q
        np.testing.assert_array_equal(np.asarray(madd(a, b, q), dtype=np.uint64), want)


def test_host_field_linear_algebra():
    f = Field(M31)
    rng = np.random.default_rng(1)
    A = rng.integers(0, M31, size=(17, 17), dtype=np.uint64)
    x = rng.integers(0, M31, size=17, dtype=np.uint64)
    y = f.matmul(x, A)
    # oracle with python ints
    want = [(sum(int(x[i]) * int(A[i, j]) for i in range(17))) % M31 for j in range(17)]
    np.testing.assert_array_equal(y, np.array(want, dtype=np.uint64))
    # solve/inverse roundtrip
    Ainv = f.inv_matrix(A)
    np.testing.assert_array_equal(f.matmul(A, Ainv), np.eye(17, dtype=np.uint64))


def test_field_pow_inv():
    f = Field(NTT)
    a = np.arange(1, 50, dtype=np.uint64)
    inv = f.inv(a)
    np.testing.assert_array_equal(f.mul(a, inv), np.ones_like(a))
    assert int(f.pow(np.uint64(3), 0)) == 1
    assert int(f.pow(np.uint64(3), 5)) == 243
