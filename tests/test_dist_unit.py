"""Fast, no-subprocess unit tests for the dist substrate:

* spec_for edge cases — non-divisible dims degrade to replicated, multi-axis
  rules, None dims, axis reuse, missing mesh axes;
* plan/collective agreement — the ppermute budget ps_encode_jit commits to
  matches the PrepareShootPlan round structure (C1 rounds, p ports each).

spec_for only consults ``mesh.shape`` / ``mesh.axis_names``, so a
lightweight fake mesh exercises multi-axis meshes without needing more than
one host device.
"""

import numpy as np
import pytest

import jax

from repro.core.schedule import plan_prepare_shoot
from repro.dist.collectives import expected_permute_count, shoot_round_slots
from repro.dist.sharding import ShardingRules, named_sharding, spec_for
from repro.launch.mesh import make_mesh


class FakeMesh:
    """Duck-typed mesh: just axis names and sizes."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


# ---------------------------------------------------------------------------
# spec_for
# ---------------------------------------------------------------------------


def test_spec_for_non_divisible_dim_replicates():
    mesh = FakeMesh(data=2, model=4)
    rules = ShardingRules()
    # d_ff → model: 10 % 4 != 0 → replicated, no error
    s = spec_for(mesh, rules, ("batch", "d_ff"), (8, 10))
    assert s == jax.sharding.PartitionSpec("data", None)
    # divisible → sharded
    s = spec_for(mesh, rules, ("batch", "d_ff"), (8, 12))
    assert s == jax.sharding.PartitionSpec("data", "model")


def test_spec_for_multi_axis_rule_partial_divisibility():
    mesh = FakeMesh(pod=2, data=4)
    rules = ShardingRules()  # batch → ("pod", "data")
    # 16 divides by 2*4 → both axes applied as a tuple entry
    s = spec_for(mesh, rules, ("batch",), (16,))
    assert s == jax.sharding.PartitionSpec(("pod", "data"))
    # 6: pod (2) divides, pod*data (8) does not → only pod applied
    s = spec_for(mesh, rules, ("batch",), (6,))
    assert s == jax.sharding.PartitionSpec("pod")
    # 3: nothing divides → replicated
    s = spec_for(mesh, rules, ("batch",), (3,))
    assert s == jax.sharding.PartitionSpec(None)


def test_spec_for_none_dims_and_unknown_names():
    mesh = FakeMesh(data=2, model=2)
    rules = ShardingRules()
    s = spec_for(mesh, rules, ("batch", None, "no_such_dim"), (4, 7, 9))
    assert s == jax.sharding.PartitionSpec("data", None, None)


def test_spec_for_without_shape_skips_divisibility():
    mesh = FakeMesh(model=4)
    s = spec_for(mesh, ShardingRules(), ("d_ff",))
    assert s == jax.sharding.PartitionSpec("model")


def test_spec_for_axis_used_at_most_once():
    mesh = FakeMesh(model=2)
    rules = ShardingRules().override(seq=("model",))
    # d_ff and seq both want "model"; first dim wins, second replicates
    s = spec_for(mesh, rules, ("d_ff", "seq"), (8, 8))
    assert s == jax.sharding.PartitionSpec("model", None)


def test_spec_for_drops_axes_missing_from_mesh():
    mesh = FakeMesh(model=2)  # no "pod"/"data"
    s = spec_for(mesh, ShardingRules(), ("batch",), (8,))
    assert s == jax.sharding.PartitionSpec(None)


def test_override_and_flags_are_functional():
    r = ShardingRules()
    r2 = r.override(seq="model", d_model=("data",))
    assert r.axes_for("seq") == () and r2.axes_for("seq") == ("model",)
    assert r2.axes_for("d_model") == ("data",)
    r3 = r2.with_flags({"attn_heads"})
    assert r3.has("attn_heads") and not r2.has("attn_heads")
    assert r3.axes_for("seq") == ("model",)  # flags preserve the mapping


def test_named_sharding_on_real_mesh():
    mesh = make_mesh((1,), ("model",))
    ns = named_sharding(mesh, ShardingRules(), ("batch", "d_ff"), (4, 16))
    assert isinstance(ns, jax.sharding.NamedSharding)
    assert "model" in str(ns.spec)


# ---------------------------------------------------------------------------
# plan / collective agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,p", [(8, 1), (8, 2), (16, 1), (27, 2), (64, 3)])
def test_round_structure_matches_c1(K, p):
    plan = plan_prepare_shoot(K, p)
    # the collective executes exactly len(prepare_shifts) + len(shoot_shifts)
    # communication rounds — the paper's C1
    assert len(plan.prepare_shifts) + len(plan.shoot_shifts) == plan.c1
    # every round has exactly p ports
    assert all(len(s) == p for s in plan.prepare_shifts)
    assert all(len(s) == p for s in plan.shoot_shifts)


@pytest.mark.parametrize("K,p", [(8, 1), (8, 2), (16, 1), (27, 2)])
def test_shoot_round_slots_consistent(K, p):
    """Slot slices the collective ships: dst/src in range, no duplicate
    targets within one (round, port) message, src strictly above dst (the
    tree reduction always pulls toward slot 0)."""
    plan = plan_prepare_shoot(K, p)
    radix = p + 1
    for t in range(1, plan.Ts + 1):
        for rho in range(1, p + 1):
            dst, src = shoot_round_slots(plan, t, rho)
            assert dst.shape == src.shape
            assert np.all(src == dst + rho * radix ** (t - 1))
            assert np.all(src < plan.n) and np.all(dst >= 0)
            assert len(set(dst.tolist())) == dst.size
            assert np.all(src > dst)


@pytest.mark.parametrize(
    "K,p,expected",
    [
        # hand-derived: p·Tp prepare permutes + one permute per non-empty
        # (shoot round, port) slice. E.g. K=8, p=1: Tp=2, Ts=1, n=2 —
        # prepare 2, shoot round 1 port 1 ships slot 1→0, total 3.
        (8, 1, 3),
        (8, 2, 4),  # Tp=Ts=1, both ports non-empty: 2 + 2
        (16, 1, 4),  # Tp=Ts=2: 2 + 2
        (27, 2, 6),  # Tp=2, Ts=1: 4 + 2
        (64, 3, 9),  # Tp=2, Ts=1: 6 + 3
    ],
)
def test_expected_permute_count_literal(K, p, expected):
    """The ppermute budget against independently hand-derived values (NOT
    recomputed via the same slot formula — that would be circular)."""
    assert expected_permute_count(plan_prepare_shoot(K, p)) == expected


def test_permute_count_vs_jaxpr():
    """The traced collective emits exactly the committed ppermute budget.
    Needs 8 devices (CI forces 8 host devices; skipped on a 1-device run)."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.core.field import M31, Field
    from repro.core.matrices import random_matrix
    from repro.dist.collectives import ps_encode_jit

    f = Field(M31)
    A = np.asarray(random_matrix(f, 8, seed=0))
    mesh8 = make_mesh((8,), ("enc",))
    for p in (1, 2):
        fn, plan = ps_encode_jit(mesh8, "enc", A, p=p)
        jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8, 4), jax.numpy.uint32))
        assert str(jaxpr).count("ppermute") == expected_permute_count(plan)
