"""Differential harness for the fused-kernel executor and the
``pipeline-rounds`` comm/compute-overlap rewrite (ISSUE 8).

Three layers of evidence, cheapest first:

* **host-side fuzz** — for every algorithm family at K ∈ {8, 12, 16}, both
  fields, random/Vandermonde/Lagrange generators and odd payload shapes,
  ``interpret(pipeline_rounds(ir))`` is bit-exact vs. the matrix oracle,
  the ppermute budget is byte-identical to the un-rewritten IR, and C1 is
  unchanged (the rewrite must never add or touch a comm round);
* **rewrite structure** — the pass actually fires (returns a different IR
  with ``overlap=True`` shadow contractions) on the prologue-heavy families
  at 64k-element payloads, and prices strictly cheaper there;
* **subprocess mesh differential** — on a forced-host 8-device mesh the
  three executor lowerings (``kernels ∈ {jnp, fused, pallas}``, pallas in
  interpret mode on CPU) × {no pipeline, "pipeline"} all produce the exact
  oracle bytes, the pipelined executors keep the committed jaxpr ppermute
  budgets, the compiled HLO stays collective-permute-only, and a traced
  pipelined run emits overlap-annotated round spans that pass
  ``tools/check_trace.py``.

Property tests are hypothesis-driven when hypothesis is installed
(tests/hyputil.py); the exhaustive parametrized sweeps below double as the
seeded-random fallback and always run.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from hyputil import given, settings, st
from repro.core.field import M31, NTT, Field
from repro.core.ir import fuse_trivial_rounds, ir_allgather, ir_permute_count
from repro.core.matrices import (
    butterfly_target_matrix,
    distinct_points,
    lagrange_matrix,
    random_matrix,
    random_vector,
    vandermonde,
)
from repro.core.prepare_shoot import encode_oracle
from repro.core.schedule import (
    draw_loose_target_matrix,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)
from repro.core.simulator import interpret
from repro.topo import (
    plan_hierarchical,
    plan_multilevel,
    plan_multilevel_dft,
    plan_ring,
    plan_two_level_dft,
    multilevel_dft_matrix,
    two_level_dft_matrix,
)
from repro.topo.model import FullyConnected
from repro.topo.passes import PIPELINES, ir_compute_time, ir_time, pipeline_rounds

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
F = Field(M31)

#: payload size at which the α-β + MAC pricing makes the overlap rewrite
#: profitable (the ISSUE's ≥64k-element acceptance regime)
BIG = 1 << 16


def _gen(field: Field, kind: str, K: int, seed: int) -> np.ndarray:
    """General-generator taxonomy the executors must be universal over."""
    if kind == "random":
        return random_matrix(field, K, seed=seed)
    if kind == "vandermonde":
        return vandermonde(field, distinct_points(field, K, seed=seed))
    if kind == "lagrange":
        omegas = distinct_points(field, K, seed=seed)
        alphas = distinct_points(field, K, seed=seed + 1)
        return lagrange_matrix(field, alphas, omegas)
    raise ValueError(kind)


def _cases():
    """(label, build() → (ir, target, q)) — every family × K ∈ {8, 12, 16},
    general families additionally × field × generator kind."""
    cases = []
    for K in (8, 12, 16):
        for q in (M31, NTT):
            for gk in ("random", "vandermonde", "lagrange"):
                f = Field(q)

                def mk_ps(K=K, q=q, gk=gk, f=f):
                    A = _gen(f, gk, K, seed=K + len(gk))
                    return plan_prepare_shoot(K, 1).to_ir(A, q=q), A, q

                cases.append((f"ps-{K}-{q & 0xffff:x}-{gk}", mk_ps))

        def mk_ps2(K=K):
            A = _gen(F, "random", K, seed=K * 5)
            return plan_prepare_shoot(K, 2).to_ir(A), A, M31

        cases.append((f"ps-{K}-p2", mk_ps2))

        def mk_ring(K=K):
            A = _gen(F, "vandermonde", K, seed=K)
            return plan_ring(K, 1).to_ir(A), A, M31

        cases.append((f"ring-{K}", mk_ring))

        def mk_ag(K=K):
            A = _gen(F, "lagrange", K, seed=K)
            return ir_allgather(K, 1, A), A, M31

        cases.append((f"allgather-{K}", mk_ag))

        for I in (2, 4):
            if K % I:
                continue

            def mk_h(K=K, I=I):
                A = _gen(F, "random", K, seed=K * 3 + I)
                return plan_hierarchical(K, 1, I).to_ir(A), A, M31

            cases.append((f"hierarchical-{K}-{I}", mk_h))

        def mk_dl(K=K):
            plan = plan_draw_loose(K, 1, NTT, seed=1)
            return plan.to_ir(), draw_loose_target_matrix(plan), NTT

        cases.append((f"draw-loose-{K}", mk_dl))

    for K, levels in [(8, (2, 2, 2)), (12, (3, 2, 2)), (16, (2, 2, 4))]:

        def mk_ml(K=K, levels=levels):
            A = _gen(F, "vandermonde", K, seed=K * 31 + levels[0])
            return plan_multilevel(K, 1, levels).to_ir(A), A, M31

        cases.append((f"multilevel-{K}-{levels}", mk_ml))

    for K in (8, 16):

        def mk_bf(K=K):
            f = Field(NTT)
            plan = plan_butterfly(K, 1, NTT)
            return plan.to_ir(), butterfly_target_matrix(f, K, 2), NTT

        cases.append((f"butterfly-{K}", mk_bf))

        def mk_dft2(K=K):
            plan = plan_two_level_dft(K, 1, NTT, 2 if K == 8 else 4)
            return plan.to_ir(), two_level_dft_matrix(plan), NTT

        cases.append((f"two-level-dft-{K}", mk_dft2))

        def mk_mldft(K=K):
            levels = (2, 2, 2) if K == 8 else (2, 2, 2, 2)
            plan = plan_multilevel_dft(K, 1, NTT, levels)
            return fuse_trivial_rounds(plan.to_ir()), multilevel_dft_matrix(plan), NTT

        cases.append((f"multilevel-dft-{K}", mk_mldft))
    return cases


_CASES = _cases()


def _check_case(idx: int, seed_salt: int = 0):
    label, build = _CASES[idx]
    ir, target, q = build()
    f = Field(q)
    topo = FullyConnected(ir.K)
    piped = pipeline_rounds(ir, topo, payload_elems=BIG)
    # comm structure untouched: byte-identical ppermute budget and C1
    assert ir_permute_count(piped) == ir_permute_count(ir), label
    assert piped.c1 == ir.c1, label
    x = random_vector(f, ir.K, seed=len(label) + seed_salt)
    out, _ = interpret(piped, x, f)
    np.testing.assert_array_equal(out, encode_oracle(x, target, q), err_msg=label)


@pytest.mark.parametrize("idx", range(len(_CASES)), ids=[l for l, _ in _CASES])
def test_pipelined_every_family_bit_exact(idx):
    """Exhaustive seeded sweep (the no-hypothesis fallback): the pipelined
    IR is bit-exact vs. the matrix oracle with the ppermute budget and C1
    unchanged, for every family/field/generator combination. (Odd payload
    shapes — padding — are exercised on the real mesh in
    test_kernel_modes_differential_on_mesh; the host interpreter is
    scalar-payload by contract.)"""
    _check_case(idx)


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from(range(len(_CASES))),
    st.integers(min_value=0, max_value=99),
)
def test_pipelined_every_family_property(idx, seed_salt):
    """Property form of the same contract over random inputs
    (hypothesis-driven when available)."""
    _check_case(idx, seed_salt)


def test_pipeline_rounds_fires_on_prologue_families():
    """On the prologue-heavy families at 64k-element payloads the rewrite
    must actually trigger: a different IR, at least one overlap=True update
    LocalOp per pipelined round, comm rounds byte-identical, and a strictly
    cheaper α-β+MAC price."""
    from repro.core.ir import CommRound, LocalOp

    builds = {
        "prepare-shoot": lambda: plan_prepare_shoot(8, 1).to_ir(
            random_matrix(F, 8, seed=0)
        ),
        "hierarchical": lambda: plan_hierarchical(12, 1, 4).to_ir(
            random_matrix(F, 12, seed=1)
        ),
        "multilevel": lambda: plan_multilevel(8, 1, (2, 2, 2)).to_ir(
            random_matrix(F, 8, seed=2)
        ),
    }
    topo8 = FullyConnected(8)
    for name, build in builds.items():
        ir = build()
        topo = FullyConnected(ir.K)
        piped = pipeline_rounds(ir, topo, payload_elems=BIG)
        assert piped is not ir, f"{name}: rewrite did not fire"
        overlaps = [
            s for s in piped.steps if isinstance(s, LocalOp) and s.overlap
        ]
        assert overlaps and all(s.update for s in overlaps), name
        assert [s for s in piped.steps if isinstance(s, CommRound)] == [
            s for s in ir.steps if isinstance(s, CommRound)
        ], f"{name}: comm rounds must be byte-identical"
        t0 = ir_time(ir, topo, payload_elems=BIG)
        t1 = ir_time(piped, topo, payload_elems=BIG)
        assert t1 < t0, (name, t0, t1)
    # structure-only IRs (autotune candidates carry coeffs=None) also rewrite
    bare = plan_multilevel(8, 1, (2, 2, 2)).to_ir()
    assert pipeline_rounds(bare, topo8, payload_elems=BIG) is not bare


def test_pipeline_registered_and_declines_non_prologue_irs():
    """"pipeline" is in the pass registry (the autotuner's ``+pipeline``
    suffix comes from here); families with no deferrable prologue —
    allgather, ring, butterfly — come back unchanged (identity, not a
    broken rewrite)."""
    assert "pipeline" in PIPELINES
    topo = FullyConnected(8)
    for ir in (
        ir_allgather(8, 1, random_matrix(F, 8, seed=3)),
        plan_ring(8, 1).to_ir(random_matrix(F, 8, seed=4)),
        plan_butterfly(8, 1, NTT).to_ir(),
    ):
        assert PIPELINES["pipeline"].apply(ir, topo, BIG) is ir
    ir = plan_prepare_shoot(8, 1).to_ir(random_matrix(F, 8, seed=3))
    piped = PIPELINES["pipeline"].apply(ir, topo, BIG)
    assert piped is not ir
    # overlap credit: the pipelined IR's charged compute is strictly below
    # what the same steps would cost with the overlap flags stripped (some
    # work actually hides under the wire)
    charged = ir_compute_time(piped, topo, BIG)
    from dataclasses import replace as _rp
    from repro.core.ir import LocalOp

    flat = _rp(
        piped,
        steps=tuple(
            _rp(s, overlap=False) if isinstance(s, LocalOp) and s.overlap else s
            for s in piped.steps
        ),
    )
    assert charged < ir_compute_time(flat, topo, BIG)


# ---------------------------------------------------------------------------
# subprocess: the three kernel lowerings on a real forced-host mesh
# ---------------------------------------------------------------------------


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_kernel_modes_differential_on_mesh():
    """All KERNEL_MODES × {"", "pipeline"} on the 8-device mesh: ps
    (both fields, Lagrange + random generators, odd payload), multilevel,
    hierarchical and butterfly — every lowering produces the exact oracle
    bytes. pallas runs in interpret mode on CPU (same kernels the TPU path
    jits)."""
    out = run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, NTT, Field
        from repro.core.matrices import (
            distinct_points, lagrange_matrix, random_matrix, random_vector)
        from repro.core.prepare_shoot import encode_oracle
        from repro.dist.collectives import (
            KERNEL_MODES, butterfly_jit, hierarchical_encode_jit,
            multilevel_encode_jit, ps_encode_jit)

        K = 8
        mesh1 = make_mesh((8,), ("enc",))
        mesh2 = make_mesh((4, 2), ("inter", "intra"))
        mesh3 = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        for q in (M31, NTT):
            f = Field(q)
            omg = distinct_points(f, K, seed=0)
            alp = distinct_points(f, K, seed=1)
            gens = {
                "lagrange": lagrange_matrix(f, alp, omg),
                "random": random_matrix(f, K, seed=2),
            }
            x = random_vector(f, (K, 16, 3), seed=3)  # odd payload: padding
            xs = jnp.asarray(x.astype(np.uint32))
            for name, A in gens.items():
                want = encode_oracle(x, A, q)
                for kern in KERNEL_MODES:
                    for pipe in ("", "pipeline"):
                        fn, _ = ps_encode_jit(mesh1, "enc", np.asarray(A),
                                              p=1, q=q, kernels=kern,
                                              pipeline=pipe)
                        got = np.asarray(fn(xs), dtype=np.uint64)
                        assert np.array_equal(got, want), (q, name, kern, pipe)
        # multilevel + hierarchical: fused/pallas with the pipeline applied
        f = Field(M31)
        A = random_matrix(f, K, seed=4)
        x = random_vector(f, (K, 7), seed=5)
        xs = jnp.asarray(x.astype(np.uint32))
        want = encode_oracle(x, A, M31)
        for kern, pipe in [("fused", "pipeline"), ("pallas", "pipeline"),
                           ("jnp", "pipeline"), ("fused", "")]:
            fn, _ = multilevel_encode_jit(
                mesh3, ("pod", "slice", "chip"), np.asarray(A), p=1,
                kernels=kern, pipeline=pipe)
            assert np.array_equal(np.asarray(fn(xs), dtype=np.uint64), want), (
                "ml", kern, pipe)
            fn, _ = hierarchical_encode_jit(
                mesh2, "inter", "intra", np.asarray(A), p=1,
                kernels=kern, pipeline=pipe)
            assert np.array_equal(np.asarray(fn(xs), dtype=np.uint64), want), (
                "hier", kern, pipe)
        # butterfly (NTT twiddles hit the butterfly_mac lowering)
        from repro.core.matrices import butterfly_target_matrix
        fq = Field(NTT)
        xb = random_vector(fq, (K, 5), seed=6)
        xbs = jnp.asarray(xb.astype(np.uint32))
        wantb = encode_oracle(xb, butterfly_target_matrix(fq, K, 2), NTT)
        for kern in KERNEL_MODES:
            fnb, _ = butterfly_jit(mesh1, "enc", q=NTT, kernels=kern)
            assert np.array_equal(np.asarray(fnb(xbs), dtype=np.uint64), wantb), kern
        print("kernel modes ok")
        """
    )
    assert "kernel modes ok" in out


def test_pipelined_budget_regression_and_hlo():
    """Satellite (c): with pipeline="pipeline" every executor still emits
    EXACTLY the committed jaxpr ppermute budget, and the compiled HLO is
    collective-permute-only (no all-gather) — the overlap rewrite must not
    leak extra communication."""
    out = run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix
        from repro.dist.collectives import (
            expected_hier_permute_count, expected_multilevel_permute_count,
            expected_permute_count, hierarchical_encode_jit,
            multilevel_encode_jit, ps_encode_jit)

        f = Field(M31)
        A = np.asarray(random_matrix(f, 8, seed=0))
        shape = jax.ShapeDtypeStruct((8, 4), jnp.uint32)
        mesh1 = make_mesh((8,), ("enc",))
        for p in (1, 2):
            fn, plan = ps_encode_jit(mesh1, "enc", A, p=p, pipeline="pipeline")
            n = str(jax.make_jaxpr(fn)(shape)).count("ppermute")
            assert n == expected_permute_count(plan), ("ps", p, n)
        mesh2 = make_mesh((4, 2), ("inter", "intra"))
        fn, plan = hierarchical_encode_jit(
            mesh2, "inter", "intra", A, p=1, pipeline="pipeline")
        n = str(jax.make_jaxpr(fn)(shape)).count("ppermute")
        assert n == expected_hier_permute_count(plan), ("hier", n)
        mesh3 = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        fn, plan = multilevel_encode_jit(
            mesh3, ("pod", "slice", "chip"), A, p=1, pipeline="pipeline")
        n = str(jax.make_jaxpr(fn)(shape)).count("ppermute")
        assert n == expected_multilevel_permute_count(plan), ("ml", n)
        txt = fn.lower(jax.ShapeDtypeStruct((8, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0
        assert "all-gather" not in txt, "pipelined encode must not all-gather"
        print("pipelined budgets ok")
        """
    )
    assert "pipelined budgets ok" in out


def test_pipelined_traced_spans_show_overlap(tmp_path):
    """The traced pipelined 2×2×2 multilevel run: round spans carry
    overlap=True + overlap_out_slots (PR 7's telemetry sees the hidden
    contraction), predicted_us stays present, and the exported Chrome trace
    passes tools/check_trace.py."""
    trace = tmp_path / "pipelined.trace.json"
    out = run_child(
        f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix, random_vector
        from repro.dist.collectives import ir_encode_jit, _apply_pipeline
        from repro.obs import Tracer
        from repro.obs.export import write_chrome_trace
        from repro.topo import Hierarchy, plan_multilevel

        K = 8
        f = Field(M31)
        A = np.asarray(random_matrix(f, K, seed=0))
        ir = _apply_pipeline(plan_multilevel(K, 1, (2, 2, 2)).to_ir(A), "pipeline")
        mesh = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        x = jnp.asarray(random_vector(f, (K, 32), seed=1).astype(np.uint32))
        tracer = Tracer()
        fn = ir_encode_jit(mesh, ("pod", "slice", "chip"), ir,
                           tracer=tracer, topo=Hierarchy(levels=(2, 2, 2)))
        from repro.core.prepare_shoot import encode_oracle
        got = np.asarray(fn(x), dtype=np.uint64)
        assert np.array_equal(got, encode_oracle(
            np.asarray(x, dtype=np.uint64), A, M31))
        comm = [s for s in tracer.spans if "comm_round" in s.attrs]
        assert len(comm) == 3, len(comm)
        overlapped = [s for s in comm if s.attrs.get("overlap")]
        assert overlapped, "no round span carries the overlap annotation"
        for s in overlapped:
            assert s.attrs["overlap_out_slots"] > 0
        for s in comm:
            assert "predicted_us" in s.attrs
        write_chrome_trace(tracer.spans, {str(trace)!r})
        print("overlap spans ok")
        """
    )
    assert "overlap spans ok" in out
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_trace.py"), str(trace)],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    data = json.loads(trace.read_text())
    assert any(
        ev.get("args", {}).get("overlap") for ev in data["traceEvents"]
    ), "exported trace lost the overlap attr"
