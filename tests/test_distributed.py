"""Distributed collectives + pipeline on 8 placeholder host devices.

Run in a subprocess so the XLA_FLAGS device-count override never leaks into
the main test process (smoke tests must see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ps_encode_and_baseline_collectives():
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("enc",))
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix, random_vector
        from repro.core.prepare_shoot import encode_oracle
        from repro.dist.collectives import ps_encode_jit, allgather_encode_jit

        f = Field(M31)
        A = random_matrix(f, 8, seed=0)
        x = random_vector(f, (8, 16), seed=1)
        for p in (1, 2):
            fn, plan = ps_encode_jit(mesh, "enc", np.asarray(A), p=p)
            out = fn(jnp.asarray(x.astype(np.uint32)))
            np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))
        ag = allgather_encode_jit(mesh, "enc", np.asarray(A))
        np.testing.assert_array_equal(
            np.asarray(ag(jnp.asarray(x.astype(np.uint32))), dtype=np.uint64),
            encode_oracle(x, A),
        )
        print("OK")
        """
    )


def test_butterfly_collective_and_inverse():
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("enc",))
        from repro.core.field import NTT, Field
        from repro.core.matrices import butterfly_target_matrix, random_vector
        from repro.core.prepare_shoot import encode_oracle
        from repro.dist.collectives import butterfly_jit

        f = Field(NTT)
        x = random_vector(f, (8, 4), seed=2)
        fn, plan = butterfly_jit(mesh, "enc", p=1)
        out = fn(jnp.asarray(x.astype(np.uint32)))
        G = butterfly_target_matrix(f, 8, 2)
        np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, G, NTT))
        ifn, _ = butterfly_jit(mesh, "enc", p=1, inverse=True)
        np.testing.assert_array_equal(np.asarray(ifn(out)), x.astype(np.uint32))
        print("OK")
        """
    )


def test_collective_hlo_has_permutes_not_allgather():
    """The prepare-and-shoot collective must lower to collective-permute ops
    (paper schedule), NOT to a K-sized all-gather."""
    out = run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("enc",))
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix
        from repro.dist.collectives import ps_encode_jit

        f = Field(M31)
        fn, plan = ps_encode_jit(mesh, "enc", np.asarray(random_matrix(f, 8, seed=0)), p=1)
        lowered = fn.lower(jax.ShapeDtypeStruct((8, 16), jnp.uint32))
        txt = lowered.compile().as_text()
        n_cp = txt.count("collective-permute")
        assert n_cp > 0, "expected collective-permute ops"
        assert "all-gather" not in txt, "universal encode must not all-gather"
        print("collective-permutes:", n_cp)
        """
    )
    assert "collective-permutes:" in out


def test_pipeline_gpipe():
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pipe",))
        from repro.dist.pipeline import pipeline_apply, stack_stage_params

        def stage(params, x):
            W, b = params
            return jnp.tanh(x @ W + b)

        rng = np.random.default_rng(0)
        S, d = 4, 8
        plist = [
            (jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.3),
             jnp.asarray(rng.normal(size=(d,)).astype(np.float32) * 0.1))
            for _ in range(S)
        ]
        x = jnp.asarray(rng.normal(size=(6, 3, d)).astype(np.float32))
        out = jax.jit(lambda p, xx: pipeline_apply(stage, p, xx, mesh=mesh, axis="pipe"))(
            stack_stage_params(plist), x
        )
        ref = x
        for pms in plist:
            ref = jax.vmap(lambda mb: stage(pms, mb))(ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
        print("OK")
        """
    )


def test_coded_checkpoint_collective_roundtrip():
    """The coded-checkpoint mesh path (rs_checkpoint.encode_parity_collective
    → dist.collectives.ps_encode_jit) produces the same parity packets as the
    single-program path, and the recovery solve is bit-exact from them."""
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.coded.rs_checkpoint import (
            build_parity_plan, encode_parity, encode_parity_collective, recover_lost)

        K = 8
        mesh = make_mesh((8,), ("dp",))
        plan = build_parity_plan(K, p=1)
        rng = np.random.default_rng(3)
        shards = rng.integers(0, 1 << 16, size=(K, 32), dtype=np.uint32)
        fn = encode_parity_collective(mesh, "dp", plan)
        parity = np.asarray(fn(jnp.asarray(shards)), dtype=np.uint64)
        ref = np.asarray(encode_parity(jnp.asarray(shards), plan), dtype=np.uint64)
        np.testing.assert_array_equal(parity, ref)
        lost = [1, 6]
        rec = recover_lost(
            plan, lost,
            {k: shards[k].astype(np.uint64) for k in range(K) if k not in lost},
            {k: parity[k] for k in range(K) if k not in lost},
        )
        for k in lost:
            np.testing.assert_array_equal(rec[k], shards[k].astype(np.uint64))
        print("OK")
        """
    )


def test_sharding_rules_divisibility():
    """Divisibility-aware logical→physical mapping (no subprocess needed)."""
    import jax

    from repro.dist.sharding import ShardingRules, spec_for
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("model",))
    rules = ShardingRules()
    # divisible dim → sharded; non-divisible → replicated
    s1 = spec_for(mesh, rules, ("batch", "d_ff"), (4, 16))
    assert s1 == jax.sharding.PartitionSpec(None, "model") or s1 == jax.sharding.PartitionSpec(
        None, ("model",)
    ) or str(s1).count("model")
    s2 = spec_for(mesh, rules, ("heads",), (7,))  # 7 % 1 == 0 → still maps
    assert "model" in str(s2)
