"""Observability layer: span tracing, Chrome-trace export, metrics registry,
the traced per-round executor, and the live calibration feed.

The expensive traced-executor test forks a subprocess with 8 forced host
devices (same harness as tests/test_ir.py) and asserts the ISSUE acceptance
criteria: exactly one span per CommRound with the α-β prediction attached,
bit-exact output vs. the fused path, and an UNCHANGED ppermute budget on the
untraced executor's jaxpr."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.obs import (
    MetricsRegistry,
    Span,
    Tracer,
    current_tracer,
    drift_rows,
    feed_calibration,
    read_spans,
    refit_from_spans,
    round_measurements,
    set_tracer,
    spans_to_chrome,
    write_chrome_trace,
    write_spans_jsonl,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# tracer + chrome export
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering():
    tr = Tracer()
    with tr.span("outer", kind="root") as outer:
        with tr.span("inner-a", i=0):
            pass
        with tr.span("inner-b", i=1) as b:
            with tr.span("leaf"):
                pass
    assert [s.name for s in tr.spans] == ["outer", "inner-a", "inner-b", "leaf"]
    assert [s.depth for s in tr.spans] == [0, 1, 1, 2]
    assert [s.parent for s in tr.spans] == [None, 0, 0, 2]
    # start-ordered, children contained in parents, durations filled
    assert all(s.dur_us >= 0 for s in tr.spans)
    for s in tr.spans[1:]:
        p = tr.spans[s.parent]
        assert p.ts_us <= s.ts_us
        assert s.ts_us + s.dur_us <= p.ts_us + p.dur_us + 1e-6
    assert outer.attrs == {"kind": "root"}
    assert b.attrs == {"i": 1}


def test_chrome_trace_roundtrip(tmp_path):
    """Spans → Chrome trace JSON: valid X events, monotonic timestamps,
    args carrying attrs — and read_spans loads them back."""
    tr = Tracer()
    with tr.span("encode", algorithm="multilevel"):
        with tr.span("round[0]", comm_round=0, predicted_us=12.5):
            pass
        with tr.span("round[1]", comm_round=1, predicted_us=30.0):
            pass
    rec = spans_to_chrome(tr.spans, process_name="test")
    evs = rec["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "test"
    xs = [e for e in evs if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["encode", "round[0]", "round[1]"]
    ts = [e["ts"] for e in xs]
    assert ts == sorted(ts)
    assert all(e["dur"] >= 0 for e in xs)
    assert xs[1]["args"] == {"comm_round": 0, "predicted_us": 12.5}

    chrome = tmp_path / "t.trace.json"
    jsonl = tmp_path / "t.jsonl"
    write_chrome_trace(tr.spans, str(chrome))
    write_spans_jsonl(tr.spans, str(jsonl))
    for path in (chrome, jsonl):
        back = read_spans(str(path))
        assert [s["name"] for s in back] == ["encode", "round[0]", "round[1]"]
        assert back[1]["attrs"]["comm_round"] == 0
    # the jsonl sink additionally preserves the span tree
    back = read_spans(str(jsonl))
    assert [s["parent"] for s in back] == [None, 0, 0]
    # and both files satisfy the CI schema gate
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        assert check_trace.check_trace(json.load(open(chrome))) == []
        assert check_trace.main([str(chrome)]) == 0
        assert check_trace.main([str(jsonl)]) == 0
    finally:
        sys.path.pop(0)


def test_default_tracer_install():
    tr = Tracer()
    set_tracer(tr)
    try:
        assert current_tracer() is tr
    finally:
        set_tracer(None)
    assert current_tracer() is None


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_snapshot_deterministic(tmp_path):
    def run():
        reg = MetricsRegistry()
        reg.counter("encode.rounds").inc(3)
        reg.gauge("serve.tokens_per_s").set(123.5)
        h = reg.histogram("encode.round_us", level=1)
        for v in (5.0, 1.0, 9.0, 3.0):
            h.observe(v)
        reg.histogram("encode.round_us", level=0).observe(2.0)
        return reg

    a, b = run().snapshot(), run().snapshot()
    assert a == b
    assert list(a) == sorted(a)  # deterministic key order
    assert a["encode.rounds"] == {"type": "counter", "value": 3.0}
    hist = a["encode.round_us{level=1}"]
    assert hist["count"] == 4 and hist["min"] == 1.0 and hist["max"] == 9.0
    assert hist["p50"] == 3.0 or hist["p50"] == 5.0
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    run().write_json(str(p1))
    run().write_json(str(p2))
    assert p1.read_text() == p2.read_text()


def test_metrics_registry_contracts():
    reg = MetricsRegistry()
    c = reg.counter("x")
    assert reg.counter("x") is c  # same series → same instrument
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("x")  # kind mismatch on an existing series
    assert reg.counter("x", shard=0) is not c  # labels make a new series
    reg.reset()
    assert reg.snapshot() == {}


# ---------------------------------------------------------------------------
# calibration feed + drift (pure host-side, synthetic spans)
# ---------------------------------------------------------------------------


def _synth_round_span(i, level, dur_us, elems, predicted_us=10.0):
    return Span(
        name=f"round[{i}]",
        ts_us=float(i * 100),
        dur_us=dur_us,
        attrs={
            "algorithm": "multilevel",
            "comm_round": i,
            "level": level,
            "msgs": 1,
            "elems": elems,
            "payload_elems": 1,  # β multiplies elems × payload in the fit
            "predicted_us": predicted_us,
        },
    )


def test_round_measurements_and_refit():
    # α=1ms, β=1µs/elem at level 0; α=2ms, β=2µs/elem at level 1 —
    # recoverable exactly because the synthetic walls ARE the model
    spans = []
    i = 0
    for level, (a_s, b_s) in enumerate([(1e-3, 1e-6), (2e-3, 2e-6)]):
        for elems in (10, 100, 1000):
            spans.append(
                _synth_round_span(i, level, (a_s + b_s * elems) * 1e6, elems)
            )
            i += 1
    ms = round_measurements(spans)
    assert len(ms) == 6
    assert ms[0]["rounds"] == [{"level": 0, "msgs": 1, "elems": 10}]
    fitted = refit_from_spans(spans)  # n_levels inferred = 2
    assert len(fitted) == 2
    assert fitted[0].alpha == pytest.approx(1e-3, rel=1e-6)
    assert fitted[0].beta == pytest.approx(1e-6, rel=1e-6)
    assert fitted[1].alpha == pytest.approx(2e-3, rel=1e-6)
    assert fitted[1].beta == pytest.approx(2e-6, rel=1e-6)
    with pytest.raises(ValueError):
        refit_from_spans([])  # no traced rounds


def test_feed_calibration_persists_where_loader_reads(tmp_path):
    """Acceptance: the live feed lands exactly where load_fitted_costs —
    and therefore resolve_profile(calibration=...) — reads fitted costs."""
    from repro.launch.profiles import resolve_profile
    from repro.topo import load_fitted_costs

    spans = []
    i = 0
    for level, (a_s, b_s) in enumerate([(0.5, 1e-6), (2.0, 1e-5)]):
        for elems in (10, 100, 1000):
            spans.append(
                _synth_round_span(i, level, (a_s + b_s * elems) * 1e6, elems)
            )
            i += 1
    path = tmp_path / "BENCH_topology.json"
    # pre-existing record keys must survive the merge
    path.write_text(json.dumps({"K": 8, "calibration": {"note": "old"}}))
    fitted = feed_calibration(spans, str(path))
    rec = json.loads(path.read_text())
    assert rec["K"] == 8 and rec["calibration"]["note"] == "old"
    assert rec["calibration"]["source"] == "live-trace"
    assert tuple(load_fitted_costs(str(path))) == tuple(fitted)
    # absurdly slow fitted α (0.5 s / 2 s) must dominate candidate pricing
    prof = resolve_profile(multi_pod=False, calibration=str(path))
    assert prof.fitted_costs == tuple(fitted)
    assert prof.tune.chosen.predicted_time > 1.0

    # the trace-path variant: resolve_profile refits from the file itself
    jsonl = tmp_path / "enc.jsonl"
    write_spans_jsonl(spans, str(jsonl))
    prof2 = resolve_profile(multi_pod=False, calibration=str(jsonl))
    assert prof2.fitted_costs is not None
    assert prof2.fitted_costs[0].alpha == pytest.approx(0.5, rel=1e-5)
    assert prof2.tune.chosen.predicted_time > 1.0


def test_drift_rows_and_render():
    from repro.launch.perf_report import render_drift

    spans = [
        _synth_round_span(0, 0, dur_us=12.0, elems=10, predicted_us=10.0),
        _synth_round_span(1, 1, dur_us=99.0, elems=10, predicted_us=10.0),
    ]
    rows = drift_rows(spans, threshold=0.5)
    assert [r["round"] for r in rows] == [1, 0]  # worst first
    assert rows[0]["flagged"] and not rows[1]["flagged"]
    assert rows[1]["rel_err"] == pytest.approx(0.2)
    table = render_drift(spans)
    assert "| 1 | multilevel | 1 | 10.0 | 99.0 |" in table
    assert "1/2 rounds flagged" in table


# ---------------------------------------------------------------------------
# traced executor on a forced-host 8-device mesh (subprocess)
# ---------------------------------------------------------------------------


def test_traced_ir_encode_one_span_per_round():
    """ISSUE acceptance: ir_encode_jit(tracer=...) on a 2×2×2 forced-host
    mesh emits exactly one span per CommRound (with predicted_us + level
    calibration attrs), stays bit-exact vs. the fused path, and the
    UNTRACED executor's jaxpr ppermute budget is unchanged."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, Field
        from repro.core.ir import ir_permute_count
        from repro.core.matrices import distinct_points, vandermonde, random_vector
        from repro.dist.collectives import ir_encode_jit
        from repro.obs import Tracer, feed_calibration, get_registry
        from repro.topo import Hierarchy, plan_multilevel

        K = 8
        f = Field(M31)
        A = np.asarray(vandermonde(f, distinct_points(f, K, seed=0)))
        ir = plan_multilevel(K, 1, (2, 2, 2)).to_ir(A)
        mesh = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        topo = Hierarchy(levels=(2, 2, 2))
        x = jnp.asarray(random_vector(f, (K, 32), seed=3).astype(np.uint32))

        fused = ir_encode_jit(mesh, ("pod", "slice", "chip"), ir)
        ref = np.asarray(fused(x))
        # untraced budget UNCHANGED: one ppermute per port group
        jaxpr = jax.make_jaxpr(fused)(jax.ShapeDtypeStruct((K, 4), jnp.uint32))
        budget = ir_permute_count(ir)
        assert str(jaxpr).count("ppermute") == budget, (
            str(jaxpr).count("ppermute"), budget)

        tracer = Tracer()
        fn = ir_encode_jit(mesh, ("pod", "slice", "chip"), ir,
                           tracer=tracer, topo=topo)
        out = np.asarray(fn(x))
        assert np.array_equal(out, ref), "traced output != fused output"
        roots = [s for s in tracer.spans if s.name == "ir_encode"]
        comm = [s for s in tracer.spans if "comm_round" in s.attrs]
        assert len(roots) == 1
        assert len(comm) == ir.c1 == 3, (len(comm), ir.c1)
        assert [s.attrs["comm_round"] for s in comm] == [0, 1, 2]
        for s in comm:
            assert s.parent == 0 and s.dur_us > 0
            for key in ("predicted_us", "level", "msgs", "elems",
                        "transfers", "ppermutes", "payload_elems"):
                assert key in s.attrs, (s.name, key)
        assert sum(s.attrs["ppermutes"] for s in comm) == budget
        # levels innermost-out: chip=0, slice=1, pod=2
        assert [s.attrs["level"] for s in comm] == [0, 1, 2]
        snap = get_registry().snapshot()
        assert snap["encode.rounds"]["value"] == 3
        assert snap["encode.ppermutes"]["value"] == budget
        assert snap["encode.bytes_on_wire"]["value"] > 0
        assert snap["encode.round_us{level=0}"]["count"] == 1
        # the live feed closes on these very spans
        import tempfile, os as _os
        tmp = tempfile.mkdtemp()
        path = _os.path.join(tmp, "cal.json")
        fn(x)  # second traced call: 6 round spans total -> fit solvable
        fitted = feed_calibration(tracer.spans, path, n_levels=3)
        from repro.topo import load_fitted_costs
        assert tuple(load_fitted_costs(path)) == tuple(fitted)
        print("traced encode ok")
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "traced encode ok" in r.stdout


def test_traced_interpret_oracle():
    """The interpret oracle takes the same tracer= and emits one span per
    CommRound without changing its output."""
    import numpy as np

    from repro.core.field import M31, Field
    from repro.core.matrices import distinct_points, random_vector, vandermonde
    from repro.core.simulator import interpret
    from repro.topo import Hierarchy, plan_multilevel

    K = 8
    f = Field(M31)
    A = np.asarray(vandermonde(f, distinct_points(f, K, seed=0)))
    ir = plan_multilevel(K, 1, (2, 2, 2)).to_ir(A)
    x = random_vector(f, (K,), seed=2)
    ref, _ = interpret(ir, x, f)
    tr = Tracer()
    out, _ = interpret(ir, x, f, tracer=tr, topo=Hierarchy(levels=(2, 2, 2)))
    np.testing.assert_array_equal(ref, out)
    comm = [s for s in tr.spans if "comm_round" in s.attrs]
    assert len(comm) == ir.c1
    assert tr.spans[0].name == "interpret"
    assert all("predicted_us" in s.attrs for s in comm)


# ---------------------------------------------------------------------------
# serve engine: batched EOS sync + metrics
# ---------------------------------------------------------------------------


def test_engine_batched_eos_and_metrics():
    """generate() only host-syncs the EOS check every eos_check_every steps
    (saved syncs counted), and records serve throughput metrics; a tracer
    yields one span per decode step."""
    import numpy as np

    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serve.engine import Engine

    cfg = smoke_config("qwen3-1.7b").replace(n_layers=1)
    model = build_model(cfg)
    import jax

    params = model.init(jax.random.key(0))
    reg = MetricsRegistry()
    tr = Tracer()
    eng = Engine(model, params, max_len=64, tracer=tr, metrics=reg)
    res = eng.generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=12, eos_id=None, eos_check_every=4
    )
    assert res.tokens.shape[0] == 2 and res.steps > 0
    snap = reg.snapshot()
    assert snap["serve.steps"]["value"] == res.steps
    assert snap["serve.step_us"]["count"] == res.steps
    assert snap["serve.tokens_per_s"]["value"] > 0
    steps = [s for s in tr.spans if s.name == "serve.step"]
    assert len(steps) == res.steps
    # eos_id set but never produced: every off-cycle step saves one sync
    reg2 = MetricsRegistry()
    eng2 = Engine(model, params, max_len=64, metrics=reg2)
    res2 = eng2.generate(
        [[1, 2, 3]], max_new_tokens=12, eos_id=-1, eos_check_every=4
    )
    saved = reg2.snapshot()["serve.eos_syncs_saved"]["value"]
    # steps not on the 4-cycle and not the final step skip the host sync
    due = sum(
        1 for s in range(1, res2.steps + 1)
        if s % 4 == 0 or s == res2.steps
    )
    assert saved == res2.steps - due
