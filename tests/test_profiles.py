"""Optimization-profile rules: shardings stay valid/divisible for the
hillclimb cells, and levers change exactly the intended logical axes."""

import jax
import pytest

from repro.configs import SHAPES, get
from repro.dist.sharding import spec_for
from repro.launch.mesh import make_mesh
from repro.launch.profiles import BASELINE, OPT, Profile, rules_for


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_moe_resident_unshards_expert_d(mesh):
    cfg = get("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    base = rules_for(cfg, shape, BASELINE)
    opt = rules_for(cfg, shape, Profile("x", moe_resident=True))
    assert base.axes_for("expert_d") == ("data",)
    assert opt.axes_for("expert_d") == ()
    assert opt.axes_for("experts") == ("model", "data")


def test_dp_only_batch_all_axes(mesh):
    cfg = get("qwen3-1.7b")
    shape = SHAPES["train_4k"]
    r = rules_for(cfg, shape, Profile("x", dp_only=True))
    assert r.axes_for("batch") == ("pod", "data", "model")
    assert r.axes_for("d_model") == ()
    # spec on a (batch=256, seq) array over (data=1, model=1) degrades fine
    s = spec_for(mesh, r, ("batch", "seq"), (256, 4096))
    assert "data" in str(s) or "model" in str(s) or s  # valid spec


def test_flags_propagate():
    cfg = get("qwen3-1.7b")
    r = rules_for(cfg, SHAPES["train_4k"], Profile("x", attn_heads=True, logits_vocab=True))
    assert r.has("attn_heads") and r.has("logits_vocab")
    assert not rules_for(cfg, SHAPES["train_4k"], BASELINE).has("attn_heads")


def test_decode_rules_shard_kv_seq():
    cfg = get("deepseek-coder-33b")
    r = rules_for(cfg, SHAPES["decode_32k"], BASELINE)
    assert r.axes_for("kv_seq") == ("model",)
    r5 = rules_for(get("rwkv6-3b"), SHAPES["long_500k"], BASELINE)
    assert r5.axes_for("kv_seq") == ("data", "model")


def test_resolve_profile_picks_hierarchical_from_mesh_topology():
    """Acceptance: make_production_mesh(multi_pod=True)'s derived three-level
    chip < slice < pod hierarchy makes the autotuner select the recursive
    multi-level encode for the coded-checkpoint DP axis; single pod selects
    the two-level hierarchical schedule. Pure host-side (no devices)."""
    from repro.launch.mesh import production_topology
    from repro.launch.profiles import resolve_profile

    prof = resolve_profile(multi_pod=True, calibration=False)
    # compute-aware pricing may pick the pipelined rewrite of the same
    # family; the base algorithm and plan are the contract here
    assert prof.algorithm.split("+")[0] == "multilevel"
    assert prof.levels == (4, 4, 2) == prof.plan.levels
    assert prof.topology.levels == production_topology(multi_pod=True).levels
    assert prof.tune.chosen.plan is prof.plan

    single = resolve_profile(multi_pod=False, calibration=False)
    assert single.algorithm.split("+")[0] == "hierarchical"
    assert single.levels == (4, 4)


def test_resolve_profile_from_live_mesh_shape():
    """mesh= path: the hierarchy is derived from the mesh's encode axes
    (outermost → innermost), so a 2×2×2 mesh resolves to the multilevel
    plan whose levels are the reversed axis sizes."""
    from types import SimpleNamespace

    from repro.launch.mesh import mesh_encode_levels, topology_for_mesh
    from repro.launch.profiles import resolve_profile

    mesh = SimpleNamespace(shape={"pod": 2, "slice": 2, "chip": 2})
    axes = ("pod", "slice", "chip")
    assert mesh_encode_levels(mesh, axes) == (2, 2, 2)
    assert topology_for_mesh(mesh, axes).levels == (2, 2, 2)
    prof = resolve_profile(mesh=mesh, axes=axes, payload_bytes=65536,
                           calibration=False)
    # at 64k payloads the compute-aware price makes the pipelined rewrite of
    # the same schedule strictly cheaper, so accept an optional +<pipeline>
    # suffix — the base family and the plan factorization are the contract
    assert prof.algorithm.split("+")[0] == "multilevel"
    assert prof.plan.levels == (2, 2, 2)
    with pytest.raises(ValueError):
        resolve_profile(mesh=mesh)  # axes required with mesh


def test_resolve_profile_measured_override():
    """Wall-clock calibration flows through: forcing every algorithm but
    prepare-shoot to be slow flips the choice (the BENCH_topology.json
    measured_s feedback path)."""
    from repro.launch.profiles import resolve_profile

    base = resolve_profile(multi_pod=True, calibration=False)
    slow = {
        c.algorithm: 1.0
        for c in base.tune.candidates
        if c.algorithm != "prepare-shoot"
    }
    forced = resolve_profile(multi_pod=True, calibration=False,
                             measured={**slow, "prepare-shoot": 1e-9})
    assert forced.algorithm == "prepare-shoot"


def test_generator_kind_taxonomy():
    """Satellite: the checkpoint layer's matrix kind maps into the autotuner
    taxonomy; unknown kinds are a loud error."""
    from repro.launch.profiles import generator_kind_for

    assert generator_kind_for("cauchy") == "general"
    assert generator_kind_for("random") == "general"
    assert generator_kind_for("vandermonde") == "vandermonde"
    assert generator_kind_for("dft") == "dft"
    with pytest.raises(ValueError, match="unknown generator matrix kind"):
        generator_kind_for("hilbert")


def test_resolve_profile_threads_generator_kind():
    """Satellite: resolve_profile defaults the generator taxonomy from the
    checkpoint layer's Cauchy matrix (→ "general": no structured families),
    and an explicit generator= unlocks them."""
    from repro.core.field import NTT
    from repro.launch.profiles import resolve_profile

    default = resolve_profile(multi_pod=False, calibration=False)
    names = {c.base_algorithm for c in default.tune.candidates}
    assert "multilevel-dft" not in names and "draw-loose" not in names

    dft = resolve_profile(
        multi_pod=False, q=NTT, generator="dft", calibration=False
    )
    dft_names = {c.base_algorithm for c in dft.tune.candidates}
    assert "hierarchical-dft" in dft_names or "multilevel-dft" in dft_names


def test_resolve_profile_prices_with_fitted_calibration(tmp_path):
    """Acceptance: when persisted calibration rows exist, resolve_profile
    loads them (topo.calibrate.load_fitted_costs), replaces the hierarchy's
    level costs, exposes them on EncodeProfile.fitted_costs, and the
    candidate table's prices visibly reflect the fitted α/β."""
    import json

    from repro.launch.profiles import resolve_profile
    from repro.topo import LinkCost, load_fitted_costs

    # absurdly slow fitted constants so the repricing is unmistakable
    rows = [
        {"level": 0, "alpha_s": 0.5, "beta_s_per_elem": 1e-6},
        {"level": 1, "alpha_s": 2.0, "beta_s_per_elem": 1e-5},
    ]
    path = tmp_path / "BENCH_topology.json"
    path.write_text(json.dumps({"calibration": {"fitted_level_costs": rows}}))

    fitted = load_fitted_costs(str(path))
    assert fitted == (LinkCost(0.5, 1e-6), LinkCost(2.0, 1e-5))
    assert load_fitted_costs(str(tmp_path / "missing.json")) is None

    # multi_pod=False → Hierarchy((4, 4)): 2 levels, exact match with rows
    prof = resolve_profile(multi_pod=False, calibration=str(path))
    assert prof.fitted_costs == fitted
    assert tuple(prof.topology.costs) == fitted
    assert prof.tune.chosen.predicted_time > 1.0  # α alone is ≥ 0.5 s/round

    base = resolve_profile(multi_pod=False, calibration=False)
    assert base.fitted_costs is None
    assert base.tune.chosen.predicted_time < 1.0

    # level-count mismatch: fitted endpoints re-interpolated to 3 levels
    deep = resolve_profile(multi_pod=True, calibration=str(path))
    assert deep.fitted_costs is not None
    assert len(deep.fitted_costs) == len(deep.topology.levels) == 3
    assert deep.fitted_costs[0] == fitted[0]
    assert deep.fitted_costs[-1] == fitted[-1]


def test_opt_profile_smoke_compiles_1dev(mesh):
    """OPT-profile rules lower a tiny train step on a 1x1 mesh."""
    from repro.configs import smoke_config
    from repro.models import build_model, make_batch
    from repro.train import OptConfig, init_state, make_train_step

    cfg = smoke_config("jamba-v0.1-52b")
    r = rules_for(cfg, SHAPES["train_4k"], OPT)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ocfg = OptConfig()
    step = jax.jit(make_train_step(model, ocfg, mesh=mesh, rules=r))
    batch = make_batch(cfg, 2, 16)
    p2, o2, m = step(params, init_state(ocfg, params), batch)
    assert float(m["loss"]) > 0
