"""Validation of the paper's claims (Lemmas 1-4, Theorems 1-4) against the
cost-exact simulator and the matrix oracle. These are the EXPERIMENTS.md
§Paper-claims results."""

import math

import numpy as np
import pytest

from repro.core import bounds
from repro.core.field import M31, NTT, Field
from repro.core.matrices import (
    butterfly_target_matrix,
    lagrange_matrix,
    random_matrix,
    random_vector,
    vandermonde,
)
from repro.core.schedule import (
    draw_loose_target_matrix,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)
from repro.core.simulator import (
    simulate_butterfly,
    simulate_draw_loose,
    simulate_prepare_shoot,
)

KS = [2, 3, 4, 5, 7, 8, 9, 12, 16, 17, 25, 31, 32, 64, 65, 100]
PS = [1, 2, 3]


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("K", KS)
def test_prepare_shoot_correct_and_costs(K, p):
    """Universal algorithm computes any A; C1 strictly optimal (Lemma 1 /
    Theorem 1), C2 equals the Lemma-3+4 closed form, within sqrt(2)·lower
    bound asymptotics (Lemma 2)."""
    f = Field(M31)
    plan = plan_prepare_shoot(K, p)
    A = random_matrix(f, K, seed=K * 7 + p)
    x = random_vector(f, K, seed=K * 13 + p)
    out, stats = simulate_prepare_shoot(x, A, plan, f)
    want = f.matmul(x, A)
    np.testing.assert_array_equal(out, want)
    # C1: strictly optimal
    assert stats.C1 == bounds.lemma1_c1_lower(K, p) == plan.c1
    # C2: equals exact live-slot accounting, bounded by the Theorem-1 form
    from repro.core.schedule import counted_c2

    assert stats.C2 == counted_c2(plan)
    assert stats.C2 <= bounds.theorem1_c2(K, p) == plan.c2
    # C2 lower bound holds
    assert stats.C2 >= math.floor(bounds.lemma2_c2_lower(K, p)) - 1


@pytest.mark.parametrize("K,p", [(2, 1), (4, 1), (8, 1), (16, 1), (32, 1), (3, 2), (9, 2), (16, 3)])
def test_butterfly_dft_exact_and_strictly_optimal(K, p):
    """Theorem 2: C1 = C2 = log_{p+1}K; computes the (rev-row) DFT matrix."""
    q = NTT if (NTT - 1) % K == 0 and K % (p + 1) == 0 else M31
    if (q - 1) % K != 0:
        pytest.skip("no K-th root of unity")
    f = Field(q)
    plan = plan_butterfly(K, p, q)
    x = random_vector(f, K, seed=K)
    out, stats = simulate_butterfly(x, plan, f)
    G = butterfly_target_matrix(f, K, p + 1)
    np.testing.assert_array_equal(out, f.matmul(x, G))
    H = bounds.ceil_log(K, p + 1)
    assert stats.C1 == stats.C2 == H
    # exponential improvement over universal C2 (Remark 4) for large K
    assert stats.C2 <= bounds.theorem1_c2(K, p)
    if K >= 16 and p == 1:
        assert stats.C2 < bounds.theorem1_c2(K, p)


@pytest.mark.parametrize("K,p", [(4, 1), (8, 1), (16, 1), (9, 2)])
def test_butterfly_inverse_roundtrip(K, p):
    """Lemma 5: the butterfly is invertible with the same C1/C2."""
    q = NTT if p == 1 else M31
    f = Field(q)
    plan = plan_butterfly(K, p, q)
    x = random_vector(f, K, seed=3 * K)
    y, st_f = simulate_butterfly(x, plan, f)
    back, st_b = simulate_butterfly(y, plan, f, inverse=True)
    np.testing.assert_array_equal(back, x)
    assert st_b.C1 == st_f.C1 and st_b.C2 == st_f.C2


@pytest.mark.parametrize(
    "K,p,q",
    [
        (8, 1, NTT),  # M=1, H=3: pure butterfly
        (12, 1, NTT),  # M=3, H=2
        (20, 1, NTT),  # M=5, H=2
        (18, 2, M31),  # M=2, H=2 (radix 3 over M31: 3^2 | q-1)
        (24, 1, NTT),  # M=3, H=3
        (7, 1, NTT),  # H=0 → degrades to pure universal draw (Remark 5)
    ],
)
def test_draw_loose_vandermonde(K, p, q):
    """Theorem 3: computes a (row-permuted) Vandermonde with C1=⌈log⌉ and
    C2 = H + Ψ(M)."""
    f = Field(q)
    plan = plan_draw_loose(K, p, q, seed=1)
    x = random_vector(f, K, seed=5 * K)
    out, stats = simulate_draw_loose(x, plan, f)
    G = draw_loose_target_matrix(plan)
    np.testing.assert_array_equal(out, f.matmul(x, G))
    c1, c2 = bounds.theorem3_c1_c2(K, p, plan.M, plan.H)
    assert stats.C1 <= c1  # ⌈log_{p+1}K⌉ is an upper bound; subgroup split can beat it
    assert stats.C2 == c2 == plan.c2
    # the generator is Vandermonde up to row permutation
    V = vandermonde(f, plan.points)
    np.testing.assert_array_equal(G, V[plan.source_perm, :])
    # and the C2 never exceeds universal prepare-and-shoot's
    assert stats.C2 <= bounds.theorem1_c2(K, p)


def test_draw_loose_gain_over_universal():
    """Remark 4/5: large-H cases give (near-)exponential C2 gains."""
    K, p, q = 64, 1, NTT
    plan = plan_draw_loose(K, p, q)
    assert plan.M == 1 and plan.H == 6
    assert plan.c2 == 6  # = log2 K
    assert bounds.theorem1_c2(K, p) == 14  # universal: (8-1)/1 + (8-1)/1


@pytest.mark.parametrize("K,p,q", [(8, 1, NTT), (12, 1, NTT), (6, 1, NTT)])
def test_lagrange_via_inverse_forward(K, p, q):
    """Theorem 4: inverse-Vandermonde(ω) then forward-Vandermonde(α) computes
    the Lagrange matrix; source permutations cancel exactly (DESIGN §3)."""
    f = Field(q)
    plan_w = plan_draw_loose(K, p, q, seed=11)
    plan_a = plan_draw_loose(K, p, q, seed=22)
    # simulate: decode ω-plan (inverse loose then inverse draw), then encode α
    x = random_vector(f, K, seed=9 * K)

    # host-exact composite via target matrices:
    Gw = draw_loose_target_matrix(plan_w)
    Ga = draw_loose_target_matrix(plan_a)
    composite = f.matmul(f.inv_matrix(Gw), Ga)
    Ltrue = lagrange_matrix(f, plan_a.points, plan_w.points)
    np.testing.assert_array_equal(composite, Ltrue)

    # algorithmic path (array-level executor is exercised in test_encode_api;
    # here verify the simulator pieces compose):
    coeffs = f.solve(Gw.T, x)  # x = coeffs @ Gw
    out, _ = simulate_draw_loose(coeffs, plan_a, f)
    np.testing.assert_array_equal(out, f.matmul(x, Ltrue))


def test_theorem1_even_L_discrepancy_documented():
    """For even L, Theorem 1's printed C2 disagrees with its own Lemmas 3+4;
    we implement/validate the lemma-consistent value (EXPERIMENTS.md)."""
    K, p = 5, 1  # L = 2 (even)
    assert bounds.ps_params(K, p)[0] == 2
    assert bounds.theorem1_c2(K, p) == 4  # (m-1)/p + (n-1)/p = 3 + 1
    # printed: ((p+1)^{L/2+1} - 2)/p = 2 — it UNDERCOUNTS its own Lemma 3+4
    # sum (the (p+1)^{L/2} shoot term is dropped)
    assert bounds.theorem1_c2_as_printed(K, p) == 2
    # simulator agrees with the lemma-consistent value
    f = Field(M31)
    plan = plan_prepare_shoot(K, p)
    out, stats = simulate_prepare_shoot(
        random_vector(f, K, seed=0), random_matrix(f, K, seed=0), plan, f
    )
    assert stats.C2 == 4


def test_baselines_are_worse():
    """prepare-and-shoot C2 ~ O(√K/p) beats all-gather (~K/p) and direct
    (~K/p) for large K — the paper's raison d'être."""
    for K in [64, 256, 1024]:
        for p in PS:
            ps = bounds.theorem1_c2(K, p)
            ag = bounds.allgather_baseline_c1_c2(K, p)[1]
            di = bounds.direct_baseline_c1_c2(K, p)[1]
            assert ps < ag and ps < di
