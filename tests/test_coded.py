"""Coded fault-tolerance layer: bit-exact RS/Cauchy recovery, gradient
coding, Lagrange coded computing."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax
import jax.numpy as jnp

from repro.coded import (
    aggregate,
    build_grad_coding,
    build_lcc,
    build_parity_plan,
    encode_parity,
    lcc_compute_and_decode,
    lcc_encode,
    limbs_to_state,
    recover_lost,
    shard_state_limbs,
    state_to_limbs,
    unshard_state_limbs,
    worker_combine,
)
from repro.core.field import M31, NTT, Field
from repro.core.matrices import cauchy_matrix


def test_limb_bitcast_roundtrip():
    state = {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(7, 5)).astype(np.float32)),
        "m": jnp.asarray(np.random.default_rng(1).normal(size=(11,)).astype(np.float32)),
        "b16": jnp.asarray(np.random.default_rng(2).normal(size=(3, 3)), dtype=jnp.bfloat16),
        "i": jnp.arange(9, dtype=jnp.int32),
    }
    limbs, meta = state_to_limbs(state)
    assert limbs.dtype == jnp.uint32 and int(limbs.max()) < 2**16
    back = limbs_to_state(limbs, meta)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(state[k]))


def test_cauchy_all_submatrices_invertible():
    f = Field(M31)
    A = cauchy_matrix(f, 6)
    import itertools

    for rows in itertools.combinations(range(6), 3):
        for cols in itertools.combinations(range(6), 3):
            sub = A[np.ix_(rows, cols)]
            f.inv_matrix(sub)  # raises if singular


@pytest.mark.parametrize("K,f_lost", [(4, 1), (8, 2), (8, 3), (16, 5)])
def test_coded_checkpoint_recovery_bit_exact(K, f_lost):
    """Kill f nodes; recover their float state bit-exactly from survivors."""
    rng = np.random.default_rng(K)
    state = {
        "params": jnp.asarray(rng.normal(size=(K * 37,)).astype(np.float32)),
        "m": jnp.asarray(rng.normal(size=(K * 13,)).astype(np.float32)),
        "step": jnp.asarray(123, jnp.int32),
    }
    shards, meta = shard_state_limbs(state, K)  # (K, S)
    plan = build_parity_plan(K, p=1)
    parity = np.asarray(encode_parity(shards, plan), dtype=np.uint64)
    shards_np = np.asarray(shards, dtype=np.uint64)

    lost = list(rng.choice(K, size=f_lost, replace=False))
    surviving_x = {k: shards_np[k] for k in range(K) if k not in lost}
    surviving_p = {k: parity[k] for k in range(K) if k not in lost}
    rec = recover_lost(plan, lost, surviving_x, surviving_p)
    for k in lost:
        np.testing.assert_array_equal(rec[k], shards_np[k])
    # full state reassembles bit-exactly
    full = shards_np.copy()
    for k in lost:
        full[k] = rec[k]
    back = unshard_state_limbs(jnp.asarray(full.astype(np.uint32)), meta)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(state[k]))


@given(K=st.integers(3, 12), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_coded_checkpoint_recovery_property(K, seed):
    rng = np.random.default_rng(seed)
    f_lost = int(rng.integers(1, max(2, K // 2)))
    shards = jnp.asarray(rng.integers(0, 2**16, size=(K, 29), dtype=np.uint32))
    plan = build_parity_plan(K, p=1)
    parity = np.asarray(encode_parity(shards, plan), dtype=np.uint64)
    sn = np.asarray(shards, dtype=np.uint64)
    lost = list(rng.choice(K, size=f_lost, replace=False))
    rec = recover_lost(
        plan,
        lost,
        {k: sn[k] for k in range(K) if k not in lost},
        {k: parity[k] for k in range(K) if k not in lost},
    )
    for k in lost:
        np.testing.assert_array_equal(rec[k], sn[k])


@pytest.mark.parametrize("K,s", [(5, 1), (8, 2), (12, 3)])
def test_gradient_coding_tolerates_stragglers(K, s):
    rng = np.random.default_rng(0)
    plan = build_grad_coding(K, s, seed=1)
    shard_grads = {
        j: {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))} for j in range(K)
    }
    want = sum(np.asarray(shard_grads[j]["w"]) for j in range(K))
    sent = {i: worker_combine(plan, i, shard_grads) for i in range(K)}
    # drop the s slowest workers (worst case: any subset)
    for drop_seed in range(3):
        drop = set(np.random.default_rng(drop_seed).choice(K, size=s, replace=False).tolist())
        received = {i: c for i, c in sent.items() if i not in drop}
        got = aggregate(plan, received)
        np.testing.assert_allclose(np.asarray(got["w"]), want, rtol=1e-4, atol=1e-4)


def test_lcc_coded_matmul():
    K, q = 8, NTT
    f = Field(q)
    rng = np.random.default_rng(7)
    plan = build_lcc(K, p=1, q=q)
    X = rng.integers(0, 1000, size=(K, 6, 4), dtype=np.uint32)  # small ints: exact
    W = rng.integers(0, 1000, size=(4, 5), dtype=np.uint64)
    encoded = lcc_encode(plan, jnp.asarray(X))
    # any K responders decode (here: all, then a rotated subset of exactly K)
    out = lcc_compute_and_decode(plan, np.asarray(encoded), W, list(range(K)))
    for i in range(K):
        np.testing.assert_array_equal(out[i], f.matmul(X[i].astype(np.uint64), W))
