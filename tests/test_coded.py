"""Coded fault-tolerance layer: bit-exact RS/Cauchy recovery, gradient
coding, Lagrange coded computing."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax
import jax.numpy as jnp

from repro.coded import (
    aggregate,
    build_grad_coding,
    build_lcc,
    build_parity_plan,
    encode_parity,
    lcc_compute_and_decode,
    lcc_decode,
    lcc_encode,
    lcc_pad,
    limbs_to_state,
    recover_lost,
    shard_state_limbs,
    state_to_limbs,
    unshard_state_limbs,
    worker_combine,
)
from repro.core.field import M31, NTT, Field
from repro.core.matrices import cauchy_matrix


def test_limb_bitcast_roundtrip():
    state = {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(7, 5)).astype(np.float32)),
        "m": jnp.asarray(np.random.default_rng(1).normal(size=(11,)).astype(np.float32)),
        "b16": jnp.asarray(np.random.default_rng(2).normal(size=(3, 3)), dtype=jnp.bfloat16),
        "i": jnp.arange(9, dtype=jnp.int32),
    }
    limbs, meta = state_to_limbs(state)
    assert limbs.dtype == jnp.uint32 and int(limbs.max()) < 2**16
    back = limbs_to_state(limbs, meta)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(state[k]))


def test_cauchy_all_submatrices_invertible():
    f = Field(M31)
    A = cauchy_matrix(f, 6)
    import itertools

    for rows in itertools.combinations(range(6), 3):
        for cols in itertools.combinations(range(6), 3):
            sub = A[np.ix_(rows, cols)]
            f.inv_matrix(sub)  # raises if singular


@pytest.mark.parametrize("K,f_lost", [(4, 1), (8, 2), (8, 3), (16, 5)])
def test_coded_checkpoint_recovery_bit_exact(K, f_lost):
    """Kill f nodes; recover their float state bit-exactly from survivors."""
    rng = np.random.default_rng(K)
    state = {
        "params": jnp.asarray(rng.normal(size=(K * 37,)).astype(np.float32)),
        "m": jnp.asarray(rng.normal(size=(K * 13,)).astype(np.float32)),
        "step": jnp.asarray(123, jnp.int32),
    }
    shards, meta = shard_state_limbs(state, K)  # (K, S)
    plan = build_parity_plan(K, p=1)
    parity = np.asarray(encode_parity(shards, plan), dtype=np.uint64)
    shards_np = np.asarray(shards, dtype=np.uint64)

    lost = list(rng.choice(K, size=f_lost, replace=False))
    surviving_x = {k: shards_np[k] for k in range(K) if k not in lost}
    surviving_p = {k: parity[k] for k in range(K) if k not in lost}
    rec = recover_lost(plan, lost, surviving_x, surviving_p)
    for k in lost:
        np.testing.assert_array_equal(rec[k], shards_np[k])
    # full state reassembles bit-exactly
    full = shards_np.copy()
    for k in lost:
        full[k] = rec[k]
    back = unshard_state_limbs(jnp.asarray(full.astype(np.uint32)), meta)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(state[k]))


@given(K=st.integers(3, 12), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_coded_checkpoint_recovery_property(K, seed):
    rng = np.random.default_rng(seed)
    f_lost = int(rng.integers(1, max(2, K // 2)))
    shards = jnp.asarray(rng.integers(0, 2**16, size=(K, 29), dtype=np.uint32))
    plan = build_parity_plan(K, p=1)
    parity = np.asarray(encode_parity(shards, plan), dtype=np.uint64)
    sn = np.asarray(shards, dtype=np.uint64)
    lost = list(rng.choice(K, size=f_lost, replace=False))
    rec = recover_lost(
        plan,
        lost,
        {k: sn[k] for k in range(K) if k not in lost},
        {k: parity[k] for k in range(K) if k not in lost},
    )
    for k in lost:
        np.testing.assert_array_equal(rec[k], sn[k])


@pytest.mark.parametrize("K,s", [(5, 1), (8, 2), (12, 3)])
def test_gradient_coding_tolerates_stragglers(K, s):
    rng = np.random.default_rng(0)
    plan = build_grad_coding(K, s, seed=1)
    shard_grads = {
        j: {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))} for j in range(K)
    }
    want = sum(np.asarray(shard_grads[j]["w"]) for j in range(K))
    sent = {i: worker_combine(plan, i, shard_grads) for i in range(K)}
    # drop the s slowest workers (worst case: any subset)
    for drop_seed in range(3):
        drop = set(np.random.default_rng(drop_seed).choice(K, size=s, replace=False).tolist())
        received = {i: c for i, c in sent.items() if i not in drop}
        got = aggregate(plan, received)
        np.testing.assert_allclose(np.asarray(got["w"]), want, rtol=1e-4, atol=1e-4)


def test_lcc_coded_matmul():
    K, q = 8, NTT
    f = Field(q)
    rng = np.random.default_rng(7)
    plan = build_lcc(K, p=1, q=q)
    X = rng.integers(0, 1000, size=(K, 6, 4), dtype=np.uint32)  # small ints: exact
    W = rng.integers(0, 1000, size=(4, 5), dtype=np.uint64)
    encoded = lcc_encode(plan, jnp.asarray(X))
    # any K responders decode (here: all, then a rotated subset of exactly K)
    out = lcc_compute_and_decode(plan, np.asarray(encoded), W, list(range(K)))
    for i in range(K):
        np.testing.assert_array_equal(out[i], f.matmul(X[i].astype(np.uint64), W))


# ---------------------------------------------------------------------------
# LCC erasure codes (N = K + R): ISSUE 10 property + edge cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [M31, NTT])
@pytest.mark.parametrize("K", [4, 8, 16])
def test_lcc_erasure_roundtrip_both_fields(q, K):
    """encode → drop R shards → decode is the identity over both fields,
    K ∈ {4, 8, 16}, odd payload shapes."""
    R = 2
    rng = np.random.default_rng(K * 17 + (q & 0xFF))
    plan = build_lcc(K, p=1, q=q, R=R)
    assert plan.N == K + R
    X = rng.integers(0, q, size=(K, 7, 3), dtype=np.uint64)  # odd payload
    coded = np.asarray(lcc_encode(plan, jnp.asarray(X)), dtype=np.uint64)
    assert coded.shape == (K + R,) + X.shape[1:]
    # rows 0..K-1 of the coded output are NOT the data (Lagrange points
    # differ from data points) — decode is what recovers it
    for _ in range(5):
        survivors = sorted(
            int(r) for r in rng.choice(K + R, size=K, replace=False)
        )
        got = lcc_decode(plan, coded[survivors], survivors)
        np.testing.assert_array_equal(got, X % q)


@pytest.mark.parametrize("q", [M31, NTT])
def test_lcc_compute_and_decode_with_parity_responders(q):
    """f(X_i) = X_i @ W recovered from any K responders INCLUDING parity
    hosts (indices ≥ K), over both fields."""
    K, R = 4, 3
    f = Field(q)
    rng = np.random.default_rng(3)
    plan = build_lcc(K, p=1, q=q, R=R)
    X = rng.integers(0, 1 << 20, size=(K, 5, 3), dtype=np.uint64)
    W = rng.integers(0, 1 << 20, size=(3, 2), dtype=np.uint64)
    encoded = np.asarray(lcc_encode(plan, jnp.asarray(X)), dtype=np.uint64)
    for responders in ([0, 1, 2, 3], [3, 4, 5, 6], [6, 0, 5, 2], [1, 6, 3, 5]):
        out = lcc_compute_and_decode(plan, encoded, W, responders)
        for i in range(K):
            np.testing.assert_array_equal(
                out[i], f.matmul(X[i] % q, W % q)
            )


def test_lcc_zero_size_payload_roundtrip():
    """A (K, 0) payload must encode/decode without error — the degenerate
    snapshot of an empty pytree."""
    K, R = 4, 2
    plan = build_lcc(K, R=R)
    X = np.zeros((K, 0), dtype=np.uint64)
    coded = np.asarray(lcc_encode(plan, jnp.asarray(X)))
    assert coded.shape == (K + R, 0)
    got = lcc_decode(plan, coded[:K], list(range(K)))
    assert got.shape == (K, 0)


def test_lcc_k_minus_1_survivors_raise_not_garbage():
    """K−1 responders under-determine the degree-(K−1) polynomial: decode
    must raise ValueError, never return interpolated garbage."""
    K, R = 4, 2
    plan = build_lcc(K, R=R)
    X = np.arange(K * 6, dtype=np.uint64).reshape(K, 6)
    coded = np.asarray(lcc_encode(plan, jnp.asarray(X)), dtype=np.uint64)
    with pytest.raises(ValueError, match="need ≥4 responders"):
        lcc_decode(plan, coded[: K - 1], list(range(K - 1)))
    with pytest.raises(ValueError, match="duplicate"):
        lcc_decode(plan, coded[[0, 0, 1, 2]], [0, 0, 1, 2])
    with pytest.raises(ValueError, match="outside"):
        lcc_decode(plan, coded[:K], [0, 1, 2, K + R])
    with pytest.raises(ValueError):
        build_lcc(K, R=-1)
    with pytest.raises(ValueError, match="K=4 rows"):
        lcc_pad(plan, np.zeros((K + 1, 3), np.uint64))


@given(
    K=st.sampled_from([4, 8, 16]),
    R=st.integers(1, 4),
    pay=st.integers(1, 31),
    seed=st.integers(0, 1000),
)
@settings(max_examples=12, deadline=None)
def test_lcc_erasure_roundtrip_property(K, R, pay, seed):
    q = NTT if seed % 2 else M31
    rng = np.random.default_rng(seed)
    plan = build_lcc(K, p=1, q=q, R=R)
    X = rng.integers(0, q, size=(K, pay), dtype=np.uint64)
    coded = np.asarray(lcc_encode(plan, jnp.asarray(X)), dtype=np.uint64)
    survivors = sorted(int(r) for r in rng.choice(K + R, size=K, replace=False))
    np.testing.assert_array_equal(
        lcc_decode(plan, coded[survivors], survivors), X % q
    )
