"""Per-architecture smoke tests: reduced config, one forward + one grad +
one decode step on CPU; asserts shapes and finiteness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.models import build_model, make_batch

ARCH_NAMES = list(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_grad(name):
    cfg = smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S, seed=1)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), metrics
    assert float(loss) > 0

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0

    # logits shape: text positions × padded vocab, padding masked to -inf
    logits, aux, _ = jax.jit(model.forward)(params, batch)
    text_s = batch["tokens"].shape[1]
    assert logits.shape == (B, text_s, cfg.vocab_padded)
    if cfg.vocab_padded > cfg.vocab_size:
        assert float(jnp.max(logits[..., cfg.vocab_size :])) < -1e20


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    cfg = smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, s_max = 2, 32
    cache = model.init_cache(B, s_max)
    tokens = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tokens, pos)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    # a second step at pos+1 must also be finite (state threading)
    logits2, cache = step(params, cache, tokens, pos + 1)
    assert bool(jnp.all(jnp.isfinite(logits2[..., : cfg.vocab_size])))


def test_decode_matches_forward_dense():
    """Decode path == forward path, token by token (dense arch)."""
    cfg = smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=3)
    full_logits, _, _ = jax.jit(model.forward)(params, batch)

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits_t, cache = step(
            params, cache, batch["tokens"][:, t : t + 1], jnp.full((B,), t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0, : cfg.vocab_size], dtype=np.float32),
            np.asarray(full_logits[:, t, : cfg.vocab_size], dtype=np.float32),
            rtol=0.15, atol=0.15,  # bf16 accumulation-order differences
        )


def test_decode_matches_forward_rwkv():
    cfg = smoke_config("rwkv6-3b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=4)
    full_logits, _, _ = jax.jit(model.forward)(params, batch)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits_t, cache = step(
            params, cache, batch["tokens"][:, t : t + 1], jnp.full((B,), t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0, : cfg.vocab_size], dtype=np.float32),
            np.asarray(full_logits[:, t, : cfg.vocab_size], dtype=np.float32),
            rtol=0.15, atol=0.15,
        )
