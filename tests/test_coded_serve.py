"""Coded straggler-tolerant serving (ISSUE 10): fault-injection
differential harness.

Acceptance:
* For EVERY survivor subset of size K (exhaustive at N ≤ 8 by killing
  each R-subset's complement; hypothesis-sampled above), the coded
  engine's token streams after mid-trace host kills are bit-identical to
  both the unfailed continuous run and the unfailed fixed-batch engine
  on the same seeded trace.
* An 8-forced-host-device subprocess variant SIGKILLs one real host
  process (``ProcessHostPool``) mid-decode while the guard's encode runs
  through the mesh collective (``ir_encode_jit``) — still bit-identical,
  ``serve.recoveries`` ≥ 1.
* ``tools/check_trace.py --kind coded-serve`` gates fresh and committed
  ``BENCH_coded_serve.json`` records (recoveries ≥ injected faults,
  ordered recovery percentiles, token-identity flag).
"""

import functools
import itertools
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hyputil import given, settings, st

import jax

from repro.configs import smoke_config
from repro.models import build_model
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import (
    CodedDecodeGroup,
    CodedServeGuard,
    ContinuousEngine,
    Engine,
    FaultInjector,
    ProcessHostPool,
    Request,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROMPTS = [[5, 9, 2, 7, 1], [3, 3, 8], [11, 4, 6, 2], [2]]
MAX_NEW = 6


@functools.lru_cache(maxsize=2)
def _smoke(arch: str = "qwen3-1.7b"):
    cfg = smoke_config(arch).replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


@functools.lru_cache(maxsize=2)
def _engine():
    cfg, model, params = _smoke()
    return ContinuousEngine(
        model, params, n_slots=2, max_len=32, buckets=(8, 16),
        max_new_tokens=MAX_NEW, metrics=MetricsRegistry(),
    )


def _reqs(**kw):
    return [
        Request(id=f"r{i}", prompt=p, max_new_tokens=MAX_NEW, **kw)
        for i, p in enumerate(PROMPTS)
    ]


def _toks(report) -> dict:
    return {r.id: tuple(r.tokens) for r in report.results}


@functools.lru_cache(maxsize=4)
def _baseline(greedy: bool = True, temperature: float = 1.0):
    rep = _engine().serve(
        _reqs(), greedy=greedy, sync_every=2, seed=0, temperature=temperature
    )
    return _toks(rep)


# ---------------------------------------------------------------------------
# unit: injector + guard edges
# ---------------------------------------------------------------------------


def test_fault_injector_fires_each_kill_once():
    inj = FaultInjector(kills=((2, 0), (2, 3), (9, 1)))
    assert inj.due(1) == []
    assert inj.due(4) == [(2, 0), (2, 3)]
    assert inj.due(5) == []  # already fired
    assert inj.due(100) == [(9, 1)]
    assert inj.injected == 3


def test_guard_requires_parity_and_snapshot():
    with pytest.raises(ValueError):
        CodedServeGuard(K=4, R=0)
    g = CodedServeGuard(K=3, R=1)
    with pytest.raises(RuntimeError, match="no snapshot"):
        g.recover([0])


def test_guard_beyond_tolerance_raises():
    """Losing R+1 hosts is past the code: recover must raise, not return
    interpolated garbage."""
    import jax.numpy as jnp

    g = CodedServeGuard(K=3, R=1, injector=FaultInjector(kills=((0, 0), (0, 2))))
    state = {"x": jnp.arange(6, dtype=jnp.float32)}
    g.snapshot({}, state, tick=0)
    dead = g.poll(4)
    assert dead == [0, 2]
    with pytest.raises(RuntimeError, match="need K=3"):
        g.recover(dead)


# ---------------------------------------------------------------------------
# the tentpole differential: every survivor subset, exhaustive at N ≤ 8
# ---------------------------------------------------------------------------

K, R = 3, 2  # N = 5 hosts; killing each 2-subset forces every 3-survivor set


def test_coded_serve_every_survivor_subset_bit_identical():
    """Exhaustive at N = 5 ≤ 8: for every R-subset of hosts killed
    mid-trace (⇔ every survivor subset of size K reconstructs), the coded
    engine's tokens equal the unfailed continuous AND fixed-batch runs."""
    eng = _engine()
    base = _baseline()

    # the unfailed fixed-batch engine on the same trace (greedy)
    cfg, model, params = _smoke()
    fixed = Engine(model, params, max_len=32, metrics=MetricsRegistry())
    res = fixed.generate(PROMPTS, max_new_tokens=MAX_NEW)
    fixed_toks = {
        f"r{b}": tuple(res.tokens[b, : len(PROMPTS[b]) + MAX_NEW].tolist())
        for b in range(len(PROMPTS))
    }
    assert base == fixed_toks  # continuous == fixed-batch, unfailed

    for killed in itertools.combinations(range(K + R), R):
        inj = FaultInjector(kills=tuple((1, h) for h in killed))
        guard = CodedServeGuard(K=K, R=R, injector=inj)
        rep = eng.serve(_reqs(), greedy=True, sync_every=2, guard=guard)
        assert sorted(guard.alive) == [
            h for h in range(K + R) if h not in killed
        ]
        assert _toks(rep) == base, f"tokens diverged after killing {killed}"
        assert rep.recoveries == R
        assert rep.coded["injected_faults"] == R
        assert len(guard.recovery_us) >= 1


def test_coded_serve_staggered_kills_and_metrics():
    """Kills at different ticks (two separate recovery events), metrics +
    spans recorded, requests in flight recovered not dropped."""
    eng = _engine()
    reg, tracer = MetricsRegistry(), Tracer()
    saved = eng._metrics, eng._tracer
    eng._metrics, eng._tracer = reg, tracer
    try:
        guard = CodedServeGuard(
            K=K, R=R, injector=FaultInjector(kills=((1, 0), (5, 4)))
        )
        rep = eng.serve(_reqs(), greedy=True, sync_every=2, guard=guard)
    finally:
        eng._metrics, eng._tracer = saved
    assert _toks(rep) == _baseline()
    snap = reg.snapshot()
    assert snap["serve.recoveries"]["value"] == 2
    assert snap["serve.recovery_us"]["count"] == 2
    assert snap["serve.recovery_us"]["p50"] <= snap["serve.recovery_us"]["p99"]
    assert snap["serve.snapshots"]["value"] == rep.coded["snapshots"] > 0
    assert rep.requests_recovered >= 1
    spans = [s for s in tracer.spans if s.name == "serve.recovery"]
    assert len(spans) == 2 and all(s.dur_us > 0 for s in spans)


def test_coded_serve_sampled_temperature_bit_identical():
    """temperature > 0: per-slot PRNG streams live in the encoded state, so
    the replayed chunk resamples the SAME tokens."""
    eng = _engine()
    base = _baseline(greedy=False, temperature=0.7)
    guard = CodedServeGuard(K=K, R=R, injector=FaultInjector(kills=((2, 1),)))
    rep = eng.serve(
        _reqs(), greedy=False, sync_every=2, seed=0, temperature=0.7,
        guard=guard,
    )
    assert _toks(rep) == base
    assert rep.recoveries == 1


@given(seed=st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_coded_serve_sampled_survivor_subsets_above_8(seed):
    """N = 10 > 8: hypothesis-sampled R-subsets of killed hosts (each ⇔ one
    survivor subset of size K) instead of all C(10,3) of them."""
    rng = np.random.default_rng(seed)
    Kb, Rb = 7, 3
    killed = tuple(int(h) for h in rng.choice(Kb + Rb, size=Rb, replace=False))
    eng = _engine()
    guard = CodedServeGuard(
        K=Kb, R=Rb, injector=FaultInjector(kills=tuple((1, h) for h in killed))
    )
    rep = eng.serve(_reqs(), greedy=True, sync_every=2, guard=guard)
    assert _toks(rep) == _baseline(), f"diverged for killed={killed}"
    assert rep.recoveries == Rb


# ---------------------------------------------------------------------------
# real host processes: SIGKILL mid-decode, 8 forced host devices
# ---------------------------------------------------------------------------


def test_process_host_pool_store_fetch_kill():
    with ProcessHostPool(3) as pool:
        arr = np.arange(17, dtype=np.uint32)
        assert pool.store(0, arr)
        np.testing.assert_array_equal(pool.fetch(0), arr)
        assert pool.fetch(1) is None  # nothing stored yet
        pool.kill(2)
        assert not pool.alive(2)
        assert not pool.store(2, arr)
        assert pool.fetch(2) is None


def test_coded_serve_sigkilled_host_process():
    """In-process engine + real OS host processes: the injector's kill is a
    SIGKILL; tokens still bit-identical."""
    eng = _engine()
    with ProcessHostPool(K + R) as pool:
        guard = CodedServeGuard(
            K=K, R=R, injector=FaultInjector(kills=((1, 2),)), hosts=pool
        )
        rep = eng.serve(_reqs(), greedy=True, sync_every=2, guard=guard)
        assert not pool.alive(2)  # actually dead, not simulated
        assert _toks(rep) == _baseline()
        assert rep.recoveries == 1


def test_coded_serve_mesh_8_host_devices_sigkill():
    """The satellite's subprocess variant: 8 forced host devices, the
    guard's Lagrange encode running as a mesh collective (ppermute rounds
    via ir_encode_jit on an 8-wide 'hosts' axis), one ProcessHostPool host
    SIGKILLed mid-decode — recovered, bit-identical, recoveries ≥ 1."""
    code = """
    import numpy as np, jax
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.obs.metrics import MetricsRegistry
    from repro.serve import (CodedServeGuard, ContinuousEngine, FaultInjector,
                             ProcessHostPool, Request)

    assert jax.device_count() == 8
    cfg = smoke_config("qwen3-1.7b").replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = [[5, 9, 2, 7, 1], [3, 3, 8], [11, 4, 6, 2], [2]]
    def reqs():
        return [Request(id=f"r{i}", prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
    reg = MetricsRegistry()
    eng = ContinuousEngine(model, params, n_slots=2, max_len=32,
                           buckets=(8, 16), max_new_tokens=6, metrics=reg)
    base = [r.tokens for r in eng.serve(reqs(), greedy=True, sync_every=2).results]

    mesh = make_mesh((8,), ("hosts",))  # N = K + R = 8 coded shard hosts
    with ProcessHostPool(8) as pool:
        guard = CodedServeGuard(K=6, R=2, injector=FaultInjector(kills=((1, 3),)),
                                hosts=pool, mesh=mesh, axis="hosts")
        rep = eng.serve(reqs(), greedy=True, sync_every=2, guard=guard)
        assert not pool.alive(3)          # the SIGKILL landed
        got = [r.tokens for r in rep.results]
        assert got == base, (got, base)
        assert rep.recoveries >= 1
        assert reg.snapshot()["serve.recoveries"]["value"] >= 1
    print("CODED-MESH-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "CODED-MESH-OK" in r.stdout


# ---------------------------------------------------------------------------
# decode group (host bookkeeping without an engine)
# ---------------------------------------------------------------------------


def test_decode_group_reconstructs_any_k_of_n():
    from repro.coded import build_lcc, lcc_encode, lcc_pad

    plan = build_lcc(3, R=2)
    X = np.arange(3 * 11, dtype=np.uint32).reshape(3, 11)
    coded = np.asarray(lcc_encode(plan, lcc_pad(plan, X)[: plan.K]))
    for killed in itertools.combinations(range(5), 2):
        grp = CodedDecodeGroup(plan)
        grp.store(coded.astype(np.uint32).reshape(5, -1))
        for h in killed:
            assert grp.kill(h)
            assert not grp.kill(h)  # can't die twice
        np.testing.assert_array_equal(grp.reconstruct().reshape(3, 11), X)


def test_decode_group_host_count_mismatch():
    from repro.coded import build_lcc

    plan = build_lcc(3, R=2)
    with ProcessHostPool(4) as pool:  # needs 5
        with pytest.raises(ValueError, match="need N=5"):
            CodedDecodeGroup(plan, hosts=pool)


# ---------------------------------------------------------------------------
# validator: coded-serve record kind, fresh + committed
# ---------------------------------------------------------------------------


def _coded_serve_record(**edits):
    cont = {
        "tokens_per_s": 100.0, "ttft_ms": {"p50": 1.0, "p99": 2.0},
        "e2e_ms": {"p50": 3.0, "p99": 4.0}, "n_requests": 4, "wall_s": 0.5,
        "slot_occupancy": 0.8, "prefill_compiles": 2, "decode_steps": 40,
    }
    coded_blk = {
        "K": 3, "R": 2, "n_hosts": 5, "injected_faults": 1, "recoveries": 1,
        "requests_recovered": 2, "snapshots": 9,
        "recovery_us": {"p50": 100.0, "p99": 200.0},
    }
    rec = {
        "workload": {"n_requests": 4, "rate_rps": 50.0, "seed": 0},
        "n_slots": 2,
        "buckets": [8, 16],
        "coded": {"K": 3, "R": 2, "n_hosts": 5},
        "engines": {"uncoded": dict(cont), "coded": dict(cont)},
        "fault_scenarios": [
            {"kills": 1, "tokens_identical": True, "tokens_per_s": 90.0,
             "coded": dict(coded_blk)},
        ],
    }
    for dotted, v in edits.items():
        cur = rec
        parts = dotted.split(".")
        for p in parts[:-1]:
            cur = cur[int(p)] if p.isdigit() else cur[p]
        cur[parts[-1]] = v
    return rec


def test_check_trace_coded_serve_kind():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        assert check_trace.check_coded_serve(_coded_serve_record()) == []
        # a fault went unrecovered
        bad = _coded_serve_record(**{"fault_scenarios.0.coded.recoveries": 0})
        assert check_trace.check_coded_serve(bad)
        # recovery latency percentiles out of order
        bad = _coded_serve_record(
            **{"fault_scenarios.0.coded.recovery_us": {"p50": 9.0, "p99": 2.0}}
        )
        assert check_trace.check_coded_serve(bad)
        # recoveries claimed but latency never measured
        bad = _coded_serve_record(
            **{"fault_scenarios.0.coded.recovery_us": {"p50": 0.0, "p99": 0.0}}
        )
        assert check_trace.check_coded_serve(bad)
        # token identity must hold
        bad = _coded_serve_record(**{"fault_scenarios.0.tokens_identical": False})
        assert check_trace.check_coded_serve(bad)
        # missing the recovery block entirely
        bad = _coded_serve_record()
        del bad["fault_scenarios"][0]["coded"]
        assert check_trace.check_coded_serve(bad)
    finally:
        sys.path.pop(0)


def test_check_trace_coded_serve_cli_fresh(tmp_path):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        path = tmp_path / "BENCH_coded_serve.json"
        path.write_text(json.dumps(_coded_serve_record()))
        assert check_trace.main([str(path)]) == 0  # auto-detected
        assert check_trace.main(["--kind", "coded-serve", str(path)]) == 0
    finally:
        sys.path.pop(0)


def test_committed_bench_record_gates():
    """The committed BENCH_coded_serve.json must pass the validator and
    show ≥ 1 recovery with token identity (the PR's acceptance bar)."""
    path = os.path.join(REPO, "results", "BENCH_coded_serve.json")
    assert os.path.exists(path), "results/BENCH_coded_serve.json not committed"
    with open(path) as fh:
        rec = json.load(fh)
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        assert check_trace.check_coded_serve(rec) == []
    finally:
        sys.path.pop(0)
    assert any(
        s["coded"]["recoveries"] >= 1 and s["tokens_identical"]
        for s in rec["fault_scenarios"]
    )
