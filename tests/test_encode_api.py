"""Array-level (jnp) executor tests: prepare-and-shoot / butterfly /
draw-and-loose / Lagrange, with payload dims, vs the host matrix oracle."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import a2a_encode, plan_for
from repro.core.draw_loose import (
    butterfly_apply,
    decode_dft,
    decode_draw_loose,
    encode_dft,
    encode_draw_loose,
    encode_lagrange,
)
from repro.core.field import M31, NTT, Field
from repro.core.matrices import (
    butterfly_target_matrix,
    lagrange_matrix,
    random_matrix,
    random_vector,
)
from repro.core.prepare_shoot import encode_oracle, encode_universal
from repro.core.schedule import (
    draw_loose_target_matrix,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)


def as_u32(a):
    return jnp.asarray(np.asarray(a, dtype=np.uint32))


@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("K", [2, 3, 5, 8, 9, 16, 17, 33, 64])
def test_encode_universal_runtime_A(K, p):
    f = Field(M31)
    A = random_matrix(f, K, seed=K + p)
    x = random_vector(f, K, seed=2 * K + p)
    out = encode_universal(as_u32(x), as_u32(A), p=p, q=M31)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))


@pytest.mark.parametrize("K,p", [(16, 1), (27, 2), (65, 2)])
def test_encode_universal_host_A_shoup_path(K, p):
    """Host numpy A → Shoup-precomputed constants path."""
    f = Field(M31)
    A = random_matrix(f, K, seed=1)
    x = random_vector(f, K, seed=2)
    out = encode_universal(as_u32(x), np.asarray(A), p=p, q=M31)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))


def test_encode_universal_payload_and_jit():
    K, p = 16, 1
    f = Field(M31)
    A = random_matrix(f, K, seed=3)
    x = random_vector(f, (K, 4, 8), seed=4)
    fn = jax.jit(lambda xx, aa: encode_universal(xx, aa, p=p, q=M31))
    out = fn(as_u32(x), as_u32(A))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))


@pytest.mark.parametrize("K,p,q", [(16, 1, NTT), (64, 1, NTT), (9, 2, M31), (256, 1, NTT)])
def test_butterfly_forward_inverse(K, p, q):
    f = Field(q)
    plan = plan_butterfly(K, p, q)
    x = random_vector(f, (K, 3), seed=5)
    y = encode_dft(as_u32(x), plan)
    G = butterfly_target_matrix(f, K, p + 1)
    np.testing.assert_array_equal(np.asarray(y, dtype=np.uint64), encode_oracle(x, G, q))
    back = decode_dft(y, plan)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, dtype=np.uint32))


@pytest.mark.parametrize("K,p,q", [(8, 1, NTT), (12, 1, NTT), (20, 1, NTT), (18, 2, M31), (7, 1, NTT)])
def test_draw_loose_and_decode(K, p, q):
    f = Field(q)
    plan = plan_draw_loose(K, p, q, seed=7)
    x = random_vector(f, (K, 2), seed=8)
    y = encode_draw_loose(as_u32(x), plan)
    G = draw_loose_target_matrix(plan)
    np.testing.assert_array_equal(np.asarray(y, dtype=np.uint64), encode_oracle(x, G, q))
    back = decode_draw_loose(y, plan)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, dtype=np.uint32))


@pytest.mark.parametrize("K,p,q", [(8, 1, NTT), (12, 1, NTT), (6, 1, NTT)])
def test_lagrange_executor(K, p, q):
    """Theorem 4 end-to-end: x holds f(ω'_k); output is f(α'_k); equals the
    true Lagrange matrix application (source permutations cancel)."""
    f = Field(q)
    plan_w = plan_draw_loose(K, p, q, seed=11)
    plan_a = plan_draw_loose(K, p, q, seed=22)
    x = random_vector(f, K, seed=9)
    out = encode_lagrange(as_u32(x), plan_w, plan_a)
    L = lagrange_matrix(f, plan_a.points, plan_w.points)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, L, q))


def test_a2a_encode_api_selection():
    f = Field(M31)
    K = 16
    A = random_matrix(f, K, seed=0)
    x = random_vector(f, K, seed=1)
    out, rep = a2a_encode(as_u32(x), as_u32(A), p=1)
    assert rep.algorithm == "prepare-and-shoot"
    assert rep.c1 == rep.c1_lower  # strictly optimal C1
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))

    plan = plan_for("dft", 16, p=1, q=NTT)
    fq = Field(NTT)
    xq = random_vector(fq, 16, seed=2)
    out2, rep2 = a2a_encode(as_u32(xq), plan=plan)
    assert rep2.algorithm == "butterfly" and rep2.c1 == rep2.c2 == 4

    plan3 = plan_for("vandermonde", 12, p=1, q=NTT)
    out3, rep3 = a2a_encode(as_u32(random_vector(fq, 12, seed=3)), plan=plan3)
    assert rep3.algorithm == "draw-and-loose"
    assert rep3.c2 <= rep2.c2 + 10  # sanity


@given(
    K=st.integers(2, 24),
    p=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_property_universal_random(K, p, seed):
    """Hypothesis: universality — random A, random x, random (K, p)."""
    f = Field(M31)
    A = random_matrix(f, K, seed=seed)
    x = random_vector(f, K, seed=seed + 1)
    out = encode_universal(as_u32(x), as_u32(A), p=p, q=M31)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))


@given(h=st.integers(1, 6), seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_property_butterfly_roundtrip(h, seed):
    K = 2**h
    f = Field(NTT)
    plan = plan_butterfly(K, 1, NTT)
    x = random_vector(f, K, seed=seed)
    y = butterfly_apply(as_u32(x), plan)
    back = butterfly_apply(y, plan, inverse=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, dtype=np.uint32))
