"""repro.topo host-side tests: topology routing, α-β pricing, lowering vs.
the cost-exact simulator (message-for-message), hierarchical/ring/two-level
DFT exactness, and the autotuner's topology-dependent choices.

Acceptance anchor: for every lowered schedule the predicted round count (C1)
equals the simulator's measured C1 — checked on flat, ring, and two-level
topologies (the round count is topology-independent; the topologies change
the *time*, which is also sanity-checked here).
"""

import numpy as np
import pytest

from hyputil import HAVE_HYPOTHESIS, given, settings, st
from repro.core.field import M31, NTT, Field
from repro.core.matrices import dft_matrix, random_matrix, random_vector
from repro.core.ir import CommRound, LocalOp
from repro.core.prepare_shoot import encode_oracle
from repro.core.schedule import plan_butterfly, plan_draw_loose, plan_prepare_shoot
from repro.core.simulator import (
    simulate_butterfly,
    simulate_draw_loose,
    simulate_prepare_shoot,
)
from repro.topo import (
    DCI,
    ICI,
    FullyConnected,
    Hierarchy,
    LinkCost,
    Ring,
    Torus2D,
    Torus3D,
    TwoLevel,
    autotune,
    default_level_costs,
    default_levels,
    lower,
    lower_allgather,
    make_topology,
    plan_hierarchical,
    plan_multilevel,
    plan_ring,
    plan_two_level_dft,
    schedule_time,
    simulate_hierarchical,
    simulate_multilevel,
    simulate_ring_encode,
    simulate_two_level_dft,
    two_level_dft_matrix,
)

F = Field(M31)


# ---------------------------------------------------------------------------
# topology model
# ---------------------------------------------------------------------------


def test_flat_routing_single_hop():
    t = FullyConnected(8)
    assert t.hops(0, 5) == 1 and t.hops(3, 3) == 0


def test_ring_routing_shorter_direction():
    t = Ring(8)
    assert t.hops(0, 1) == 1
    assert t.hops(0, 4) == 4
    assert t.hops(0, 5) == 3  # backwards is shorter
    assert t.route(0, 7) == (("ring", 0, 7),)


def test_torus_routing_dimension_ordered():
    t = Torus2D(4, 4)
    # (0,0) → (1,2): 2 x-hops then 1 y-hop
    assert t.hops(0, 6) == 3
    links = t.route(0, 6)
    assert [l[0] for l in links] == ["x", "x", "y"]
    # wraparound both dims
    assert t.hops(0, 15) == 2  # (0,0)→(3,3) is 1 back in each ring


def test_two_level_routing_and_costs():
    t = TwoLevel(k_intra=4, k_inter=2)
    assert t.route(0, 3) == (("intra", 0, 3),)
    assert t.route(1, 6) == (("inter", 0, 1),)
    assert t.link_cost(("intra", 0, 3)) == ICI
    assert t.link_cost(("inter", 0, 1)) == DCI


def test_hierarchy_routing_and_two_level_equivalence():
    """Hierarchy((I, G)) routes and prices exactly like TwoLevel(I, G)."""
    h = Hierarchy(levels=(4, 2), costs=(ICI, DCI))
    t = TwoLevel(k_intra=4, k_inter=2)
    assert h.n == t.n == 8
    for src in range(8):
        for dst in range(8):
            assert h.hops(src, dst) == t.hops(src, dst)
            if src != dst:
                assert h.link_cost(h.route(src, dst)[0]) == t.link_cost(
                    t.route(src, dst)[0]
                )
    low = lower(plan_hierarchical(8, 1, 4))
    assert low.time(h, 64).total == pytest.approx(low.time(t, 64).total, rel=1e-12)


def test_hierarchy_three_level_routing():
    h = Hierarchy(levels=(2, 2, 2))
    assert h.coords(5) == (1, 0, 1)
    # same chip pair → private level-0 link; sibling slices share one trunk
    assert h.route(0, 1) == (("lvl", 0, 0, 1),)
    assert h.route(0, 2)[0][:2] == ("lvl", 1)
    assert h.route(0, 2)[0] == h.route(1, 3)[0]  # all chip pairs share it
    # pod crossing uses the level-2 trunk regardless of lower coords
    assert h.route(0, 7)[0][:2] == ("lvl", 2)
    assert h.route(0, 7)[0] == h.route(3, 4)[0]
    # default per-level costs are monotone ICI → DCI
    c = default_level_costs(3)
    assert c[0] == ICI and c[-1] == DCI
    assert c[0].alpha < c[1].alpha < c[2].alpha
    assert c[0].beta < c[1].beta < c[2].beta


def test_hierarchy_validation():
    with pytest.raises(ValueError):
        Hierarchy(levels=(4, 0))
    with pytest.raises(ValueError):
        Hierarchy(levels=(4, 2), costs=(ICI,))
    with pytest.raises(ValueError):
        make_topology("hierarchy", 8, levels=(2, 2))  # Π levels ≠ K
    assert default_levels(8) == (2, 2, 2)
    assert make_topology("hierarchy", 8).levels == (2, 2, 2)
    # unsplittable remainders collapse OUTERMOST — level 0 is never trivial
    assert default_levels(4) == (2, 2, 1)
    assert default_levels(2) == (2, 1, 1)
    assert default_levels(7) == (7, 1, 1)
    assert default_levels(6) == (3, 2, 1)
    # the factory honors the intra/inter cost overrides at the endpoints
    fast = LinkCost(alpha=1e-7, beta=1e-12)
    slow = LinkCost(alpha=1e-4, beta=1e-8)
    h = make_topology("hierarchy", 8, intra=fast, inter=slow)
    assert h.level_cost(0) == fast and h.level_cost(2) == slow


def test_schedule_time_collapses_to_paper_model_on_flat():
    """On FullyConnected the α-β estimate is exactly C1·α + Σ d_t·β."""
    plan = plan_prepare_shoot(16, 1)
    low = lower(plan)
    topo = FullyConnected(16, cost=LinkCost(alpha=1e-6, beta=1e-9))
    est = low.time(topo, payload_elems=7)
    expect = low.c1 * 1e-6 + low.c2 * 7 * 1e-9
    assert est.total == pytest.approx(expect, rel=1e-12)
    assert est.max_contention == 1  # private link per pair: no contention


def test_hierarchical_gather_stays_on_fast_links():
    """The flat schedule's bulky gather phase leaks onto the slow inter-group
    trunks (its shifts ignore group boundaries); the hierarchical schedule's
    gather rounds touch intra links only — and the α-β clock rewards it."""
    topo = TwoLevel(k_intra=4, k_inter=4)
    ps = plan_prepare_shoot(16, 1)
    hp = plan_hierarchical(16, 1, k_intra=4)
    flat, hier = lower(ps), lower(hp)
    for loads in hier.link_loads(topo)[: len(hp.intra_rounds)]:
        assert all(link[0] == "intra" for link in loads)
    assert any(
        link[0] == "inter"
        for loads in flat.link_loads(topo)[: ps.Tp]
        for link in loads
    )
    assert hier.time(topo, 1024).total < flat.time(topo, 1024).total


# ---------------------------------------------------------------------------
# lowering ≡ simulation (satellite: per-round per-link utilization)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,p", [(8, 1), (8, 2), (16, 1), (27, 2), (65, 2), (5, 1), (2, 2)])
def test_lower_prepare_shoot_matches_simulator_messages(K, p):
    plan = plan_prepare_shoot(K, p)
    x = random_vector(F, K, seed=K)
    _, st = simulate_prepare_shoot(x, random_matrix(F, K, seed=K), plan, F)
    low = lower(plan)
    assert list(low.rounds) == st.round_messages
    assert low.c1 == st.C1 and low.c2 == st.C2


@pytest.mark.parametrize("K,p,q", [(8, 1, NTT), (9, 2, M31), (16, 1, NTT)])
def test_lower_butterfly_matches_simulator_messages(K, p, q):
    f = Field(q)
    plan = plan_butterfly(K, p, q)
    _, st = simulate_butterfly(random_vector(f, K, seed=1), plan, f)
    low = lower(plan)
    assert list(low.rounds) == st.round_messages
    assert low.c1 == st.C1 and low.c2 == st.C2


@pytest.mark.parametrize("K,p,q", [(8, 1, NTT), (12, 1, M31)])
def test_lower_draw_loose_c1_c2_match_simulator(K, p, q):
    """Draw-loose sub-phases are simulated per-subgroup (local indices), so
    cross-check the aggregate C1/C2 — the merged lowering must agree."""
    f = Field(q)
    plan = plan_draw_loose(K, p, q)
    _, st = simulate_draw_loose(random_vector(f, K, seed=2), plan, f)
    low = lower(plan)
    assert low.c1 == st.C1 and low.c2 == st.C2


def test_link_utilization_cross_check_on_ring():
    """Satellite check: per-round per-link loads derived from the simulator's
    round_messages equal the analytical lowering's loads, link for link."""
    from repro.topo.model import round_link_loads

    K, p = 16, 1
    plan = plan_prepare_shoot(K, p)
    x = random_vector(F, K, seed=0)
    _, st = simulate_prepare_shoot(x, random_matrix(F, K, seed=0), plan, F)
    topo = Ring(K)
    low = lower(plan)
    analytical = low.link_loads(topo)
    from_sim = [round_link_loads(topo, msgs) for msgs in st.round_messages]
    assert analytical == from_sim


# ---------------------------------------------------------------------------
# hierarchical / ring / two-level DFT exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "K,k_intra,p", [(8, 2, 1), (8, 4, 1), (12, 3, 1), (12, 4, 2), (16, 4, 1), (15, 3, 2)]
)
def test_hierarchical_simulator_exact_and_counted(K, k_intra, p):
    A = random_matrix(F, K, seed=K + k_intra)
    x = random_vector(F, K, seed=k_intra)
    plan = plan_hierarchical(K, p, k_intra)
    out, st = simulate_hierarchical(x, A, plan, F)
    np.testing.assert_array_equal(out, encode_oracle(x, A))
    assert st.C1 == plan.c1 and st.C2 == plan.c2
    low = lower(plan)
    assert list(low.rounds) == st.round_messages


@pytest.mark.parametrize("K,p", [(8, 2), (9, 2), (8, 1), (5, 3)])
def test_ring_schedule_exact(K, p):
    A = random_matrix(F, K, seed=K)
    x = random_vector(F, K, seed=1)
    plan = plan_ring(K, p)
    out, st = simulate_ring_encode(x, A, plan, F)
    np.testing.assert_array_equal(out, encode_oracle(x, A))
    assert st.C1 == plan.c1 and st.C2 == plan.c2
    assert list(lower(plan).rounds) == st.round_messages


@pytest.mark.parametrize(
    "K,k_intra,p,q", [(8, 2, 1, NTT), (8, 4, 1, NTT), (16, 4, 1, NTT), (9, 3, 2, M31)]
)
def test_two_level_dft_exact_and_permutation_of_dft(K, k_intra, p, q):
    f = Field(q)
    plan = plan_two_level_dft(K, p, q, k_intra)
    x = random_vector(f, K, seed=5)
    out, st = simulate_two_level_dft(x, plan, f)
    M = two_level_dft_matrix(plan)
    np.testing.assert_array_equal(out, encode_oracle(x, M, q))
    assert st.C1 == plan.c1 == st.C2 == plan.c2
    # M is a row/col relabeling of the true DFT matrix (still MDS)
    D = dft_matrix(f, K)
    assert sorted(map(tuple, M.tolist())) == sorted(map(tuple, D.tolist()))
    assert list(lower(plan).rounds) == st.round_messages


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([(K, d, p) for K in (8, 12, 16, 18, 20, 24) for d in range(2, K)
                        if K % d == 0 for p in (1, 2)]))
def test_hierarchical_every_factorization_matches_oracle(params):
    """Property (hyputil-guarded): EVERY K = K_intra × K_inter factorization
    is bit-exact against the matrix oracle."""
    K, k_intra, p = params
    A = random_matrix(F, K, seed=K * 31 + k_intra)
    x = random_vector(F, K, seed=p)
    plan = plan_hierarchical(K, p, k_intra)
    out, _ = simulate_hierarchical(x, A, plan, F)
    np.testing.assert_array_equal(out, encode_oracle(x, A))


# ---------------------------------------------------------------------------
# recursive multi-level exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "K,levels,p",
    [
        (8, (2, 2, 2), 1),
        (8, (2, 2, 2), 2),
        (12, (3, 2, 2), 1),
        (16, (2, 2, 2, 2), 1),
        (16, (4, 2, 2), 2),
        (24, (2, 3, 4), 1),
        (30, (5, 3, 2), 2),
    ],
)
def test_multilevel_simulator_exact_and_counted(K, levels, p):
    A = random_matrix(F, K, seed=K + levels[0])
    x = random_vector(F, K, seed=p)
    plan = plan_multilevel(K, p, levels)
    out, st = simulate_multilevel(x, A, plan, F)
    np.testing.assert_array_equal(out, encode_oracle(x, A))
    assert st.C1 == plan.c1 and st.C2 == plan.c2
    low = lower(plan)
    assert list(low.rounds) == st.round_messages


def _deep_factorizations(K, min_levels=3):
    """Ordered factorizations of K into ≥ min_levels factors, each ≥ 2."""
    out = []

    def rec(rest, acc):
        if rest == 1:
            if len(acc) >= min_levels:
                out.append(tuple(acc))
            return
        for d in range(2, rest + 1):
            if rest % d == 0:
                rec(rest // d, acc + [d])

    rec(K, [])
    return out


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([(K, lv, p) for K in (8, 12, 16)
                        for lv in _deep_factorizations(K) for p in (1, 2)]))
def test_multilevel_every_deep_factorization_matches_oracle(params):
    """Property (hyputil-guarded): EVERY factorization of K ∈ {8, 12, 16}
    into ≥ 3 levels is bit-exact against the matrix oracle, with the
    lowering matching the simulation message-for-message."""
    K, levels, p = params
    A = random_matrix(F, K, seed=K * 31 + levels[0])
    x = random_vector(F, K, seed=p)
    plan = plan_multilevel(K, p, levels)
    out, st = simulate_multilevel(x, A, plan, F)
    np.testing.assert_array_equal(out, encode_oracle(x, A))
    assert list(lower(plan).rounds) == st.round_messages


@pytest.mark.parametrize("K,I,p", [(8, 2, 1), (12, 3, 1), (16, 4, 2)])
def test_multilevel_collapses_to_two_level(K, I, p):
    """A trivial level is a no-op: the recursive plan with levels (I, G, 1)
    or (I, 1, G) lowers to the SAME rounds as the two-level plan — so its
    cost on every topology is identical."""
    from repro.topo.lower import rounds_hierarchical, rounds_multilevel

    G = K // I
    h = plan_hierarchical(K, p, I)
    ref = rounds_hierarchical(h)
    for levels in [(I, G), (I, G, 1), (I, 1, G), (I, G, 1, 1)]:
        m = plan_multilevel(K, p, levels)
        assert rounds_multilevel(m) == ref, levels
        assert m.c1 == h.c1 and m.c2 == h.c2, levels
    topo = TwoLevel(k_intra=I, k_inter=G)
    t_h = lower(h).time(topo, 32).total
    t_m = lower(plan_multilevel(K, p, (I, G, 1))).time(topo, 32).total
    assert t_m == pytest.approx(t_h, rel=1e-12)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

TOPOS = {
    "flat": FullyConnected(16),
    "ring": Ring(16),
    "two-level": TwoLevel(k_intra=4, k_inter=4),
    "hierarchy": Hierarchy(levels=(4, 2, 2)),
}


@pytest.mark.parametrize("topo_name", list(TOPOS))
def test_autotuner_c1_matches_simulator_on_every_topology(topo_name):
    """Acceptance: each candidate's predicted round count equals the
    simulator's measured C1, on flat, ring, and two-level topologies."""
    topo = TOPOS[topo_name]
    K, p, q = 16, 1, NTT
    f = Field(q)
    result = autotune(K, p, 4096, topo, q=q, generator="dft")
    A = random_matrix(f, K, seed=7)
    x = random_vector(f, K, seed=8)
    for cand in result.candidates:
        if cand.algorithm == "prepare-shoot":
            _, st = simulate_prepare_shoot(x, A, cand.plan, f)
        elif cand.algorithm == "butterfly":
            _, st = simulate_butterfly(x, cand.plan, f)
        elif cand.algorithm == "draw-loose":
            _, st = simulate_draw_loose(x, cand.plan, f)
        elif cand.algorithm == "hierarchical":
            _, st = simulate_hierarchical(x, A, cand.plan, f)
        elif cand.algorithm == "multilevel":
            _, st = simulate_multilevel(x, A, cand.plan, f)
        elif cand.algorithm == "hierarchical-dft":
            _, st = simulate_two_level_dft(x, cand.plan, f)
        elif cand.algorithm == "ring":
            _, st = simulate_ring_encode(x, A, cand.plan, f)
        elif cand.algorithm == "allgather":
            continue  # baseline foil has no message-passing simulator
        elif cand.pipeline and any(
            isinstance(s, LocalOp) and s.coeffs is None for s in cand.ir.steps
        ):
            # structure-only pipelined rewrite (e.g. +pipeline over the
            # structure-only prepare-shoot IR): it cannot be interpreted, but
            # its comm rounds must be byte-identical to its (validated) base
            # candidate's, so its C1 is the base's C1
            base = next(
                c for c in result.candidates if c.algorithm == cand.base_algorithm
            )
            assert [s for s in cand.ir.steps if isinstance(s, CommRound)] == [
                s for s in base.ir.steps if isinstance(s, CommRound)
            ], (topo_name, cand.algorithm)
            assert cand.c1 == base.c1, (topo_name, cand.algorithm)
            continue
        else:
            # algorithms born after the ScheduleIR refactor need no bespoke
            # simulator: their candidate IR interprets directly
            from repro.core.simulator import interpret

            _, st = interpret(cand.ir, x, f)
        assert cand.c1 == st.C1, (topo_name, cand.algorithm)


def test_autotuner_prefers_level_aligned_schedule_on_two_level():
    topo = TwoLevel(k_intra=4, k_inter=4)
    r = autotune(16, 1, 65536, topo, generator="general")
    # the compute-aware price may promote the pipelined rewrite of the same
    # family at 64k payloads; the winning base family is the contract
    assert r.chosen.base_algorithm == "hierarchical"
    flat = autotune(16, 1, 65536, FullyConnected(16), generator="general")
    assert flat.chosen.base_algorithm == "prepare-shoot"


def test_autotuner_prefers_multilevel_on_deep_hierarchy():
    """On a 3-level hierarchy the recursive schedule wins (its phases align
    with the levels); the plan factorization is the topology's own levels."""
    topo = Hierarchy(levels=(4, 4, 2))
    r = autotune(32, 1, 65536, topo, generator="general")
    assert r.chosen.base_algorithm == "multilevel"
    assert r.chosen.plan.levels == (4, 4, 2)
    # the multilevel candidate is NOT offered on non-hierarchy topologies
    flat = autotune(32, 1, 65536, FullyConnected(32), generator="general")
    assert all(c.algorithm != "multilevel" for c in flat.candidates)


def test_autotuner_prefers_neighbor_schedule_on_ring():
    r = autotune(16, 2, 1 << 20, Ring(16), generator="general")
    assert r.algorithm == "ring"


def test_autotuner_measured_override_hook():
    topo = FullyConnected(16)
    base = autotune(16, 1, 4096, topo, generator="general")
    assert base.algorithm != "allgather"
    forced = autotune(
        16, 1, 4096, topo, generator="general",
        measured={c.algorithm: 1.0 for c in base.candidates if c.algorithm != "allgather"},
    )
    assert forced.algorithm == "allgather"


def test_make_topology_factory():
    assert isinstance(make_topology("flat", 8), FullyConnected)
    assert isinstance(make_topology("ring", 8), Ring)
    t = make_topology("two-level", 8, k_intra=4)
    assert t.k_intra == 4 and t.k_inter == 2
    tor = make_topology("torus", 12, k_intra=3)
    assert (tor.rows, tor.cols) == (3, 4)
    t3 = make_topology("torus3d", 16, levels=(4, 2, 2))
    assert isinstance(t3, Torus3D)
    assert (t3.cols, t3.rows, t3.depth) == (4, 2, 2) and t3.n == 16
    with pytest.raises(ValueError):
        make_topology("torus3d", 16, levels=(4, 4))  # needs 3 dims
    with pytest.raises(ValueError):
        make_topology("moebius", 8)


# ---------------------------------------------------------------------------
# 3D torus + the pass-pipeline optimizer
# ---------------------------------------------------------------------------


def test_torus3d_routing_dimension_ordered():
    t = Torus3D(depth=2, rows=2, cols=4)
    assert t.n == 16
    # k = (z·rows + r)·cols + c
    assert t.coords(13) == (1, 1, 1)
    # (0,0,0) → (1,1,2): 2 x-hops (col ring of size 4), 1 y, 1 z
    dst = (1 * 2 + 1) * 4 + 2
    assert t.hops(0, dst) == 4
    assert [l[0] for l in t.route(0, dst)] == ["x", "x", "y", "z"]
    # wraparound in every dimension: (0,0,0) → (1,1,3) is 1 hop per dim
    assert t.hops(0, (1 * 2 + 1) * 4 + 3) == 3
    assert t.hops(5, 5) == 0 and t.route(5, 5) == ()
    # two messages riding the same physical ring segment share a link key
    assert t.route(0, 1)[0] == t.route(0, 2)[0]  # both start on x@(z=0,r=0) 0→1
    # different planes use different links
    assert t.route(0, 1)[0] != t.route(8, 9)[0]


# (fabric, K, p, payload bytes, topology, q, generator, expected winning
# "<base>+<pipeline>" candidate, whether it must be the GLOBAL winner)
_FABRIC_WINS = [
    (
        "ring",
        16,
        2,
        1 << 20,
        Ring(16, cost=LinkCost(1e-6, 4.0 / 50e9, gamma=0.5)),
        M31,
        "general",
        "prepare-shoot+split-contended",
        False,  # the neighbor-only ring schedule still wins globally
    ),
    ("torus2d", 16, 1, 65536, Torus2D(4, 4), NTT, "dft",
     "butterfly+remap-digits", True),
    ("torus3d", 16, 1, 65536, Torus3D(depth=2, rows=2, cols=4), NTT, "dft",
     "butterfly+remap-digits", True),
    ("hierarchy", 12, 1, 65536, Hierarchy(levels=(4, 3)), NTT, "vandermonde",
     "draw-loose+align-subgroups", True),
]


@pytest.mark.parametrize(
    "fabric,K,p,payload,topo,q,generator,winner,is_global",
    _FABRIC_WINS,
    ids=[row[0] for row in _FABRIC_WINS],
)
def test_pipeline_beats_unrewritten_ir_on_every_fabric(
    fabric, K, p, payload, topo, q, generator, winner, is_global
):
    """Acceptance: on at least one scenario per fabric (ring, 2D torus, 3D
    torus, hierarchy) a non-empty pass pipeline strictly beats the
    un-rewritten IR of the same algorithm by the α-β price."""
    r = autotune(K, p, payload, topo, q=q, generator=generator)
    cand = next(c for c in r.candidates if c.algorithm == winner)
    base = next(c for c in r.candidates if c.algorithm == cand.base_algorithm)
    assert cand.pipeline and cand.algorithm == f"{cand.base_algorithm}+{cand.pipeline}"
    assert cand.predicted_time < base.predicted_time, fabric
    if is_global:
        assert r.algorithm == winner
        assert r.chosen.pipeline == cand.pipeline


def test_autotune_candidates_carry_pipeline_fields():
    """Every candidate names its (base_algorithm, pipeline) pair; pipelined
    rewrites are extra candidates, never replacements for the base compile."""
    r = autotune(16, 1, 65536, Torus2D(4, 4), q=NTT, generator="dft")
    names = [c.algorithm for c in r.candidates]
    assert "butterfly" in names and "butterfly+remap-digits" in names
    for c in r.candidates:
        if c.pipeline:
            assert c.algorithm == f"{c.base_algorithm}+{c.pipeline}"
        else:
            assert c.algorithm == c.base_algorithm
    # pipelines=False restores the un-rewritten candidate set exactly
    off = autotune(16, 1, 65536, Torus2D(4, 4), q=NTT, generator="dft",
                   pipelines=False)
    assert [c.algorithm for c in off.candidates] == [
        c.algorithm for c in r.candidates if not c.pipeline
    ]


def test_preference_rank_tolerates_unknown_algorithm_names():
    """Regression: the tie-break historically did _PREFERENCE.index(name) and
    raised ValueError for any name outside the hardcoded tuple (e.g. a
    pipelined candidate's suffixed name reaching it, or a plugin family).
    Unknown names now sort last instead of blowing up the whole autotune."""
    from dataclasses import replace

    from repro.topo.autotune import _PREFERENCE, _preference_rank

    assert _preference_rank("butterfly") == 0
    assert _preference_rank("no-such-family") == len(_PREFERENCE)
    assert _preference_rank("butterfly+remap-digits") == len(_PREFERENCE)
    # a full tune whose candidates include an unknown base name still ranks
    base = autotune(8, 1, 4096, FullyConnected(8), generator="general")
    renamed = [
        replace(c, algorithm="plugin-" + c.algorithm,
                base_algorithm="plugin-" + c.base_algorithm)
        for c in base.candidates
    ]
    ranked = sorted(
        renamed,
        key=lambda c: (c.time, c.pipeline != "",
                       _preference_rank(c.base_algorithm or c.algorithm)),
    )
    assert len(ranked) == len(base.candidates)
