"""ScheduleIR equivalence suite: every plan family compiles to the SAME IR
pipeline, and the pipeline agrees with the matrix oracle, the closed-form
C1/C2, and the committed ppermute budgets.

* property test (hyputil): for every family and K ∈ {8, 12, 16},
  ``interpret(plan.to_ir())`` is bit-exact vs. the matrix oracle and
  ``ir_messages`` equals the interpreter's recorded ``round_messages``;
* ``fuse_trivial_rounds`` is exact and actually removes trivial structure;
* ``remap_digits`` partners are torus neighbors (hop count 1) in EVERY round
  on 2×4 / 4×2 / 4×4 tori, stays bit-exact, and the autotuner flips to the
  remapped schedule on the torus;
* ``fit_level_costs`` recovers planted per-level α/β from synthetic sweeps;
* subprocess: the remapped butterfly executes on an 8-device torus mesh via
  the generic ``ir_encode_jit`` (the CI torus-mesh step).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from hyputil import given, settings, st
from repro.core.field import M31, NTT, Field
from repro.core.ir import (
    fuse_trivial_rounds,
    ir_allgather,
    ir_messages,
    ir_permute_count,
)
from repro.core.matrices import (
    butterfly_target_matrix,
    random_matrix,
    random_vector,
)
from repro.core.prepare_shoot import encode_oracle
from repro.core.schedule import (
    draw_loose_target_matrix,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)
from repro.core.simulator import interpret
from repro.topo import (
    PIPELINES,
    Hierarchy,
    LinkCost,
    Ring,
    Torus2D,
    Torus3D,
    TwoLevel,
    autotune,
    fit_level_costs,
    fuse_rounds,
    ir_time,
    lower,
    max_round_hops,
    plan_hierarchical,
    plan_multilevel,
    plan_multilevel_dft,
    plan_ring,
    plan_two_level_dft,
    remap_digits,
    round_features,
    split_contended,
    multilevel_dft_matrix,
    two_level_dft_matrix,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
F = Field(M31)


# ---------------------------------------------------------------------------
# IR ≡ oracle ≡ closed forms, for every family (property over K ∈ {8,12,16})
# ---------------------------------------------------------------------------


def _cases():
    """(label, build() → (ir, target_matrix, q, c1, c2)) for every family."""
    cases = []
    for K in (8, 12, 16):
        for p in (1, 2):
            def mk_ps(K=K, p=p):
                plan = plan_prepare_shoot(K, p)
                A = random_matrix(F, K, seed=K * 7 + p)
                return plan.to_ir(A), A, M31, plan.c1, None  # C2 ≤ closed form
            cases.append((f"prepare-shoot-{K}-{p}", mk_ps))

            def mk_ring(K=K, p=p):
                plan = plan_ring(K, p)
                A = random_matrix(F, K, seed=K + p)
                return plan.to_ir(A), A, M31, plan.c1, plan.c2
            cases.append((f"ring-{K}-{p}", mk_ring))

            def mk_ag(K=K, p=p):
                A = random_matrix(F, K, seed=K - p)
                return ir_allgather(K, p, A), A, M31, None, None
            cases.append((f"allgather-{K}-{p}", mk_ag))

        for I in (2, 4):
            if K % I:
                continue

            def mk_h(K=K, I=I):
                plan = plan_hierarchical(K, 1, I)
                A = random_matrix(F, K, seed=K * 3 + I)
                return plan.to_ir(A), A, M31, plan.c1, plan.c2
            cases.append((f"hierarchical-{K}-{I}", mk_h))

        def mk_dl(K=K):
            plan = plan_draw_loose(K, 1, NTT, seed=1)
            return plan.to_ir(), draw_loose_target_matrix(plan), NTT, plan.c1, plan.c2
        cases.append((f"draw-loose-{K}", mk_dl))

    for K, levels in [(8, (2, 2, 2)), (12, (3, 2, 2)), (16, (2, 2, 4)), (16, (4, 2, 2))]:

        def mk_ml(K=K, levels=levels):
            plan = plan_multilevel(K, 1, levels)
            A = random_matrix(F, K, seed=K * 31 + levels[0])
            return plan.to_ir(A), A, M31, plan.c1, plan.c2
        cases.append((f"multilevel-{K}-{levels}", mk_ml))

    for K in (8, 16):

        def mk_bf(K=K):
            plan = plan_butterfly(K, 1, NTT)
            f = Field(NTT)
            return plan.to_ir(), butterfly_target_matrix(f, K, 2), NTT, plan.c1, plan.c2
        cases.append((f"butterfly-{K}", mk_bf))

        def mk_dft2(K=K):
            plan = plan_two_level_dft(K, 1, NTT, 2 if K == 8 else 4)
            return plan.to_ir(), two_level_dft_matrix(plan), NTT, plan.c1, plan.c2
        cases.append((f"two-level-dft-{K}", mk_dft2))

    for K, levels in [(8, (2, 2, 2)), (16, (4, 4)), (16, (2, 2, 2, 2)), (16, (4, 2, 2))]:

        def mk_mldft(K=K, levels=levels):
            plan = plan_multilevel_dft(K, 1, NTT, levels)
            return (
                fuse_trivial_rounds(plan.to_ir()),
                multilevel_dft_matrix(plan),
                NTT,
                plan.c1,
                plan.c2,
            )
        cases.append((f"multilevel-dft-{K}-{levels}", mk_mldft))
    return cases


_CASES = _cases()


def _check_case(idx, seed_salt=0):
    from repro.topo.lower import lower_ir

    label, build = _CASES[idx]
    ir, target, q, c1, c2 = build()
    f = Field(q)
    x = random_vector(f, ir.K, seed=len(label) + seed_salt)
    out, st_ = interpret(ir, x, f)
    np.testing.assert_array_equal(out, encode_oracle(x, target, q), err_msg=label)
    assert list(lower_ir(ir).rounds) == ir_messages(ir) == st_.round_messages, label
    assert ir.c1 == st_.C1 and ir.c2 == st_.C2, label
    if c1 is not None:
        assert st_.C1 == c1, label
    if c2 is not None:
        assert st_.C2 == c2, label


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(range(len(_CASES))), st.integers(min_value=0, max_value=7))
def test_every_family_ir_matches_oracle_and_messages(idx, seed_salt):
    """Property (hyputil): interpret(plan.to_ir()) == x @ target bit-exactly
    over random inputs, the measured C1/C2 match the plan's closed forms,
    and lower()'s rounds == ir_messages == the interpreter's recorded
    per-round message maps."""
    _check_case(idx, seed_salt)


@pytest.mark.parametrize("idx", range(len(_CASES)), ids=[l for l, _ in _CASES])
def test_every_family_ir_pipeline(idx):
    """Exhaustive non-property sweep of the same contract (runs even when
    hypothesis is unavailable)."""
    _check_case(idx)


# ---------------------------------------------------------------------------
# fuse_trivial_rounds
# ---------------------------------------------------------------------------


def test_fuse_trivial_rounds_exact_and_effective():
    """Trivial multilevel levels and all-ones DFT twiddles vanish; outputs
    are bit-identical before and after."""
    f = Field(NTT)
    plan = plan_multilevel_dft(8, 1, NTT, (2, 2, 2))
    ir = plan.to_ir()
    fused = fuse_trivial_rounds(ir)
    n_local = lambda s: sum(1 for t in s.steps if not hasattr(t, "transfers"))
    assert n_local(fused) < n_local(ir)  # the stage-0 all-ones twiddle died
    assert ir_messages(fused) == ir_messages(ir)
    x = random_vector(f, 8, seed=2)
    np.testing.assert_array_equal(interpret(ir, x, f)[0], interpret(fused, x, f)[0])

    # a trivial hierarchy level contributes zero rounds either way
    A = random_matrix(F, 12, seed=9)
    tri = plan_multilevel(12, 1, (3, 4, 1)).to_ir(A)
    ref = plan_multilevel(12, 1, (3, 4)).to_ir(A)
    assert ir_messages(fuse_trivial_rounds(tri)) == ir_messages(ref)
    x = random_vector(F, 12, seed=3)
    np.testing.assert_array_equal(
        interpret(fuse_trivial_rounds(tri), x, F)[0], interpret(ref, x, F)[0]
    )


def test_fuse_keeps_truncating_identity_and_empty_rounds_are_loud():
    """A LocalOp replaces the buffer, so an 'identity' op whose out_slots
    don't cover every live slot is a truncation, not a no-op — fuse must
    keep it. And an empty CommRound is a loud error (the §I model never
    schedules one), not a silent skip, in both ir_messages and interpret."""
    from repro.core.ir import CommRound, LocalOp, ScheduleIR, Transfer

    K = 2
    gather = CommRound(
        tuple(
            Transfer(k, (k + 1) % K, port=1, slots=((0, 1),), mode="store")
            for k in range(K)
        )
    )
    eye = np.broadcast_to(np.eye(1, dtype=np.uint64), (K, 1, 1)).copy()
    truncate = LocalOp((0,), (0,), eye)  # identity on slot 0 — but slot 1 is live
    ship1 = CommRound(
        tuple(
            Transfer(k, (k + 1) % K, port=1, slots=((1, 0),), mode="store")
            for k in range(K)
        )
    )
    ir = ScheduleIR("synthetic", K, 1, (gather, truncate, ship1))
    fused = fuse_trivial_rounds(ir)
    assert len(fused.steps) == 3  # the truncating identity survived
    x = random_vector(F, K, seed=1)
    np.testing.assert_array_equal(interpret(ir, x, F)[0], interpret(fused, x, F)[0])

    empty = ScheduleIR("synthetic", K, 1, (gather, CommRound(()), ship1))
    with pytest.raises(ValueError, match="empty communication round"):
        ir_messages(empty)
    with pytest.raises(ValueError, match="empty communication round"):
        interpret(empty, x, F)
    assert len(fuse_trivial_rounds(empty).steps) == 2  # fuse removes it


# ---------------------------------------------------------------------------
# remap_digits: torus-native butterfly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,cols", [(2, 4), (4, 2), (4, 4)])
def test_remap_digits_hop_count_1_and_exact(rows, cols):
    """Acceptance: every round's partners are torus neighbors after the
    pass (the plain butterfly is multi-hop), and the relabeled schedule
    stays bit-exact with unchanged C1/C2."""
    K = rows * cols
    topo = Torus2D(rows, cols)
    plan = plan_butterfly(K, 1, NTT)
    ir = plan.to_ir()
    assert max_round_hops(ir, topo) > 1
    rir = remap_digits(ir, topo)
    assert max_round_hops(rir, topo) == 1
    f = Field(NTT)
    x = random_vector(f, K, seed=K)
    out, st_ = interpret(rir, x, f)
    np.testing.assert_array_equal(
        out, encode_oracle(x, butterfly_target_matrix(f, K, 2), NTT)
    )
    assert st_.C1 == plan.H and st_.C2 == plan.H
    assert ir_permute_count(rir) == ir_permute_count(ir)


def test_autotune_flips_to_remapped_butterfly_on_torus():
    """Acceptance: on the 2D torus the remap-digits pipeline's rewrite
    prices cheaper (contention 1, single-hop) and the tuner picks the
    (butterfly, remap-digits) candidate; on flat topologies no remap
    candidate is even offered (the pipeline's predicate rejects)."""
    r = autotune(16, 1, 65536, Torus2D(4, 4), q=NTT, generator="dft")
    assert r.algorithm == "butterfly+remap-digits"
    chosen = r.chosen
    assert chosen.base_algorithm == "butterfly"
    assert chosen.pipeline == "remap-digits"
    assert chosen.estimate.max_contention == 1
    plain = next(c for c in r.candidates if c.algorithm == "butterfly")
    assert chosen.predicted_time < plain.predicted_time
    from repro.topo import FullyConnected

    flat = autotune(16, 1, 65536, FullyConnected(16), q=NTT, generator="dft")
    assert all(c.pipeline != "remap-digits" for c in flat.candidates)


def test_autotuner_offers_multilevel_dft_on_hierarchy():
    """The first post-IR algorithm participates with no bespoke simulator /
    lowering / executor: it appears, prices, and can win on a deep
    hierarchy with a DFT generator."""
    topo = Hierarchy(levels=(4, 2, 2))
    r = autotune(16, 1, 65536, topo, q=NTT, generator="dft")
    names = [c.algorithm for c in r.candidates]
    assert "multilevel-dft" in names
    cand = next(c for c in r.candidates if c.algorithm == "multilevel-dft")
    assert cand.c1 == cand.c2 == 4  # log2 16, per-level stages
    # structured beats the universal multilevel on the same topology
    uni = next(c for c in r.candidates if c.algorithm == "multilevel")
    assert cand.predicted_time < uni.predicted_time


# ---------------------------------------------------------------------------
# pass pipelines: exactness + ppermute budget, over every family × fabric
# ---------------------------------------------------------------------------

#: a contended ring whose LinkCost γ > 0 — the only regime in which
#: split_contended can strictly win (γ = 0 makes the per-link max subadditive)
_GAMMA_RING = lambda K: Ring(K, cost=LinkCost(1e-6, 4.0 / 50e9, gamma=0.5))


def _pipeline_topos(K):
    """Per-K fabrics to exercise every pass predicate: contended ring
    (split/fuse), tori (remap), two-level + hierarchy (align)."""
    topos = [_GAMMA_RING(K)]
    if K == 8:
        topos += [Torus2D(2, 4), Torus3D(depth=2, rows=2, cols=2),
                  TwoLevel(k_intra=4, k_inter=2), Hierarchy(levels=(2, 2, 2))]
    elif K == 12:
        topos += [TwoLevel(k_intra=4, k_inter=3), Hierarchy(levels=(4, 3))]
    elif K == 16:
        topos += [Torus2D(4, 4), Torus3D(depth=2, rows=2, cols=4),
                  TwoLevel(k_intra=4, k_inter=4), Hierarchy(levels=(4, 2, 2))]
    return topos


@pytest.mark.parametrize("idx", range(len(_CASES)), ids=[l for l, _ in _CASES])
def test_every_pipeline_stays_exact_and_within_ppermute_budget(idx):
    """Property (ISSUE acceptance): every registered PassPipeline, applied to
    every family's compiled IR at K ∈ {8, 12, 16} on every fabric where its
    predicate passes, stays bit-exact vs. the matrix oracle and never exceeds
    the original IR's ppermute budget."""
    label, build = _CASES[idx]
    ir, target, q, _, _ = build()
    f = Field(q)
    x = random_vector(f, ir.K, seed=idx)
    want = encode_oracle(x, target, q)
    budget = ir_permute_count(ir)
    applied = 0
    for topo in _pipeline_topos(ir.K):
        for pl in PIPELINES.values():
            if not pl.applicable(ir, topo):
                continue
            rewritten = pl.apply(ir, topo)
            applied += 1
            ctx = f"{label} × {pl.name} × {topo.name}"
            np.testing.assert_array_equal(
                interpret(rewritten, x, f)[0], want, err_msg=ctx
            )
            assert ir_permute_count(rewritten) <= budget, ctx
            if rewritten is not ir and pl.name != "remap-digits":
                # price-guarded passes never regress the α-β price
                # (remap minimizes HOPS; the autotuner prices it separately)
                assert ir_time(rewritten, topo) <= ir_time(ir, topo) * (
                    1 + 1e-9
                ), ctx
    assert applied > 0, f"no pipeline applicable anywhere for {label}"


def test_split_contended_strictly_improves_on_contended_ring():
    """ISSUE acceptance: on a ring whose links degrade under contention
    (γ > 0) the staggered schedule strictly beats the original α-β price,
    preserving the ppermute count and bit-exactness."""
    K, p = 16, 2
    topo = _GAMMA_RING(K)
    plan = plan_prepare_shoot(K, p)
    A = random_matrix(F, K, seed=3)
    ir = plan.to_ir(A)
    pay = (1 << 20) // 4
    split = split_contended(ir, topo, pay)
    assert split is not ir
    assert ir_time(split, topo, pay) < ir_time(ir, topo, pay)
    assert split.c1 > ir.c1  # staggering costs rounds, wins time
    assert ir_permute_count(split) == ir_permute_count(ir)
    x = random_vector(F, K, seed=4)
    np.testing.assert_array_equal(
        interpret(split, x, F)[0], encode_oracle(x, A, M31)
    )
    # γ = 0 additive model: the identical call is a provable no-op
    assert split_contended(ir, Ring(K), pay) is ir


def test_fuse_rounds_merges_legal_neighbors_and_repacks_split():
    """fuse_rounds merges adjacent hazard-free rounds within the p-port
    budget (synthetic IR: 2 rounds → 1, bit-identical), and re-packs
    split_contended's staggering back to the original round count when the
    pricing topology doesn't charge for contention."""
    from repro.core.ir import CommRound, ScheduleIR, Transfer

    K, p = 4, 2
    a = CommRound(tuple(
        Transfer(k, (k + 1) % K, port=1, slots=((0, 1),), mode="store")
        for k in range(K)
    ))
    b = CommRound(tuple(
        Transfer(k, (k + 2) % K, port=1, slots=((0, 2),), mode="store")
        for k in range(K)
    ))
    ir = ScheduleIR("synthetic", K, p, (a, b))
    fused = fuse_rounds(ir, Ring(K))
    assert fused.c1 == 1 and ir.c1 == 2
    assert ir_permute_count(fused) == ir_permute_count(ir)  # 2 port groups
    x = random_vector(F, K, seed=7)
    np.testing.assert_array_equal(interpret(fused, x, F)[0], interpret(ir, x, F)[0])
    # p=1 would blow the port budget: the merge must be refused
    assert fuse_rounds(ScheduleIR("synthetic", K, 1, (a, b)), Ring(K)).c1 == 2

    topo = _GAMMA_RING(16)
    base = plan_prepare_shoot(16, 2).to_ir(random_matrix(F, 16, seed=5))
    split = split_contended(base, topo, 1 << 18)
    assert split.c1 > base.c1
    repacked = fuse_rounds(split, Ring(16), 1 << 18)  # γ = 0: merging is free
    assert repacked.c1 == base.c1


def test_remap_digits_torus3d_hop_count_1_and_exact():
    """Torus3D: the 3D Gray embedding makes every butterfly partner a torus
    neighbor for all-2/4 dims, bit-exactly, with unchanged budgets."""
    f = Field(NTT)
    for depth, rows, cols in [(2, 2, 2), (2, 2, 4)]:
        K = depth * rows * cols
        topo = Torus3D(depth=depth, rows=rows, cols=cols)
        plan = plan_butterfly(K, 1, NTT)
        ir = plan.to_ir()
        if (depth, rows, cols) != (2, 2, 2):
            # (all-size-2 dims are already neighbor-complete; 2×2×4 is not)
            assert max_round_hops(ir, topo) > 1
        rir = remap_digits(ir, topo)
        assert max_round_hops(rir, topo) == 1, (depth, rows, cols)
        x = random_vector(f, K, seed=K)
        np.testing.assert_array_equal(
            interpret(rir, x, f)[0],
            encode_oracle(x, butterfly_target_matrix(f, K, 2), NTT),
        )
        assert ir_permute_count(rir) == ir_permute_count(ir)


def test_remap_digits_radix_reexpression_on_binary_torus():
    """A radix-4 butterfly (p = 3) has no radix-4 digits on a 2×8 torus; the
    pass re-expresses its digits in binary (radix 4 is a 2-power) and still
    finds a low-dilation embedding — exact, budget preserved."""
    from repro.topo.passes import _remap_radix

    f = Field(NTT)
    K, p = 16, 3
    topo = Torus2D(2, 8)
    plan = plan_butterfly(K, p, NTT)
    ir = plan.to_ir()
    assert _remap_radix(ir, topo) == (2, 4)
    rir = remap_digits(ir, topo)
    assert rir is not ir
    assert max_round_hops(rir, topo) < max_round_hops(ir, topo)
    x = random_vector(f, K, seed=11)
    np.testing.assert_array_equal(
        interpret(rir, x, f)[0],
        encode_oracle(x, butterfly_target_matrix(f, K, p + 1), NTT),
    )
    assert ir_permute_count(rir) == ir_permute_count(ir)


def test_remap_digits_greedy_fallback_warns_and_stays_exact():
    """Satellite: forcing the assignment search over its exhaustive limit
    takes the greedy-swap fallback, which WARNS (never silently truncates —
    the historical H > 12 behavior) and still returns an exact relabeling."""
    f = Field(NTT)
    K = 16
    topo = Torus2D(4, 4)
    plan = plan_butterfly(K, 1, NTT)
    ir = plan.to_ir()
    with pytest.warns(RuntimeWarning, match="greedy swap"):
        rir = remap_digits(ir, topo, exhaustive_limit=1)
    assert max_round_hops(rir, topo) == 1  # greedy finds the Gray embedding
    x = random_vector(f, K, seed=13)
    np.testing.assert_array_equal(
        interpret(rir, x, f)[0],
        encode_oracle(x, butterfly_target_matrix(f, K, 2), NTT),
    )


# ---------------------------------------------------------------------------
# calibration fit
# ---------------------------------------------------------------------------


def test_fit_level_costs_recovers_planted_alpha_beta():
    """Synthetic sweep: price schedules with a KNOWN per-level α/β, feed the
    exact walls to the fitter, recover the constants."""
    from repro.topo import LinkCost

    topo = Hierarchy(levels=(2, 2, 2))
    true = (
        LinkCost(1e-6, 1e-10),
        LinkCost(3e-6, 8e-10),
        LinkCost(1e-5, 8e-9),
    )
    schedules = {
        "prepare-shoot": lower(plan_prepare_shoot(8, 1)).rounds,
        "hierarchical": lower(plan_hierarchical(8, 1, 2)).rounds,
        "multilevel": lower(plan_multilevel(8, 1, (2, 2, 2))).rounds,
        "ring": lower(plan_ring(8, 1)).rounds,
    }
    samples = []
    for rounds in schedules.values():
        feats = round_features(rounds, topo)
        for pay in (1 << 10, 1 << 14, 1 << 18):
            wall = sum(
                r["msgs"] * true[r["level"]].alpha
                + r["elems"] * pay * true[r["level"]].beta
                for r in feats
            )
            samples.append({"payload_elems": pay, "wall_s": wall, "rounds": feats})
    fitted = fit_level_costs(samples, n_levels=3)
    for got, want in zip(fitted, true):
        assert got.alpha == pytest.approx(want.alpha, rel=1e-6)
        assert got.beta == pytest.approx(want.beta, rel=1e-6)
    with pytest.raises(ValueError):
        fit_level_costs(samples[:2], n_levels=3)


def test_bench_topology_calibration_block_roundtrips():
    """If the benchmark has produced results/BENCH_topology.json with a
    calibration block, the samples feed fit_level_costs directly."""
    import json

    path = os.path.join(REPO, "results", "BENCH_topology.json")
    if not os.path.exists(path):
        pytest.skip("benchmark results not present")
    rec = json.load(open(path))
    if "calibration" not in rec:
        pytest.skip("old-format benchmark results")
    fitted = fit_level_costs(rec["calibration"]["samples"], n_levels=3)
    assert len(fitted) == 3 and all(c.alpha > 0 and c.beta > 0 for c in fitted)


# ---------------------------------------------------------------------------
# generic executor on a torus mesh (subprocess; the CI torus-mesh step)
# ---------------------------------------------------------------------------


def test_remapped_butterfly_on_torus_mesh():
    """8 forced host devices as a 2×4 (y × x) torus mesh: the Gray-remapped
    butterfly IR runs through the generic ir_encode_jit, is bit-exact vs.
    the butterfly target matrix under the placement permutation, and lowers
    to collective-permutes only with the committed H·p budget."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import NTT, Field
        from repro.core.matrices import butterfly_target_matrix, random_vector
        from repro.core.prepare_shoot import encode_oracle
        from repro.core.schedule import plan_butterfly
        from repro.topo import Torus2D, max_round_hops, remap_digits
        from repro.dist.collectives import ir_encode_jit

        f = Field(NTT)
        K = 8
        topo = Torus2D(2, 4)
        plan = plan_butterfly(K, 1, NTT)
        rir = remap_digits(plan.to_ir(), topo)
        assert max_round_hops(rir, topo) == 1
        mesh = make_mesh((2, 4), ("y", "x"))
        fn = ir_encode_jit(mesh, ("y", "x"), rir, q=NTT)
        x = random_vector(f, (K, 16), seed=5)
        place = np.asarray(rir.placement)
        inv = np.empty(K, np.int64); inv[place] = np.arange(K)
        out_dev = np.asarray(
            fn(jnp.asarray(x[inv].astype(np.uint32))), dtype=np.uint64)
        out = out_dev[place]
        G = butterfly_target_matrix(f, K, 2)
        np.testing.assert_array_equal(out, encode_oracle(x, G, NTT))
        jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((K, 4), jnp.uint32))
        assert str(jaxpr).count("ppermute") == plan.H * 1
        txt = fn.lower(jax.ShapeDtypeStruct((K, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0 and "all-gather" not in txt
        print("torus remap exec ok")
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "torus remap exec ok" in r.stdout


def test_remapped_butterfly_on_torus3d_mesh():
    """8 forced host devices as a 2×2×2 (z × y × x) 3D torus mesh: the
    3D-embedded butterfly IR runs through the generic ir_encode_jit,
    bit-exact under the placement permutation, collective-permutes only
    (the CI 3D-torus-mesh step)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import NTT, Field
        from repro.core.matrices import butterfly_target_matrix, random_vector
        from repro.core.prepare_shoot import encode_oracle
        from repro.core.schedule import plan_butterfly
        from repro.topo import Torus3D, max_round_hops, remap_digits
        from repro.dist.collectives import ir_encode_jit

        f = Field(NTT)
        K = 8
        topo = Torus3D(depth=2, rows=2, cols=2)
        plan = plan_butterfly(K, 1, NTT)
        rir = remap_digits(plan.to_ir(), topo)
        assert max_round_hops(rir, topo) == 1
        mesh = make_mesh((2, 2, 2), ("z", "y", "x"))
        fn = ir_encode_jit(mesh, ("z", "y", "x"), rir, q=NTT)
        x = random_vector(f, (K, 16), seed=6)
        place = np.asarray(rir.placement if rir.placement is not None
                           else np.arange(K))
        inv = np.empty(K, np.int64); inv[place] = np.arange(K)
        out_dev = np.asarray(
            fn(jnp.asarray(x[inv].astype(np.uint32))), dtype=np.uint64)
        out = out_dev[place]
        G = butterfly_target_matrix(f, K, 2)
        np.testing.assert_array_equal(out, encode_oracle(x, G, NTT))
        jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((K, 4), jnp.uint32))
        assert str(jaxpr).count("ppermute") == plan.H * 1
        txt = fn.lower(jax.ShapeDtypeStruct((K, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0 and "all-gather" not in txt
        print("torus3d remap exec ok")
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "torus3d remap exec ok" in r.stdout


def test_ir_permute_counts_match_committed_budgets():
    """Host-side: the IR's port-group count equals the legacy committed
    budgets for the canonical configs (equality, not just ≤)."""
    from repro.dist.collectives import (
        expected_hier_permute_count,
        expected_multilevel_permute_count,
        expected_permute_count,
    )

    for K, p in [(8, 1), (8, 2), (16, 1), (27, 2), (64, 3)]:
        plan = plan_prepare_shoot(K, p)
        assert ir_permute_count(plan.to_ir()) == expected_permute_count(plan)
    for K, I, p in [(8, 2, 1), (8, 4, 2), (12, 3, 1), (16, 4, 2)]:
        plan = plan_hierarchical(K, p, I)
        assert ir_permute_count(plan.to_ir()) == expected_hier_permute_count(plan)
    for K, levels, p in [(8, (2, 2, 2), 1), (12, (3, 2, 2), 1), (24, (2, 3, 4), 2)]:
        plan = plan_multilevel(K, p, levels)
        assert ir_permute_count(plan.to_ir()) == expected_multilevel_permute_count(plan)
    for K, p in [(8, 1), (9, 2), (16, 1)]:
        q = NTT if p == 1 else M31
        plan = plan_butterfly(K, p, q)
        assert ir_permute_count(plan.to_ir()) == plan.H * p
