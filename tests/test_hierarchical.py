"""hierarchical_encode_jit on a 2D (inter × intra) mesh and
multilevel_encode_jit on a 3D (pod × slice × chip) mesh of 8 host devices.

Subprocess-isolated like tests/test_distributed.py (the XLA device-count
override must not leak). Acceptance: on 4×2 and 2×2×2 meshes the level-
aligned collectives are bit-exact vs. the single-program prepare_shoot
oracle for Vandermonde and DFT generators, and they lower to
collective-permutes only with exactly the plans' committed ppermute budgets.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_hierarchical_encode_bitexact_vandermonde_and_dft():
    """4×2 and 2×4 meshes, p ∈ {1, 2}, Vandermonde (M31) + DFT (NTT) + a
    random matrix — all bit-exact vs. the matrix oracle and vs. the flat
    single-axis ps_encode_jit on the same inputs."""
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, NTT, Field
        from repro.core.matrices import (
            dft_matrix, distinct_points, random_matrix, random_vector, vandermonde)
        from repro.core.prepare_shoot import encode_oracle
        from repro.dist.collectives import hierarchical_encode_jit, ps_encode_jit

        K = 8
        for (G, I) in [(4, 2), (2, 4)]:
            mesh = make_mesh((G, I), ("inter", "intra"))
            for q in (M31, NTT):
                f = Field(q)
                gens = {
                    "random": random_matrix(f, K, seed=0),
                    "vandermonde": vandermonde(f, distinct_points(f, K, seed=1)),
                }
                if (q - 1) % K == 0:
                    gens["dft"] = dft_matrix(f, K)
                x = random_vector(f, (K, 16), seed=2)
                for p in (1, 2):
                    for name, A in gens.items():
                        fn, plan = hierarchical_encode_jit(
                            mesh, "inter", "intra", np.asarray(A), p=p, q=q)
                        out = fn(jnp.asarray(x.astype(np.uint32)))
                        np.testing.assert_array_equal(
                            np.asarray(out, dtype=np.uint64), encode_oracle(x, A, q))
        # same packets through the flat single-axis oracle executor
        mesh1 = make_mesh((8,), ("enc",))
        mesh2 = make_mesh((4, 2), ("inter", "intra"))
        f = Field(M31)
        A = np.asarray(vandermonde(f, distinct_points(f, K, seed=3)))
        x = random_vector(f, (K, 8), seed=4)
        f1, _ = ps_encode_jit(mesh1, "enc", A, p=1)
        f2, _ = hierarchical_encode_jit(mesh2, "inter", "intra", A, p=1)
        xs = jnp.asarray(x.astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(f1(xs)), np.asarray(f2(xs)))
        print("OK")
        """
    )


def test_hierarchical_lowers_to_permutes_only():
    """jaxpr: exactly the committed ppermute budget; compiled HLO: at least
    one collective-permute and no all-gather (mirrors ps_encode_jit's
    communication-discipline assertion)."""
    out = run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix
        from repro.dist.collectives import (
            expected_hier_permute_count, hierarchical_encode_jit)

        f = Field(M31)
        A = np.asarray(random_matrix(f, 8, seed=0))
        mesh = make_mesh((4, 2), ("inter", "intra"))
        for p in (1, 2):
            fn, plan = hierarchical_encode_jit(mesh, "inter", "intra", A, p=p)
            jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8, 4), jnp.uint32))
            n = str(jaxpr).count("ppermute")
            assert n == expected_hier_permute_count(plan), (p, n)
        fn, plan = hierarchical_encode_jit(mesh, "inter", "intra", A, p=1)
        txt = fn.lower(jax.ShapeDtypeStruct((8, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0
        assert "all-gather" not in txt, "hierarchical encode must not all-gather"
        print("collective-permutes ok")
        """
    )
    assert "collective-permutes ok" in out


def test_multilevel_encode_bitexact_on_2x2x2():
    """2×2×2 pod×slice×chip mesh, p ∈ {1, 2}, Vandermonde + DFT + random —
    the recursive three-level collective is bit-exact vs. the matrix oracle
    and vs. the flat single-axis ps_encode_jit on the same inputs."""
    run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, NTT, Field
        from repro.core.matrices import (
            dft_matrix, distinct_points, random_matrix, random_vector, vandermonde)
        from repro.core.prepare_shoot import encode_oracle
        from repro.dist.collectives import multilevel_encode_jit, ps_encode_jit

        K = 8
        mesh = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        axes = ("pod", "slice", "chip")
        for q in (M31, NTT):
            f = Field(q)
            gens = {
                "random": random_matrix(f, K, seed=0),
                "vandermonde": vandermonde(f, distinct_points(f, K, seed=1)),
            }
            if (q - 1) % K == 0:
                gens["dft"] = dft_matrix(f, K)
            x = random_vector(f, (K, 16), seed=2)
            for p in (1, 2):
                for name, A in gens.items():
                    fn, plan = multilevel_encode_jit(mesh, axes, np.asarray(A), p=p, q=q)
                    out = fn(jnp.asarray(x.astype(np.uint32)))
                    np.testing.assert_array_equal(
                        np.asarray(out, dtype=np.uint64), encode_oracle(x, A, q))
        # same packets through the flat single-axis oracle executor
        mesh1 = make_mesh((8,), ("enc",))
        f = Field(M31)
        A = np.asarray(vandermonde(f, distinct_points(f, K, seed=3)))
        x = random_vector(f, (K, 8), seed=4)
        f1, _ = ps_encode_jit(mesh1, "enc", A, p=1)
        f3, _ = multilevel_encode_jit(mesh, axes, A, p=1)
        xs = jnp.asarray(x.astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(f1(xs)), np.asarray(f3(xs)))
        print("OK")
        """
    )


def test_multilevel_lowers_to_permutes_only_2x2x2():
    """Acceptance: on the 2×2×2 mesh the jaxpr has exactly the committed
    ppermute budget and the compiled HLO is collective-permute-only (no
    all-gather) — including through the coded-checkpoint dispatch."""
    out = run_child(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.field import M31, Field
        from repro.core.matrices import random_matrix
        from repro.coded.rs_checkpoint import build_parity_plan, encode_parity_collective
        from repro.dist.collectives import (
            expected_multilevel_permute_count, multilevel_encode_jit)

        f = Field(M31)
        A = np.asarray(random_matrix(f, 8, seed=0))
        mesh = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
        axes = ("pod", "slice", "chip")
        for p in (1, 2):
            fn, plan = multilevel_encode_jit(mesh, axes, A, p=p)
            jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8, 4), jnp.uint32))
            n = str(jaxpr).count("ppermute")
            assert n == expected_multilevel_permute_count(plan), (p, n)
        fn, plan = multilevel_encode_jit(mesh, axes, A, p=1)
        txt = fn.lower(jax.ShapeDtypeStruct((8, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0
        assert "all-gather" not in txt, "multilevel encode must not all-gather"
        # coded-checkpoint dispatch: a tuple of DP axes routes to the
        # multilevel executor with the same ppermute-only discipline
        pplan = build_parity_plan(8, p=1)
        fn_c = encode_parity_collective(mesh, axes, pplan)
        txt = fn_c.lower(jax.ShapeDtypeStruct((8, 16), jnp.uint32)).compile().as_text()
        assert txt.count("collective-permute") > 0 and "all-gather" not in txt
        print("collective-permutes ok")
        """
    )
    assert "collective-permutes ok" in out


def test_multilevel_permute_budget_host_side():
    """The committed multilevel budget matches the lowered schedule's
    per-round sender out-degree — no devices needed."""
    from repro.dist.collectives import expected_multilevel_permute_count
    from repro.topo import lower, plan_multilevel

    for K, levels, p in [
        (8, (2, 2, 2), 1),
        (8, (2, 2, 2), 2),
        (12, (3, 2, 2), 1),
        (16, (2, 2, 2, 2), 1),
        (24, (2, 3, 4), 2),
    ]:
        plan = plan_multilevel(K, p, levels)
        low = lower(plan)
        ports = 0
        for msgs in low.rounds:
            out_deg: dict[int, int] = {}
            for (src, _dst) in msgs:
                out_deg[src] = out_deg.get(src, 0) + 1
            ports += max(out_deg.values())
        assert expected_multilevel_permute_count(plan) == ports, (K, levels, p)


def test_hier_permute_budget_host_side():
    """The committed budget matches the lowered schedule's non-empty
    (round, port) structure — no devices needed."""
    from repro.dist.collectives import expected_hier_permute_count
    from repro.topo import lower, plan_hierarchical

    for K, I, p in [(8, 2, 1), (8, 4, 2), (12, 3, 1), (16, 4, 2)]:
        plan = plan_hierarchical(K, p, I)
        low = lower(plan)
        # one ppermute per port per round = each sender's out-degree
        ports = 0
        for msgs in low.rounds:
            out_deg: dict[int, int] = {}
            for (src, _dst) in msgs:
                out_deg[src] = out_deg.get(src, 0) + 1
            ports += max(out_deg.values())
        assert expected_hier_permute_count(plan) == ports, (K, I, p)
