"""Cost-model validation: jaxpr walker exactness, collective parsing, and
analytic param counts vs PUBLIC model sizes (catches config drift)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get
from repro.launch.dryrun import parse_collectives
from repro.launch.jaxpr_cost import cost_of_fn
from repro.launch.roofline import param_counts


def test_jaxpr_cost_scan_trip_counts():
    def body(c, x):
        return c @ x, ()

    def f(c, xs):
        out, _ = jax.lax.scan(body, c, xs)
        return out

    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    xs = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    cost = cost_of_fn(f, c, xs)
    assert cost.flops == pytest.approx(10 * 2 * 64**3, rel=1e-6)


def test_jaxpr_cost_nested_scan():
    def f(c, xs):
        def outer(c, x):
            def inner(c2, x2):
                return c2 @ x2, ()
            o, _ = jax.lax.scan(inner, c, xs)
            return o, ()
        out, _ = jax.lax.scan(outer, c, xs)
        return out

    c = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    cost = cost_of_fn(f, c, xs)
    assert cost.flops == pytest.approx(25 * 2 * 32**3, rel=1e-6)


def test_jaxpr_cost_counts_grad_and_remat():
    def layer(w, x):
        return jnp.tanh(x @ w)

    def loss(w, x):
        return jax.checkpoint(layer)(w, x).sum()

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    base = cost_of_fn(loss, w, x).flops
    g = cost_of_fn(jax.grad(loss), w, x).flops
    assert g >= 2.5 * base  # fwd + recompute + 2 bwd matmuls


def test_parse_collectives():
    hlo = """
  %ag = bf16[256,1024]{1,0} all-gather(%x), replica_groups={...}
  %ar.1 = f32[128]{0} all-reduce(%y), to_apply=%sum
  %cp = u32[16,16]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ags = bf16[64]{0} all-gather-start(%w)
  %agd = bf16[64]{0} all-gather-done(%ags)
"""
    got = parse_collectives(hlo)
    assert got["all-gather"]["count"] == 2
    assert got["all-gather"]["bytes"] == 256 * 1024 * 2 + 64 * 2
    assert got["all-reduce"]["bytes"] == 128 * 4
    assert got["collective-permute"]["bytes"] == 16 * 16 * 4


# public sizes: (total_B, active_B, rel_tol)
PUBLIC_SIZES = {
    "qwen1.5-32b": (32.5e9, 32.5e9, 0.12),
    "deepseek-coder-33b": (33.3e9, 33.3e9, 0.05),
    "qwen3-1.7b": (1.72e9, 1.72e9, 0.05),
    "internlm2-20b": (19.9e9, 19.9e9, 0.05),
    "arctic-480b": (480e9, 17e9, 0.12),
    "deepseek-v3-671b": (671e9, 37e9, 0.05),
    "rwkv6-3b": (3.0e9, 3.0e9, 0.08),
    "jamba-v0.1-52b": (52e9, 12e9, 0.05),
    "internvl2-26b": (20e9, 20e9, 0.05),  # LLM backbone only (ViT stubbed)
    "whisper-base": (74e6, 74e6, 0.45),  # + vocab padding & cross-attn acct
}


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_counts_match_public(name):
    pc = param_counts(get(name))
    tot, act, tol = PUBLIC_SIZES[name]
    assert pc["total"] == pytest.approx(tot, rel=tol), pc
    assert pc["active"] == pytest.approx(act, rel=tol), pc
