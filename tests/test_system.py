"""End-to-end system behaviour: train → node failure → coded recovery →
training continues IDENTICALLY to an uninterrupted run (bit-exact state
restore); plus disk checkpoint restart equivalence."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model
from repro.train import (
    CodedStateGuard,
    OptConfig,
    SyntheticLM,
    init_state,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def _setup():
    cfg = smoke_config("qwen3-1.7b").replace(n_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    ostate = init_state(ocfg, params)
    step_fn = jax.jit(make_train_step(model, ocfg))
    ds = SyntheticLM(cfg)
    return cfg, model, params, ostate, step_fn, ds


def _run(step_fn, ds, params, ostate, steps, start=0):
    for s in range(start, start + steps):
        b = ds.batch(s, 2, 16)
        params, ostate, m = step_fn(
            params, ostate, {k: jnp.asarray(v) for k, v in b.items()}
        )
    return params, ostate, m


def test_coded_recovery_resumes_identically():
    cfg, model, params, ostate, step_fn, ds = _setup()
    K = 8

    # uninterrupted reference: 6 steps
    p_ref, o_ref, _ = _run(step_fn, ds, params, ostate, 6)

    # guarded run: snapshot at step 3, lose 3 of 8 replicas, recover, resume
    p, o, _ = _run(step_fn, ds, params, ostate, 3)
    guard = CodedStateGuard(K=K)
    guard.snapshot({"params": p, "opt": o}, step=3)
    recovered, at_step = guard.fail_and_recover(lost=[1, 4, 6])
    assert at_step == 3
    # bit-exact state recovery
    for a, b in zip(jax.tree.leaves(recovered["params"]), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p2, o2, _ = _run(step_fn, ds, recovered["params"], recovered["opt"], 3, start=3)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_disk_restart_resumes_identically(tmp_path):
    cfg, model, params, ostate, step_fn, ds = _setup()
    p_ref, o_ref, _ = _run(step_fn, ds, params, ostate, 6)

    p, o, _ = _run(step_fn, ds, params, ostate, 3)
    save_checkpoint(str(tmp_path / "c"), {"params": p, "opt": o}, step=3)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), {"params": p, "opt": o}
    )
    restored, step = restore_checkpoint(str(tmp_path / "c"), like)
    p2, o2, _ = _run(step_fn, ds, restored["params"], restored["opt"], 3, start=3)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
