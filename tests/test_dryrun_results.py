"""Deliverable integrity: the committed dry-run artifacts cover all 40
assigned (arch × shape) cells on BOTH production meshes, with by-design
skips only where the brief allows them, and trip-count-aware costs present.

(The artifacts are produced by `python -m repro.launch.dryrun --all
--both-meshes` + `python -m repro.launch.costpass --both-meshes`; these
tests read them — they do not recompile.)"""

import glob
import json
import os

import pytest

from repro.configs import SHAPES, get, shape_applicable
from repro.configs.registry import all_arch_names

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results", "dryrun")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(OUT), reason="run repro.launch.dryrun first"
)

MESHES = ["pod16x16", "pod2x16x16"]


def _load(arch, shape, mesh):
    p = os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")
    assert os.path.exists(p), f"missing dry-run cell {p}"
    return json.load(open(p))


@pytest.mark.parametrize("mesh", MESHES)
def test_all_40_cells_present_and_consistent(mesh):
    n_ok = n_skip = 0
    for arch in all_arch_names():
        for shape in SHAPES:
            rec = _load(arch, shape, mesh)
            ok, reason = shape_applicable(get(arch), SHAPES[shape])
            if ok:
                assert rec["status"] == "ok", (arch, shape, mesh, rec.get("error"))
                n_ok += 1
            else:
                assert rec["status"] == "skipped", (arch, shape, mesh)
                n_skip += 1
    assert n_ok == 32 and n_skip == 8


@pytest.mark.parametrize("mesh", MESHES)
def test_compiled_cells_have_costs_and_collectives(mesh):
    for p in glob.glob(os.path.join(OUT, f"*{mesh}.json")):
        rec = json.load(open(p))
        if rec.get("status") != "ok":
            continue
        assert rec["cost"]["flops_per_device"] > 0, p
        assert "jaxpr_cost" in rec and rec["jaxpr_cost"]["flops_global"] > 0, p
        assert "tile_bytes_global" in rec["jaxpr_cost"], p
        assert "collective_bytes_per_device_corrected" in rec, p
        assert rec["memory"]["argument_bytes"] > 0, p


def test_long_500k_runs_only_for_sub_quadratic():
    for arch in all_arch_names():
        rec = _load(arch, "long_500k", "pod16x16")
        if arch in ("rwkv6-3b", "jamba-v0.1-52b"):
            assert rec["status"] == "ok"
        else:
            assert rec["status"] == "skipped"


def test_jaxpr_flops_match_xla_order_of_magnitude():
    """jaxpr flops ≥ XLA-counted flops (XLA undercounts scans), within 1e4×."""
    for arch in ("qwen3-1.7b", "deepseek-coder-33b"):
        rec = _load(arch, "train_4k", "pod16x16")
        xla_global = rec["cost"]["flops_per_device"] * rec["n_chips"]
        assert rec["jaxpr_cost"]["flops_global"] >= 0.8 * xla_global
