"""Optional-hypothesis shim for the test suite.

``hypothesis`` is an optional test dependency (see pyproject's ``test``
extra). When it is installed, this module re-exports the real ``given`` /
``settings`` / ``st``. When it is missing, property-based tests degrade to
individual skips — NOT a module-level collection error — so the rest of each
module's tests still run.

Usage (instead of ``from hypothesis import given, settings, strategies as st``)::

    from hyputil import given, settings, st
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to per-test skips
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement: hypothesis would have supplied the
            # arguments, so the original signature must not leak to pytest
            # (it would try to resolve them as fixtures).
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Builds inert placeholders for strategy expressions evaluated at
        decoration time (st.integers(...), st.lists(...), .map(...), ...)."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
