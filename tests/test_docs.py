"""Docs hygiene (CI satellite): internal links in docs/*.md and README.md
resolve to real files, and every public ``topo``/``dist`` symbol a doc names
actually exists — stale docs fail the build, not the reader.
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = [
    os.path.join(REPO, "README.md"),
    *sorted(
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md")
    ),
]

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
# dotted references to repro.topo / repro.dist API inside code spans, e.g.
# `topo/autotune.py`, `dist.collectives.multilevel_encode_jit`,
# `launch.profiles.resolve_profile`
SYMBOL_RE = re.compile(
    # serve/models/train require the explicit ``repro.`` prefix: bare
    # ``serve.xxx`` in docs is usually a METRIC series name, not a symbol
    r"`(?:(?:repro\.)?(topo|dist|launch|coded|core|obs)"
    r"|repro\.(serve|models|train))\.([A-Za-z_][\w.]*)(?:\([^`]*\))?`",
    re.DOTALL,
)


def test_docs_exist_and_are_linked_from_readme():
    assert os.path.exists(os.path.join(REPO, "docs", "ARCHITECTURE.md"))
    assert os.path.exists(os.path.join(REPO, "docs", "TOPOLOGY.md"))
    assert os.path.exists(os.path.join(REPO, "docs", "OBSERVABILITY.md"))
    assert os.path.exists(os.path.join(REPO, "docs", "SERVING.md"))
    readme = open(os.path.join(REPO, "README.md")).read()
    assert "docs/ARCHITECTURE.md" in readme and "docs/TOPOLOGY.md" in readme
    assert "docs/OBSERVABILITY.md" in readme
    assert "docs/SERVING.md" in readme


@pytest.mark.parametrize("path", DOCS, ids=[os.path.relpath(p, REPO) for p in DOCS])
def test_internal_links_resolve(path):
    text = open(path).read()
    base = os.path.dirname(path)
    bad = []
    for target in LINK_RE.findall(text):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue  # external / intra-page
        rel = target.split("#", 1)[0]
        if not (
            os.path.exists(os.path.join(base, rel))
            or os.path.exists(os.path.join(REPO, rel))
        ):
            bad.append(target)
    assert not bad, f"{os.path.relpath(path, REPO)}: broken links {bad}"


def _resolve(modname: str, dotted: str) -> bool:
    """True iff ``repro.<modname>.<dotted>`` names a real module/attr chain."""
    import importlib

    parts = dotted.split(".")
    try:
        obj = importlib.import_module(f"repro.{modname}")
    except ImportError:
        return False
    for i, part in enumerate(parts):
        if hasattr(obj, part):
            obj = getattr(obj, part)
            continue
        try:
            obj = importlib.import_module(
                f"repro.{modname}." + ".".join(parts[: i + 1])
            )
        except ImportError:
            return False
    return True


@pytest.mark.parametrize("path", DOCS, ids=[os.path.relpath(p, REPO) for p in DOCS])
def test_documented_symbols_exist(path):
    text = open(path).read()
    bad = []
    for legacy, prefixed, dotted in SYMBOL_RE.findall(text):
        modname = legacy or prefixed
        if not _resolve(modname, dotted):
            bad.append(f"{modname}.{dotted}")
    assert not bad, f"{os.path.relpath(path, REPO)}: unknown symbols {bad}"


def test_public_topo_and_dist_api_is_documented():
    """The load-bearing public surface must appear somewhere in the docs —
    new exports come with docs, or this list is updated consciously."""
    all_docs = "\n".join(open(p).read() for p in DOCS)
    for name in [
        "autotune",
        "make_topology",
        "Hierarchy",
        "TwoLevel",
        "lower",
        "plan_hierarchical",
        "plan_multilevel",
        "simulate_multilevel",
        "ps_encode_jit",
        "hierarchical_encode_jit",
        "multilevel_encode_jit",
        "resolve_profile",
        # the ScheduleIR pipeline (PR 4)
        "ScheduleIR",
        "to_ir",
        "interpret",
        "ir_encode_jit",
        "fuse_trivial_rounds",
        "remap_digits",
        "fit_level_costs",
        "plan_multilevel_dft",
        # the pass-pipeline optimizer + calibrated pricing (PR 6)
        "PassPipeline",
        "pipelines_for",
        "split_contended",
        "fuse_rounds",
        "align_subgroups",
        "load_fitted_costs",
        "generator_kind_for",
        "Torus3D",
        # the observability layer (PR 7)
        "Tracer",
        "write_chrome_trace",
        "read_spans",
        "MetricsRegistry",
        "get_registry",
        "feed_calibration",
        "fitted_costs_from_trace",
        "render_drift",
        "drift_rows",
        # fused kernels + pipelined rounds (PR 8)
        "pipeline_rounds",
        "ir_compute_time",
        "local_op_unit_work",
        "MAC_SECONDS",
        "KERNEL_MODES",
        "gf_matmul",
        "butterfly_mac",
        # the continuous-batching serving tier (PR 9)
        "ContinuousEngine",
        "SlotScheduler",
        "ServeReport",
        "Request",
        "poisson_trace",
        "bucket_for",
        "prefill_into_cache",
        "supports_prefill",
        "make_prefill_step",
        "LengthBand",
        # coded straggler-tolerant serving (PR 10)
        "CodedServeGuard",
        "CodedDecodeGroup",
        "FaultInjector",
        "ProcessHostPool",
        "build_lcc",
        "lcc_encode_collective",
        "lcc_decode",
        "shard_state_limbs",
    ]:
        assert name in all_docs, f"public symbol {name} not mentioned in docs"
