"""Training substrate: optimizer descends, data pipeline deterministic,
checkpoint roundtrip (incl. bf16), serving engine generates."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model, make_batch
from repro.serve import Engine
from repro.train import (
    OptConfig,
    Prefetcher,
    SyntheticLM,
    init_state,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def test_train_loss_decreases():
    cfg = smoke_config("qwen3-1.7b").replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ocfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60, weight_decay=0.0)
    opt_state = init_state(ocfg, params)
    step = jax.jit(make_train_step(model, ocfg))
    ds = SyntheticLM(cfg)
    losses = []
    for s in range(30):
        b = ds.batch(s % 4, 4, 32)
        params, opt_state, metrics = step(
            params, opt_state, {k: jnp.asarray(v) for k, v in b.items()}
        )
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    cfg = smoke_config("deepseek-coder-33b").replace(n_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s1 = jax.jit(make_train_step(model, ocfg, accum=1))
    s2 = jax.jit(make_train_step(model, ocfg, accum=2))
    batch = make_batch(cfg, 4, 16, seed=5)
    o1 = s1(params, init_state(ocfg, params), batch)
    o2 = s2(params, init_state(ocfg, params), batch)
    # same data, same update (up to accum-order float assoc.)
    l1 = jax.tree.leaves(o1[0])
    l2 = jax.tree.leaves(o2[0])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2
        )


def test_data_determinism():
    cfg = smoke_config("qwen3-1.7b")
    ds = SyntheticLM(cfg)
    b1 = ds.batch(7, 4, 32)
    b2 = ds.batch(7, 4, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(8, 4, 32)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    pf = Prefetcher(ds, 4, 32, start_step=0, depth=2)
    s0, bb = pf.next()
    assert s0 == 0 and bb["tokens"].shape == (4, 32)
    pf.close()


def test_checkpoint_roundtrip_bf16(tmp_path):
    cfg = smoke_config("rwkv6-3b").replace(n_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    ocfg = OptConfig(moment_dtype="bfloat16")
    state = {"params": params, "opt": init_state(ocfg, params)}
    save_checkpoint(str(tmp_path / "ckpt"), state, step=42)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = restore_checkpoint(str(tmp_path / "ckpt"), like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_engine_generates():
    cfg = smoke_config("qwen3-1.7b").replace(n_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    eng = Engine(model, params, max_len=24)
    res = eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=6)
    assert res.tokens.shape == (2, 9)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()
    # prompts preserved
    assert list(res.tokens[0, :3]) == [1, 2, 3]
    assert list(res.tokens[1, :2]) == [4, 5]
    # greedy is deterministic
    res2 = eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=6)
    np.testing.assert_array_equal(res.tokens, res2.tokens)
