"""Continuous-batching serving tier (ISSUE 9).

Acceptance:
* ``prefill_into_cache`` writes the prompt's K/V bit-exactly equal to the
  per-token refeed it replaces on every layer's prompt-region rows (the
  padded bucket tail is never attended — decode overwrites a position
  before reading it), leaving every other slot's cache row untouched.
* Greedy decode of N staggered requests through the slot scheduler is
  token-identical to the same prompts run one-at-a-time through the
  compiled prefill+decode path — dense and MLA+MoE variants, plus an
  8-forced-host-device (2×4 data×model) mesh variant in a subprocess.
* The fixed-batch ``Engine`` reports generated-tokens-only throughput and
  per-sequence EOS-trimmed ``lengths``.
* ``tools/check_trace.py --kind serve`` gates the harness record's schema
  and semantic invariants (p50 ≤ p99, occupancy ∈ [0, 1], compile bound).
"""

import functools
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model
from repro.obs.metrics import MetricsRegistry
from repro.serve import (
    ContinuousEngine,
    DEFAULT_BUCKETS,
    Engine,
    LengthBand,
    Request,
    SlotScheduler,
    bucket_for,
    poisson_trace,
)
from repro.train.train_loop import make_decode_step, make_prefill_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROMPTS = [[5, 9, 2, 7, 1], [3, 3, 8], [11, 4, 6, 2, 9, 10, 1], [2], [7, 5, 5, 5, 1, 2]]


@functools.lru_cache(maxsize=4)
def _smoke(arch: str):
    cfg = smoke_config(arch).replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _one_at_a_time(model, params, prompts, max_new, buckets, max_len):
    """Reference: each prompt alone through the compiled prefill graph +
    B=1 decode steps (greedy). The engine must reproduce this exactly."""
    pf = jax.jit(make_prefill_step(model, into_cache=True))
    dec = jax.jit(make_decode_step(model))
    V = model.cfg.vocab_size
    out = []
    for p in prompts:
        b = bucket_for(len(p), buckets)
        cache = model.init_cache(1, max_len)
        tb = np.zeros((1, b), np.int32)
        tb[0, : len(p)] = p
        last, cache = pf(params, cache, jnp.asarray(tb), jnp.int32(0), jnp.int32(len(p)))
        toks = [int(jnp.argmax(last[0, :V]))]
        pos = len(p)
        for _ in range(max_new - 1):
            lg, cache = dec(
                params, cache,
                jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32),
            )
            toks.append(int(jnp.argmax(lg[0, 0, :V])))
            pos += 1
        out.append(list(p) + toks)
    return out


# ---------------------------------------------------------------------------
# scheduler + traffic units
# ---------------------------------------------------------------------------


def test_bucket_for_rounds_up_and_bounds():
    assert bucket_for(1) == 32 and bucket_for(32) == 32
    assert bucket_for(33) == 64 and bucket_for(129) == 256
    assert bucket_for(5, (8, 16)) == 8
    with pytest.raises(ValueError):
        bucket_for(300, DEFAULT_BUCKETS)
    with pytest.raises(ValueError):
        bucket_for(0)


def test_scheduler_fifo_arrival_gating_and_refill():
    s = SlotScheduler(2)
    for i, arr in enumerate([0.0, 0.0, 0.0, 5.0]):
        s.submit(Request(id=f"r{i}", prompt=[1], arrival_s=arr))
    a = s.next_assignment(now_s=0.0)
    b = s.next_assignment(now_s=0.0)
    assert a is not None and b is not None
    assert a[1].id == "r0" and a[0] == 0
    assert b[1].id == "r1" and b[0] == 1
    # pool full: r2 waits even though it has arrived
    assert s.next_assignment(now_s=0.0) is None
    assert s.pending == 2 and s.occupied == [0, 1] and s.has_work
    # retiring slot 0 lets r2 in — mid-decode refill, FIFO order
    assert s.retire(0).id == "r0"
    c = s.next_assignment(now_s=0.0)
    assert c is not None and c[0] == 0 and c[1].id == "r2"
    # r3 hasn't arrived yet at t=0, but is assignable at t=5
    s.retire(1)
    assert s.next_assignment(now_s=0.0) is None
    assert s.next_arrival_s() == 5.0
    d = s.next_assignment(now_s=5.0)
    assert d is not None and d[1].id == "r3"
    s.retire(d[0])
    s.retire(0)
    assert not s.has_work and s.free == [0, 1]


def test_poisson_trace_seeded_and_mixed():
    mix = (LengthBand(2, 4, 0.5), LengthBand(5, 9, 0.5))
    a = poisson_trace(32, 100.0, mix=mix, max_new_tokens=8, seed=3)
    b = poisson_trace(32, 100.0, mix=mix, max_new_tokens=8, seed=3)
    assert [(r.prompt, r.arrival_s, r.max_new_tokens) for r in a] == [
        (r.prompt, r.arrival_s, r.max_new_tokens) for r in b
    ]
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[0] > 0
    for r in a:
        assert 2 <= len(r.prompt) <= 9
        assert 4 <= r.max_new_tokens <= 8
    # both bands actually drawn from
    assert {len(r.prompt) <= 4 for r in a} == {True, False}
    c = poisson_trace(32, 100.0, mix=mix, max_new_tokens=8, seed=4)
    assert [r.prompt for r in a] != [r.prompt for r in c]


# ---------------------------------------------------------------------------
# prefill graph correctness
# ---------------------------------------------------------------------------


def test_prefill_into_cache_bitexact_vs_refeed():
    """One-pass prefill writes byte-identical prompt-region K/V to the
    per-token refeed it replaces, into the right slot, touching nothing
    else. (Bucket-tail rows beyond plen are scratch: decode overwrites a
    position before ever attending it.)"""
    cfg, model, params = _smoke("qwen3-1.7b")
    B, smax, bucket = 3, 32, 8
    prompt = [5, 9, 2, 7, 1]
    plen = len(prompt)

    step = jax.jit(make_decode_step(model))
    cache_refeed = model.init_cache(B, smax)
    for t in range(plen):
        toks = np.zeros((B,), np.int32)
        toks[1] = prompt[t]
        logits_r, cache_refeed = step(
            params, cache_refeed, jnp.asarray(toks)[:, None],
            jnp.full((B,), t, jnp.int32),
        )

    pf = jax.jit(make_prefill_step(model, into_cache=True))
    cache_init = model.init_cache(B, smax)
    tb = np.zeros((1, bucket), np.int32)
    tb[0, :plen] = prompt
    last, cache_pf = pf(
        params, cache_init, jnp.asarray(tb), jnp.int32(1), jnp.int32(plen)
    )

    ra, rb = jax.tree.flatten(cache_refeed)[0], jax.tree.flatten(cache_pf)[0]
    ri = jax.tree.flatten(model.init_cache(B, smax))[0]
    for leaf_r, leaf_p, leaf_0 in zip(ra, rb, ri):
        r, p, z = (np.asarray(x) for x in (leaf_r, leaf_p, leaf_0))
        # layout (R, B, Smax, ...): prompt region of slot 1 bit-exact
        np.testing.assert_array_equal(r[:, 1, :plen], p[:, 1, :plen])
        # every other slot untouched (still the init value)
        np.testing.assert_array_equal(p[:, 0], z[:, 0])
        np.testing.assert_array_equal(p[:, 2], z[:, 2])
    # same first-token distribution argmax as the refeed's last step
    V = cfg.vocab_size
    assert int(jnp.argmax(last[0, :V])) == int(jnp.argmax(logits_r[1, 0, :V]))


def test_prefill_unsupported_kinds_fall_back():
    cfg, model, params = _smoke("rwkv6-3b")
    assert not model.supports_prefill
    with pytest.raises(NotImplementedError):
        ContinuousEngine(model, params, n_slots=2, max_len=32)
    with pytest.raises(NotImplementedError):
        model.prefill_into_cache(params, model.init_cache(1, 8), jnp.zeros((1, 8), jnp.int32), 0)


# ---------------------------------------------------------------------------
# continuous batching == one-at-a-time (the tentpole invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v3-671b"])
def test_continuous_matches_one_at_a_time(arch):
    """N staggered requests through 2 slots (forcing mid-decode refills)
    produce token-for-token what each prompt produces alone through the
    compiled prefill+decode path. n_slots ≤ 4 keeps the smoke MoE
    capacity floor above any possible expert load, so routing drops can't
    make the batched run diverge."""
    cfg, model, params = _smoke(arch)
    max_new, buckets, max_len = 6, (8, 16), 32
    reqs = [
        Request(id=f"r{i}", prompt=p, max_new_tokens=max_new)
        for i, p in enumerate(PROMPTS)
    ]
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=max_len, buckets=buckets,
        max_new_tokens=8, metrics=MetricsRegistry(),
    )
    rep = eng.serve(reqs, greedy=True, sync_every=2)
    want = _one_at_a_time(model, params, PROMPTS, max_new, buckets, max_len)
    got = [r.tokens for r in rep.results]
    assert got == want
    assert rep.prefill_compiles <= len(buckets)
    assert all(r.gen_len == max_new for r in rep.results)
    assert all(r.ttft_s >= 0 and r.e2e_s >= r.ttft_s for r in rep.results)


def test_sampled_decoding_batch_invariant():
    """ISSUE 10 fix: sampled decoding (temperature > 0) draws token i of a
    request from fold_in(request_key, i) — a per-slot stream independent
    of batch composition — so slot-scheduled output is token-identical to
    the same prompts served one at a time, like greedy already was."""
    cfg, model, params = _smoke("qwen3-1.7b")
    reqs = [
        Request(id=f"r{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(PROMPTS)
    ]
    batched = ContinuousEngine(
        model, params, n_slots=3, max_len=32, buckets=(8, 16),
        max_new_tokens=8, metrics=MetricsRegistry(),
    )
    solo = ContinuousEngine(
        model, params, n_slots=1, max_len=32, buckets=(8, 16),
        max_new_tokens=8, metrics=MetricsRegistry(),
    )
    rep = batched.serve(reqs, greedy=False, seed=3, temperature=0.8, sync_every=2)
    got = {r.id: r.tokens for r in rep.results}
    for req in reqs:
        one = solo.serve([req], greedy=False, seed=3, temperature=0.8)
        assert got[req.id] == one.results[0].tokens, req.id
    # an explicit per-request seed overrides the id-derived stream
    seeded = [
        Request(id=f"s{i}", prompt=p, max_new_tokens=5, seed=77)
        for i, p in enumerate(PROMPTS[:2])
    ]
    rep2 = batched.serve(seeded, greedy=False, seed=3, temperature=0.8)
    same_prompt = [
        Request(id="other-id", prompt=PROMPTS[0], max_new_tokens=5, seed=77)
    ]
    rep3 = solo.serve(same_prompt, greedy=False, seed=3, temperature=0.8)
    assert rep2.results[0].tokens == rep3.results[0].tokens
    # temperature must be positive when sampling
    with pytest.raises(ValueError, match="temperature"):
        batched.serve(reqs, greedy=False, temperature=0.0)


def test_continuous_eos_trims_generation():
    cfg, model, params = _smoke("qwen3-1.7b")
    buckets, max_len, max_new = (8,), 24, 6
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(PROMPTS[:3])]
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=max_len, buckets=buckets,
        max_new_tokens=8, metrics=MetricsRegistry(),
    )
    free = eng.serve(reqs, greedy=True, sync_every=2)
    # pick a token request 0 actually generates as EOS and re-serve: the
    # sequence must stop at its FIRST occurrence (EOS token included),
    # others unchanged unless they emit it too
    r0 = free.results[0]
    gen0 = r0.tokens[r0.prompt_len :]
    eos = gen0[2]
    first = gen0.index(eos)
    rep = eng.serve(reqs, greedy=True, eos_id=eos, sync_every=2)
    t0 = rep.results[0]
    assert t0.gen_len == first + 1
    assert t0.tokens == r0.tokens[: r0.prompt_len + first + 1]
    for a, b in zip(rep.results, free.results):
        cut = a.prompt_len + a.gen_len
        assert a.tokens == b.tokens[:cut]
        assert a.gen_len == max_new or a.tokens[-1] == eos


def test_continuous_mesh_8_host_devices():
    """The 2×4 (data×model) forced-host mesh variant: same staggered trace,
    same tokens as the no-mesh reference."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_mesh
    from repro.launch.profiles import BASELINE, rules_for
    from repro.models import build_model
    from repro.obs.metrics import MetricsRegistry
    from repro.serve import ContinuousEngine, Request

    assert jax.device_count() == 8
    cfg = smoke_config("qwen3-1.7b").replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = [[5, 9, 2, 7, 1], [3, 3, 8], [11, 4, 6, 2, 9, 10, 1], [2],
               [7, 5, 5, 5, 1, 2]]
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]

    mesh = make_mesh((2, 4), ("data", "model"))
    rules = rules_for(cfg, ShapeSpec("serve-test", "decode", 32, 4), BASELINE)
    meshed = ContinuousEngine(
        model, params, n_slots=4, max_len=32, buckets=(8, 16),
        max_new_tokens=8, mesh=mesh, rules=rules, metrics=MetricsRegistry())
    plain = ContinuousEngine(
        model, params, n_slots=2, max_len=32, buckets=(8, 16),
        max_new_tokens=8, metrics=MetricsRegistry())
    got = [r.tokens for r in meshed.serve(reqs, greedy=True, sync_every=2).results]
    want = [r.tokens for r in plain.serve(reqs, greedy=True, sync_every=3).results]
    assert got == want, (got, want)
    print("MESH-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"child failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "MESH-OK" in r.stdout


# ---------------------------------------------------------------------------
# fixed-batch engine satellites
# ---------------------------------------------------------------------------


def test_engine_lengths_and_generated_only_throughput():
    cfg, model, params = _smoke("qwen3-1.7b")
    reg = MetricsRegistry()
    eng = Engine(model, params, max_len=24, metrics=reg)
    res = eng.generate(PROMPTS[:3], max_new_tokens=4)
    plens = np.array([len(p) for p in PROMPTS[:3]])
    np.testing.assert_array_equal(res.prompt_lens, plens)
    np.testing.assert_array_equal(res.lengths, plens + 4)
    # throughput counts generated tokens only, not prompt-refeed steps
    snap = reg.snapshot()
    wall_s = snap["serve.generate_ms"]["value"] / 1e3
    tps = snap["serve.tokens_per_s"]["value"]
    assert tps == pytest.approx(12 / wall_s, rel=1e-6)
    assert tps < res.steps * len(PROMPTS[:3]) / wall_s  # old formula inflated


def test_engine_lengths_eos_trimmed():
    cfg, model, params = _smoke("qwen3-1.7b")
    eng = Engine(model, params, max_len=24, metrics=MetricsRegistry())
    free = eng.generate(PROMPTS[:2], max_new_tokens=5)
    p0 = len(PROMPTS[0])
    gen0 = free.tokens[0, p0 : p0 + 5].tolist()
    eos = gen0[1]  # a token seq 0 actually generates
    first = gen0.index(eos)
    reg = MetricsRegistry()
    eng2 = Engine(model, params, max_len=24, metrics=reg)
    res = eng2.generate(PROMPTS[:2], max_new_tokens=5, eos_id=eos,
                        eos_check_every=100)
    # trimmed at the first EOS occurrence, the EOS token itself counted
    assert res.lengths[0] == p0 + first + 1
    for b in range(2):
        assert res.lengths[b] <= len(PROMPTS[b]) + 5
    gen_total = int((res.lengths - res.prompt_lens).sum())
    snap = reg.snapshot()
    wall_s = snap["serve.generate_ms"]["value"] / 1e3
    assert snap["serve.tokens_per_s"]["value"] == pytest.approx(
        gen_total / wall_s, rel=1e-6
    )


# ---------------------------------------------------------------------------
# observability + harness record gating
# ---------------------------------------------------------------------------


def test_continuous_metrics_and_report():
    cfg, model, params = _smoke("qwen3-1.7b")
    reg = MetricsRegistry()
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32, buckets=(8, 16),
        max_new_tokens=8, metrics=reg,
    )
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=4)
            for i, p in enumerate(PROMPTS)]
    rep = eng.serve(reqs, greedy=True, sync_every=2)
    snap = reg.snapshot()
    assert snap["serve.prefill_compiles"]["value"] == rep.prefill_compiles
    assert rep.prefill_compiles <= 2
    assert snap["serve.decode_steps"]["value"] == rep.decode_steps
    assert snap["serve.ttft_ms"]["count"] == len(reqs)
    assert snap["serve.e2e_ms"]["count"] == len(reqs)
    assert 0.0 <= rep.slot_occupancy <= 1.0
    assert rep.tokens_per_s > 0
    rec = rep.to_record()
    assert rec["ttft_ms"]["p50"] <= rec["ttft_ms"]["p99"]
    # re-serving reuses the compiled graphs: no new prefill compiles
    eng.serve(reqs, greedy=True, sync_every=2)
    assert eng.prefill_compiles == rep.prefill_compiles


def _serve_record(**edits):
    eng = {
        "tokens_per_s": 100.0, "ttft_ms": {"p50": 1.0, "p99": 2.0},
        "e2e_ms": {"p50": 3.0, "p99": 4.0}, "n_requests": 4, "wall_s": 0.5,
    }
    rec = {
        "workload": {"n_requests": 4, "rate_rps": 50.0, "seed": 0},
        "n_slots": 2,
        "buckets": [8, 16],
        "engines": {
            "fixed_batch": dict(eng),
            "continuous": {
                **eng, "slot_occupancy": 0.8, "prefill_compiles": 2,
                "decode_steps": 40,
            },
        },
    }
    for dotted, v in edits.items():
        cur = rec
        parts = dotted.split(".")
        for p in parts[:-1]:
            cur = cur[p]
        cur[parts[-1]] = v
    return rec


def test_check_trace_serve_kind():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        assert check_trace.check_serve(_serve_record()) == []
        # p50 > p99
        bad = _serve_record(**{"engines.continuous.ttft_ms": {"p50": 9.0, "p99": 2.0}})
        assert check_trace.check_serve(bad)
        # occupancy outside [0, 1]
        bad = _serve_record(**{"engines.continuous.slot_occupancy": 1.5})
        assert check_trace.check_serve(bad)
        # unbounded recompiles
        bad = _serve_record(**{"engines.continuous.prefill_compiles": 3})
        assert check_trace.check_serve(bad)
        # missing engine row
        bad = _serve_record()
        del bad["engines"]["fixed_batch"]
        assert check_trace.check_serve(bad)
    finally:
        sys.path.pop(0)


def test_check_trace_serve_cli(tmp_path):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_trace

        path = tmp_path / "BENCH_serve.json"
        path.write_text(json.dumps(_serve_record()))
        assert check_trace.main([str(path)]) == 0  # auto-detected via engines
        assert check_trace.main(["--kind", "serve", str(path)]) == 0
    finally:
        sys.path.pop(0)
