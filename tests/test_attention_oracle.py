"""Chunked (flash-style) attention vs naive softmax oracle, and MLA
decode-vs-forward consistency (absorbed decode == decompressed forward)."""

import numpy as np
import pytest
from hyputil import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model, make_batch
from repro.models.layers import chunked_causal_attention


def naive_attention(q, k, v, causal=True):
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(np.float32)
    s = np.einsum("bhgqd,bhkd->bhgqk", qg, np.asarray(k, np.float32)) / np.sqrt(D)
    if causal:
        mask = np.tril(np.ones((Sq, Sk), bool), k=Sk - Sq)
        s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bhkd->bhgqd", p, np.asarray(v, np.float32))
    return o.reshape(B, Hq, Sq, D)


@pytest.mark.parametrize("Sq,Sk,cq,ck", [(64, 64, 16, 16), (100, 100, 32, 16), (7, 7, 16, 16), (128, 128, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_matches_naive(Sq, Sk, cq, ck, causal):
    rng = np.random.default_rng(Sq + Sk)
    B, Hq, Hkv, D = 2, 4, 2, 8
    q = rng.normal(size=(B, Hq, Sq, D)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, Sk, D)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, Sk, D)).astype(np.float32)
    got = chunked_causal_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), chunk_q=cq, chunk_k=ck, causal=causal
    )
    want = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


@given(sq=st.integers(2, 40), cq=st.sampled_from([4, 8, 16]), ck=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_chunked_attention_property(sq, cq, ck, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(1, 2, sq, 4)).astype(np.float32)
    k = rng.normal(size=(1, 2, sq, 4)).astype(np.float32)
    v = rng.normal(size=(1, 2, sq, 4)).astype(np.float32)
    got = chunked_causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), chunk_q=cq, chunk_k=ck)
    np.testing.assert_allclose(np.asarray(got), naive_attention(q, k, v), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("name", ["deepseek-v3-671b", "jamba-v0.1-52b", "whisper-base"])
def test_decode_matches_forward_more_archs(name):
    """MLA absorbed decode / Jamba mixed-cache decode / whisper enc-dec
    decode all reproduce the teacher-forced forward logits."""
    cfg = smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=6)
    full_logits, _, _ = jax.jit(model.forward)(params, batch)
    cache = model.init_cache(B, S)
    if model.is_encdec:
        cache = dict(cache)
        enc = model._encode_frames(params, batch["frames"].astype(model.dtype), model_ctx())
        cache["enc_out"] = enc
    step = jax.jit(model.decode_step)
    text_s = batch["tokens"].shape[1]
    for t in range(text_s):
        logits_t, cache = step(
            params, cache, batch["tokens"][:, t : t + 1], jnp.full((B,), t, jnp.int32)
        )
        if model.is_vlm:
            continue  # VLM decode lacks the patch prefix — logits differ by design
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0, : cfg.vocab_size], np.float32),
            np.asarray(full_logits[:, t, : cfg.vocab_size], np.float32),
            rtol=0.2, atol=0.2,
        )


def model_ctx():
    from repro.models.layers import NO_CTX

    return NO_CTX
