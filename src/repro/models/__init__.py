from .inputs import batch_dims, decode_input_specs, make_batch, train_batch_specs  # noqa: F401
from .layers import NO_CTX, Ctx  # noqa: F401
from .model import Model, build_model  # noqa: F401
