"""Attention-free sequence mixers: Mamba (selective SSM, for Jamba) and
RWKV-6 "Finch" (data-dependent decay WKV), with O(1)-state decode steps.

Training uses lax.scan over time (state dims are small: d_state=16 for
Mamba, head_dim×head_dim for RWKV) — sequence-parallel chunking is applied
by the caller via scan; the recurrences themselves are exact.
"""

from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

from .layers import NO_CTX, rmsnorm, rmsnorm_init, truncnorm_init


# ---------------------------------------------------------------------------
# Mamba (S6) block — Jamba's mixer [arXiv:2312.00752, 2403.19887]
# ---------------------------------------------------------------------------


def mamba_init(key, cfg, dtype=jnp.bfloat16):
    sc = cfg.ssm
    d = cfg.d_model
    d_in = sc.expand * d
    ks = jax.random.split(key, 7)
    dt_rank = sc.dt_rank or max(1, math.ceil(d / 16))
    A = np.tile(np.arange(1, sc.d_state + 1, dtype=np.float32), (d_in, 1))
    return {
        "in_proj": truncnorm_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": truncnorm_init(ks[1], (sc.d_conv, d_in), dtype, scale=0.1),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": truncnorm_init(ks[2], (d_in, dt_rank + 2 * sc.d_state), dtype),
        "dt_proj_w": truncnorm_init(ks[3], (dt_rank, d_in), dtype),
        "dt_proj_b": jnp.asarray(
            np.log(np.expm1(np.clip(np.random.default_rng(0).uniform(1e-3, 1e-1, d_in), 1e-4, None))),
            dtype=jnp.float32,
        ),
        "A_log": jnp.asarray(np.log(A), dtype=jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": truncnorm_init(ks[4], (d_in, d), dtype),
        "dt_norm": rmsnorm_init(dt_rank, dtype),
        "b_norm": rmsnorm_init(sc.d_state, dtype),
        "c_norm": rmsnorm_init(sc.d_state, dtype),
    }


def mamba_specs(cfg):
    return {
        "in_proj": ("d_model", "d_ff"),
        "conv_w": (None, "d_ff"),
        "conv_b": ("d_ff",),
        "x_proj": ("d_ff", None),
        "dt_proj_w": (None, "d_ff"),
        "dt_proj_b": ("d_ff",),
        "A_log": ("d_ff", None),
        "D": ("d_ff",),
        "out_proj": ("d_ff", "d_model"),
        "dt_norm": {"scale": (None,)},
        "b_norm": {"scale": (None,)},
        "c_norm": {"scale": (None,)},
    }


def _mamba_scan(u, dt, B, C, A, D, h0=None, time_chunk: int = 0):
    """u: (Bt, S, Din); dt: (Bt, S, Din); B/C: (Bt, S, N); A: (Din, N).
    h_{t} = exp(dt·A)·h_{t-1} + dt·B_t·u_t;  y_t = (h_t · C_t) + D·u_t.

    ``time_chunk > 0``: scan over S/chunk checkpointed chunks — the backward
    pass saves only chunk-boundary states (S/chunk × state bytes) instead of
    every step's state (§Perf 'time_chunk' lever).

    The discretized dA = exp(dt·A) and dBu = dt·B·u are computed PER STEP
    inside the scan body — materializing them up-front costs 2·(B,S,Din,N)
    f32 ≈ 2×69 GB/layer for Jamba (measured: §Perf j.iter4, −70% temp)."""

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs  # (Bt,Din), (Bt,Din), (Bt,N), (Bt,N)
        dA_t = jnp.exp(dt_t[..., None] * A[None])  # (Bt, Din, N)
        dBu_t = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA_t * h + dBu_t  # (Bt, Din, N)
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    Bt, S, Din = u.shape
    N = A.shape[1]
    h0 = jnp.zeros((Bt, Din, N), jnp.float32) if h0 is None else h0
    xs = (
        u.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
        B.transpose(1, 0, 2),
        C.transpose(1, 0, 2),
    )
    if time_chunk and S > time_chunk and S % time_chunk == 0:
        nc = S // time_chunk

        def chunk_body(h, xs_c):
            return jax.lax.scan(step, h, xs_c)

        chunk_body = jax.checkpoint(chunk_body)
        xs_c = jax.tree.map(
            lambda a: a.reshape((nc, time_chunk) + a.shape[1:]), xs
        )
        h_last, ys = jax.lax.scan(chunk_body, h0, xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + D[None, None] * u
    return y, h_last


def mamba_fwd(params, x, cfg, ctx=NO_CTX, h0=None, conv0=None, return_state=False):
    """x: (B, S, d) → (y, (h_last, conv_tail)). Full-sequence (train/prefill)."""
    sc = cfg.ssm
    B_, S, d = x.shape
    d_in = sc.expand * d
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv1d (kernel d_conv)
    pad = sc.d_conv - 1
    u_p = jnp.pad(u, ((0, 0), (pad, 0), (0, 0))) if conv0 is None else jnp.concatenate(
        [conv0.astype(u.dtype), u], axis=1
    )
    conv = sum(
        u_p[:, i : i + S] * params["conv_w"][i][None, None] for i in range(sc.d_conv)
    ) + params["conv_b"]
    u_c = jax.nn.silu(conv)
    dbl = u_c @ params["x_proj"]
    dt_rank = params["dt_proj_w"].shape[0]
    dt, Bm, Cm = jnp.split(dbl, [dt_rank, dt_rank + sc.d_state], axis=-1)
    dt = rmsnorm(params["dt_norm"], dt)
    Bm = rmsnorm(params["b_norm"], Bm).astype(jnp.float32)
    Cm = rmsnorm(params["c_norm"], Cm).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) @ params["dt_proj_w"].astype(jnp.float32)
        + params["dt_proj_b"]
    )
    A = -jnp.exp(params["A_log"])
    y, h_last = _mamba_scan(
        u_c.astype(jnp.float32), dt, Bm, Cm, A, params["D"], h0,
        time_chunk=getattr(cfg, "time_chunk", 0),
    )
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        conv_tail = u_p[:, -pad:] if pad > 0 else None
        return out, (h_last, conv_tail)
    return out, None


def mamba_decode(params, x, cfg, state):
    """One token: x (B, 1, d); state = (h: (B,Din,N) f32, conv_tail: (B, d_conv-1, Din))."""
    h, conv_tail = state
    out, (h2, tail2) = mamba_fwd(params, x, cfg, h0=h, conv0=conv_tail, return_state=True)
    return out, (h2, tail2)


def mamba_state_init(cfg, batch, dtype=jnp.bfloat16):
    sc = cfg.ssm
    d_in = sc.expand * cfg.d_model
    return (
        jnp.zeros((batch, d_in, sc.d_state), jnp.float32),
        jnp.zeros((batch, sc.d_conv - 1, d_in), dtype),
    )


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" — data-dependent decay WKV [arXiv:2404.05892]
# ---------------------------------------------------------------------------


def rwkv6_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 12)
    lora_r = 32
    lora_w = 64
    p = {
        # token-shift ddlerp: 5 targets (r, k, v, w, g)
        "mu": truncnorm_init(ks[0], (5, d), dtype, scale=0.5),
        "lora_A": truncnorm_init(ks[1], (d, 5 * lora_r), dtype),
        "lora_B": truncnorm_init(ks[2], (5, lora_r, d), dtype, scale=0.01),
        "wr": truncnorm_init(ks[3], (d, d), dtype),
        "wk": truncnorm_init(ks[4], (d, d), dtype),
        "wv": truncnorm_init(ks[5], (d, d), dtype),
        "wg": truncnorm_init(ks[6], (d, d), dtype),
        "wo": truncnorm_init(ks[7], (d, d), dtype),
        # decay: w_t = exp(-exp(w0 + lora_w(x)))
        "w0": jnp.asarray(
            np.linspace(-6.0, -0.5, d, dtype=np.float32), dtype=jnp.float32
        ),
        "w_lora_A": truncnorm_init(ks[8], (d, lora_w), dtype),
        "w_lora_B": truncnorm_init(ks[9], (lora_w, d), dtype, scale=0.01),
        "u": truncnorm_init(ks[10], (H, hd), jnp.float32, scale=0.3),  # bonus
        "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
    }
    return p


def rwkv6_specs(cfg):
    return {
        "mu": (None, "d_model"),
        "lora_A": ("d_model", None),
        "lora_B": (None, None, "d_model"),
        "wr": ("d_model", "heads"),
        "wk": ("d_model", "heads"),
        "wv": ("d_model", "heads"),
        "wg": ("d_model", "heads"),
        "wo": ("heads", "d_model"),
        "w0": ("d_model",),
        "w_lora_A": ("d_model", None),
        "w_lora_B": (None, "d_model"),
        "u": ("heads", None),
        "ln_x": {"scale": ("d_model",), "bias": ("d_model",)},
    }


def _wkv6_scan(r, k, v, w, u, S0=None, time_chunk: int = 0):
    """r,k,v: (B,S,H,hd); w: (B,S,H,hd) decay in (0,1); u: (H,hd) bonus.
    S state: (B,H,hd,hd).  y_t = (S_{t-1} + u⊙k_t v_tᵀ)ᵀ r_t ;
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ   (per head; kᵀv outer product).

    ``time_chunk``: checkpointed chunking as in _mamba_scan (§Perf lever —
    the (B,H,hd,hd) state saved per step dominates train memory otherwise).
    """
    B, S, H, hd = r.shape
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32) if S0 is None else S0

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum(
            "bhij,bhi->bhj", state + u[None, :, :, None] * kv, r_t
        )
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    if time_chunk and S > time_chunk and S % time_chunk == 0:
        nc = S // time_chunk

        def chunk_body(state, xs_c):
            return jax.lax.scan(step, state, xs_c)

        chunk_body = jax.checkpoint(chunk_body)
        xs_c = jax.tree.map(lambda a: a.reshape((nc, time_chunk) + a.shape[1:]), xs)
        S_last, ys = jax.lax.scan(chunk_body, S0, xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        S_last, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S_last  # (B,S,H,hd)


def rwkv6_time_mix(params, x, cfg, ctx=NO_CTX, state=None, x_prev=None, return_state=False):
    """x: (B,S,d). state: (B,H,hd,hd) f32; x_prev: (B,1,d) (token shift tail)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xp = (
        jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if x_prev is None
        else jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)[:, :-1]
    )
    dx = xp - x
    # data-dependent lerp (ddlerp) per target
    lora = jnp.tanh(x @ params["lora_A"]).reshape(B, S, 5, -1)
    mixes = []
    for i in range(5):
        mu_i = params["mu"][i][None, None]
        bump = lora[:, :, i] @ params["lora_B"][i]
        mixes.append(x + dx * (mu_i + bump))
    xr, xk, xv, xw, xg = mixes
    r = (xr @ params["wr"]).reshape(B, S, H, hd)
    k = (xk @ params["wk"]).reshape(B, S, H, hd)
    v = (xv @ params["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ params["wg"])
    wdec = params["w0"][None, None] + jnp.tanh(
        xw @ params["w_lora_A"]
    ).astype(jnp.float32) @ params["w_lora_B"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wdec.astype(jnp.float32))).reshape(B, S, H, hd)
    y, S_last = _wkv6_scan(
        r, k, v, w, params["u"], state, time_chunk=getattr(cfg, "time_chunk", 0)
    )
    y = y.reshape(B, S, d).astype(x.dtype)
    from .layers import layernorm

    y = layernorm(params["ln_x"], y) * g
    out = y @ params["wo"]
    if return_state:
        return out, (S_last, x[:, -1:, :])
    return out, None


def rwkv6_channel_mix_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": truncnorm_init(ks[0], (d,), dtype, scale=0.5),
        "wk": truncnorm_init(ks[1], (d, cfg.d_ff), dtype),
        "wv": truncnorm_init(ks[2], (cfg.d_ff, d), dtype),
    }


def rwkv6_channel_mix_specs():
    return {"mu_k": ("d_model",), "wk": ("d_model", "d_ff"), "wv": ("d_ff", "d_model")}


def rwkv6_channel_mix(params, x, x_prev=None, return_state=False):
    xp = (
        jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if x_prev is None
        else jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)[:, :-1]
    )
    xk = x + (xp - x) * params["mu_k"][None, None]
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = h @ params["wv"]
    if return_state:
        return out, x[:, -1:, :]
    return out, None


def rwkv6_state_init(cfg, batch, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    return {
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((batch, 1, d), dtype),
        "cm_prev": jnp.zeros((batch, 1, d), dtype),
    }
