"""Multi-head Latent Attention (DeepSeek-V2/V3) with compressed KV cache.

Train/prefill: decompress the latent c_kv to full K/V and run chunked
attention. Decode: ABSORBED form — q_nope is folded through W_uk so scores
are taken directly against the cached 512-dim latent (plus the shared rope
key), and the output is reconstructed through W_uv. The cache holds only
(c_kv: kv_lora_rank, k_rope: qk_rope_head_dim) per token — MLA's point.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (
    NO_CTX,
    _scatter_time,
    apply_rope,
    chunked_causal_attention,
    rmsnorm,
    rmsnorm_init,
    rope_angles,
    truncnorm_init,
)


def mla_init(key, cfg, dtype=jnp.bfloat16):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": truncnorm_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": truncnorm_init(ks[1], (m.q_lora_rank, H * qk_head), dtype),
        "w_dkv": truncnorm_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_uk": truncnorm_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": truncnorm_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "w_kr": truncnorm_init(ks[5], (d, m.qk_rope_head_dim), dtype),  # shared 1 head
        "wo": truncnorm_init(ks[6], (H * m.v_head_dim, d), dtype),
    }


def mla_specs(cfg):
    return {
        "w_dq": ("d_model", None),
        "q_norm": {"scale": (None,)},
        "w_uq": (None, "heads"),
        "w_dkv": ("d_model", None),
        "kv_norm": {"scale": (None,)},
        "w_uk": (None, "heads"),
        "w_uv": (None, "heads"),
        "w_kr": ("d_model", None),
        "wo": ("heads", "d_model"),
    }


def _mla_qkr(params, x, cfg, positions):
    """Shared q computation + rope pieces. Returns q_nope (B,S,H,dn),
    q_rope (B,S,H,dr), c_kv (B,S,r), k_rope (B,S,1,dr)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = rmsnorm(params["q_norm"], x @ params["w_dq"]) @ params["w_uq"]
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])
    k_rope = (x @ params["w_kr"]).reshape(B, S, 1, m.qk_rope_head_dim)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(params, x, cfg, ctx=NO_CTX, positions=None):
    """Full-sequence (train/prefill). Returns (y, (c_kv, k_rope)) for caching."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, positions)
    # decompress K/V
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1
    )
    if ctx.flag("attn_heads"):
        q_full = ctx.cons(q_full, ("batch", None, "heads", None))
        k_full = ctx.cons(k_full, ("batch", None, "heads", None))
    else:
        q_full = ctx.cons(q_full, ("batch", "seq", "heads", None))
        k_full = ctx.cons(k_full, ("batch", "seq", "heads", None))
    # pad v to qk head dim for the shared chunked kernel, then slice
    o = chunked_causal_attention(
        q_full.transpose(0, 2, 1, 3),
        k_full.transpose(0, 2, 1, 3),
        jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q_full.shape[-1] - m.v_head_dim))).transpose(0, 2, 1, 3),
    )
    o = o.transpose(0, 2, 1, 3)[..., : m.v_head_dim].reshape(B, S, -1)
    y = o @ params["wo"]
    return ctx.cons(y, ("batch", "seq", "d_model")), (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, cfg, cache, pos, ctx=NO_CTX):
    """Absorbed decode. cache: {"c_kv": (B,Smax,r), "k_rope": (B,Smax,dr)}."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(params, x, cfg, pos[:, None])
    ckv = _scatter_time(cache["c_kv"], c_kv_new, pos)  # (B,Smax,r)
    krp = _scatter_time(cache["k_rope"], k_rope_new[:, :, 0, :], pos)
    Smax = ckv.shape[1]
    # absorb: q_lat[h] = q_nope[h] @ W_uk[h]^T → score vs latent directly
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)  # (B,H,r)
    s = jnp.einsum(
        "bhr,bsr->bhs", q_lat.astype(jnp.float32), ckv.astype(jnp.float32)
    ) + jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), krp.astype(jnp.float32)
    )
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    mask = jnp.arange(Smax)[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv.astype(jnp.float32))  # (B,H,r)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv).astype(x.dtype)
    y = o.reshape(B, 1, -1) @ params["wo"]
    return y, {"c_kv": ckv, "k_rope": krp}


def mla_cache_init(cfg, batch, s_max, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, s_max, m.qk_rope_head_dim), dtype),
    }
