"""Shared model layers (pure JAX, pytree params, scan-friendly).

Conventions:
* params are nested dicts of jnp arrays; every builder has an ``init`` and
  an ``apply``-style function; shapes carry logical dim names via the
  parallel ``*_specs`` functions (for the dry-run's NamedShardings).
* activations: bf16 by default; softmax / norms / router in f32.
* attention is chunked (online-softmax over KV blocks, lax.scan) so 32k
  prefill compiles with bounded memory — no S×S score tensor.
* ``Ctx`` threads (mesh, rules) for with_sharding_constraint annotations;
  ctx=None (single host tests) skips them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, constrain


@dataclasses.dataclass(frozen=True)
class Ctx:
    mesh: Any = None
    rules: ShardingRules | None = None

    def cons(self, x, dims):
        if self.mesh is None:
            return x
        return constrain(x, self.mesh, self.rules, dims)

    def flag(self, name: str) -> bool:
        return self.rules is not None and self.rules.has(name)


NO_CTX = Ctx()


def truncnorm_init(key, shape, dtype, scale=0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope_angles(positions, head_dim, theta):
    """positions: (...,) int32 → (cos, sin): (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, 1, D/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked causal attention (online softmax — no S×S tensor)
# ---------------------------------------------------------------------------


def _attn_chunk(q, k, v, scale, mask):
    """q: (B,Hq,Tq,D) k/v: (B,Hkv,Tk,D); GQA via head grouping. mask: (Tq,Tk)
    or None. Returns (out_unnorm f32, row_max f32, row_sum f32)."""
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)  # (B,Hkv,G,Tq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o, m, l


def chunked_causal_attention(q, k, v, *, chunk_q=1024, chunk_k=1024, causal=True,
                             q_offset=0):
    """q: (B,Hq,Sq,D), k/v: (B,Hkv,Sk,D) → (B,Hq,Sq,D) in q.dtype.

    Online-softmax over KV chunks inside a scan over Q chunks. ``q_offset``
    is the absolute position of q[0] (for prefill continuation / decode).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    # pad to multiples
    pq = (-Sq) % cq
    pk = (-Sk) % ck
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = qp.shape[2] // cq, kp.shape[2] // ck

    q_pos = jnp.arange(cq)
    k_pos = jnp.arange(ck)

    def q_step(_, iq):
        qc = jax.lax.dynamic_slice_in_dim(qp, iq * cq, cq, axis=2)

        def k_step(carry, ik):
            o, m, l = carry
            kc = jax.lax.dynamic_slice_in_dim(kp, ik * ck, ck, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vp, ik * ck, ck, axis=2)
            abs_k = ik * ck + k_pos
            valid = abs_k < Sk  # mask KV PADDING (ragged Sk) in every mode
            if causal:
                abs_q = q_offset + iq * cq + q_pos
                mask = (abs_q[:, None] >= abs_k[None, :]) & valid[None, :]
            else:
                mask = jnp.broadcast_to(valid[None, :], (cq, ck))
            oc, mc, lc = _attn_chunk(qc, kc, vc, scale, mask)
            m_new = jnp.maximum(m, mc)
            alpha = jnp.exp(m - m_new)
            beta = jnp.exp(mc - m_new)
            o = o * alpha[..., None] + oc * beta[..., None]
            l = l * alpha + lc * beta
            return (o, m_new, l), None

        o0 = jnp.zeros((B, Hkv, G, cq, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        (o, m, l), _ = jax.lax.scan(k_step, (o0, m0, l0), jnp.arange(nk))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return None, out.reshape(B, Hq, cq, D).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, B, Hq, cq, D) → (B, Hq, Sq, D)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, nq * cq, D)
    return out[:, :, :Sq]


def decode_attention(q, k_cache, v_cache, kv_len_mask):
    """q: (B,Hq,1,D); caches: (B,Hkv,Smax,D); kv_len_mask: (B,Smax) bool.
    Plain softmax over the cache (linear in Smax)."""
    B, Hq, _, D = q.shape
    Hkv = k_cache.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = s * scale
    s = jnp.where(kv_len_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype=jnp.bfloat16):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": truncnorm_init(ks[0], (d, H * hd), dtype),
        "wk": truncnorm_init(ks[1], (d, Hkv * hd), dtype),
        "wv": truncnorm_init(ks[2], (d, Hkv * hd), dtype),
        "wo": truncnorm_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def attention_specs(cfg):
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": ("d_model", "heads"),
        "wk": ("d_model", "kv_heads"),
        "wv": ("d_model", "kv_heads"),
        "wo": ("heads", "d_model"),
    }
    if cfg.qkv_bias:
        s |= {"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)}
    if cfg.qk_norm:
        s |= {"q_norm": {"scale": ("head_dim",)}, "k_norm": {"scale": ("head_dim",)}}
    return s


def _qkv(params, x, cfg, positions, rope=True):
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if rope:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_fwd(params, x, cfg, ctx=NO_CTX, positions=None, rope=True, causal=True):
    """Training/prefill full-sequence attention. Returns (y, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _qkv(params, x, cfg, positions, rope)
    if ctx.flag("attn_heads"):
        # head-sharded attention internals (Megatron-style): gather seq once,
        # keep the chunk scans slice-local — avoids GSPMD involuntary reshard
        q = ctx.cons(q, ("batch", None, "heads", None))
        k = ctx.cons(k, ("batch", None, "kv_heads", None))
        v = ctx.cons(v, ("batch", None, "kv_heads", None))
    else:
        q = ctx.cons(q, ("batch", "seq", "heads", None))
        k = ctx.cons(k, ("batch", "seq", "kv_heads", None))
    o = chunked_causal_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    y = o @ params["wo"]
    return ctx.cons(y, ("batch", "seq", "d_model")), (k, v)


def attention_decode(params, x, cfg, cache, pos, ctx=NO_CTX, rope=True):
    """x: (B,1,d); cache: {"k": (B,Smax,Hkv,hd), "v": ..., } pos: (B,) int32.
    Returns (y, new_cache)."""
    B = x.shape[0]
    q, k, v = _qkv(params, x, cfg, pos[:, None], rope)
    kc = _scatter_time(cache["k"], k, pos)
    vc = _scatter_time(cache["v"], v, pos)
    Smax = kc.shape[1]
    mask = jnp.arange(Smax)[None, :] <= pos[:, None]
    o = decode_attention(
        q.transpose(0, 2, 1, 3), kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3), mask
    )
    y = o.transpose(0, 2, 1, 3).reshape(B, 1, -1) @ params["wo"]
    return y, {"k": kc, "v": vc}


def _scatter_time(cache, new, pos):
    """cache: (B, Smax, ...), new: (B, 1, ...), pos: (B,) → write at [b, pos[b]]."""
    B = cache.shape[0]
    t = jnp.arange(cache.shape[1])
    sel = (t[None, :] == pos[:, None]).reshape(
        (B, cache.shape[1]) + (1,) * (cache.ndim - 2)
    )
    return jnp.where(sel, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": truncnorm_init(ks[0], (d, d_ff), dtype),
        "w_up": truncnorm_init(ks[1], (d, d_ff), dtype),
        "w_down": truncnorm_init(ks[2], (d_ff, d), dtype),
    }


def swiglu_specs():
    return {
        "w_gate": ("d_model", "d_ff"),
        "w_up": ("d_model", "d_ff"),
        "w_down": ("d_ff", "d_model"),
    }


def swiglu(params, x, ctx=NO_CTX):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = ctx.cons(h, ("batch", "seq", "d_ff"))
    return ctx.cons(h @ params["w_down"], ("batch", "seq", "d_model"))


def gelu_mlp_init(key, d, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 2)
    return {
        "w_up": truncnorm_init(ks[0], (d, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": truncnorm_init(ks[1], (d_ff, d), dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def gelu_mlp_specs():
    return {
        "w_up": ("d_model", "d_ff"),
        "b_up": ("d_ff",),
        "w_down": ("d_ff", "d_model"),
        "b_down": ("d_model",),
    }


def gelu_mlp(params, x, ctx=NO_CTX):
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    h = ctx.cons(h, ("batch", "seq", "d_ff"))
    return ctx.cons(h @ params["w_down"] + params["b_down"], ("batch", "seq", "d_model"))


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-based sort dispatch — FLOPs ∝ active experts)
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype=jnp.bfloat16):
    mc = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": truncnorm_init(ks[0], (d, mc.n_experts), jnp.float32, scale=0.006),
        "w_gate": truncnorm_init(ks[1], (mc.n_experts, d, mc.expert_ff), dtype),
        "w_up": truncnorm_init(ks[2], (mc.n_experts, d, mc.expert_ff), dtype),
        "w_down": truncnorm_init(ks[3], (mc.n_experts, mc.expert_ff, d), dtype),
    }
    if mc.shared_ff:
        p["shared"] = swiglu_init(ks[4], d, mc.shared_ff, dtype)
    return p


def moe_specs(cfg):
    # expert weights use the dedicated "expert_d" logical name so profiles
    # can exclude them from FSDP while keeping dense params sharded
    s = {
        "router": ("d_model", "experts"),
        "w_gate": ("experts", "expert_d", "moe_ff"),
        "w_up": ("experts", "expert_d", "moe_ff"),
        "w_down": ("experts", "moe_ff", "expert_d"),
    }
    if cfg.moe.shared_ff:
        s["shared"] = swiglu_specs()
    return s


def moe_block(params, x, cfg, ctx=NO_CTX):
    """Top-k routed experts with capacity-factor sort-based dispatch.

    Gathers/scatters (O(T·k·d) bytes, ~0 FLOPs) move tokens into per-expert
    buffers of capacity C = ceil(T·k/E · capacity_factor); expert matmuls
    are dense (E, C, d)×(E, d, f) einsums — compiled FLOPs stay proportional
    to ACTIVE parameters (MODEL_FLOPS ratio in the roofline stays honest).
    Overflowing tokens are dropped (standard GShard/Switch semantics).
    """
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mc.n_experts, mc.top_k
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if mc.router_softmax_topk:  # softmax-then-topk (Switch/Mixtral style)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eidx = jax.lax.top_k(probs, k)  # (T, k)
    else:  # topk-then-softmax (DeepSeek style normalization)
        gate_logits, eidx = jax.lax.top_k(logits, k)
        gate_vals = jax.nn.softmax(gate_logits, axis=-1)
    if mc.norm_topk_prob:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * k / E * mc.capacity_factor))
    C = max(C, 4)
    # flatten (token, slot) pairs and sort by expert id (stable)
    flat_e = eidx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert group
    same = jnp.concatenate([jnp.zeros((1,), jnp.int32), (se[1:] == se[:-1]).astype(jnp.int32)])
    seg_pos = _segment_rank(same)
    keep = seg_pos < C
    buf_idx = se * C + jnp.where(keep, seg_pos, 0)
    if ctx.flag("moe_gather"):
        # gather-form dispatch/combine (§Perf lever): scatters with computed
        # indices force GSPMD to replicate+all-reduce the buffers; both maps
        # are re-expressed as gathers with an explicit inverse permutation.
        # dispatch: slot (e, c) pulls its token (slot_token built by scatter
        # over (T*k,)-index space — 8-byte rows, negligible vs (·, d) arrays)
        slot_token = (
            jnp.full((E * C + 1,), T, jnp.int32)
            .at[jnp.where(keep, buf_idx, E * C)]
            .set(st.astype(jnp.int32))
        )[: E * C]
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
        eb = xt_pad[slot_token].reshape(E, C, d)
    else:
        # scatter-form dispatch (baseline)
        buf = jnp.zeros((E * C, d), x.dtype)
        vals = jnp.where(keep[:, None], xt[st], 0).astype(x.dtype)
        buf = buf.at[buf_idx].add(vals)  # collisions only among dropped → add of 0s
        eb = buf.reshape(E, C, d)
    eb = ctx.cons(eb, ("experts", None, "d_model"))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", eb, params["w_up"]
    )
    h = ctx.cons(h, ("experts", None, "moe_ff"))
    out_b = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, d)
    if ctx.flag("moe_gather"):
        # combine: every token has exactly k (possibly zeroed) contributions;
        # invert the expert-sort and segment-sum groups of k — gather + dense
        # reduce, no scatter-add of (T, d) partials.
        contrib = out_b[buf_idx] * (sg * keep.astype(sg.dtype))[:, None]
        inv = jnp.argsort(st, stable=True)  # groups the k slots of each token
        out = contrib[inv].reshape(T, k, d).astype(jnp.float32).sum(axis=1)
    else:
        contrib = out_b[buf_idx] * (sg * keep.astype(sg.dtype))[:, None]
        out = jnp.zeros((T, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, S, d)
    if mc.shared_ff:
        out = out + swiglu(params["shared"], x, ctx)
    # load-balance aux loss (Switch): E * Σ_e f_e · p_e
    me = jax.nn.softmax(logits, axis=-1).mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)
    return ctx.cons(out, ("batch", "seq", "d_model")), aux


def _segment_rank(same_as_prev):
    """Given 0/1 'same as previous' flags of a sorted array, return the rank
    of each element within its run (vectorized prefix trick)."""
    n = same_as_prev.shape[0]
    idx = jnp.arange(n)
    # start-of-run positions: cummax of idx*(1-same)
    starts = jax.lax.associative_scan(jnp.maximum, jnp.where(same_as_prev == 0, idx, 0))
    return idx - starts
