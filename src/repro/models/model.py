"""Model assembly: decoder-only LM / MoE / SSM / hybrid / enc-dec / VLM.

A model is a layer PATTERN: an optional non-repeated prefix plus a repeated
body period, scanned with ``jax.lax.scan`` (params stacked over repeats) so
the HLO stays one-layer-sized regardless of depth — essential for the 40-cell
dry-run compile budget.

Public surface (used by train/, serve/, launch/):
    build_model(cfg)        → Model
    model.init(rng)         → params
    model.param_specs()     → (ShapeDtypeStruct pytree, logical-dims pytree)
    model.forward(params, batch, ctx)          → logits (train/prefill)
    model.loss(params, batch, ctx)             → (loss, metrics)
    model.init_cache(batch) / model.cache_specs(batch)
    model.prefill(params, batch, ctx)          → (logits, cache)
    model.decode_step(params, cache, tokens, pos, ctx) → (logits, cache)
    model.prefill_into_cache(params, cache, tokens, slot, ctx)
                            → (logits, cache)   # one-pass KV fill of a slot
    model.supports_prefill  → bool              # False for recurrent/enc-dec
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from . import mla as MLA
from . import ssm as SSM


# ---------------------------------------------------------------------------
# layer-kind registry
# ---------------------------------------------------------------------------
# kind → (init, specs, fwd, decode, cache_init, cache_specs)


def _dense_init(key, cfg, dtype, d_ff=None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.swiglu_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype),
    }


def _dense_specs(cfg):
    return {
        "ln1": {"scale": ("d_model",)},
        "attn": L.attention_specs(cfg),
        "ln2": {"scale": ("d_model",)},
        "mlp": L.swiglu_specs(),
    }


def _dense_fwd(params, x, cfg, ctx, aux):
    h, _ = L.attention_fwd(params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx)
    x = x + h
    x = x + L.swiglu(params["mlp"], L.rmsnorm(params["ln2"], x), ctx)
    return x, aux


def _dense_decode(params, x, cfg, cache, pos, ctx):
    h, cache2 = L.attention_decode(
        params["attn"], L.rmsnorm(params["ln1"], x), cfg, cache, pos, ctx
    )
    x = x + h
    x = x + L.swiglu(params["mlp"], L.rmsnorm(params["ln2"], x), ctx)
    return x, cache2


def _dense_prefill(params, x, cfg, ctx, aux):
    """Full-sequence forward that also returns this layer's cache content
    (the K/V rows for positions [0, S)) — the decode path's cache is filled
    in ONE pass instead of a per-token refeed."""
    h, (k, v) = L.attention_fwd(params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx)
    x = x + h
    x = x + L.swiglu(params["mlp"], L.rmsnorm(params["ln2"], x), ctx)
    return x, aux, {"k": k, "v": v}


def _kv_cache_init(cfg, batch, s_max, dtype):
    return {
        "k": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def _kv_cache_dims():
    return {
        "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    }


def _moe_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "moe": L.moe_init(k2, cfg, dtype),
    }
    if cfg.moe.dense_residual_ff:
        p["dense_mlp"] = L.swiglu_init(
            jax.random.fold_in(key, 7), cfg.d_model, cfg.moe.dense_residual_ff, dtype
        )
    return p


def _moe_specs(cfg):
    s = {
        "ln1": {"scale": ("d_model",)},
        "attn": L.attention_specs(cfg),
        "ln2": {"scale": ("d_model",)},
        "moe": L.moe_specs(cfg),
    }
    if cfg.moe.dense_residual_ff:
        s["dense_mlp"] = L.swiglu_specs()
    return s


def _moe_fwd(params, x, cfg, ctx, aux):
    h, _ = L.attention_fwd(params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx)
    x = x + h
    xn = L.rmsnorm(params["ln2"], x)
    mo, a = L.moe_block(params["moe"], xn, cfg, ctx)
    if cfg.moe.dense_residual_ff:
        mo = mo + L.swiglu(params["dense_mlp"], xn, ctx)
    return x + mo, aux + a


def _moe_decode(params, x, cfg, cache, pos, ctx):
    h, cache2 = L.attention_decode(
        params["attn"], L.rmsnorm(params["ln1"], x), cfg, cache, pos, ctx
    )
    x = x + h
    xn = L.rmsnorm(params["ln2"], x)
    mo, _ = L.moe_block(params["moe"], xn, cfg, ctx)
    if cfg.moe.dense_residual_ff:
        mo = mo + L.swiglu(params["dense_mlp"], xn, ctx)
    return x + mo, cache2


def _moe_prefill(params, x, cfg, ctx, aux):
    h, (k, v) = L.attention_fwd(params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx)
    x = x + h
    xn = L.rmsnorm(params["ln2"], x)
    mo, a = L.moe_block(params["moe"], xn, cfg, ctx)
    if cfg.moe.dense_residual_ff:
        mo = mo + L.swiglu(params["dense_mlp"], xn, ctx)
    return x + mo, aux + a, {"k": k, "v": v}


def _mla_block_init(moe: bool):
    def init(key, cfg, dtype):
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": MLA.mla_init(k1, cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        }
        if moe:
            p["moe"] = L.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.swiglu_init(k2, cfg.d_model, cfg.moe.dense_ff or cfg.d_ff, dtype)
        return p

    return init


def _mla_block_specs(moe: bool):
    def specs(cfg):
        s = {
            "ln1": {"scale": ("d_model",)},
            "attn": MLA.mla_specs(cfg),
            "ln2": {"scale": ("d_model",)},
        }
        if moe:
            s["moe"] = L.moe_specs(cfg)
        else:
            s["mlp"] = L.swiglu_specs()
        return s

    return specs


def _mla_fwd(moe: bool):
    def fwd(params, x, cfg, ctx, aux):
        h, _ = MLA.mla_fwd(params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx)
        x = x + h
        xn = L.rmsnorm(params["ln2"], x)
        if moe:
            mo, a = L.moe_block(params["moe"], xn, cfg, ctx)
            return x + mo, aux + a
        return x + L.swiglu(params["mlp"], xn, ctx), aux

    return fwd


def _mla_decode(moe: bool):
    def dec(params, x, cfg, cache, pos, ctx):
        h, cache2 = MLA.mla_decode(
            params["attn"], L.rmsnorm(params["ln1"], x), cfg, cache, pos, ctx
        )
        x = x + h
        xn = L.rmsnorm(params["ln2"], x)
        if moe:
            mo, _ = L.moe_block(params["moe"], xn, cfg, ctx)
            return x + mo, cache2
        return x + L.swiglu(params["mlp"], xn, ctx), cache2

    return dec


def _mla_prefill(moe: bool):
    def pf(params, x, cfg, ctx, aux):
        h, (c_kv, k_rope) = MLA.mla_fwd(
            params["attn"], L.rmsnorm(params["ln1"], x), cfg, ctx
        )
        x = x + h
        xn = L.rmsnorm(params["ln2"], x)
        content = {"c_kv": c_kv, "k_rope": k_rope}
        if moe:
            mo, a = L.moe_block(params["moe"], xn, cfg, ctx)
            return x + mo, aux + a, content
        return x + L.swiglu(params["mlp"], xn, ctx), aux, content

    return pf


def _mamba_block_init(moe: bool):
    def init(key, cfg, dtype):
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "mamba": SSM.mamba_init(k1, cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        }
        if moe:
            p["moe"] = L.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
        return p

    return init


def _mamba_block_specs(moe: bool):
    def specs(cfg):
        s = {
            "ln1": {"scale": ("d_model",)},
            "mamba": SSM.mamba_specs(cfg),
            "ln2": {"scale": ("d_model",)},
        }
        s["moe" if moe else "mlp"] = L.moe_specs(cfg) if moe else L.swiglu_specs()
        return s

    return specs


def _mamba_fwd(moe: bool):
    def fwd(params, x, cfg, ctx, aux):
        h, _ = SSM.mamba_fwd(params["mamba"], L.rmsnorm(params["ln1"], x), cfg, ctx)
        x = x + h
        xn = L.rmsnorm(params["ln2"], x)
        if moe:
            mo, a = L.moe_block(params["moe"], xn, cfg, ctx)
            return x + mo, aux + a
        return x + L.swiglu(params["mlp"], xn, ctx), aux

    return fwd


def _mamba_decode(moe: bool):
    def dec(params, x, cfg, cache, pos, ctx):
        h, st = SSM.mamba_decode(params["mamba"], L.rmsnorm(params["ln1"], x), cfg, cache)
        x = x + h
        xn = L.rmsnorm(params["ln2"], x)
        if moe:
            mo, _ = L.moe_block(params["moe"], xn, cfg, ctx)
            return x + mo, st
        return x + L.swiglu(params["mlp"], xn, ctx), st

    return dec


def _rwkv_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "tm": SSM.rwkv6_init(k1, cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "cm": SSM.rwkv6_channel_mix_init(k2, cfg, dtype),
    }


def _rwkv_specs(cfg):
    return {
        "ln1": {"scale": ("d_model",), "bias": ("d_model",)},
        "tm": SSM.rwkv6_specs(cfg),
        "ln2": {"scale": ("d_model",), "bias": ("d_model",)},
        "cm": SSM.rwkv6_channel_mix_specs(),
    }


def _rwkv_fwd(params, x, cfg, ctx, aux):
    h, _ = SSM.rwkv6_time_mix(params["tm"], L.layernorm(params["ln1"], x), cfg, ctx)
    x = x + h
    h2, _ = SSM.rwkv6_channel_mix(params["cm"], L.layernorm(params["ln2"], x))
    return x + h2, aux


def _rwkv_decode(params, x, cfg, cache, pos, ctx):
    xn = L.layernorm(params["ln1"], x)
    h, (wkv, tm_prev) = SSM.rwkv6_time_mix(
        params["tm"], xn, cfg, ctx, state=cache["wkv"], x_prev=cache["tm_prev"],
        return_state=True,
    )
    x = x + h
    xn2 = L.layernorm(params["ln2"], x)
    h2, cm_prev = SSM.rwkv6_channel_mix(params["cm"], xn2, x_prev=cache["cm_prev"], return_state=True)
    return x + h2, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}


_KINDS: dict[str, dict[str, Any]] = {
    "dense": dict(init=_dense_init, specs=_dense_specs, fwd=_dense_fwd, decode=_dense_decode, cache="kv", prefill=_dense_prefill),
    "moe": dict(init=_moe_init, specs=_moe_specs, fwd=_moe_fwd, decode=_moe_decode, cache="kv", prefill=_moe_prefill),
    "mla_dense": dict(init=_mla_block_init(False), specs=_mla_block_specs(False), fwd=_mla_fwd(False), decode=_mla_decode(False), cache="mla", prefill=_mla_prefill(False)),
    "mla_moe": dict(init=_mla_block_init(True), specs=_mla_block_specs(True), fwd=_mla_fwd(True), decode=_mla_decode(True), cache="mla", prefill=_mla_prefill(True)),
    # recurrent states have no per-position cache rows a one-pass prefill
    # could write; engines fall back to the per-token refeed for these
    "mamba": dict(init=_mamba_block_init(False), specs=_mamba_block_specs(False), fwd=_mamba_fwd(False), decode=_mamba_decode(False), cache="mamba", prefill=None),
    "mamba_moe": dict(init=_mamba_block_init(True), specs=_mamba_block_specs(True), fwd=_mamba_fwd(True), decode=_mamba_decode(True), cache="mamba", prefill=None),
    "rwkv": dict(init=_rwkv_init, specs=_rwkv_specs, fwd=_rwkv_fwd, decode=_rwkv_decode, cache="rwkv", prefill=None),
}


def layer_pattern(cfg: ModelConfig) -> tuple[list[str], list[str], int]:
    """(prefix kinds, body period kinds, n_repeats)."""
    n = cfg.n_layers
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return [], ["rwkv"], n
    if cfg.ssm is not None and cfg.ssm.kind == "mamba":
        period = cfg.ssm.attn_layer_period or 8
        kinds = []
        for i in range(period):
            is_attn = (i % period) == cfg.ssm.attn_layer_offset
            is_moe = cfg.moe is not None and (i % cfg.moe.layer_period) == cfg.moe.layer_offset
            if is_attn:
                kinds.append("moe" if is_moe else "dense")
            else:
                kinds.append("mamba_moe" if is_moe else "mamba")
        assert n % period == 0
        return [], kinds, n // period
    if cfg.mla is not None:
        fd = cfg.moe.first_dense if cfg.moe else 0
        return ["mla_dense"] * fd, ["mla_moe"], n - fd
    if cfg.moe is not None:
        return [], ["moe"], n
    return [], ["dense"], n


# ---------------------------------------------------------------------------
# cache constructors
# ---------------------------------------------------------------------------


def _cache_init_for(kind: str, cfg, batch, s_max, dtype):
    c = _KINDS[kind]["cache"]
    if c == "kv":
        return _kv_cache_init(cfg, batch, s_max, dtype)
    if c == "mla":
        return MLA.mla_cache_init(cfg, batch, s_max, dtype)
    if c == "mamba":
        return SSM.mamba_state_init(cfg, batch, dtype)
    if c == "rwkv":
        return SSM.rwkv6_state_init(cfg, batch, dtype)
    raise KeyError(c)


def _write_slot(cache_tree, content_tree, slot):
    """Write per-layer prefill content (1, L, ...) into row ``slot`` of the
    batched cache leaves (B, Smax, ...) — ``slot`` may be a traced scalar."""

    def write(leaf, content):
        starts = (slot,) + (0,) * (leaf.ndim - 1)
        return jax.lax.dynamic_update_slice(leaf, content.astype(leaf.dtype), starts)

    return jax.tree.map(write, cache_tree, content_tree)


def _cache_dims_for(kind: str):
    c = _KINDS[kind]["cache"]
    if c == "kv":
        return _kv_cache_dims()
    if c == "mla":
        return {"c_kv": ("batch", "kv_seq", None), "k_rope": ("batch", "kv_seq", None)}
    if c == "mamba":
        return (("batch", "d_ff", "state"), ("batch", "conv", "d_ff"))
    if c == "rwkv":
        return {
            "wkv": ("batch", "heads", None, None),
            "tm_prev": ("batch", None, "d_model"),
            "cm_prev": ("batch", None, "d_model"),
        }
    raise KeyError(c)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.prefix, self.body, self.repeats = layer_pattern(cfg)
        self.is_encdec = cfg.encdec is not None
        self.is_vlm = cfg.vlm is not None

    # -- params ------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(rng, 8)
        params: dict[str, Any] = {
            "embed": L.truncnorm_init(keys[0], (cfg.vocab_padded, cfg.d_model), dtype),
            "ln_f": L.rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.truncnorm_init(keys[1], (cfg.d_model, cfg.vocab_padded), dtype)
        for i, kind in enumerate(self.prefix):
            params[f"prefix_{i}"] = _KINDS[kind]["init"](jax.random.fold_in(keys[2], i), cfg, dtype)
        body = []
        for r in range(self.repeats):
            blk = {}
            for j, kind in enumerate(self.body):
                blk[f"b{j}"] = _KINDS[kind]["init"](
                    jax.random.fold_in(keys[3], r * len(self.body) + j), cfg, dtype
                )
            body.append(blk)
        params["body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *body)
        if self.is_encdec:
            params["encoder"] = self._encoder_init(keys[4])
        if cfg.mtp:
            params["mtp"] = self._mtp_init(keys[5])
        return params

    def _encoder_init(self, key):
        cfg, dtype = self.cfg, self.dtype
        enc_layers = []
        for i in range(cfg.encdec.n_enc_layers):
            k = jax.random.fold_in(key, i)
            k1, k2 = jax.random.split(k)
            enc_layers.append(
                {
                    "ln1": L.layernorm_init(cfg.d_model, dtype),
                    "attn": L.attention_init(k1, cfg, dtype),
                    "ln2": L.layernorm_init(cfg.d_model, dtype),
                    "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
                }
            )
        cross = []
        for i in range(cfg.n_layers):
            k = jax.random.fold_in(jax.random.fold_in(key, 1000), i)
            cross.append({"ln": L.layernorm_init(cfg.d_model, dtype), "attn": L.attention_init(k, cfg, dtype)})
        return {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "ln_post": L.layernorm_init(cfg.d_model, dtype),
            "cross": jax.tree.map(lambda *xs: jnp.stack(xs), *cross),
        }

    def _mtp_init(self, key):
        cfg, dtype = self.cfg, self.dtype
        k1, k2 = jax.random.split(key)
        return {
            "norm_h": L.rmsnorm_init(cfg.d_model, dtype),
            "norm_e": L.rmsnorm_init(cfg.d_model, dtype),
            "proj": L.truncnorm_init(k1, (2 * cfg.d_model, cfg.d_model), dtype),
            "block": _KINDS[self.body[-1]]["init"](k2, cfg, dtype),
        }

    def param_specs(self):
        """(ShapeDtypeStruct pytree, logical-dims pytree) without allocation."""
        shapes = jax.eval_shape(lambda: self.init(jax.random.key(0)))
        dims = self._dims_tree()
        return shapes, dims

    def _dims_tree(self):
        cfg = self.cfg
        dims: dict[str, Any] = {
            "embed": ("vocab", "d_model"),
            "ln_f": {"scale": ("d_model",)},
        }
        if not cfg.tie_embeddings:
            dims["lm_head"] = ("d_model", "vocab")
        for i, kind in enumerate(self.prefix):
            dims[f"prefix_{i}"] = _KINDS[kind]["specs"](cfg)
        body = {}
        for j, kind in enumerate(self.body):
            # leading scan dim → None
            body[f"b{j}"] = jax.tree.map(
                lambda d: (None, *d),
                _KINDS[kind]["specs"](cfg),
                is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
            )
        dims["body"] = body
        if self.is_encdec:
            enc_specs = {
                "ln1": {"scale": ("d_model",), "bias": ("d_model",)},
                "attn": L.attention_specs(cfg),
                "ln2": {"scale": ("d_model",), "bias": ("d_model",)},
                "mlp": L.gelu_mlp_specs(),
            }
            stack = lambda tree: jax.tree.map(
                lambda d: (None, *d), tree,
                is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
            )
            dims["encoder"] = {
                "layers": stack(enc_specs),
                "ln_post": {"scale": ("d_model",), "bias": ("d_model",)},
                "cross": stack({"ln": {"scale": ("d_model",), "bias": ("d_model",)}, "attn": L.attention_specs(cfg)}),
            }
        if cfg.mtp:
            dims["mtp"] = {
                "norm_h": {"scale": ("d_model",)},
                "norm_e": {"scale": ("d_model",)},
                "proj": (None, "d_model"),
                "block": _KINDS[self.body[-1]]["specs"](cfg),
            }
        return dims

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def _head(self, params, x):
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        logits = (x @ w).astype(jnp.float32)
        if self.cfg.vocab_padded > self.cfg.vocab_size:
            pad = self.cfg.vocab_padded - self.cfg.vocab_size
            logits = logits - jnp.pad(
                jnp.zeros((self.cfg.vocab_size,), jnp.float32),
                (0, pad),
                constant_values=1e30,
            )
        return logits

    # -- encoder (whisper stub frontend) -------------------------------------
    def _encode_frames(self, params, frames, ctx):
        """frames: (B, F, d) precomputed stub embeddings → encoder output."""
        cfg = self.cfg
        pos = _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)
        x = frames + pos[None]

        def step(x, lp):
            h, _ = L.attention_fwd(
                lp["attn"], L.layernorm(lp["ln1"], x), cfg, ctx, rope=False, causal=False
            )
            x = x + h
            x = x + L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], x), ctx)
            return x, None

        x, _ = jax.lax.scan(step, x, params["encoder"]["layers"])
        return L.layernorm(params["encoder"]["ln_post"], x)

    # -- trunk ----------------------------------------------------------------
    def _trunk(self, params, x, ctx, enc_out=None):
        """Full-seq forward through prefix + scanned body. Returns (x, aux)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(self.prefix):
            x, aux = _KINDS[kind]["fwd"](params[f"prefix_{i}"], x, cfg, ctx, aux)

        body_fns = [_KINDS[k]["fwd"] for k in self.body]
        cross_params = params["encoder"]["cross"] if self.is_encdec else None

        def body_step(carry, xs):
            x, aux, li = carry
            blk = xs["blk"]
            for j, fn in enumerate(body_fns):
                x, aux = fn(blk[f"b{j}"], x, cfg, ctx, aux)
                if cross_params is not None:
                    cp = jax.tree.map(lambda a, _li=li, _j=j: a[li * len(body_fns) + _j], cross_params)
                    x = x + self._cross_attn(cp, x, enc_out, cfg, ctx)
            return (x, aux, li + 1), None

        if self.is_encdec:
            # index cross params dynamically inside scan
            def body_step2(carry, blk):
                x, aux, li = carry
                for j, fn in enumerate(body_fns):
                    x, aux = fn(blk[f"b{j}"], x, cfg, ctx, aux)
                    cp = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, li * len(body_fns) + j, keepdims=False),
                        cross_params,
                    )
                    x = x + self._cross_attn(cp, x, enc_out, cfg, ctx)
                return (x, aux, li + 1), None

            (x, aux, _), _ = jax.lax.scan(body_step2, (x, aux, 0), params["body"])
        else:
            def body_step3(carry, blk):
                x, aux = carry
                for j, fn in enumerate(body_fns):
                    fn_ = fn
                    if cfg.remat == "block":
                        fn_ = jax.checkpoint(fn, static_argnums=(2, 3))
                    x, aux = fn_(blk[f"b{j}"], x, cfg, ctx, aux)
                return (x, aux), None

            (x, aux), _ = jax.lax.scan(body_step3, (x, aux), params["body"])
        return L.rmsnorm(params["ln_f"], x), aux

    def _cross_attn(self, cp, x, enc_out, cfg, ctx):
        """Decoder cross-attention onto encoder output (whisper)."""
        xn = L.layernorm(cp["ln"], x)
        B, S, _ = x.shape
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (xn @ cp["attn"]["wq"]).reshape(B, S, H, hd)
        k = (enc_out @ cp["attn"]["wk"]).reshape(B, -1, Hkv, hd)
        v = (enc_out @ cp["attn"]["wv"]).reshape(B, -1, Hkv, hd)
        o = L.chunked_causal_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=False,
        )
        return o.transpose(0, 2, 1, 3).reshape(B, S, -1) @ cp["attn"]["wo"]

    # -- public forward/loss --------------------------------------------------
    def forward(self, params, batch, ctx=L.NO_CTX):
        """batch: {"tokens": (B,S) int32, optional "frames"/"patches"} → logits."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"]).astype(self.dtype)
        enc_out = None
        if self.is_encdec:
            enc_out = self._encode_frames(params, batch["frames"].astype(self.dtype), ctx)
            pos = _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)
            x = x + pos[None]
        if self.is_vlm:
            x = jnp.concatenate([batch["patches"].astype(self.dtype), x], axis=1)
        x = ctx.cons(x, ("batch", "seq", "d_model"))
        h, aux = self._trunk(params, x, ctx, enc_out)
        if self.is_vlm:
            h = h[:, batch["patches"].shape[1] :]
        logits = self._head(params, h)
        return logits, aux, h

    def loss(self, params, batch, ctx=L.NO_CTX):
        """Causal LM loss (+MoE aux, +MTP when enabled)."""
        cfg = self.cfg
        logits, aux, h = self.forward(params, batch, ctx)
        tokens = batch["tokens"]
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        ce = _xent(logits[:, :-1], labels[:, 1:], mask[:, 1:])
        metrics = {"ce": ce, "aux": aux}
        total = ce + 0.01 * aux
        if cfg.mtp:
            mtp = params["mtp"]
            # predict t+2: combine h_i with embed(t_{i+1})
            emb_next = self._embed(params, tokens[:, 1:]).astype(self.dtype)
            hcomb = jnp.concatenate(
                [L.rmsnorm(mtp["norm_h"], h[:, :-1]), L.rmsnorm(mtp["norm_e"], emb_next)],
                axis=-1,
            ) @ mtp["proj"]
            hm, _ = _KINDS[self.body[-1]]["fwd"](
                mtp["block"], hcomb, cfg, ctx, jnp.zeros((), jnp.float32)
            )
            mtp_logits = self._head(params, hm)
            mtp_ce = _xent(mtp_logits[:, :-1], labels[:, 2:], mask[:, 2:])
            metrics["mtp_ce"] = mtp_ce
            total = total + 0.3 * mtp_ce
        metrics["loss"] = total
        return total, metrics

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, s_max: int):
        cfg, dtype = self.cfg, self.dtype
        caches = []
        for r in range(self.repeats):
            blk = {f"b{j}": _cache_init_for(k, cfg, batch, s_max, dtype) for j, k in enumerate(self.body)}
            caches.append(blk)
        cache: dict[str, Any] = {"body": jax.tree.map(lambda *xs: jnp.stack(xs), *caches)}
        for i, kind in enumerate(self.prefix):
            cache[f"prefix_{i}"] = _cache_init_for(kind, cfg, batch, s_max, dtype)
        if self.is_encdec:
            cache["enc_out"] = jnp.zeros((batch, cfg.encdec.n_frames, cfg.d_model), dtype)
        return cache

    def cache_dims(self):
        dims: dict[str, Any] = {}
        body = {}
        for j, kind in enumerate(self.body):
            body[f"b{j}"] = jax.tree.map(
                lambda d: (None, *d),
                _cache_dims_for(kind),
                is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
            )
        dims["body"] = body
        for i, kind in enumerate(self.prefix):
            dims[f"prefix_{i}"] = _cache_dims_for(kind)
        if self.is_encdec:
            dims["enc_out"] = ("batch", "frames", "d_model")
        return dims

    def decode_step(self, params, cache, tokens, pos, ctx=L.NO_CTX):
        """tokens: (B,1) int32; pos: (B,) int32 → (logits (B,1,V), new cache)."""
        cfg = self.cfg
        cache = dict(cache)
        x = self._embed(params, tokens).astype(self.dtype)
        if self.is_encdec:
            ppos = _sinusoidal_at(pos, cfg.d_model).astype(x.dtype)
            x = x + ppos[:, None, :]
        enc_out = cache.get("enc_out") if self.is_encdec else None
        for i, kind in enumerate(self.prefix):
            x, cache[f"prefix_{i}"] = _KINDS[kind]["decode"](
                params[f"prefix_{i}"], x, cfg, cache[f"prefix_{i}"], pos, ctx
            )
        dec_fns = [_KINDS[k]["decode"] for k in self.body]
        cross_params = params["encoder"]["cross"] if self.is_encdec else None

        def step(carry, xs):
            x, li = carry
            blk, bcache = xs
            new_bcache = {}
            for j, fn in enumerate(dec_fns):
                x, new_bcache[f"b{j}"] = fn(blk[f"b{j}"], x, cfg, bcache[f"b{j}"], pos, ctx)
                if cross_params is not None:
                    cp = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, li * len(dec_fns) + j, keepdims=False),
                        cross_params,
                    )
                    x = x + self._cross_attn(cp, x, enc_out, cfg, ctx)
            return (x, li + 1), new_bcache

        (x, _), new_body = jax.lax.scan(step, (x, 0), (params["body"], cache["body"]))
        cache["body"] = new_body
        logits = self._head(params, L.rmsnorm(params["ln_f"], x))
        return logits, cache

    def prefill(self, params, batch, ctx=L.NO_CTX):
        """Run the full prompt, returning logits; cache building for decode is
        exercised separately (decode_step), matching the dry-run contract."""
        return self.forward(params, batch, ctx)

    @property
    def supports_prefill(self) -> bool:
        """True iff every layer kind can emit its cache rows from one
        full-sequence pass (attention K/V and MLA latents can; recurrent
        mamba/rwkv states and the enc-dec/VLM frontends cannot)."""
        if self.is_encdec or self.is_vlm:
            return False
        return all(
            _KINDS[k].get("prefill") is not None for k in (*self.prefix, *self.body)
        )

    def prefill_into_cache(self, params, cache, tokens, slot, ctx=L.NO_CTX):
        """One-pass prompt prefill into a decode-slot cache row.

        ``tokens``: (1, L) int32, the prompt right-padded to a length bucket
        L ≤ Smax. Runs the full-sequence trunk once, writing every layer's
        cache content for positions [0, L) into row ``slot`` of the batched
        decode ``cache``, and returns ``(logits (1, L, V_padded), cache)``.
        Rows of the padded tail carry garbage K/V, which the decode path
        never attends (its mask is ``t <= pos`` and the per-token decode
        overwrites position p before attending it).
        """
        if not self.supports_prefill:
            raise NotImplementedError(
                f"{self.cfg.name}: one-pass prefill needs per-position cache "
                "rows in every layer (recurrent/enc-dec/VLM models refeed)"
            )
        cfg = self.cfg
        cache = dict(cache)
        x = self._embed(params, tokens).astype(self.dtype)
        x = ctx.cons(x, ("batch", "seq", "d_model"))
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(self.prefix):
            x, aux, content = _KINDS[kind]["prefill"](
                params[f"prefix_{i}"], x, cfg, ctx, aux
            )
            cache[f"prefix_{i}"] = _write_slot(cache[f"prefix_{i}"], content, slot)
        pf_fns = [_KINDS[k]["prefill"] for k in self.body]

        def step(carry, xs):
            x, aux = carry
            blk, bcache = xs
            new_bcache = {}
            for j, fn in enumerate(pf_fns):
                x, aux, content = fn(blk[f"b{j}"], x, cfg, ctx, aux)
                new_bcache[f"b{j}"] = _write_slot(bcache[f"b{j}"], content, slot)
            return (x, aux), new_bcache

        (x, _), new_body = jax.lax.scan(step, (x, aux), (params["body"], cache["body"]))
        cache["body"] = new_body
        logits = self._head(params, L.rmsnorm(params["ln_f"], x))
        return logits, cache


def _xent(logits, labels, mask):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


@functools.lru_cache(maxsize=8)
def _sin_table(S: int, d: int):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _sinusoidal(S: int, d: int):
    return jnp.asarray(_sin_table(S, d))


def _sinusoidal_at(pos, d: int):
    half = d // 2
    i = jnp.arange(half, dtype=jnp.float32)
    ang = pos.astype(jnp.float32)[:, None] / (10000 ** (2 * i / d))[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
