"""Model input construction: concrete batches (tests/benches) and
ShapeDtypeStruct specs (dry-run — no allocation).

Modality frontends are STUBS per the brief: whisper gets precomputed frame
embeddings (B, n_frames, d_model); the VLM gets precomputed patch embeddings
(B, n_patches, d_model). For VLM shapes, seq_len counts the TOTAL positions
(patches + text)."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def batch_dims(cfg: ModelConfig, kind: str) -> dict:
    """Logical dim names for each batch field (for in_shardings)."""
    d: dict = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.encdec is not None:
        d["frames"] = ("batch", "frames", "d_model")
    if cfg.vlm is not None:
        d["patches"] = ("batch", "seq", "d_model")
    if kind == "decode":
        d = {"tokens": ("batch", None), "pos": ("batch",)}
    return d


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    text_s = S - (cfg.vlm.n_patches if cfg.vlm else 0)
    spec: dict = {
        "tokens": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, text_s), jnp.int32),
    }
    if cfg.encdec is not None:
        spec["frames"] = jax.ShapeDtypeStruct((B, cfg.encdec.n_frames, cfg.d_model), dtype)
    if cfg.vlm is not None:
        spec["patches"] = jax.ShapeDtypeStruct((B, cfg.vlm.n_patches, cfg.d_model), dtype)
    return spec


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def make_batch(cfg: ModelConfig, B: int, S: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    text_s = S - (cfg.vlm.n_patches if cfg.vlm else 0)
    tokens = rng.integers(0, cfg.vocab_size, size=(B, text_s), dtype=np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}
    if cfg.encdec is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encdec.n_frames, cfg.d_model)).astype(np.float32) * 0.02,
            dtype=jnp.bfloat16,
        )
    if cfg.vlm is not None:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.d_model)).astype(np.float32) * 0.02,
            dtype=jnp.bfloat16,
        )
    return batch
