"""Process-local metrics registry: counters, gauges, histograms.

One :class:`MetricsRegistry` per process (module-level :data:`REGISTRY`,
reachable via :func:`get_registry`) holds named instruments, optionally
labelled — ``registry.histogram("encode.round_us", level=1)`` materializes
the series ``encode.round_us{level=1}``. Instrument names in use across the
repo:

* ``encode.rounds`` / ``encode.ppermutes`` / ``encode.bytes_on_wire`` —
  counters bumped per traced :class:`~repro.core.ir.CommRound` by
  ``dist.collectives.ir_encode_jit(tracer=...)``;
* ``encode.round_us{level=j}`` — histogram of measured per-round wall µs,
  labelled by the round's topology level (the rows ``repro.obs.feed``
  refits α/β from);
* ``serve.step_us`` / ``serve.tokens_per_s`` / ``serve.eos_syncs_saved`` —
  the fixed-batch serving engine's decode-step latency histogram, its
  generated-tokens-only throughput gauge (shared with the continuous
  engine), and the device→host syncs avoided by batched EOS checking;
* ``serve.prefill_compiles`` / ``serve.decode_steps`` / ``serve.ttft_ms``
  / ``serve.e2e_ms`` / ``serve.slot_occupancy`` — the continuous-batching
  engine: compiled-prefill-graph count (bounded by the length-bucket
  set), decode ticks, per-request time-to-first-token and end-to-end
  latency histograms, and the mean occupied-slot fraction; with
  ``tracer=`` also ``serve.prefill_us`` / ``serve.decode_chunk_us``;
* ``serve.snapshots`` / ``serve.recoveries`` / ``serve.recovery_us`` —
  coded straggler-tolerant serving (``serve.coded.CodedServeGuard``):
  LCC snapshots of the decode-path state taken per chunk, hosts
  recovered from after injected/real faults, and the any-K-of-N
  Lagrange reconstruction latency histogram;
* ``bench.*_us`` — benchmark sample histograms routed through
  ``benchmarks.common.time_fn(metric=...)``.

Snapshots are deterministic: keys sorted, histogram statistics derived
from the full sample list (count/sum/min/max/mean/p50/p90/p99), so two
identical runs produce byte-identical JSON (asserted in tests/test_obs.py).
"""

from __future__ import annotations

import json
import os


def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


def _quantile(sorted_vals: list, q: float) -> float:
    """Nearest-rank quantile over an already-sorted sample list."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


class Histogram:
    __slots__ = ("samples",)

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    def snapshot(self) -> dict:
        s = sorted(self.samples)
        n = len(s)
        return {
            "type": "histogram",
            "count": n,
            "sum": sum(s),
            "min": s[0] if n else 0.0,
            "max": s[-1] if n else 0.0,
            "mean": (sum(s) / n) if n else 0.0,
            "p50": _quantile(s, 0.50),
            "p90": _quantile(s, 0.90),
            "p99": _quantile(s, 0.99),
        }


class MetricsRegistry:
    """Lazily-materializing instrument registry; same (name, labels) always
    returns the same instrument, and asking for an existing series with a
    different instrument kind is an error."""

    def __init__(self):
        self._series: dict[str, object] = {}

    def _get(self, cls, name: str, labels: dict):
        key = _series_key(name, labels)
        inst = self._series.get(key)
        if inst is None:
            inst = cls()
            self._series[key] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {key!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}"
            )
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def snapshot(self) -> dict:
        """Deterministic {series_key: stats} map, keys sorted."""
        return {k: self._series[k].snapshot() for k in sorted(self._series)}

    def write_json(self, path: str) -> dict:
        snap = self.snapshot()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(snap, fh, indent=2, sort_keys=True)
        return snap

    def reset(self) -> None:
        self._series.clear()


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-local default registry instrumented layers record into."""
    return REGISTRY
