"""Close the telemetry loop: traced round spans → fitted α/β → profiles.

``dist.collectives.ir_encode_jit(tracer=...)`` stamps every per-round span
with the round's busiest-link calibration features (``level``, ``msgs``,
``elems`` — exactly the rows ``topo.calibrate.round_features`` derives) next
to the measured wall time and the α-β model's prediction. This module turns
those spans back into the calibration pipeline's inputs:

* :func:`round_measurements` — spans → ``fit_level_costs`` measurement
  dicts (one per traced round: its wall seconds, payload, and single
  feature row — finer-grained than the offline aggregate sweeps, which
  only see whole-encode wall times);
* :func:`refit_from_spans` — re-run the least-squares α/β fit on live
  telemetry;
* :func:`persist_fitted_costs` — write the fit into the ``calibration``
  block of ``results/BENCH_topology.json`` (or any path), EXACTLY where
  ``topo.calibrate.load_fitted_costs`` — and therefore
  ``launch.profiles.resolve_profile`` — already reads fitted costs. This is
  the ROADMAP follow-on "feed the fit from LIVE sweep telemetry";
* :func:`feed_calibration` — the one-shot compose of the three above;
* :func:`drift_rows` — per-round predicted-vs-measured comparison
  (relative error, threshold flag), rendered as a table by
  ``launch.perf_report.render_drift``.
"""

from __future__ import annotations

import json
import os


def _attrs(span) -> dict:
    return span.get("attrs", {}) if isinstance(span, dict) else span.attrs


def _field(span, key, default=None):
    if isinstance(span, dict):
        return span.get(key, default)
    return getattr(span, key, default)


def comm_round_spans(spans) -> list:
    """The spans that carry traced CommRound telemetry (attr ``comm_round``),
    in recorded order."""
    return [s for s in spans if "comm_round" in _attrs(s)]


def round_measurements(spans) -> list[dict]:
    """Traced round spans → :func:`topo.calibrate.fit_level_costs`
    measurement dicts: one measurement per round, whose single feature row
    is the round's busiest-link (level, msgs, elems) stamped by the traced
    executor. Spans without calibration features (e.g. traced on a flat
    topology with no ``level`` attr) are skipped."""
    out = []
    for sp in comm_round_spans(spans):
        a = _attrs(sp)
        if a.get("level") is None:
            continue
        out.append(
            {
                "algorithm": a.get("algorithm", ""),
                "round": int(a["comm_round"]),
                "wall_s": float(_field(sp, "dur_us", 0.0)) * 1e-6,
                "payload_elems": int(a.get("payload_elems", 1)),
                "rounds": [
                    {
                        "level": int(a["level"]),
                        "msgs": int(a["msgs"]),
                        "elems": int(a["elems"]),
                    }
                ],
            }
        )
    return out


def refit_from_spans(spans, n_levels: int | None = None):
    """Least-squares per-level α/β from live traced rounds (see
    ``topo.calibrate.fit_level_costs`` for the model). ``n_levels`` defaults
    to 1 + the highest level any span saw."""
    from repro.topo.calibrate import fit_level_costs

    ms = round_measurements(spans)
    if not ms:
        raise ValueError("no traced comm-round spans with calibration features")
    if n_levels is None:
        n_levels = 1 + max(r["level"] for m in ms for r in m["rounds"])
    return fit_level_costs(ms, n_levels)


def persist_fitted_costs(fitted, path: str | None = None, *, samples=None) -> str:
    """Merge fitted per-level costs into the ``calibration`` block at
    ``path`` (default: the same ``results/BENCH_topology.json`` that
    ``topo.calibrate.load_fitted_costs`` reads), preserving every other key
    of an existing record. ``samples`` (the measurement dicts the fit came
    from) are stored under ``calibration.samples`` so the loader's
    refit-from-raw fallback keeps working."""
    from repro.topo.calibrate import DEFAULT_CALIBRATION_PATH

    path = path if path is not None else DEFAULT_CALIBRATION_PATH
    record = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                record = json.load(fh)
        except (OSError, json.JSONDecodeError):
            record = {}
    cal = record.setdefault("calibration", {})
    cal["fitted_level_costs"] = [
        {"level": j, "alpha_s": c.alpha, "beta_s_per_elem": c.beta}
        for j, c in enumerate(fitted)
    ]
    cal["source"] = "live-trace"
    if samples is not None:
        cal["samples"] = samples
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(record, fh, indent=2)
    return path


def feed_calibration(spans, path: str | None = None, n_levels: int | None = None):
    """The one-shot live loop: traced spans → measurements → α/β fit →
    persisted where ``load_fitted_costs`` / ``resolve_profile`` read it.
    Returns the fitted per-level :class:`~repro.topo.model.LinkCost`s."""
    fitted = refit_from_spans(spans, n_levels)
    persist_fitted_costs(fitted, path, samples=round_measurements(spans))
    return fitted


def fitted_costs_from_trace(path: str, n_levels: int | None = None):
    """Refit α/β straight from a trace file (JSONL span sink or Chrome
    trace) — the hook ``launch.profiles.resolve_profile`` uses when its
    ``calibration=`` argument is a trace path instead of a results JSON."""
    from repro.obs.export import read_spans

    return refit_from_spans(read_spans(path), n_levels)


def drift_rows(spans, threshold: float = 0.5) -> list[dict]:
    """Per traced round: predicted vs measured µs, relative error, and a
    ``flagged`` bool (|measured−predicted|/predicted > threshold), sorted by
    relative error descending — the drift report
    ``launch.perf_report.render_drift`` renders. Forced-host CPU meshes
    drift wildly (collective emulation, not ICI); on real hardware a flagged
    round means the α-β constants — or the schedule — need a second look."""
    rows = []
    for sp in comm_round_spans(spans):
        a = _attrs(sp)
        pred = a.get("predicted_us")
        if pred is None:
            continue
        meas = float(_field(sp, "dur_us", 0.0))
        rel = abs(meas - pred) / pred if pred > 0 else float("inf")
        rows.append(
            {
                "round": int(a["comm_round"]),
                "name": _field(sp, "name", ""),
                "algorithm": a.get("algorithm", ""),
                "level": a.get("level"),
                "predicted_us": float(pred),
                "measured_us": meas,
                "rel_err": rel,
                "flagged": rel > threshold,
            }
        )
    rows.sort(key=lambda r: r["rel_err"], reverse=True)
    return rows
