"""Nestable span tracing for the encode pipeline.

A :class:`Tracer` records :class:`Span` records — named, attributed,
wall-clocked intervals on one monotonic timeline (``time.perf_counter``
anchored at tracer creation). Spans nest: ``with tracer.span("outer"):``
inside another span records the parent index and depth, so an export
(``repro.obs.export``) can reconstruct the call tree and Perfetto renders
the nesting from the ``"X"`` complete-event containment.

The tracer is deliberately dumb — no sampling, no threads, no flushing
policy. Instrumented layers (``dist.collectives.ir_encode_jit(tracer=...)``,
``core.simulator.interpret(tracer=...)``, ``serve.engine.Engine``,
``benchmarks/run.py --trace``) open spans around their rounds/steps and
attach the :class:`~repro.core.ir.CommRound` metadata (round index,
transfer count, slots on the wire, predicted µs from the α-β model) as
span attributes; ``repro.obs.feed`` then turns those attributed spans back
into calibration measurements.

A module-level default tracer (:func:`set_tracer` / :func:`current_tracer`)
lets entry points like ``benchmarks/run.py --trace`` hand one tracer to
code they don't call directly.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Span:
    """One traced interval. ``ts_us``/``dur_us`` are microseconds on the
    owning tracer's monotonic timeline; ``parent`` is the index (into
    ``Tracer.spans``) of the enclosing span, or ``None`` at top level.
    ``attrs`` may be extended while the span is open (e.g. a measured
    byte count discovered mid-span)."""

    name: str
    ts_us: float
    dur_us: float = 0.0
    depth: int = 0
    parent: int | None = None
    attrs: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "ts_us": self.ts_us,
            "dur_us": self.dur_us,
            "depth": self.depth,
            "parent": self.parent,
            "attrs": dict(self.attrs),
        }


class Tracer:
    """Collects spans; see module doc. Spans are appended at OPEN time so
    ``spans`` is in start order and a parent always precedes its children;
    ``dur_us`` is filled when the span closes."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._stack: list[int] = []
        self.spans: list[Span] = []

    def now_us(self) -> float:
        """Microseconds since tracer creation (monotonic)."""
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, **attrs):
        """Open a nested span; yields the :class:`Span` so callers can add
        attrs (``sp.attrs["bytes"] = n``) before it closes."""
        sp = Span(
            name=name,
            ts_us=self.now_us(),
            depth=len(self._stack),
            parent=self._stack[-1] if self._stack else None,
            attrs=dict(attrs),
        )
        idx = len(self.spans)
        self.spans.append(sp)
        self._stack.append(idx)
        try:
            yield sp
        finally:
            sp.dur_us = self.now_us() - sp.ts_us
            self._stack.pop()

    def to_dicts(self) -> list[dict]:
        return [s.to_dict() for s in self.spans]


_DEFAULT: Tracer | None = None


def set_tracer(tracer: Tracer | None) -> None:
    """Install ``tracer`` as the process-wide default (None clears it)."""
    global _DEFAULT
    _DEFAULT = tracer


def current_tracer() -> Tracer | None:
    """The tracer installed by :func:`set_tracer`, if any — consulted by
    entry points that cannot take a ``tracer=`` argument directly."""
    return _DEFAULT
