# Runtime telemetry for the encode pipeline (ROADMAP: live-fed calibration).
#
# - trace.py    Tracer/Span: nestable, attributed wall-clock spans; the
#               instrumented layers (ir_encode_jit(tracer=...), the
#               interpret oracle, serve.Engine, benchmarks/run.py --trace)
#               stamp per-CommRound metadata onto them
# - export.py   Chrome-trace-event JSON (Perfetto-loadable) + JSONL span
#               sinks under results/traces/, and the reader for both
# - metrics.py  process-local counters/gauges/histograms registry with
#               deterministic JSON snapshots (encode.rounds,
#               encode.round_us{level=}, serve.step_us, ...)
# - feed.py     the live calibration loop: traced round spans → per-level
#               α/β refit → persisted where topo.calibrate.load_fitted_costs
#               (and hence launch.profiles.resolve_profile) reads them,
#               plus the predicted-vs-measured drift rows perf_report renders

from .export import (  # noqa: F401
    DEFAULT_TRACE_DIR,
    default_trace_path,
    read_spans,
    spans_to_chrome,
    write_chrome_trace,
    write_spans_jsonl,
)
from .feed import (  # noqa: F401
    comm_round_spans,
    drift_rows,
    feed_calibration,
    fitted_costs_from_trace,
    persist_fitted_costs,
    refit_from_spans,
    round_measurements,
)
from .metrics import (  # noqa: F401
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
)
from .trace import Span, Tracer, current_tracer, set_tracer  # noqa: F401
