"""Span export: Chrome-trace-event JSON (Perfetto-loadable) and JSONL sinks.

Two formats, one source of truth (:class:`~repro.obs.trace.Span`):

* :func:`write_chrome_trace` — the Trace Event Format's ``"X"`` complete
  events (``{"name", "ph": "X", "ts", "dur", "pid", "tid", "args"}``), one
  per span, sorted by start time. Load the file in Perfetto / ``chrome://
  tracing``; nesting renders from event containment on one track. Span
  attributes travel in ``args`` (JSON-safe stringification for anything
  exotic), so the per-round ``CommRound`` metadata — round index, transfer
  count, predicted µs — is inspectable in the UI and machine-checkable by
  ``tools/check_trace.py``.
* :func:`write_spans_jsonl` — one span dict per line under
  ``results/traces/`` by default: the machine-first sink
  ``repro.obs.feed`` and ``launch.perf_report.render_drift`` consume.

:func:`read_spans` loads either format back into plain span dicts (the
shape ``Span.to_dict`` produces), so every downstream consumer is
indifferent to which file it was handed.
"""

from __future__ import annotations

import json
import os

#: repo-root-relative default sink directory for traces
DEFAULT_TRACE_DIR = os.path.join(
    os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    ),
    "results",
    "traces",
)


def _as_dicts(spans) -> list[dict]:
    out = []
    for s in spans:
        out.append(s if isinstance(s, dict) else s.to_dict())
    return out


def _json_safe(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    try:  # numpy scalars
        return v.item()
    except AttributeError:
        return str(v)


def spans_to_chrome(spans, *, pid: int = 0, process_name: str = "repro") -> dict:
    """Spans → a Trace Event Format dict (``traceEvents`` of ``"X"`` complete
    events on one track, start-time sorted; a leading process-name metadata
    event labels the track in Perfetto)."""
    events = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "tid": 0,
            "args": {"name": process_name},
        }
    ]
    for sp in sorted(_as_dicts(spans), key=lambda d: d["ts_us"]):
        events.append(
            {
                "name": sp["name"],
                "ph": "X",
                "ts": float(sp["ts_us"]),
                "dur": max(float(sp["dur_us"]), 0.0),
                "pid": pid,
                "tid": 0,
                "args": _json_safe(sp.get("attrs", {})),
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans, path: str, **kw) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(spans_to_chrome(spans, **kw), fh, indent=2)
    return path


def write_spans_jsonl(spans, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as fh:
        for sp in _as_dicts(spans):
            fh.write(json.dumps(_json_safe(sp)) + "\n")
    return path


def default_trace_path(name: str, kind: str = "jsonl") -> str:
    """``results/traces/<name>.trace.json`` (chrome) or ``.jsonl`` (spans)."""
    ext = "trace.json" if kind == "chrome" else "jsonl"
    return os.path.join(DEFAULT_TRACE_DIR, f"{name}.{ext}")


def read_spans(path: str) -> list[dict]:
    """Load spans back from either sink format (see module doc)."""
    with open(path) as fh:
        text = fh.read()
    if path.endswith(".jsonl"):
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    record = json.loads(text)
    if isinstance(record, list):  # bare span-dict list
        return record
    spans = []
    for ev in record.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        spans.append(
            {
                "name": ev["name"],
                "ts_us": float(ev["ts"]),
                "dur_us": float(ev.get("dur", 0.0)),
                "depth": 0,
                "parent": None,
                "attrs": dict(ev.get("args", {})),
            }
        )
    return spans
