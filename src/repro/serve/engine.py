"""Serving engines: fixed-batch (legacy) and continuous-batching.

Two tiers share the model's compiled graphs:

* :class:`Engine` — the original fixed-capacity batch: prompts are
  right-padded and refed token-by-token through the single compiled
  decode step, then new tokens are sampled until max length or EOS. One
  long prompt or one slow finisher stalls the whole batch; it stays as
  the measured baseline and the encoder-decoder/recurrent fallback.

* :class:`ContinuousEngine` — the maxtext-style continuous-batching
  tier. A **separate compiled prefill graph**
  (``train.train_loop.make_prefill_step(into_cache=True)`` →
  ``models.model.Model.prefill_into_cache``) writes a whole prompt's
  K/V into one cache slot in a single forward pass and returns the first
  sampled token; prompts are right-padded to a length **bucket** so the
  number of prefill compilations is bounded by the bucket set (counted
  in ``serve.prefill_compiles``). A :class:`~repro.serve.scheduler.
  SlotScheduler` keeps a fixed pool of decode slots fed from a FIFO
  arrival queue — when a slot hits EOS or its token budget it is retired
  and the next queued request is prefilled into that slot **mid-decode**,
  without draining the batch. The decode step threads per-slot position
  counters and an active-slot mask entirely on device; the host syncs
  only every ``sync_every`` ticks (one bool-mask fetch), so retired
  slots cost no per-token sampling syncs.

Observability (``repro.obs``): ``serve.steps`` / ``serve.generate_ms`` /
``serve.tokens_per_s`` (generated-tokens-only in BOTH engines) /
``serve.eos_syncs_saved`` on the fixed path; ``serve.prefill_compiles``
/ ``serve.decode_steps`` / ``serve.ttft_ms`` / ``serve.e2e_ms`` /
``serve.slot_occupancy`` on the continuous path. Passing ``tracer=``
wraps prefills and decode chunks in spans and feeds the
``serve.step_us`` / ``serve.prefill_us`` / ``serve.decode_chunk_us``
latency histograms (forces a device sync per span — opt-in).
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.train_loop import make_decode_step, make_prefill_step

from .scheduler import (
    DEFAULT_BUCKETS,
    Request,
    RequestResult,
    SlotScheduler,
    bucket_for,
)


def _request_seed(req: Request) -> int:
    """The request's sampling-stream seed: explicit ``req.seed`` or a
    stable hash of its id — never a function of batch composition."""
    if req.seed is not None:
        return int(req.seed)
    return zlib.crc32(req.id.encode()) & 0x7FFFFFFF


def _percentiles_ms(samples_s: list[float]) -> dict:
    if not samples_s:
        return {"p50": 0.0, "p99": 0.0}
    ms = np.asarray(samples_s) * 1e3
    return {"p50": float(np.percentile(ms, 50)), "p99": float(np.percentile(ms, 99))}


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, total)
    steps: int
    #: per-sequence prompt + generated length, trimmed at the first EOS in
    #: the generated region (the EOS token itself counts)
    lengths: np.ndarray  # (B,)
    prompt_lens: np.ndarray  # (B,)


class Engine:
    """Fixed-batch engine (baseline + encdec/recurrent fallback)."""

    def __init__(
        self,
        model: Model,
        params,
        max_len: int = 256,
        mesh=None,
        rules=None,
        tracer=None,
        metrics=None,
    ):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(make_decode_step(model, mesh, rules))
        self._tracer = tracer
        self._metrics = metrics

    def _registry(self):
        if self._metrics is not None:
            return self._metrics
        from repro.obs.metrics import get_registry

        return get_registry()

    def generate(
        self,
        prompts: list[list[int]],
        max_new_tokens: int = 32,
        eos_id: int | None = None,
        greedy: bool = True,
        seed: int = 0,
        eos_check_every: int = 8,
    ) -> GenerationResult:
        B = len(prompts)
        cfg = self.model.cfg
        plen = np.array([len(p) for p in prompts])
        total = int(plen.max()) + max_new_tokens
        assert total <= self.max_len
        toks = np.zeros((B, total), dtype=np.int32)
        for b, p in enumerate(prompts):
            toks[b, : len(p)] = p
        cache = self.model.init_cache(B, self.max_len)
        if self.model.is_encdec:
            # stub frames: zeros (real system: audio frontend output)
            cache = dict(cache)
            cache["enc_out"] = jnp.zeros(
                (B, cfg.encdec.n_frames, cfg.d_model), self.model.dtype
            )
        toks_j = jnp.asarray(toks)
        key = jax.random.key(seed)
        reg = self._registry()
        tracer = self._tracer
        steps = 0
        last_t = 0
        t_start = time.perf_counter()
        for t in range(total - 1):
            cur = toks_j[:, t : t + 1]
            pos = jnp.full((B,), t, jnp.int32)
            if tracer is not None:
                with tracer.span("serve.step", step=steps, pos=t, batch=B) as sp:
                    logits, cache = self._step(self.params, cache, cur, pos)
                    jax.block_until_ready(logits)
                reg.histogram("serve.step_us").observe(sp.dur_us)
            else:
                logits, cache = self._step(self.params, cache, cur, pos)
            steps += 1
            last_t = t
            lg = logits[:, 0, : cfg.vocab_size]
            if greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, lg).astype(jnp.int32)
            # only overwrite positions beyond each prompt
            write = (t + 1) >= jnp.asarray(plen)
            new_col = jnp.where(write, nxt, toks_j[:, t + 1])
            toks_j = toks_j.at[:, t + 1].set(new_col)
            if eos_id is not None:
                # the all-sequences-done check is a device→host sync; batch
                # it every eos_check_every steps (and on the last step) so
                # the decode loop stays asynchronous in between
                due = steps % max(eos_check_every, 1) == 0 or t == total - 2
                if due:
                    if bool(jnp.all(jnp.any(toks_j == eos_id, axis=1))):
                        break
                else:
                    reg.counter("serve.eos_syncs_saved").inc()
        wall_s = time.perf_counter() - t_start
        toks_np = np.asarray(toks_j)
        # generated-tokens-only accounting: columns 0..last_t+1 are filled;
        # a sequence's generated region is [plen, last_t+2), EOS-trimmed
        filled = last_t + 2
        gen = np.clip(filled - plen, 0, max_new_tokens)
        if eos_id is not None:
            for b in range(B):
                region = toks_np[b, plen[b] : plen[b] + gen[b]]
                hits = np.nonzero(region == eos_id)[0]
                if hits.size:
                    gen[b] = hits[0] + 1
        reg.counter("serve.steps").inc(steps)
        reg.gauge("serve.generate_ms").set(wall_s * 1e3)
        if wall_s > 0:
            reg.gauge("serve.tokens_per_s").set(float(gen.sum()) / wall_s)
        return GenerationResult(
            tokens=toks_np,
            steps=steps,
            lengths=plen + gen,
            prompt_lens=plen,
        )


@dataclass
class ServeReport:
    """Outcome of one :meth:`ContinuousEngine.serve` run: per-request
    results (arrival order) + the latency/throughput aggregates the
    traffic harness commits to ``results/BENCH_serve.json``."""

    results: list[RequestResult]
    wall_s: float
    tokens_per_s: float  # generated tokens only
    ttft_ms: dict  # {"p50", "p99"}
    e2e_ms: dict  # {"p50", "p99"}
    slot_occupancy: float  # mean occupied-slot fraction over decode ticks
    prefill_compiles: int  # engine-lifetime compiled prefill graph count
    decode_steps: int
    #: guard.stats() when the run was coded (K/R, injected_faults,
    #: recoveries, requests_recovered, recovery_us percentiles)
    coded: dict | None = None

    @property
    def recoveries(self) -> int:
        return int(self.coded["recoveries"]) if self.coded else 0

    @property
    def requests_recovered(self) -> int:
        return int(self.coded["requests_recovered"]) if self.coded else 0

    def to_record(self) -> dict:
        """JSON-ready engine row for BENCH_serve.json."""
        rec = {
            "tokens_per_s": self.tokens_per_s,
            "ttft_ms": dict(self.ttft_ms),
            "e2e_ms": dict(self.e2e_ms),
            "slot_occupancy": self.slot_occupancy,
            "prefill_compiles": self.prefill_compiles,
            "decode_steps": self.decode_steps,
            "n_requests": len(self.results),
            "wall_s": self.wall_s,
        }
        if self.coded is not None:
            rec["coded"] = dict(self.coded)
        return rec


class ContinuousEngine:
    """Continuous-batching engine: compiled prefill graph per length
    bucket + slot-scheduled decode with mid-stream insertion."""

    def __init__(
        self,
        model: Model,
        params,
        n_slots: int = 4,
        max_len: int = 256,
        buckets=None,
        max_new_tokens: int = 32,
        mesh=None,
        rules=None,
        tracer=None,
        metrics=None,
    ):
        if not model.supports_prefill:
            raise NotImplementedError(
                f"{model.cfg.name}: one-pass prefill needs per-position cache "
                "rows (recurrent/encdec/VLM models serve via the fixed-batch "
                "Engine)"
            )
        if buckets is None:
            buckets = tuple(b for b in DEFAULT_BUCKETS if b <= max_len) or (max_len,)
        if max(buckets) > max_len:
            raise ValueError(f"bucket {max(buckets)} exceeds max_len {max_len}")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.max_new_tokens = max_new_tokens
        self._mesh, self._rules = mesh, rules
        self._tracer = tracer
        self._metrics = metrics
        self._prefill_jits: dict = {}  # (bucket, greedy) -> jitted graph
        self._tick_jits: dict = {}  # greedy -> jitted decode tick

    # -- observability ------------------------------------------------------
    def _registry(self):
        if self._metrics is not None:
            return self._metrics
        from repro.obs.metrics import get_registry

        return get_registry()

    @property
    def prefill_compiles(self) -> int:
        """Compiled prefill graphs over this engine's lifetime — bounded by
        len(buckets) per sampling mode by construction."""
        return len(self._prefill_jits)

    # -- compiled graphs ----------------------------------------------------
    def _tick_for(self, greedy: bool):
        tick = self._tick_jits.get(greedy)
        if tick is None:
            tick = self._make_tick(greedy)
            self._tick_jits[greedy] = tick
        return tick

    def _make_tick(self, greedy: bool):
        decode = make_decode_step(self.model, self._mesh, self._rules)
        V = self.model.cfg.vocab_size
        G = self.max_new_tokens

        def tick(params, cache, state, eos_id, temperature):
            logits, cache = decode(
                params, cache, state["last_tok"][:, None], state["pos"]
            )
            lg = logits[:, 0, :V]
            if greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                # per-slot streams: token i of a request is sampled with
                # fold_in(request_key, i) — independent of batch
                # composition, so slot-scheduled == one-at-a-time
                keys = jax.random.wrap_key_data(state["rng"])
                tok_keys = jax.vmap(jax.random.fold_in)(keys, state["gen_count"])
                nxt = jax.vmap(jax.random.categorical)(
                    tok_keys, lg / temperature
                ).astype(jnp.int32)
            active = state["active"]
            nxt = jnp.where(active, nxt, state["last_tok"])
            gc = state["gen_count"]
            # masked append: retired slots write nothing, cost no host sync
            write = (jnp.arange(G)[None, :] == gc[:, None]) & active[:, None]
            gen_buf = jnp.where(write, nxt[:, None], state["gen_buf"])
            gc = gc + active.astype(jnp.int32)
            pos = state["pos"] + active.astype(jnp.int32)
            hit_eos = active & (eos_id >= 0) & (nxt == eos_id)
            active = active & ~hit_eos & (gc < state["max_gen"])
            state = {
                "last_tok": nxt,
                "pos": pos,
                "active": active,
                "gen_buf": gen_buf,
                "gen_count": gc,
                "max_gen": state["max_gen"],
                "rng": state["rng"],
            }
            return cache, state

        return jax.jit(tick, donate_argnums=(1, 2))

    def _prefill_for(self, bucket: int, greedy: bool):
        key = (bucket, greedy)
        pf = self._prefill_jits.get(key)
        if pf is None:
            pf = self._make_prefill(greedy)
            self._prefill_jits[key] = pf
            self._registry().counter("serve.prefill_compiles").inc()
        return pf

    def _make_prefill(self, greedy: bool):
        raw = make_prefill_step(self.model, self._mesh, self._rules, into_cache=True)
        V = self.model.cfg.vocab_size
        G = self.max_new_tokens

        def prefill(
            params, cache, state, tokens, slot, plen, req_max, eos_id,
            rng_kd, temperature,
        ):
            last, cache = raw(params, cache, tokens, slot, plen)
            lg = last[0, :V]
            if greedy:
                t0 = jnp.argmax(lg).astype(jnp.int32)
            else:
                # token 0 of this request's stream (see _make_tick)
                k0 = jax.random.fold_in(jax.random.wrap_key_data(rng_kd), 0)
                t0 = jax.random.categorical(k0, lg / temperature).astype(jnp.int32)
            done = ((eos_id >= 0) & (t0 == eos_id)) | (req_max <= 1)
            row = jnp.zeros((G,), jnp.int32).at[0].set(t0)
            state = {
                "last_tok": state["last_tok"].at[slot].set(t0),
                "pos": state["pos"].at[slot].set(plen),
                "active": state["active"].at[slot].set(~done),
                "gen_buf": state["gen_buf"].at[slot].set(row),
                "gen_count": state["gen_count"].at[slot].set(1),
                "max_gen": state["max_gen"].at[slot].set(req_max),
                "rng": state["rng"].at[slot].set(rng_kd),
            }
            return cache, state

        return jax.jit(prefill, donate_argnums=(1, 2))

    # -- serve loop ---------------------------------------------------------
    def _validate(self, req: Request) -> None:
        plen = len(req.prompt)
        bucket_for(plen, self.buckets)  # raises if no bucket covers it
        if req.max_new_tokens < 1 or req.max_new_tokens > self.max_new_tokens:
            raise ValueError(
                f"{req.id}: max_new_tokens {req.max_new_tokens} outside "
                f"[1, {self.max_new_tokens}]"
            )
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"{req.id}: prompt {plen} + budget {req.max_new_tokens} "
                f"exceeds max_len {self.max_len}"
            )

    def serve(
        self,
        requests: list[Request],
        greedy: bool = True,
        eos_id: int | None = None,
        seed: int = 0,
        sync_every: int = 4,
        temperature: float = 1.0,
        guard=None,
    ) -> ServeReport:
        """Run a trace of requests to completion; returns a ServeReport with
        per-request results in arrival order.

        ``sync_every`` is the decode-chunk length between host syncs: one
        bool-mask fetch per chunk detects retirements (a finished slot may
        run up to ``sync_every - 1`` masked ticks before harvest — the
        latency/throughput knob).

        ``guard`` (a :class:`repro.serve.coded.CodedServeGuard`) makes the
        run straggler-tolerant: the decode-path state is LCC-encoded to
        N = K + R coded hosts before every chunk, host faults are polled
        at the chunk sync, and a lost host triggers exact reconstruction
        from any K survivors + a deterministic chunk replay — in-flight
        requests are recovered, not dropped, and the token streams stay
        bit-identical to an unfailed run.
        """
        if not greedy and temperature <= 0:
            raise ValueError(f"sampling needs temperature > 0, got {temperature}")
        reg = self._registry()
        tracer = self._tracer
        ordered = sorted(requests, key=lambda r: (r.arrival_s, r.id))
        sched = SlotScheduler(self.n_slots)
        for r in ordered:
            self._validate(r)
            sched.submit(r)
        S, G = self.n_slots, self.max_new_tokens
        cache = self.model.init_cache(S, self.max_len)
        state = {
            "last_tok": jnp.zeros((S,), jnp.int32),
            "pos": jnp.zeros((S,), jnp.int32),
            "active": jnp.zeros((S,), jnp.bool_),
            "gen_buf": jnp.zeros((S, G), jnp.int32),
            "gen_count": jnp.zeros((S,), jnp.int32),
            "max_gen": jnp.zeros((S,), jnp.int32),
            "rng": jnp.zeros((S, 2), jnp.uint32),
        }
        base_key = jax.random.key(seed)
        temp = jnp.float32(temperature)
        eos = jnp.int32(-1 if eos_id is None else eos_id)
        tick = self._tick_for(greedy)
        if guard is not None:
            guard.attach(reg, tracer)
        meta: dict[int, tuple[Request, float]] = {}  # slot -> (req, ttft_s)
        results: dict[str, RequestResult] = {}
        ticks_active = ticks_total = decode_steps = 0
        t0 = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t0

        def run_chunk(cache, state):
            for _ in range(sync_every):
                cache, state = tick(self.params, cache, state, eos, temp)
            return cache, state, np.asarray(state["active"])

        while sched.has_work:
            # 1. refill free slots with every arrived request (mid-decode
            #    insertion: the rest of the batch is untouched)
            while (a := sched.next_assignment(now())) is not None:
                slot, req = a
                plen = len(req.prompt)
                bucket = bucket_for(plen, self.buckets)
                pf = self._prefill_for(bucket, greedy)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :plen] = req.prompt
                rng_kd = jax.random.key_data(
                    jax.random.fold_in(base_key, _request_seed(req))
                )
                if tracer is not None:
                    with tracer.span(
                        "serve.prefill", slot=slot, bucket=bucket, plen=plen
                    ) as sp:
                        cache, state = pf(
                            self.params, cache, state, jnp.asarray(toks),
                            jnp.int32(slot), jnp.int32(plen),
                            jnp.int32(req.max_new_tokens), eos, rng_kd, temp,
                        )
                        jax.block_until_ready(state["last_tok"])
                    reg.histogram("serve.prefill_us").observe(sp.dur_us)
                else:
                    cache, state = pf(
                        self.params, cache, state, jnp.asarray(toks),
                        jnp.int32(slot), jnp.int32(plen),
                        jnp.int32(req.max_new_tokens), eos, rng_kd, temp,
                    )
                    # first token is materialized here — that's TTFT
                    jax.block_until_ready(state["last_tok"])
                ttft = now() - req.arrival_s
                meta[slot] = (req, ttft)
                reg.histogram("serve.ttft_ms").observe(ttft * 1e3)
            occ = sched.occupied
            if not occ:
                nxt_arr = sched.next_arrival_s()
                if nxt_arr is None:
                    break  # queue drained, all slots retired
                wait = nxt_arr - now()
                if wait > 0:
                    time.sleep(wait)
                continue
            # 2. one decode chunk: sync_every fully-async ticks, then a
            #    single host sync on the active mask to detect retirements.
            #    Under a guard the chunk-start state was LCC-encoded first,
            #    so a host lost mid-chunk costs one reconstruct + replay.
            if guard is not None:
                guard.snapshot(cache, state, tick=decode_steps)
            if tracer is not None:
                with tracer.span(
                    "serve.decode_chunk", ticks=sync_every, occupied=len(occ)
                ) as sp:
                    cache, state, active_now = run_chunk(cache, state)
                reg.histogram("serve.decode_chunk_us").observe(sp.dur_us)
            else:
                cache, state, active_now = run_chunk(cache, state)
            decode_steps += sync_every
            ticks_active += len(occ) * sync_every
            ticks_total += S * sync_every
            if guard is not None:
                dead = guard.poll(decode_steps)
                if dead:
                    # exact chunk-start state from any K survivors, then a
                    # deterministic replay (the PRNG lives in the state) —
                    # the replayed tokens are bit-identical
                    cache, state = guard.recover(
                        dead, requests_in_flight=len(occ)
                    )
                    cache, state, active_now = run_chunk(cache, state)
            # 3. harvest + retire finished slots (they refill next iteration)
            finished = [s for s in occ if not active_now[s]]
            if finished:
                gen_counts = np.asarray(state["gen_count"])
                gen_buf = np.asarray(state["gen_buf"])
                for s in finished:
                    req, ttft = meta.pop(s)
                    sched.retire(s)
                    g = int(gen_counts[s])
                    e2e = now() - req.arrival_s
                    results[req.id] = RequestResult(
                        id=req.id,
                        tokens=list(req.prompt) + gen_buf[s, :g].tolist(),
                        prompt_len=len(req.prompt),
                        gen_len=g,
                        ttft_s=ttft,
                        e2e_s=e2e,
                    )
                    reg.histogram("serve.e2e_ms").observe(e2e * 1e3)
        wall_s = now()
        out = [results[r.id] for r in ordered]
        gen_total = sum(r.gen_len for r in out)
        occupancy = (ticks_active / ticks_total) if ticks_total else 0.0
        tokens_per_s = (gen_total / wall_s) if wall_s > 0 else 0.0
        reg.counter("serve.decode_steps").inc(decode_steps)
        reg.gauge("serve.slot_occupancy").set(occupancy)
        reg.gauge("serve.tokens_per_s").set(tokens_per_s)
        return ServeReport(
            results=out,
            wall_s=wall_s,
            tokens_per_s=tokens_per_s,
            ttft_ms=_percentiles_ms([r.ttft_s for r in out]),
            e2e_ms=_percentiles_ms([r.e2e_s for r in out]),
            slot_occupancy=occupancy,
            prefill_compiles=self.prefill_compiles,
            decode_steps=decode_steps,
            coded=guard.stats() if guard is not None else None,
        )
