"""Batched serving engine: prefill + greedy/temperature decode loop.

Minimal-but-real: a fixed-capacity batch of sequences, each with its own
position counter; prompts are right-padded, prefill fills the caches via
per-token decode of the prompt region (keeps one compiled step — the
latency-optimal path would add a separate prefill graph, which
launch/dryrun.py exercises at the 32k shapes), then new tokens are sampled
until max length or EOS.

Observability (``repro.obs``): every ``generate`` records
``serve.steps`` / ``serve.tokens_per_s`` / ``serve.generate_ms`` into the
process-local metrics registry; passing ``tracer=`` to the constructor
additionally wraps each decode step in a span and feeds the
``serve.step_us`` latency histogram (this forces a device sync per step —
opt-in, like the traced encode path). EOS termination is checked only
every ``eos_check_every`` steps (plus the final step) instead of per
token: the ``bool(jnp.all(...))`` check is a device→host round-trip, and
batching it keeps the decode loop async; the avoided syncs are counted in
``serve.eos_syncs_saved``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.train_loop import make_decode_step


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, total)
    steps: int


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        max_len: int = 256,
        mesh=None,
        rules=None,
        tracer=None,
        metrics=None,
    ):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(make_decode_step(model, mesh, rules))
        self._tracer = tracer
        self._metrics = metrics

    def _registry(self):
        if self._metrics is not None:
            return self._metrics
        from repro.obs.metrics import get_registry

        return get_registry()

    def generate(
        self,
        prompts: list[list[int]],
        max_new_tokens: int = 32,
        eos_id: int | None = None,
        greedy: bool = True,
        seed: int = 0,
        eos_check_every: int = 8,
    ) -> GenerationResult:
        B = len(prompts)
        cfg = self.model.cfg
        plen = np.array([len(p) for p in prompts])
        total = int(plen.max()) + max_new_tokens
        assert total <= self.max_len
        toks = np.zeros((B, total), dtype=np.int32)
        for b, p in enumerate(prompts):
            toks[b, : len(p)] = p
        cache = self.model.init_cache(B, self.max_len)
        if self.model.is_encdec:
            # stub frames: zeros (real system: audio frontend output)
            cache = dict(cache)
            cache["enc_out"] = jnp.zeros(
                (B, cfg.encdec.n_frames, cfg.d_model), self.model.dtype
            )
        toks_j = jnp.asarray(toks)
        key = jax.random.key(seed)
        reg = self._registry()
        tracer = self._tracer
        steps = 0
        t_start = time.perf_counter()
        for t in range(total - 1):
            cur = toks_j[:, t : t + 1]
            pos = jnp.full((B,), t, jnp.int32)
            if tracer is not None:
                with tracer.span("serve.step", step=steps, pos=t, batch=B) as sp:
                    logits, cache = self._step(self.params, cache, cur, pos)
                    jax.block_until_ready(logits)
                reg.histogram("serve.step_us").observe(sp.dur_us)
            else:
                logits, cache = self._step(self.params, cache, cur, pos)
            steps += 1
            lg = logits[:, 0, : cfg.vocab_size]
            if greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, lg).astype(jnp.int32)
            # only overwrite positions beyond each prompt
            write = (t + 1) >= jnp.asarray(plen)
            new_col = jnp.where(write, nxt, toks_j[:, t + 1])
            toks_j = toks_j.at[:, t + 1].set(new_col)
            if eos_id is not None:
                # the all-sequences-done check is a device→host sync; batch
                # it every eos_check_every steps (and on the last step) so
                # the decode loop stays asynchronous in between
                due = steps % max(eos_check_every, 1) == 0 or t == total - 2
                if due:
                    if bool(jnp.all(jnp.any(toks_j == eos_id, axis=1))):
                        break
                else:
                    reg.counter("serve.eos_syncs_saved").inc()
        wall_s = time.perf_counter() - t_start
        reg.counter("serve.steps").inc(steps)
        reg.gauge("serve.generate_ms").set(wall_s * 1e3)
        if wall_s > 0:
            reg.gauge("serve.tokens_per_s").set(steps * B / wall_s)
        return GenerationResult(tokens=np.asarray(toks_j), steps=steps)
