"""Coded straggler-tolerant serving: LCC-protected decode state.

The paper's all-to-all encode exists so decentralized computation survives
failures; this module wires it into the continuous-batching engine. The
decode-path state (every layer's KV-cache slab + the per-slot decode state
holding the logits-contribution counters, token buffers and PRNG streams)
is flattened to field limbs, sharded K ways, and encoded into **N = K + R
coded replicas** with the padded Lagrange/Vandermonde generator
(``repro.coded.lcc_encode`` — one universal prepare-and-shoot all-to-all
encode; with ``mesh=`` the same generator executes through
``dist.collectives.ir_encode_jit`` as ppermute rounds on an N-wide host
axis). Each coded shard is owned by one simulated "host".

A :class:`FaultInjector` kills hosts at scheduled decode ticks (or a
:class:`ProcessHostPool` host — a real OS process holding its shard —
is SIGKILLed). The engine detects the fault at the next chunk sync,
:class:`CodedServeGuard` reconstructs the exact chunk-start state from any
K of the surviving shards via Lagrange interpolation
(``repro.coded.lcc_decode``), and the chunk replays deterministically —
requests in flight on the dead host are **recovered, not dropped**, and
the emitted token stream is bit-identical to an unfailed run.

Observability: ``serve.recoveries`` (hosts recovered from), ``serve.
recovery_us`` (reconstruction latency histogram), ``serve.snapshots``,
and a ``serve.recovery`` span per event when a tracer is attached.
"""

from __future__ import annotations

import base64
import contextlib
import signal
import subprocess
import sys
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.coded.lagrange_compute import (
    build_lcc,
    lcc_decode,
    lcc_encode,
    lcc_encode_collective,
    lcc_pad,
)
from repro.coded.rs_checkpoint import shard_state_limbs, unshard_state_limbs
from repro.core.field import NTT


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


@dataclass
class FaultInjector:
    """Deterministic fault schedule: kill host ``h`` once decode tick ``t``
    has completed. ``due(now)`` returns the not-yet-fired kills with
    ``t < now`` (the chunk that crossed tick t detects them at its sync)."""

    kills: tuple[tuple[int, int], ...]  # (tick, host) pairs
    _fired: set = field(default_factory=set)

    def due(self, now_tick: int) -> list[tuple[int, int]]:
        out = []
        for i, (t, h) in enumerate(self.kills):
            if i not in self._fired and t < now_tick:
                self._fired.add(i)
                out.append((t, h))
        return out

    @property
    def injected(self) -> int:
        """Faults fired so far."""
        return len(self._fired)


# ---------------------------------------------------------------------------
# host processes (the SIGKILL-able variant)
# ---------------------------------------------------------------------------

#: the whole host program: store one shard, serve it back on request. No
#: repro imports — a host is just memory that can die.
_HOST_LOOP = r"""
import sys
store = None
for line in sys.stdin:
    line = line.strip()
    if not line:
        continue
    cmd, _, arg = line.partition(" ")
    if cmd == "put":
        store = arg
        sys.stdout.write("ok\n")
    elif cmd == "get":
        sys.stdout.write(("none" if store is None else store) + "\n")
    elif cmd == "quit":
        break
    else:
        sys.stdout.write("err\n")
    sys.stdout.flush()
"""


class ProcessHostPool:
    """N coded-shard hosts, each a separate OS process holding its shard in
    its own memory over a line pipe — so a ``SIGKILL`` is a *real* host
    loss, not a simulation flag. Store/fetch failures (dead pipe, EOF)
    report the host dead rather than raising."""

    def __init__(self, n_hosts: int):
        self.procs = [
            subprocess.Popen(
                [sys.executable, "-c", _HOST_LOOP],
                stdin=subprocess.PIPE,
                stdout=subprocess.PIPE,
                text=True,
                bufsize=1,
            )
            for _ in range(n_hosts)
        ]

    def __len__(self) -> int:
        return len(self.procs)

    def alive(self, host: int) -> bool:
        return self.procs[host].poll() is None

    def store(self, host: int, shard: np.ndarray) -> bool:
        p = self.procs[host]
        if p.poll() is not None:
            return False
        payload = base64.b64encode(
            np.ascontiguousarray(shard, dtype=np.uint32).tobytes()
        ).decode()
        try:
            p.stdin.write(f"put {payload}\n")
            p.stdin.flush()
            return p.stdout.readline().strip() == "ok"
        except (BrokenPipeError, OSError, ValueError):
            return False

    def fetch(self, host: int) -> np.ndarray | None:
        p = self.procs[host]
        if p.poll() is not None:
            return None
        try:
            p.stdin.write("get\n")
            p.stdin.flush()
            line = p.stdout.readline().strip()
        except (BrokenPipeError, OSError, ValueError):
            return None
        if not line or line in ("none", "err"):
            return None
        return np.frombuffer(base64.b64decode(line), dtype=np.uint32).copy()

    def kill(self, host: int, sig: int = signal.SIGKILL) -> None:
        p = self.procs[host]
        if p.poll() is None:
            p.send_signal(sig)
            p.wait()  # the host is DEAD before the engine carries on

    def close(self) -> None:
        for p in self.procs:
            if p.poll() is None:
                try:
                    p.stdin.write("quit\n")
                    p.stdin.flush()
                except (BrokenPipeError, OSError, ValueError):
                    pass
                p.terminate()
            p.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# the K-of-N decode group
# ---------------------------------------------------------------------------


class CodedDecodeGroup:
    """The N = K + R coded shard holders and the any-K-of-N reconstruction.

    A "host" is either an in-memory slot (default) or one
    :class:`ProcessHostPool` child process. The group hands coded shard j
    to host j after each encode, tracks which hosts are alive, and
    rebuilds all K data shards from the first K survivors via Lagrange
    interpolation (``repro.coded.lcc_decode``)."""

    def __init__(self, plan, hosts: ProcessHostPool | None = None):
        if hosts is not None and len(hosts) != plan.N:
            raise ValueError(
                f"host pool has {len(hosts)} hosts, need N={plan.N}"
            )
        self.plan = plan
        self.hosts = hosts
        self.alive: set[int] = set(range(plan.N))
        self._mem: dict[int, np.ndarray] = {}

    def store(self, coded: np.ndarray) -> None:
        """Hand coded row j to host j; a host found dead mid-store is
        dropped from the alive set, not raised on."""
        self._mem = {}
        for j in sorted(self.alive):
            if self.hosts is not None:
                if not self.hosts.store(j, coded[j]):
                    self.alive.discard(j)
            else:
                self._mem[j] = np.asarray(coded[j], dtype=np.uint32)

    def kill(self, host: int) -> bool:
        """Take host down (SIGKILL when it is a process). Returns whether
        it was alive — dead hosts can't die twice."""
        if host not in self.alive:
            return False
        if self.hosts is not None:
            self.hosts.kill(host)
        self.alive.discard(host)
        return True

    def scan(self) -> list[int]:
        """Detect hosts that died without the injector's help (process
        pools only — an in-memory slot can't die by itself)."""
        if self.hosts is None:
            return []
        dead = [h for h in sorted(self.alive) if not self.hosts.alive(h)]
        self.alive.difference_update(dead)
        return dead

    def reconstruct(self) -> np.ndarray:
        """All K data shards, bit-exact, from the first K surviving coded
        shards. Raises RuntimeError when fewer than K survive — past the
        code's R-failure tolerance there is nothing to interpolate."""
        values, responders = [], []
        for j in sorted(self.alive):
            if self.hosts is not None:
                v = self.hosts.fetch(j)
                if v is None:  # died between scan and fetch
                    self.alive.discard(j)
                    continue
            else:
                v = self._mem.get(j)
                if v is None:
                    continue
            values.append(v)
            responders.append(j)
            if len(responders) == self.plan.K:
                break
        if len(responders) < self.plan.K:
            raise RuntimeError(
                f"{len(responders)} coded shards survive, need "
                f"K={self.plan.K} (R={self.plan.R} tolerates at most "
                f"{self.plan.R} lost hosts)"
            )
        return lcc_decode(self.plan, np.stack(values), responders)


# ---------------------------------------------------------------------------
# the guard
# ---------------------------------------------------------------------------


class CodedServeGuard:
    """``train.elastic.CodedStateGuard``'s pattern extended to the serving
    engine: snapshot the decode-path state as N = K + R LCC shards every
    decode chunk, and rebuild the exact chunk-start state from any K
    survivors after a host loss.

    Wire it in with ``ContinuousEngine.serve(..., guard=guard)``; the
    engine calls :meth:`snapshot` before each decode chunk, :meth:`poll`
    at the chunk sync, and :meth:`recover` + chunk replay when a host died.

    ``hosts=`` (a :class:`ProcessHostPool`) stores each shard in its own
    OS process — the injector then delivers real SIGKILLs, and externally
    killed hosts are detected at :meth:`poll` too. ``mesh=``/``axis=``
    (an N-wide mesh axis) routes the encode through the ScheduleIR mesh
    executor ``dist.collectives.ir_encode_jit`` instead of the
    single-program jit."""

    def __init__(
        self,
        K: int,
        R: int = 1,
        p: int = 1,
        q: int = NTT,
        injector: FaultInjector | None = None,
        hosts: ProcessHostPool | None = None,
        mesh=None,
        axis: str | None = None,
        kernels: str | None = None,
    ):
        if R < 1:
            raise ValueError("coded serving needs R ≥ 1 parity shards")
        self.plan = build_lcc(K, p=p, q=q, R=R)
        self.K, self.R, self.N = K, R, K + R
        self.injector = injector
        self.group = CodedDecodeGroup(self.plan, hosts=hosts)
        if mesh is not None:
            if axis is None:
                raise ValueError("mesh= requires axis=")
            self._encode = lcc_encode_collective(
                mesh, axis, self.plan, kernels=kernels
            )
        else:
            plan = self.plan
            self._encode = jax.jit(
                lambda xp: lcc_encode(plan, xp[: plan.K])
            )
        self._meta = None
        self._tick = -1
        self._metrics = None
        self._tracer = None
        #: every fault seen: (host, decode tick at detection)
        self.faults: list[tuple[int, int]] = []
        self.recoveries = 0
        self.requests_recovered = 0
        self.recovery_us: list[float] = []
        self.snapshots = 0

    # -- engine plumbing ----------------------------------------------------
    def attach(self, metrics, tracer) -> None:
        self._metrics, self._tracer = metrics, tracer

    @property
    def alive(self) -> set[int]:
        return self.group.alive

    @property
    def injected_faults(self) -> int:
        """Scheduled kills fired (injector) or external deaths detected."""
        return self.injector.injected if self.injector is not None else len(self.faults)

    def snapshot(self, cache, state, tick: int) -> None:
        """Encode the decode-path state ((cache, state) pytree → limbs →
        K shards → N coded shards) and hand shard j to host j."""
        shards, meta = shard_state_limbs((cache, state), self.K)
        coded = np.asarray(
            self._encode(lcc_pad(self.plan, shards)), dtype=np.uint32
        )
        self._meta, self._tick = meta, tick
        self.group.store(coded)
        self.snapshots += 1
        if self._metrics is not None:
            self._metrics.counter("serve.snapshots").inc()

    def poll(self, now_tick: int) -> list[int]:
        """Fire due injector kills (SIGKILL when hosts are processes) and
        detect externally dead hosts; returns hosts lost this chunk."""
        dead = []
        if self.injector is not None:
            for _t, h in self.injector.due(now_tick):
                if self.group.kill(h):
                    dead.append(h)
        dead.extend(self.group.scan())
        for h in dead:
            self.faults.append((h, now_tick))
        return dead

    def recover(self, dead: list[int], requests_in_flight: int = 0):
        """Rebuild the chunk-start (cache, state) bit-exactly from any K
        surviving coded shards (Lagrange interpolation). Raises RuntimeError
        once fewer than K shards survive — beyond the code's tolerance."""
        if self._meta is None:
            raise RuntimeError("no snapshot taken before recovery")
        span = (
            self._tracer.span(
                "serve.recovery", hosts=str(sorted(dead)), tick=self._tick
            )
            if self._tracer is not None
            else contextlib.nullcontext()
        )
        with span:
            t0 = time.perf_counter()
            X = self.group.reconstruct()
            cache, state = unshard_state_limbs(
                jnp.asarray(X.astype(np.uint32)), self._meta
            )
            jax.block_until_ready(jax.tree.leaves(state))
            dur_us = (time.perf_counter() - t0) * 1e6
        self.recoveries += len(dead)
        self.requests_recovered += requests_in_flight
        self.recovery_us.append(dur_us)
        if self._metrics is not None:
            self._metrics.counter("serve.recoveries").inc(len(dead))
            self._metrics.histogram("serve.recovery_us").observe(dur_us)
        return cache, state

    def stats(self) -> dict:
        """JSON-ready recovery block for the benchmark record."""
        us = sorted(self.recovery_us)
        return {
            "K": self.K,
            "R": self.R,
            "n_hosts": self.N,
            "injected_faults": self.injected_faults,
            "recoveries": self.recoveries,
            "requests_recovered": self.requests_recovered,
            "snapshots": self.snapshots,
            "recovery_us": {
                "p50": float(np.percentile(us, 50)) if us else 0.0,
                "p99": float(np.percentile(us, 99)) if us else 0.0,
            },
        }
