"""Synthetic serving traffic: seeded Poisson arrivals over a mixed
prompt-length distribution.

``poisson_trace`` is the workload generator behind ``benchmarks/
bench_serve.py``: exponential interarrival gaps at ``rate_rps`` requests
per second, each request drawing its prompt length from a weighted set of
:class:`LengthBand`\\ s (short chat turns vs. long documents) and its
token ids uniformly from the vocabulary. Everything is derived from one
``numpy`` Generator seed, so the fixed-batch baseline and the continuous
engine can be measured on the *same* trace and two benchmark runs produce
identical workloads.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .scheduler import Request


@dataclass(frozen=True)
class LengthBand:
    """Uniform prompt-length band [lo, hi] with a sampling weight."""

    lo: int
    hi: int
    weight: float


#: default mixed-length workload: mostly short turns, a tail of long prompts
DEFAULT_MIX = (
    LengthBand(4, 16, 0.55),
    LengthBand(17, 48, 0.30),
    LengthBand(49, 120, 0.15),
)


def poisson_trace(
    n_requests: int,
    rate_rps: float,
    mix=DEFAULT_MIX,
    max_new_tokens: int = 16,
    vocab_size: int = 256,
    seed: int = 0,
) -> list[Request]:
    """``n_requests`` seeded requests, sorted by arrival time.

    Arrivals: cumulative Exp(1/rate_rps) gaps. Prompt lengths: pick a band
    by weight, then uniform within it. Generation budgets: uniform in
    [max(1, max_new_tokens // 2), max_new_tokens] so finishers stagger —
    the case continuous batching exists for.
    """
    if n_requests < 1:
        raise ValueError("need at least one request")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps)
    weights = np.array([b.weight for b in mix], dtype=np.float64)
    weights = weights / weights.sum()
    bands = rng.choice(len(mix), size=n_requests, p=weights)
    lo_new = max(1, max_new_tokens // 2)
    reqs = []
    for i in range(n_requests):
        band = mix[int(bands[i])]
        plen = int(rng.integers(band.lo, band.hi + 1))
        prompt = rng.integers(1, vocab_size, size=plen).astype(np.int32).tolist()
        reqs.append(
            Request(
                id=f"req-{i:04d}",
                prompt=prompt,
                max_new_tokens=int(rng.integers(lo_new, max_new_tokens + 1)),
                arrival_s=float(arrivals[i]),
            )
        )
    return reqs
