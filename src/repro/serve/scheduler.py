"""Slot-based continuous-batching scheduler (host-side, framework-free).

The decode batch is a fixed pool of ``n_slots`` slots. Requests enter a
FIFO queue stamped with an arrival time; ``next_assignment`` hands out
(slot, request) pairs whenever a slot is free AND the head of the queue
has arrived — so a finished slot is refilled mid-decode without draining
the rest of the batch. The scheduler is pure bookkeeping (no jax): the
engine owns the device state and calls back in at retire/assign points,
which keeps this logic unit-testable without a model.

Slot lifecycle::

    FREE --assign--> OCCUPIED --retire (EOS / max-tokens)--> FREE

Prefill length bucketing lives here too: ``bucket_for(plen, buckets)``
rounds a prompt length up to the next bucket so the compiled prefill
graph count is bounded by ``len(buckets)`` instead of one graph per
distinct prompt length.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

#: default prefill length buckets (right-pad the prompt to the next one)
DEFAULT_BUCKETS = (32, 64, 128, 256)


def bucket_for(plen: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket ≥ plen. Raises if the prompt outgrows every bucket
    (pick buckets that cover the workload's max prompt length)."""
    if plen < 1:
        raise ValueError(f"prompt length must be ≥ 1, got {plen}")
    for b in sorted(buckets):
        if plen <= b:
            return int(b)
    raise ValueError(
        f"prompt length {plen} exceeds largest prefill bucket {max(buckets)}"
    )


@dataclass
class Request:
    """One serving request: a prompt, a generation budget, and the time it
    arrives (seconds, relative to serve start — 0 means 'already queued')."""

    id: str
    prompt: list[int]
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    #: per-request sampling stream (None derives one from ``id``), so a
    #: request's sampled tokens never depend on batch composition
    seed: int | None = None


@dataclass
class RequestResult:
    """Per-request outcome: full token sequence (prompt + generated,
    EOS-trimmed) and the two latencies the harness reports."""

    id: str
    tokens: list[int]
    prompt_len: int
    gen_len: int
    ttft_s: float
    e2e_s: float

    @property
    def length(self) -> int:
        return self.prompt_len + self.gen_len


@dataclass
class _Slot:
    request: Request
    started_s: float


class SlotScheduler:
    """Fixed pool of decode slots + FIFO arrival queue.

    The engine drives it: ``submit`` requests, then alternate
    ``next_assignment(now)`` (claims a free slot for the oldest arrived
    request) with ``retire(slot)`` (frees a slot whose sequence finished).
    ``occupied`` / ``has_work`` expose the state the serve loop needs for
    occupancy accounting and termination.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._slots: list[_Slot | None] = [None] * n_slots
        self._queue: deque[Request] = deque()

    # -- queue side ---------------------------------------------------------
    def submit(self, request: Request) -> None:
        self._queue.append(request)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def next_arrival_s(self) -> float | None:
        """Arrival time of the queue head (None if the queue is empty)."""
        return self._queue[0].arrival_s if self._queue else None

    # -- slot side ----------------------------------------------------------
    @property
    def occupied(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def free(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def request_in(self, slot: int) -> Request:
        s = self._slots[slot]
        assert s is not None, f"slot {slot} is free"
        return s.request

    def next_assignment(self, now_s: float) -> tuple[int, Request] | None:
        """Claim the lowest free slot for the oldest ARRIVED request; None
        if no slot is free or the queue head hasn't arrived yet."""
        if not self._queue or self._queue[0].arrival_s > now_s:
            return None
        free = self.free
        if not free:
            return None
        req = self._queue.popleft()
        slot = free[0]
        self._slots[slot] = _Slot(request=req, started_s=now_s)
        return slot, req

    def retire(self, slot: int) -> Request:
        s = self._slots[slot]
        assert s is not None, f"retiring free slot {slot}"
        self._slots[slot] = None
        return s.request
