from .coded import (  # noqa: F401
    CodedDecodeGroup,
    CodedServeGuard,
    FaultInjector,
    ProcessHostPool,
)
from .engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    GenerationResult,
    ServeReport,
)
from .scheduler import (  # noqa: F401
    DEFAULT_BUCKETS,
    Request,
    RequestResult,
    SlotScheduler,
    bucket_for,
)
from .traffic import DEFAULT_MIX, LengthBand, poisson_trace  # noqa: F401
