"""Whisper-base: encoder-decoder, conv frontend STUB (precomputed frame
embeddings via input_specs) [arXiv:2212.04356]."""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,  # decoder layers; encoder layers in encdec
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,  # padded to 51968
    encdec=EncDecConfig(n_enc_layers=6, n_frames=1500),
    source="arXiv:2212.04356 (6L enc + 6L dec, d512 8H ff2048 v51865)",
)
