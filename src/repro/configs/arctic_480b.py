"""Snowflake Arctic 480B: dense-MoE hybrid — every layer has a dense
residual FFN in parallel with a 128-expert top-2 MoE
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    rope_theta=1e6,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        expert_ff=4864,
        dense_residual_ff=4864,
        router_softmax_topk=True,
    ),
    source="hf:Snowflake/snowflake-arctic-base (35L d7168 56H kv8 ff4864 v32000, 128e top-2 + dense residual)",
)
