"""Architecture registry: full assigned configs + reduced smoke variants."""

from __future__ import annotations

from .base import (
    EncDecConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    VLMConfig,
)
from . import (
    arctic_480b,
    deepseek_coder_33b,
    deepseek_v3_671b,
    internlm2_20b,
    internvl2_26b,
    jamba_v0_1_52b,
    qwen1_5_32b,
    qwen3_1_7b,
    rwkv6_3b,
    whisper_base,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen1_5_32b,
        deepseek_coder_33b,
        qwen3_1_7b,
        internlm2_20b,
        arctic_480b,
        deepseek_v3_671b,
        rwkv6_3b,
        jamba_v0_1_52b,
        internvl2_26b,
        whisper_base,
    )
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, small
    width, tiny vocab/experts — preserves every structural feature."""
    cfg = get(name)
    kw: dict = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=96,
        vocab_size=503,  # deliberately non-multiple of 256 → padding path
        vocab_padded=0,
        remat="none",
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            expert_ff=32,
            shared_ff=32 if cfg.moe.shared_ff else 0,
            dense_residual_ff=32 if cfg.moe.dense_residual_ff else 0,
            layer_period=cfg.moe.layer_period,
            layer_offset=cfg.moe.layer_offset,
            first_dense=min(cfg.moe.first_dense, 1),
            dense_ff=96 if cfg.moe.dense_ff else 0,
            router_softmax_topk=cfg.moe.router_softmax_topk,
            norm_topk_prob=cfg.moe.norm_topk_prob,
        )
    if cfg.mla:
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
        kw["head_dim"] = 16
    if cfg.ssm:
        kw["ssm"] = SSMConfig(
            kind=cfg.ssm.kind,
            d_state=8,
            d_conv=cfg.ssm.d_conv,
            expand=cfg.ssm.expand,
            attn_layer_period=4 if cfg.ssm.attn_layer_period else 0,
            attn_layer_offset=min(cfg.ssm.attn_layer_offset, 3),
        )
        if cfg.ssm.kind == "rwkv6":
            kw["n_heads"] = 4
            kw["d_model"] = 64  # head_dim 16
        if cfg.ssm.attn_layer_period:
            kw["n_layers"] = 4  # one full jamba period
            if cfg.moe:
                kw["moe"] = kw["moe"].__class__(
                    **{**kw["moe"].__dict__, "layer_period": 2, "layer_offset": 1}
                )
    if cfg.encdec:
        kw["encdec"] = EncDecConfig(n_enc_layers=2, n_frames=8)
    if cfg.vlm:
        kw["vlm"] = VLMConfig(n_patches=4)
    if cfg.mtp:
        kw["mtp"] = True
    return cfg.replace(name=f"{cfg.name}-smoke", **kw)


def all_arch_names() -> list[str]:
    return list(ARCHS)
