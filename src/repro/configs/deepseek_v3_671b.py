"""DeepSeek-V3 671B: MLA + 256-expert top-8 MoE (1 shared), 3 leading dense
layers, MTP [arXiv:2412.19437; hf]."""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,  # routed-expert width (assignment's d_ff)
    vocab_size=129280,
    rope_theta=1e4,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        expert_ff=2048,
        shared_ff=2048,  # 1 shared expert
        first_dense=3,
        dense_ff=18432,
        router_softmax_topk=False,  # sigmoid/topk-then-norm style routing
        norm_topk_prob=True,
    ),
    mtp=True,
    source="arXiv:2412.19437 (61L d7168 128H MLA, 256e top-8 + 1 shared, MTP)",
)
