from .base import SHAPES, ModelConfig, ShapeSpec, shape_applicable  # noqa: F401
from .registry import ARCHS, all_arch_names, get, smoke_config  # noqa: F401
