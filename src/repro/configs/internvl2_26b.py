"""InternVL2-26B: InternViT-6B frontend (STUB: precomputed patch embeddings)
+ InternLM2-20B backbone [arXiv:2404.16821; hf]."""
from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,  # padded to 92672 (vocab_padded auto)
    rope_theta=1e6,
    vlm=VLMConfig(n_patches=256),
    source="arXiv:2404.16821 (InternViT stub + InternLM2-20B: 48L d6144 48H kv8 ff16384 v92553)",
)
