"""RWKV-6 (Finch) 3B: attention-free, data-dependent decay WKV
[arXiv:2404.05892; hf]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # head_dim 64 (RWKV convention d/64)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6"),
    source="arXiv:2404.05892 (32L d2560 attn-free ff8960 v65536)",
)
