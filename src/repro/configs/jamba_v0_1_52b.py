"""Jamba-v0.1 52B: Mamba+attention 1:7 interleave, 16-expert top-2 MoE on
alternate layers [arXiv:2403.19887; hf]."""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        expert_ff=14336,
        layer_period=2,
        layer_offset=1,
    ),
    ssm=SSMConfig(
        kind="mamba",
        d_state=16,
        d_conv=4,
        expand=2,
        attn_layer_period=8,
        attn_layer_offset=4,
    ),
    source="arXiv:2403.19887 (32L d4096 32H kv8 ff14336 v65536, attn 1:7, 16e top-2)",
)
