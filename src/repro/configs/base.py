"""Config dataclasses for architectures and input shapes."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    shared_ff: int = 0  # width of always-on shared expert(s); 0 = none
    dense_residual_ff: int = 0  # Arctic: dense FFN in parallel with the MoE
    layer_period: int = 1  # MoE every `period` layers ...
    layer_offset: int = 0  # ... starting at `offset`
    first_dense: int = 0  # leading dense layers (DeepSeek-V3: 3)
    dense_ff: int = 0  # d_ff of the dense layers when first_dense > 0
    capacity_factor: float = 1.25
    router_softmax_topk: bool = True  # False → topk-then-softmax (DeepSeek)
    norm_topk_prob: bool = False


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"  # "mamba" | "rwkv6"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None
    attn_layer_period: int = 0  # Jamba: attention every `period` layers
    attn_layer_offset: int = 0


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 6
    n_frames: int = 1500  # precomputed frame-embedding stub length


@dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256  # precomputed patch-embedding stub length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    vocab_padded: int = 0  # 0 → auto-pad to multiple of 256
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    mtp: bool = False  # DeepSeek-V3 multi-token-prediction module
    dtype: str = "bfloat16"
    remat: str = "block"  # "none" | "block" — activation checkpoint per layer
    time_chunk: int = 0  # >0: chunk+checkpoint SSM/RWKV time scans (§Perf lever)
    source: str = ""  # public provenance tag

    def __post_init__(self):
        if self.vocab_padded == 0:
            object.__setattr__(
                self, "vocab_padded", ((self.vocab_size + 255) // 256) * 256
            )
        assert self.vocab_padded >= self.vocab_size

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch has an O(1)-state decode path (long_500k eligible)."""
        return self.ssm is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs a sub-quadratic state path; "
            f"{cfg.name} is a pure full-attention architecture (skip per brief)"
        )
    return True, ""
