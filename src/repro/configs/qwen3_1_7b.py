"""Qwen3-1.7B: dense GQA with qk_norm [hf:Qwen/Qwen3-1.7B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-1.7B (28L d2048 16H kv8 ff6144 v151936, qk_norm)",
)
