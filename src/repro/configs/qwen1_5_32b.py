"""Qwen1.5-32B: dense, QKV bias, large vocab [hf:Qwen/Qwen1.5-32B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-32B (per-assignment: 64L d5120 40H kv40 ff27392 v152064)",
)
