import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Second dry-run pass: trip-count-aware costs (launch/jaxpr_cost.py) and
differential-compile collective correction.

XLA's HloCostAnalysis counts while bodies once (verified; see jaxpr_cost
docstring), so the first-pass `cost` and `collectives` fields undercount
scanned layers by ~n_layers×. This pass updates each cell JSON with:

* ``jaxpr_cost``: global flops/bytes from the scan-aware jaxpr walk
  (exact dot flops incl. backward + remat recompute),
* ``collectives_corrected``: per-device collective bytes from two extra
  compiles at body-repeat counts r=1 and r=2 — per-layer collective delta
  Δ = coll(r2) − coll(r1), corrected = coll(r1) + (R−1)·Δ. (Collectives
  never sit inside the inner attention/time scans, so the layer-level
  differential is exact for them.)

Usage: PYTHONPATH=src python -m repro.launch.costpass [--multi-pod] [--out results/dryrun]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get, shape_applicable  # noqa: E402
from repro.configs.registry import all_arch_names  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.jaxpr_cost import cost_of_fn  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.rules import big_model, rules_for  # noqa: E402
from repro.models import build_model, decode_input_specs, train_batch_specs  # noqa: E402
from repro.models.model import layer_pattern  # noqa: E402
from repro.train import (  # noqa: E402
    OptConfig,
    batch_shardings,
    cache_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_state_shardings,
    param_shardings,
    state_specs,
)
from repro.dist.sharding import named_sharding  # noqa: E402


def _cfg_with_repeats(cfg, r: int):
    prefix, body, repeats = layer_pattern(cfg)
    n_layers = len(prefix) + r * len(body)
    kw = {"n_layers": n_layers}
    if cfg.encdec is not None:
        kw["encdec"] = cfg.encdec.__class__(
            n_enc_layers=r, n_frames=cfg.encdec.n_frames
        )
    return cfg.replace(**kw), repeats


def _build_step(cfg, shape, mesh, rules, moment_dtype=None):
    """Returns (jitted_or_fn, arg_specs) for the cell's step function."""
    model = build_model(cfg)
    pshapes, _ = model.param_specs()
    ps = param_shardings(model, mesh, rules) if mesh else None
    if shape.kind == "train":
        ocfg = OptConfig(
            moment_dtype=moment_dtype
            or ("bfloat16" if big_model(cfg) else "float32")
        )
        step = make_train_step(model, ocfg, mesh=mesh, rules=rules)
        ospecs = state_specs(ocfg, pshapes)
        bspecs = train_batch_specs(cfg, shape)
        args = (pshapes, ospecs, bspecs)
        if mesh:
            jt = jax.jit(
                step,
                in_shardings=(
                    ps,
                    opt_state_shardings(ocfg, model, mesh, rules),
                    batch_shardings(model, mesh, rules, "train"),
                ),
                out_shardings=(ps, opt_state_shardings(ocfg, model, mesh, rules), None),
            )
        else:
            jt = step
        return jt, args
    if shape.kind == "prefill":
        step = make_prefill_step(model, mesh=mesh, rules=rules)
        bspecs = train_batch_specs(cfg, shape)
        bspecs.pop("labels")
        args = (pshapes, bspecs)
        if mesh:
            bshard = {
                k: v
                for k, v in batch_shardings(model, mesh, rules, "train").items()
                if k in bspecs
            }
            jt = jax.jit(step, in_shardings=(ps, bshard), out_shardings=None)
        else:
            jt = step
        return jt, args
    step = make_decode_step(model, mesh=mesh, rules=rules)
    cshapes = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
    dspecs = decode_input_specs(cfg, shape)
    args = (pshapes, cshapes, dspecs["tokens"], dspecs["pos"])
    if mesh:
        cshard = cache_shardings(model, mesh, rules, cshapes)
        jt = jax.jit(
            step,
            in_shardings=(
                ps,
                cshard,
                named_sharding(mesh, rules, ("batch", None), dspecs["tokens"].shape),
                named_sharding(mesh, rules, ("batch",), dspecs["pos"].shape),
            ),
            out_shardings=(None, cshard),
        )
    else:
        jt = step
    return jt, args


def costpass_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if not os.path.exists(path):
        print(f"[missing] {path}")
        return
    rec = json.load(open(path))
    if rec.get("status") != "ok":
        return
    have_jaxpr = "jaxpr_cost" in rec and "tile_bytes_global" in rec.get("jaxpr_cost", {})
    have_coll = "collectives_corrected" in rec
    if have_jaxpr and have_coll:
        print(f"[done already] {arch} {shape_name} {mesh_tag}")
        return
    cfg = get(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    try:
        # --- jaxpr walk (no mesh needed: logical/global program) ----------
        if not have_jaxpr:
            fn, args = _build_step(cfg, shape, None, None)
            c = cost_of_fn(fn, *args)
            rec["jaxpr_cost"] = {
                "flops_global": c.flops,
                "bytes_global": c.bytes,
                "tile_bytes_global": c.tile_bytes,
                "has_while": c.has_while,
                "by_op": {
                    k: v for k, v in sorted(c.by_op.items(), key=lambda kv: -kv[1])
                },
            }
        # --- differential collective compile -------------------------------
        if not have_coll:
            mesh = make_production_mesh(multi_pod=multi_pod)
            rules = rules_for(cfg, shape)
            colls = {}
            for r in (1, 2):
                cfg_r, repeats = _cfg_with_repeats(cfg, r)
                jt, args_r = _build_step(cfg_r, shape, mesh, rules)
                txt = jt.lower(*args_r).compile().as_text()
                colls[r] = parse_collectives(txt)
            _, R = _cfg_with_repeats(cfg, 1)
            merged = {}
            for op in set(colls[1]) | set(colls[2]):
                b1 = colls[1].get(op, {}).get("bytes", 0)
                b2 = colls[2].get(op, {}).get("bytes", 0)
                delta = b2 - b1
                merged[op] = {
                    "bytes": int(b1 + (R - 1) * delta),
                    "base": b1,
                    "per_layer": delta,
                }
            rec["collectives_corrected"] = merged
            rec["collective_bytes_per_device_corrected"] = int(
                sum(max(v["bytes"], 0) for v in merged.values())
            )
        rec["costpass_s"] = round(time.time() - t0, 2)
        print(
            f"[cost] {arch} × {shape_name} × {mesh_tag}: "
            f"jaxpr flops {rec['jaxpr_cost']['flops_global']:.3e}, coll_corr "
            f"{rec['collective_bytes_per_device_corrected'] / 1e9:.2f} GB/dev "
            f"({rec['costpass_s']}s)"
        )
    except Exception as e:  # noqa: BLE001
        rec["costpass_error"] = f"{type(e).__name__}: {e}"
        rec["costpass_traceback"] = traceback.format_exc()[-3000:]
        print(f"[cost ERROR] {arch} {shape_name}: {rec['costpass_error']}")
    json.dump(rec, open(path, "w"), indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = [args.arch] if args.arch else all_arch_names()
    for mp in meshes:
        for arch in archs:
            for shape in SHAPES:
                costpass_cell(arch, shape, mp, args.out)


if __name__ == "__main__":
    main()
