"""Production mesh construction + the network topology it implies.

A FUNCTION (not a module constant) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (TPU v5e-256). Multi-pod:
(pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries only
data-parallel gradient reduction (DCN-friendly), ``model`` stays inside the
pod's ICI domain.

:func:`production_topology` models the coded-checkpoint encode domain (the
DP replicas) as a recursive :class:`~repro.topo.model.Hierarchy` so
``launch.profiles.resolve_profile`` can pick the encode algorithm from the
network rather than hard-coding the flat schedule — the pure host-side
mirror of :func:`make_production_mesh` (no devices needed to price it).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def production_topology(*, multi_pod: bool = False):
    """Topology of the DP-replica encode domain of the production mesh.

    Each DP replica is a model-parallel group of 16 chips; the 16 replicas
    of a pod sit 4-per-slice across 4 slices, so replica↔replica traffic is
    chip-level ICI inside a slice, slice-trunk ICI across slices, and DCI
    across pods. Multi-pod (K = 32 replicas): three-level chip < slice < pod
    ``Hierarchy(levels=(4, 4, 2))``. Single pod (K = 16): two-level
    ``Hierarchy(levels=(4, 4))``. Per-level α/β come from
    ``topo.model.default_level_costs`` (ICI → geometric midpoint → DCI).
    """
    from repro.topo import Hierarchy

    return Hierarchy(levels=(4, 4, 2) if multi_pod else (4, 4))


def mesh_encode_levels(mesh, axes) -> tuple[int, ...]:
    """Innermost-first level sizes of an encode domain spanning ``axes``
    (given outermost → innermost, the order multilevel_encode_jit takes)."""
    return tuple(int(mesh.shape[a]) for a in reversed(tuple(axes)))


def topology_for_mesh(mesh, axes):
    """Derive the :class:`Hierarchy` a mesh's encode axes imply (outermost
    axis = slowest level), for autotuning against a live mesh."""
    from repro.topo import Hierarchy

    return Hierarchy(levels=mesh_encode_levels(mesh, axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh with Auto axis types where the installed jax supports
    them (>= 0.5); older versions have no axis_types kwarg and every axis is
    implicitly Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
