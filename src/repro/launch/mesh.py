"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (TPU v5e-256). Multi-pod:
(pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries only
data-parallel gradient reduction (DCN-friendly), ``model`` stays inside the
pod's ICI domain.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh with Auto axis types where the installed jax supports
    them (>= 0.5); older versions have no axis_types kwarg and every axis is
    implicitly Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
