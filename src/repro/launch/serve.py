"""Production serving launcher: mesh-placed params + serving engine.

Continuous batching by default (compiled bucketed prefill + slot
scheduler); ``--engine fixed`` falls back to the fixed-batch loop (also
the automatic fallback for model kinds without one-pass prefill:
recurrent, encoder-decoder, VLM).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompts "1,2,3;4,5" --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_mesh
from repro.launch.profiles import BASELINE, rules_for
from repro.models import build_model
from repro.serve import ContinuousEngine, Engine, Request
from repro.train import latest_step, param_shardings, restore_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--prompts", default="1,2,3;7,8")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--engine", choices=["continuous", "fixed"], default="continuous")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    shape = ShapeSpec("cli", "decode", args.max_len, 1)
    rules = rules_for(cfg, shape, BASELINE)
    model = build_model(cfg)
    ps = param_shardings(model, mesh, rules)
    params = jax.jit(model.init, out_shardings=ps)(jax.random.key(0))
    if args.ckpt and latest_step(args.ckpt) is not None:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        params, _ = restore_checkpoint(args.ckpt, like, shardings=ps)

    prompts = [[int(t) for t in p.split(",") if t] for p in args.prompts.split(";")]
    use_continuous = args.engine == "continuous" and model.supports_prefill
    if args.engine == "continuous" and not use_continuous:
        print(f"{cfg.name}: no one-pass prefill; falling back to fixed-batch")

    if use_continuous:
        eng = ContinuousEngine(
            model, params, n_slots=args.slots, max_len=args.max_len,
            max_new_tokens=args.max_new, mesh=mesh, rules=rules,
        )
        reqs = [
            Request(id=f"cli-{i}", prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)
        ]
        rep = eng.serve(reqs)
        print(
            f"{rep.decode_steps} decode steps, {len(rep.results)} reqs, "
            f"{rep.tokens_per_s:.1f} tok/s, ttft p99 {rep.ttft_ms['p99']:.1f} ms, "
            f"{rep.prefill_compiles} prefill graphs"
        )
        for r in rep.results:
            print(f"{r.id}: {r.tokens}")
    else:
        eng = Engine(model, params, max_len=args.max_len, mesh=mesh, rules=rules)
        t0 = time.time()
        res = eng.generate(prompts, max_new_tokens=args.max_new)
        dt = time.time() - t0
        print(f"{res.steps} decode steps, {len(prompts)} seqs, {dt:.2f}s")
        for i, row in enumerate(res.tokens):
            print(f"seq {i}: {row[: res.lengths[i]].tolist()}")


if __name__ == "__main__":
    main()
