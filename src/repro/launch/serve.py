"""Production serving launcher: mesh-placed params + serving engine.

Continuous batching by default (compiled bucketed prefill + slot
scheduler); ``--engine fixed`` falls back to the fixed-batch loop (also
the automatic fallback for model kinds without one-pass prefill:
recurrent, encoder-decoder, VLM).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompts "1,2,3;4,5" --max-new 16

``--coded K,R`` makes the run straggler-tolerant: the decode-path state
is LCC-encoded to N = K + R simulated hosts every chunk
(``serve.coded.CodedServeGuard``) and ``--kill TICK:HOST`` (repeatable)
injects host faults mid-trace — in-flight requests are recovered from
any K surviving shards, not dropped:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompts "1,2,3;4,5" --coded 3,2 --kill 2:0 --kill 6:4
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_mesh
from repro.launch.profiles import BASELINE, rules_for
from repro.models import build_model
from repro.serve import (
    CodedServeGuard,
    ContinuousEngine,
    Engine,
    FaultInjector,
    Request,
)
from repro.train import latest_step, param_shardings, restore_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--prompts", default="1,2,3;7,8")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--engine", choices=["continuous", "fixed"], default="continuous")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument(
        "--coded", default=None, metavar="K,R",
        help="LCC-protect the decode state: K data + R parity shards "
        "over N=K+R simulated hosts (continuous engine only)",
    )
    ap.add_argument(
        "--kill", action="append", default=[], metavar="TICK:HOST",
        help="inject a host fault after decode tick TICK (repeatable; "
        "needs --coded)",
    )
    args = ap.parse_args()
    if args.kill and args.coded is None:
        ap.error("--kill requires --coded K,R")

    cfg = smoke_config(args.arch) if args.smoke else get(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    shape = ShapeSpec("cli", "decode", args.max_len, 1)
    rules = rules_for(cfg, shape, BASELINE)
    model = build_model(cfg)
    ps = param_shardings(model, mesh, rules)
    params = jax.jit(model.init, out_shardings=ps)(jax.random.key(0))
    if args.ckpt and latest_step(args.ckpt) is not None:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        params, _ = restore_checkpoint(args.ckpt, like, shardings=ps)

    prompts = [[int(t) for t in p.split(",") if t] for p in args.prompts.split(";")]
    use_continuous = args.engine == "continuous" and model.supports_prefill
    if args.engine == "continuous" and not use_continuous:
        print(f"{cfg.name}: no one-pass prefill; falling back to fixed-batch")

    if args.coded is not None and not use_continuous:
        raise SystemExit("--coded needs the continuous engine")

    if use_continuous:
        guard = None
        if args.coded is not None:
            K, R = (int(x) for x in args.coded.split(","))
            kills = tuple(
                tuple(int(x) for x in k.split(":")) for k in args.kill
            )
            guard = CodedServeGuard(
                K=K, R=R,
                injector=FaultInjector(kills=kills) if kills else None,
            )
        eng = ContinuousEngine(
            model, params, n_slots=args.slots, max_len=args.max_len,
            max_new_tokens=args.max_new, mesh=mesh, rules=rules,
        )
        reqs = [
            Request(id=f"cli-{i}", prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)
        ]
        rep = eng.serve(reqs, guard=guard)
        print(
            f"{rep.decode_steps} decode steps, {len(rep.results)} reqs, "
            f"{rep.tokens_per_s:.1f} tok/s, ttft p99 {rep.ttft_ms['p99']:.1f} ms, "
            f"{rep.prefill_compiles} prefill graphs"
        )
        if rep.coded is not None:
            c = rep.coded
            print(
                f"coded K={c['K']} R={c['R']}: {c['injected_faults']} faults "
                f"injected, {c['recoveries']} hosts recovered from, "
                f"{c['requests_recovered']} in-flight requests recovered, "
                f"recovery p99 {c['recovery_us']['p99']:.0f} us"
            )
        for r in rep.results:
            print(f"{r.id}: {r.tokens}")
    else:
        eng = Engine(model, params, max_len=args.max_len, mesh=mesh, rules=rules)
        t0 = time.time()
        res = eng.generate(prompts, max_new_tokens=args.max_new)
        dt = time.time() - t0
        print(f"{res.steps} decode steps, {len(prompts)} seqs, {dt:.2f}s")
        for i, row in enumerate(res.tokens):
            print(f"seq {i}: {row[: res.lengths[i]].tolist()}")


if __name__ == "__main__":
    main()
