"""Render EXPERIMENTS.md §Perf from results/perf_iterations.jsonl."""

from __future__ import annotations

import json
import sys


def render(log_path: str = "results/perf_iterations.jsonl") -> str:
    rows = [json.loads(l) for l in open(log_path)]
    out = []
    out.append(
        "| iter | cell | levers | compute_s | mem_s (flash) | coll_s | coll GB/dev | "
        "temp GB/dev | bound | roofline-frac |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        lev = "+".join(k for k, v in r["levers"].items() if v) or "—"
        mf = r.get("memory_flash_s", r["memory_s"])
        out.append(
            f"| {r['profile']} | {r['arch']}×{r['shape']}×{r['mesh']} | {lev} | "
            f"{r['compute_s']:.3f} | {mf:.3f} | {r['collective_s']:.3f} | "
            f"{r['collective_gb_per_dev']:.1f} | {r['temp_gb_per_dev']:.0f} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} |"
        )
    out.append("")
    out.append("Hypotheses (verbatim from the run log):")
    out.append("")
    for r in rows:
        verdict = ""
        out.append(f"* **{r['profile']}** — {r.get('hypothesis', '')}")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else "results/perf_iterations.jsonl"))
