"""Render EXPERIMENTS.md §Perf from results/perf_iterations.jsonl, the
topology validation table from results/BENCH_topology.json (predicted α-β
time vs. measured wall time per algorithm — the autotuner calibration
input), and the per-round predicted-vs-measured drift table from a trace
file (:func:`render_drift` — the observability layer's report)."""

from __future__ import annotations

import json
import sys


def render(log_path: str = "results/perf_iterations.jsonl") -> str:
    rows = [json.loads(l) for l in open(log_path)]
    out = []
    out.append(
        "| iter | cell | levers | compute_s | mem_s (flash) | coll_s | coll GB/dev | "
        "temp GB/dev | bound | roofline-frac |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        lev = "+".join(k for k, v in r["levers"].items() if v) or "—"
        mf = r.get("memory_flash_s", r["memory_s"])
        out.append(
            f"| {r['profile']} | {r['arch']}×{r['shape']}×{r['mesh']} | {lev} | "
            f"{r['compute_s']:.3f} | {mf:.3f} | {r['collective_s']:.3f} | "
            f"{r['collective_gb_per_dev']:.1f} | {r['temp_gb_per_dev']:.0f} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} |"
        )
    out.append("")
    out.append("Hypotheses (verbatim from the run log):")
    out.append("")
    for r in rows:
        verdict = ""
        out.append(f"* **{r['profile']}** — {r.get('hypothesis', '')}")
    return "\n".join(out)


def _topology_table(r: dict, K, p, payload) -> list:
    out = [
        f"Topology benchmark — K={K}, p={p}, payload "
        f"{payload} elems, mesh {r['mesh']}, model {r['topology']}; "
        f"autotuner choice: **{r['autotuner_choice']}**",
        "",
        "| algorithm | C1 | C2 | predicted µs | measured µs |",
        "|---|---|---|---|---|",
    ]
    for alg, pred in r["predicted"].items():
        meas = r["measured_us"].get(alg)
        out.append(
            f"| {alg} | {pred['c1']} | {pred['c2']} | {pred['us']:.1f} | "
            f"{f'{meas:.1f}' if meas is not None else '—'} |"
        )
    return out


def render_topology(path: str = "results/BENCH_topology.json") -> str:
    r = json.load(open(path))
    out = _topology_table(r, r["K"], r["p"], r["payload_elems"])
    if "three_level" in r:
        out.append("")
        out.extend(
            _topology_table(r["three_level"], r["K"], r["p"], r["payload_elems"])
        )
    out.append("")
    out.append(
        "Measured numbers come from forced-host CPU meshes (collective "
        "emulation, not ICI) — feed them back via `autotune(..., measured=...)` "
        "rather than comparing across columns directly."
    )
    return "\n".join(out)


def render_drift(source, threshold: float = 0.5) -> str:
    """Per-round predicted-vs-measured drift table, sorted by relative
    error (worst first). ``source`` is a trace file path (the JSONL span
    sink or Chrome trace ``dist.collectives.ir_encode_jit(tracer=...)``
    emitted) or an in-memory span list; rows whose
    |measured−predicted|/predicted exceeds ``threshold`` are flagged ⚠ —
    on real hardware those are the rounds whose α/β constants (or
    schedule) are mispriced and should be re-fed through
    ``obs.feed.feed_calibration``."""
    from repro.obs.feed import drift_rows

    if isinstance(source, str):
        from repro.obs.export import read_spans

        source = read_spans(source)
    rows = drift_rows(source, threshold)
    out = [
        f"Per-round drift — predicted α-β µs vs. measured wall µs "
        f"(flag threshold: rel err > {threshold:g})",
        "",
        "| round | algorithm | level | predicted µs | measured µs | rel err | |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lvl = "—" if r["level"] is None else str(r["level"])
        out.append(
            f"| {r['round']} | {r['algorithm']} | {lvl} | "
            f"{r['predicted_us']:.1f} | {r['measured_us']:.1f} | "
            f"{r['rel_err']:.2f} | {'⚠' if r['flagged'] else ''} |"
        )
    if not rows:
        out.append("| — | — | — | — | — | — | (no traced rounds) |")
    out.append("")
    n_flag = sum(r["flagged"] for r in rows)
    out.append(
        f"{n_flag}/{len(rows)} rounds flagged. Forced-host CPU traces "
        "always drift (collective emulation, not ICI); refit with "
        "`obs.feed.feed_calibration` to re-price from these measurements."
    )
    return "\n".join(out)


if __name__ == "__main__":
    arg = sys.argv[1] if len(sys.argv) > 1 else "results/perf_iterations.jsonl"
    if arg.endswith(".jsonl") and "trace" in arg:
        print(render_drift(arg))
    elif arg.endswith(".trace.json"):
        print(render_drift(arg))
    elif arg.endswith(".json"):
        print(render_topology(arg))
    else:
        print(render(arg))
