"""Render EXPERIMENTS.md §Perf from results/perf_iterations.jsonl, and the
topology validation table from results/BENCH_topology.json (predicted α-β
time vs. measured wall time per algorithm — the autotuner calibration
input)."""

from __future__ import annotations

import json
import sys


def render(log_path: str = "results/perf_iterations.jsonl") -> str:
    rows = [json.loads(l) for l in open(log_path)]
    out = []
    out.append(
        "| iter | cell | levers | compute_s | mem_s (flash) | coll_s | coll GB/dev | "
        "temp GB/dev | bound | roofline-frac |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        lev = "+".join(k for k, v in r["levers"].items() if v) or "—"
        mf = r.get("memory_flash_s", r["memory_s"])
        out.append(
            f"| {r['profile']} | {r['arch']}×{r['shape']}×{r['mesh']} | {lev} | "
            f"{r['compute_s']:.3f} | {mf:.3f} | {r['collective_s']:.3f} | "
            f"{r['collective_gb_per_dev']:.1f} | {r['temp_gb_per_dev']:.0f} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} |"
        )
    out.append("")
    out.append("Hypotheses (verbatim from the run log):")
    out.append("")
    for r in rows:
        verdict = ""
        out.append(f"* **{r['profile']}** — {r.get('hypothesis', '')}")
    return "\n".join(out)


def _topology_table(r: dict, K, p, payload) -> list:
    out = [
        f"Topology benchmark — K={K}, p={p}, payload "
        f"{payload} elems, mesh {r['mesh']}, model {r['topology']}; "
        f"autotuner choice: **{r['autotuner_choice']}**",
        "",
        "| algorithm | C1 | C2 | predicted µs | measured µs |",
        "|---|---|---|---|---|",
    ]
    for alg, pred in r["predicted"].items():
        meas = r["measured_us"].get(alg)
        out.append(
            f"| {alg} | {pred['c1']} | {pred['c2']} | {pred['us']:.1f} | "
            f"{f'{meas:.1f}' if meas is not None else '—'} |"
        )
    return out


def render_topology(path: str = "results/BENCH_topology.json") -> str:
    r = json.load(open(path))
    out = _topology_table(r, r["K"], r["p"], r["payload_elems"])
    if "three_level" in r:
        out.append("")
        out.extend(
            _topology_table(r["three_level"], r["K"], r["p"], r["payload_elems"])
        )
    out.append("")
    out.append(
        "Measured numbers come from forced-host CPU meshes (collective "
        "emulation, not ICI) — feed them back via `autotune(..., measured=...)` "
        "rather than comparing across columns directly."
    )
    return "\n".join(out)


if __name__ == "__main__":
    arg = sys.argv[1] if len(sys.argv) > 1 else "results/perf_iterations.jsonl"
    print(render_topology(arg) if arg.endswith(".json") else render(arg))
