import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and record memory/cost/collective analyses (deliverable (e)).

MUST be run as its own process (the two lines above run before any other
import — jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Per cell it writes results/dryrun/<arch>__<shape>__<mesh>.json with:
    memory_analysis  (per-device arg/output/temp bytes)
    cost_analysis    (per-device HLO flops / bytes accessed)
    collectives      (op-type → count + output bytes, parsed from the
                      compiled per-device HLO — the ICI roofline term)
    param/state byte totals, skip reasons, wall times.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get, shape_applicable  # noqa: E402
from repro.configs.registry import all_arch_names  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.rules import big_model, rules_for  # noqa: E402
from repro.models import build_model, decode_input_specs, train_batch_specs  # noqa: E402
from repro.train import (  # noqa: E402
    OptConfig,
    batch_shardings,
    cache_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_state_shardings,
    param_shardings,
    state_specs,
)
from repro.dist.sharding import named_sharding  # noqa: E402

_COLL_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum OUTPUT bytes of every collective op in the per-device HLO.

    '-start' variants are counted once ('-done' carries no shape of its own
    in the match because its operand is the start op's result token — the
    regex only matches ops whose result is an array type).
    """
    out: dict[str, dict] = {}
    seen_start = set()
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        full = m.group(0)
        if "-done(" in full:
            continue
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def _tree_bytes(shapes) -> int:
    return int(
        sum(
            np.prod(l.shape, dtype=np.int64) * jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(shapes)
        )
    )


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, force=False):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        print(f"[skip existing] {path}")
        return json.load(open(path))

    cfg = get(arch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        json.dump(rec, open(path, "w"), indent=2)
        print(f"[skipped by design] {arch} × {shape_name}: {reason}")
        return rec

    t_start = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(np.prod(list(mesh.shape.values())))
        rules = rules_for(cfg, shape)
        model = build_model(cfg)
        pshapes, _ = model.param_specs()
        rec["param_bytes"] = _tree_bytes(pshapes)
        ps = param_shardings(model, mesh, rules)

        if shape.kind == "train":
            ocfg = OptConfig(
                moment_dtype="bfloat16" if big_model(cfg) else "float32"
            )
            rec["moment_dtype"] = ocfg.moment_dtype
            step = make_train_step(model, ocfg, mesh=mesh, rules=rules)
            oshard = opt_state_shardings(ocfg, model, mesh, rules)
            ospecs = state_specs(ocfg, pshapes)
            bshard = batch_shardings(model, mesh, rules, "train")
            bspecs = train_batch_specs(cfg, shape)
            rec["state_bytes"] = rec["param_bytes"] + _tree_bytes(ospecs)
            jitted = jax.jit(
                step, in_shardings=(ps, oshard, bshard), out_shardings=(ps, oshard, None)
            )
            args = (pshapes, ospecs, bspecs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, mesh=mesh, rules=rules)
            bshard = batch_shardings(model, mesh, rules, "train")
            bspecs = train_batch_specs(cfg, shape)
            bspecs.pop("labels")
            bshard = {k: v for k, v in bshard.items() if k in bspecs}
            jitted = jax.jit(step, in_shardings=(ps, bshard), out_shardings=None)
            args = (pshapes, bspecs)
        else:  # decode
            step = make_decode_step(model, mesh=mesh, rules=rules)
            cshapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            rec["cache_bytes"] = _tree_bytes(cshapes)
            cshard = cache_shardings(model, mesh, rules, cshapes)
            dspecs = decode_input_specs(cfg, shape)
            tshard = named_sharding(mesh, rules, ("batch", None), dspecs["tokens"].shape)
            pshard_pos = named_sharding(mesh, rules, ("batch",), dspecs["pos"].shape)
            jitted = jax.jit(
                step,
                in_shardings=(ps, cshard, tshard, pshard_pos),
                out_shardings=(None, cshard),
            )
            args = (pshapes, cshapes, dspecs["tokens"], dspecs["pos"])

        lowered = jitted.lower(*args)
        t_low = time.time()
        compiled = lowered.compile()
        t_comp = time.time()

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        cost = {
            "flops_per_device": float(ca.get("flops", -1.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed", -1.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        txt = compiled.as_text()
        colls = parse_collectives(txt)

        rec.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t_low - t_start, 2),
            compile_s=round(t_comp - t_low, 2),
            memory=mem,
            cost=cost,
            collectives=colls,
            collective_bytes_per_device=int(sum(c["bytes"] for c in colls.values())),
            hlo_bytes=len(txt),
        )
        print(
            f"[ok] {arch} × {shape_name} × {mesh_tag}: "
            f"compile {rec['compile_s']}s, "
            f"flops/dev {cost['flops_per_device']:.3e}, "
            f"coll {rec['collective_bytes_per_device']/1e6:.1f} MB/dev, "
            f"temp {mem['temp_bytes']/1e9:.2f} GB/dev"
        )
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[ERROR] {arch} × {shape_name} × {mesh_tag}: {rec['error']}")
    json.dump(rec, open(path, "w"), indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in all_arch_names() for s in SHAPES]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]
    for mp in meshes:
        for arch, shape in cells:
            dryrun_cell(arch, shape, mp, args.out, force=args.force)


if __name__ == "__main__":
    main()
