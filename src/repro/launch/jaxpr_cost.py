"""Trip-count-aware cost model from the jaxpr (launch/roofline.py input).

XLA's HloCostAnalysis counts while-loop bodies ONCE (verified empirically —
a scan of 10 dots reports 1 dot), which silently destroys the compute/memory
roofline for scanned-layer models. This walker traverses the CLOSED jaxpr —
where every ``scan`` carries its static trip count — and accumulates:

* flops: 2·MACs for dot_general (batch/contract aware); |out| for
  elementwise arithmetic; 0 for layout/move ops.
* hbm_bytes: inputs+outputs of dot_general / gather / scatter / reduce /
  cumulative ops at full weight, elementwise traffic at 1/FUSION_DISCOUNT
  weight (XLA fuses elementwise chains; the discount — default 4 — models a
  4-op average fusion depth; documented in EXPERIMENTS §Roofline).
* per-op breakdown for the hillclimb's "where are the flops" question.

Scan bodies are multiplied by ``length``; while bodies (none in our models)
by 1 with a warning flag; cond branches by their max.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

import jax


FUSION_DISCOUNT = 4.0

_MOVE_OPS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "rev",
    "convert_element_type", "bitcast_convert_type", "copy", "stop_gradient",
    "slice",
}
_HEAVY_OPS = {
    "dot_general", "gather", "scatter", "scatter-add", "scatter_add",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "sort",
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "argmax",
    "argmin", "reduce_and", "reduce_or", "cumsum", "cummax", "cumlogsumexp",
    "top_k", "iota", "pad",
}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    # bytes attributable to attention score/prob TILES: rank ≥ 4 arrays whose
    # last two dims are both ≥ 256 (e.g. (B,Hkv,G,1024,1024) f32). In the
    # fused TPU kernel these are VMEM-resident (1024²·4B = 4 MiB < 16 MiB
    # VMEM) and never touch HBM; `bytes - tile_bytes` is the flash-fused
    # memory-roofline term (EXPERIMENTS §Perf q.iter4).
    tile_bytes: float = 0.0
    by_op: dict = field(default_factory=lambda: defaultdict(float))
    has_while: bool = False

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k, self.tile_bytes * k,
                 has_while=self.has_while)
        for o, v in self.by_op.items():
            c.by_op[o] = v * k
        return c

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.tile_bytes += other.tile_bytes
        self.has_while |= other.has_while
        for o, v in other.by_op.items():
            self.by_op[o] += v

    @property
    def bytes_flash(self) -> float:
        return self.bytes - self.tile_bytes


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64) * aval.dtype.itemsize)
    except Exception:  # noqa: BLE001
        return 0.0


def _aval_size(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64))
    except Exception:  # noqa: BLE001
        return 0.0


def _is_attn_tile(aval) -> bool:
    """Attention score/prob tile: rank ≥ 4 with both trailing dims ≥ 256."""
    try:
        sh = aval.shape
        return len(sh) >= 4 and sh[-1] >= 256 and sh[-2] >= 256
    except Exception:  # noqa: BLE001
        return False


def _dot_flops(eqn) -> float:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb else 1.0
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc else 1.0
    lfree = np.prod(
        [s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb)],
        dtype=np.float64,
    )
    rfree = np.prod(
        [s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb)],
        dtype=np.float64,
    )
    return 2.0 * batch * contract * lfree * rfree


def _is_closed(v):
    return hasattr(v, "jaxpr") and hasattr(v, "consts")


def _is_jaxpr(v):
    return hasattr(v, "eqns") and hasattr(v, "invars") and not _is_closed(v)


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in ("scan",):
            inner = eqn.params["jaxpr"].jaxpr
            length = eqn.params["length"]
            total.add(jaxpr_cost(inner).scaled(float(length)))
            continue
        if name in ("while",):
            total.has_while = True
            total.add(jaxpr_cost(eqn.params["body_jaxpr"].jaxpr))
            continue
        if name in ("cond",):
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(b.jaxpr) for b in branches]
            best = max(costs, key=lambda c: c.flops)
            total.add(best)
            continue
        # generic: recurse into ANY sub-jaxpr-carrying primitive (pjit,
        # remat/remat2/checkpoint, custom_vjp, shard_map, ... — robust
        # against primitive renames across jax versions)
        subs = []
        for v in eqn.params.values():
            if _is_closed(v):
                subs.append(v.jaxpr)
            elif _is_jaxpr(v):
                subs.append(v)
            elif isinstance(v, (tuple, list)):
                for e in v:
                    if _is_closed(e):
                        subs.append(e.jaxpr)
                    elif _is_jaxpr(e):
                        subs.append(e)
        if subs:
            for s in subs:
                total.add(jaxpr_cost(s))
            continue
        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(
            _aval_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval")
        )
        tile_io = sum(
            _aval_bytes(v.aval)
            for vs in (eqn.outvars, [v for v in eqn.invars if hasattr(v, "aval")])
            for v in vs
            if _is_attn_tile(v.aval)
        )
        if name == "dot_general":
            fl = _dot_flops(eqn)
            total.flops += fl
            total.bytes += in_bytes + out_bytes
            total.tile_bytes += tile_io
            total.by_op["dot_general"] += fl
        elif name in _MOVE_OPS:
            pass  # fused / layout-only
        elif name in _HEAVY_OPS:
            total.bytes += in_bytes + out_bytes
            total.tile_bytes += tile_io
            total.by_op[name] += in_bytes + out_bytes
        else:
            # elementwise arithmetic (incl. transcendentals, reduce via
            # generic 'reduce_*' caught above)
            sz = sum(_aval_size(v.aval) for v in eqn.outvars)
            total.flops += sz
            total.bytes += (in_bytes + out_bytes) / FUSION_DISCOUNT
            total.tile_bytes += tile_io / FUSION_DISCOUNT
            total.by_op["elementwise"] += sz
    return total


def cost_of_fn(fn, *arg_specs) -> Cost:
    """Trace fn abstractly and walk the jaxpr (GLOBAL logical cost — divide
    by chip count for per-device roofline terms under even sharding)."""
    jx = jax.make_jaxpr(fn)(*arg_specs)
    return jaxpr_cost(jx.jaxpr)
