"""Roofline analysis from dry-run artifacts (deliverable (g)).

Per (arch × shape × mesh) cell, derive the three roofline terms from the
compiled per-device HLO (TPU v5e constants):

    compute    = flops_per_device            / 197e12  [s]
    memory     = bytes_accessed_per_device   / 819e9   [s]
    collective = collective_bytes_per_device / (p_links × 50e9) [s]

(The spec's global form HLO_FLOPs/(chips·peak) equals the per-device form
since the SPMD module is per-device.) ``p_links`` defaults to 1 ICI link —
conservative; the prepare-and-shoot schedule itself is generated for any p.

MODEL_FLOPS (analytic useful flops):
    train : 6 · N_active · tokens   (+ attention term 12·L·d_head·H·S²·B·(…))
    prefill: 2 · N_active · tokens  (+ attention)
    decode : 2 · N_active · B  + 4·L·H·d_head·S_kv·B  (score+value reads)

The ratio MODEL_FLOPS / HLO_FLOPS measures how much compiled compute is
useful (catches remat recompute, dense-MoE waste, padding waste).
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
P_LINKS = 1


# ---------------------------------------------------------------------------
# analytic parameter/flop counts per architecture
# ---------------------------------------------------------------------------


def param_counts(cfg) -> dict:
    """(total, active) parameter counts from the config (embeddings included
    once; active = per-token touched params for MoE)."""
    d, L = cfg.d_model, cfg.n_layers
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    emb = cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.mla:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (
                d * m.q_lora_rank
                + m.q_lora_rank * H * qk
                + d * m.kv_lora_rank
                + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                + d * m.qk_rope_head_dim
                + H * m.v_head_dim * d
            )
        return d * (H + 2 * Hkv) * hd + H * hd * d

    def mlp_params(ff):
        return 3 * d * ff

    total = emb
    active = emb
    prefix_dense = cfg.moe.first_dense if cfg.moe else 0
    for i in range(L):
        if cfg.ssm and cfg.ssm.kind == "rwkv6":
            tm = 5 * d * d + d * (5 * 32 + 5 * 32) + d * 64 * 2  # proj + loras
            cm = 2 * d * cfg.d_ff
            total += tm + cm
            active += tm + cm
            continue
        is_attn_layer = True
        if cfg.ssm and cfg.ssm.kind == "mamba":
            period = cfg.ssm.attn_layer_period or 8
            is_attn_layer = (i % period) == cfg.ssm.attn_layer_offset
        mix = attn_params() if is_attn_layer else _mamba_params(cfg)
        total += mix
        active += mix
        if cfg.moe and i >= prefix_dense and (i % cfg.moe.layer_period) == cfg.moe.layer_offset % cfg.moe.layer_period:
            e = cfg.moe
            total += e.n_experts * 3 * d * e.expert_ff + d * e.n_experts
            active += e.top_k * 3 * d * e.expert_ff + d * e.n_experts
            if e.shared_ff:
                total += 3 * d * e.shared_ff
                active += 3 * d * e.shared_ff
            if e.dense_residual_ff:
                total += 3 * d * e.dense_residual_ff
                active += 3 * d * e.dense_residual_ff
        elif cfg.moe and i < prefix_dense:
            total += mlp_params(cfg.moe.dense_ff or cfg.d_ff)
            active += mlp_params(cfg.moe.dense_ff or cfg.d_ff)
        else:
            total += mlp_params(cfg.d_ff)
            active += mlp_params(cfg.d_ff)
    if cfg.encdec:
        for _ in range(cfg.encdec.n_enc_layers):
            total += attn_params() + 2 * d * cfg.d_ff
            active += attn_params() + 2 * d * cfg.d_ff
        total += L * attn_params()  # cross attention
        active += L * attn_params()
    return {"total": int(total), "active": int(active)}


def _mamba_params(cfg):
    d = cfg.d_model
    din = cfg.ssm.expand * d
    dtr = max(1, -(-d // 16))
    return d * 2 * din + cfg.ssm.d_conv * din + din * (dtr + 2 * cfg.ssm.d_state) + dtr * din + din * d


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global, not per device)."""
    pc = param_counts(cfg)
    N_act = pc["active"]
    B, S = shape.global_batch, shape.seq_len
    d_attn = cfg.head_dim * cfg.n_heads
    L_attn = cfg.n_layers
    if cfg.ssm and cfg.ssm.kind == "mamba":
        period = cfg.ssm.attn_layer_period or 8
        L_attn = cfg.n_layers // period
    elif cfg.ssm and cfg.ssm.kind == "rwkv6":
        L_attn = 0
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * N_act * tokens
        # causal attention: 2(fwd)+4(bwd... included in 3x rule) — add QK^T+PV
        flops += 3 * 2 * 2 * L_attn * d_attn * (S * S / 2) * B
        return flops
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * N_act * tokens + 2 * 2 * L_attn * d_attn * (S * S / 2) * B
    # decode: one token; KV reads
    kv_dim = (
        (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
        if cfg.mla
        else 2 * cfg.n_kv_heads * cfg.head_dim
    )
    return 2.0 * N_act * B + 2 * L_attn * (d_attn + kv_dim) * S * B


# ---------------------------------------------------------------------------
# the table
# ---------------------------------------------------------------------------


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = float("nan")
    memory_s: float = float("nan")
    collective_s: float = float("nan")
    bottleneck: str = ""
    model_flops: float = float("nan")
    hlo_flops_global: float = float("nan")
    useful_ratio: float = float("nan")
    hbm_gb_per_dev: float = float("nan")
    note: str = ""


def analyze(rec: dict) -> RooflineRow:
    from repro.configs import SHAPES, get

    row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"], rec.get("status", "?"))
    if rec.get("status") != "ok":
        row.note = rec.get("reason", rec.get("error", ""))[:120]
        return row
    n = rec["n_chips"]
    # prefer the trip-count-aware jaxpr costs (XLA's HloCostAnalysis counts
    # while bodies once — see jaxpr_cost.py); fall back to XLA numbers.
    # memory uses the flash-fused byte count when available (S² score tiles
    # are VMEM-resident in the fused TPU kernel — jaxpr_cost.Cost.tile_bytes)
    if "jaxpr_cost" in rec:
        jc = rec["jaxpr_cost"]
        fl = jc["flops_global"] / n
        by = (jc["bytes_global"] - jc.get("tile_bytes_global", 0.0)) / n
        row.note = "jaxpr-cost" + ("+flash" if "tile_bytes_global" in jc else "")
    else:
        fl = rec["cost"]["flops_per_device"]
        by = rec["cost"]["bytes_accessed_per_device"]
        row.note = "xla-cost(undercounts scans)"
    cb = rec.get(
        "collective_bytes_per_device_corrected", rec["collective_bytes_per_device"]
    )
    row.compute_s = fl / PEAK_FLOPS
    row.memory_s = by / HBM_BW
    row.collective_s = cb / (P_LINKS * ICI_BW)
    terms = {
        "compute": row.compute_s,
        "memory": row.memory_s,
        "collective": row.collective_s,
    }
    row.bottleneck = max(terms, key=terms.get)
    cfg = get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    row.model_flops = model_flops(cfg, shape)
    row.hlo_flops_global = fl * n
    row.useful_ratio = row.model_flops / row.hlo_flops_global if fl > 0 else float("nan")
    m = rec["memory"]
    row.hbm_gb_per_dev = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]) / 1e9
    return row


def load_all(out_dir: str = "results/dryrun") -> list[RooflineRow]:
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(analyze(json.load(open(p))))
    return rows


def render_table(rows: list[RooflineRow]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':11s} {'status':8s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} {'bound':>10s} "
        f"{'useful':>7s} {'HBM_GB':>7s}  note"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:11s} {r.status:8s} "
            f"{r.compute_s:10.3e} {r.memory_s:10.3e} {r.collective_s:10.3e} {r.bottleneck:>10s} "
            f"{r.useful_ratio:7.3f} {r.hbm_gb_per_dev:7.2f}  {r.note}"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--json", default=None, help="also dump rows as json")
    args = ap.parse_args()
    rows = load_all(args.out)
    print(render_table(rows))
    if args.json:
        json.dump([r.__dict__ for r in rows], open(args.json, "w"), indent=2)


if __name__ == "__main__":
    main()
