"""Per-shape sharding-rule presets (DESIGN §6).

train / prefill:
    batch → (pod, data);  seq → model (Megatron-style sequence sharding of
    activations at block boundaries — GSPMD inserts the gather/scatter
    around attention);  params FSDP-sharded: feature dims → model, d_model →
    data (ZeRO-3 semantics via GSPMD all-gathers).
decode:
    batch → (pod, data);  KV-cache sequence → model (flash-decoding-style
    split-KV — works for every arch incl. kv_heads < mesh axis);
    long_500k (batch=1): KV seq → (data, model) — all 256/512 chips split
    the half-million-token cache.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist.sharding import ShardingRules


def rules_for(cfg: ModelConfig, shape: ShapeSpec) -> ShardingRules:
    r = ShardingRules().override(seq=("model",), d_model=("data",))
    if shape.kind == "decode":
        kv = ("data", "model") if shape.global_batch == 1 else ("model",)
        r = r.override(seq=(), kv_seq=kv, kv_heads=())
    return r


def big_model(cfg: ModelConfig) -> bool:
    """>100B params → bf16 optimizer moments (EXPERIMENTS §Dry-run notes)."""
    return cfg.name.split("-")[-1] in ("480b", "671b") or cfg.family == "moe"
