"""Production training launcher.

Assembles mesh → sharding rules/profile → model → train_step → data pipeline
→ checkpoint/coded-parity cadence, and runs. On a real TPU slice the mesh
comes from jax.devices(); in this container pass ``--devices N`` smoke sizes
or use examples/train_lm.py for the single-host path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --mesh 4x2 --batch 8 --seq 256 --steps 20 --smoke
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get, smoke_config
from repro.dist.sharding import named_sharding
from repro.launch.mesh import make_mesh
from repro.launch.profiles import BASELINE, OPT, rules_for
from repro.configs.base import ShapeSpec
from repro.models import build_model, batch_dims
from repro.train import (
    CodedStateGuard,
    OptConfig,
    SyntheticLM,
    init_state,
    latest_step,
    make_train_step,
    param_shardings,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.train_loop import _tree_shard, opt_state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--profile", default="opt", choices=["baseline", "opt"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--coded-every", type=int, default=25)
    ap.add_argument("--coded-k", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    if d * m > len(jax.devices()):
        raise SystemExit(
            f"mesh {args.mesh} needs {d * m} devices, have {len(jax.devices())}"
        )
    mesh = make_mesh((d, m), ("data", "model"))
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    profile = OPT if args.profile == "opt" else BASELINE
    rules = rules_for(cfg, shape, profile)
    model = build_model(cfg)

    ocfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)
    ps = param_shardings(model, mesh, rules)
    params = jax.jit(model.init, out_shardings=ps)(jax.random.key(0))
    opt_state = init_state(ocfg, params)
    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt_state},
        )
        state, start = restore_checkpoint(args.ckpt, like)
        params, opt_state = state["params"], state["opt"]
        print(f"restored checkpoint at step {start}")

    step_fn = jax.jit(
        make_train_step(model, ocfg, mesh=mesh, rules=rules),
        in_shardings=(ps, opt_state_shardings(ocfg, model, mesh, rules), None),
        out_shardings=(ps, opt_state_shardings(ocfg, model, mesh, rules), None),
    )
    ds = SyntheticLM(cfg)
    guard = CodedStateGuard(K=args.coded_k)
    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s, args.batch, args.seq).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if s % 10 == 0 or s == args.steps - 1:
            print(
                f"step {s:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f}"
            )
        if args.coded_every and s and s % args.coded_every == 0:
            guard.snapshot({"params": params, "opt": opt_state}, s)
        if args.ckpt and s and s % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": params, "opt": opt_state}, s)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt_state}, args.steps)
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s")


if __name__ == "__main__":
    main()
