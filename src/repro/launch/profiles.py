"""Sharding/remat optimization profiles for the §Perf hillclimb.

``baseline`` is the paper-faithful first implementation measured in
EXPERIMENTS §Roofline. Each lever is an independently-toggleable change with
an explicit hypothesis (EXPERIMENTS §Perf logs before/after per lever):

* ``attn_heads``   — constrain q/k/v to head-sharding inside attention
                     instead of inheriting the block-boundary seq-sharding
                     (kills GSPMD 'involuntary full rematerialization'
                     reshards in the chunked-attention scans).
* ``moe_ep``       — expert parallelism: experts → data axis, expert ff →
                     model axis (weights fully sharded with NO per-layer
                     FSDP all-gather; tokens all-to-all to expert owners).
                     Divisibility: jamba 16e/16, arctic 128e/16, dsv3 256e/16.
* ``logits_vocab`` — constrain lm-head logits to vocab-sharding (batch, ∅,
                     vocab) so the CE never materializes a full-vocab tensor.
* ``no_fsdp``      — drop d_model→data param sharding for models whose
                     sharded-over-model state fits HBM (≤8B params):
                     removes ALL per-layer param gathers; gradient sync
                     becomes one reduce of model-sharded grads.
* ``time_chunk``   — chunked+checkpointed time scans in RWKV/Mamba
                     (256-step chunks): backward saves only chunk-boundary
                     states instead of every step's state.

Besides the sharding levers, :func:`resolve_profile` picks the
coded-checkpoint DP-axis **encode algorithm** from the production mesh's
network topology (``launch.mesh.production_topology`` → ``topo.autotune``):
multi-pod derives a three-level chip < slice < pod hierarchy and selects the
recursive multi-level schedule instead of the flat prepare-and-shoot.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist.sharding import ShardingRules
from repro.launch.rules import rules_for as _baseline_rules


@dataclass(frozen=True)
class Profile:
    name: str
    attn_heads: bool = False
    moe_ep: bool = False
    moe_resident: bool = False  # expert weights resident (no expert FSDP)
    moe_gather: bool = False  # gather-form dispatch/combine (no scatter-add)
    dp_only: bool = False  # pure DP for small models: batch over ALL axes
    bf16_moments: bool = False
    logits_vocab: bool = False
    no_fsdp: bool = False
    time_chunk: int = 0


BASELINE = Profile("baseline")
OPT = Profile("opt", attn_heads=True, moe_ep=True, logits_vocab=True,
              no_fsdp=True, time_chunk=256)


def profile_with(name: str, **kw) -> Profile:
    return Profile(name, **kw)


def rules_for(cfg: ModelConfig, shape: ShapeSpec, profile: Profile = BASELINE) -> ShardingRules:
    r = _baseline_rules(cfg, shape)
    flags = set()
    if profile.attn_heads:
        flags.add("attn_heads")
    if profile.logits_vocab:
        flags.add("logits_vocab")
    if profile.moe_gather:
        flags.add("moe_gather")
    if profile.moe_ep:
        r = r.override(experts=("data",), moe_ff=("model",))
    if profile.moe_resident:
        # experts spread over (model, data) when divisible (dsv3: 1/chip),
        # else model only (jamba: 1 per model shard); weights NOT FSDP'd
        r = r.override(experts=("model", "data"), expert_d=())
    if profile.dp_only:
        r = r.override(batch=("pod", "data", "model"), seq=(), d_model=())
    if profile.no_fsdp and _params_fit_without_fsdp(cfg):
        r = r.override(d_model=())
    if flags:
        r = r.with_flags(flags)
    return r


def apply_profile_cfg(cfg: ModelConfig, profile: Profile) -> ModelConfig:
    if profile.time_chunk and cfg.ssm is not None:
        return cfg.replace(time_chunk=profile.time_chunk)
    return cfg


def _params_fit_without_fsdp(cfg: ModelConfig) -> bool:
    """Model-axis-only sharding fits v5e HBM when total params ≤ ~8B
    (bf16 params + f32 moments over 16 model shards ≲ 5 GB)."""
    from repro.launch.roofline import param_counts

    return param_counts(cfg)["total"] <= 8e9


# ---------------------------------------------------------------------------
# coded-checkpoint encode profile: algorithm from the mesh topology
# ---------------------------------------------------------------------------


#: checkpoint generator-matrix kind → the autotuner's generator taxonomy
#: (which structured candidate families are applicable). The production
#: coded-checkpoint parity plan uses a Cauchy matrix (``coded.rs_checkpoint``)
#: — an unstructured MDS generator, hence "general".
_GENERATOR_TAXONOMY = {
    "cauchy": "general",
    "random": "general",
    "general": "general",
    "vandermonde": "vandermonde",
    "dft": "dft",
}

#: the matrix kind ``coded.rs_checkpoint.ParityPlan`` actually builds
CHECKPOINT_GENERATOR_KIND = "cauchy"


def generator_kind_for(matrix_kind: str) -> str:
    """Map a generator-matrix kind (what the caller builds, e.g. the
    checkpoint layer's Cauchy matrix) to the autotuner's generator taxonomy
    ∈ {general, vandermonde, dft} — which structured schedule families may
    be enumerated for it."""
    try:
        return _GENERATOR_TAXONOMY[matrix_kind]
    except KeyError:
        raise ValueError(
            f"unknown generator matrix kind {matrix_kind!r}; "
            f"expected one of {sorted(_GENERATOR_TAXONOMY)}"
        ) from None


@dataclass(frozen=True)
class EncodeProfile:
    """Autotuned encode selection for the coded-checkpoint DP axis.

    ``algorithm`` is the chosen candidate's full name — a plan family
    (prepare-shoot, hierarchical, multilevel, ring, allgather, …) optionally
    suffixed ``+<pipeline>`` when a pass pipeline's rewrite won on price;
    ``pipeline`` is that pipeline's registry name ("" = un-rewritten).
    ``plan`` is the matching compile-time schedule plan (None for the
    plan-less allgather); ``levels`` the innermost-first hierarchy the choice
    was priced on — also the level sizes ``multilevel_encode_jit`` expects
    its mesh axes (reversed) to have. The selection is made over priced
    ScheduleIRs (the autotuner enumerates ``plan.to_ir()`` compiles ×
    applicable ``topo.passes`` pipelines); ``ir`` is the chosen candidate's
    compiled, pass-rewritten schedule — the exact object
    ``dist.collectives.ir_encode_jit`` executes (structure-only here: the
    executors recompile with the generator matrix at dispatch and re-apply
    the named pipeline, e.g. ``pipeline="pipeline"`` for the
    comm/compute-overlap rewrite). ``kernels`` is the LocalOp lowering the
    executors should use (None = auto: Pallas kernels on TPU, the batched
    fused-jnp contraction elsewhere; "jnp" = the legacy unfused loop kept
    behind the flag). ``fitted_costs`` records the calibrated per-level
    α/β the pricing used (None = v5e defaults)."""

    topology: object  # repro.topo Topology the choice was priced on
    algorithm: str
    plan: object
    tune: object  # full repro.topo.TuneResult (candidate table)
    pipeline: str = ""  # winning PassPipeline name ("" = un-rewritten)
    fitted_costs: tuple | None = None  # calibrated LinkCosts used for pricing
    kernels: str | None = None  # ir_encode_jit LocalOp lowering (None = auto)

    @property
    def levels(self) -> tuple[int, ...]:
        return getattr(self.topology, "levels", (self.topology.n,))

    @property
    def ir(self):
        return self.tune.chosen.ir


def resolve_profile(
    *,
    multi_pod: bool = False,
    mesh=None,
    axes=None,
    payload_bytes: int = 1 << 20,
    p: int = 1,
    q: int | None = None,
    measured: dict[str, float] | None = None,
    generator: str | None = None,
    calibration: str | bool | None = None,
    kernels: str | None = None,
) -> EncodeProfile:
    """Pick the coded-checkpoint DP-axis encode algorithm from the mesh
    topology via the autotuner (ROADMAP: "wire the autotuner into launch/").

    Default: price on :func:`launch.mesh.production_topology` — multi-pod
    derives the three-level chip < slice < pod hierarchy and selects the
    recursive multi-level schedule. Pass ``mesh`` + ``axes`` (outermost →
    innermost, e.g. ``("pod", "slice", "chip")``) to derive the hierarchy
    from a live mesh instead. ``measured`` feeds wall-clock calibration
    (e.g. ``results/BENCH_topology.json``'s ``measured_s``) through
    ``autotune(..., measured=...)``.

    ``generator`` is the autotuner taxonomy kind; when omitted it defaults
    from the checkpoint layer's actual generator matrix kind (Cauchy →
    "general") via :func:`generator_kind_for` — callers with structured
    generators pass ``generator=generator_kind_for("vandermonde")`` etc. to
    unlock the structured candidate families.

    ``calibration`` selects fitted α/β pricing: ``None`` (default) loads
    ``results/BENCH_topology.json`` when present, a path loads that file,
    ``False`` disables calibration. A path ending in ``.jsonl`` or
    ``.trace.json`` is treated as a span trace emitted by
    ``dist.collectives.ir_encode_jit(tracer=...)`` and re-fit on the fly
    via ``obs.feed.fitted_costs_from_trace`` — live telemetry straight
    into pricing, no intermediate results file. When fitted per-level
    costs exist and
    the priced topology is a Hierarchy, its level costs are replaced by the
    fit (level counts matching exactly, otherwise the fitted innermost/
    outermost endpoints re-interpolated through
    ``topo.model.default_level_costs``) so candidate prices — and the chosen
    (algorithm, pipeline) — reflect measured hardware.

    ``kernels`` is recorded verbatim on the profile for dispatch-time use
    (``dist.collectives`` LocalOp lowering mode: None = auto-select by
    backend, "pallas"/"fused"/"jnp" to force)."""
    from repro.core.field import M31
    from repro.launch.mesh import production_topology, topology_for_mesh
    from repro.topo import autotune
    from repro.topo.calibrate import load_fitted_costs
    from repro.topo.model import Hierarchy, default_level_costs

    if mesh is not None:
        if axes is None:
            raise ValueError("pass axes=(outermost, ..., innermost) with mesh")
        topo = topology_for_mesh(mesh, axes)
    else:
        topo = production_topology(multi_pod=multi_pod)
    fitted = None
    if calibration is not False:
        if isinstance(calibration, str) and calibration.endswith(
            (".jsonl", ".trace.json")
        ):
            from repro.obs.feed import fitted_costs_from_trace

            try:
                fitted = tuple(fitted_costs_from_trace(calibration))
            except (OSError, ValueError):  # unreadable/unfittable trace
                fitted = None
        else:
            fitted = load_fitted_costs(
                calibration if isinstance(calibration, str) else None
            )
    if fitted is not None and isinstance(topo, Hierarchy):
        from dataclasses import replace as _replace

        if len(fitted) == len(topo.levels):
            topo = _replace(topo, costs=fitted)
        else:
            topo = _replace(
                topo,
                costs=default_level_costs(
                    len(topo.levels), lo=fitted[0], hi=fitted[-1]
                ),
            )
        fitted = topo.costs
    else:
        fitted = None
    result = autotune(
        topo.n,
        p,
        payload_bytes,
        topo,
        q=q if q is not None else M31,
        generator=generator
        if generator is not None
        else generator_kind_for(CHECKPOINT_GENERATOR_KIND),
        measured=measured,
    )
    return EncodeProfile(
        topology=topo,
        algorithm=result.algorithm,
        plan=result.chosen.plan,
        tune=result,
        pipeline=result.chosen.pipeline,
        fitted_costs=fitted,
        kernels=kernels,
    )
