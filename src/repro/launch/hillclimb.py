import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: measure one (arch × shape × mesh) cell under a
set of optimization levers (launch/profiles.py) and append the iteration to
results/perf_iterations.jsonl (hypothesis → change → before → after).

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch jamba-v0.1-52b \
      --shape train_4k [--multi-pod] --levers attn_heads,logits_vocab \
      --hypothesis "..." [--tag iter2]

Metrics per run: three roofline terms (trip-count-aware jaxpr compute/memory
+ differential-corrected collective bytes), HBM footprint from the full
compile's memory_analysis, useful-flops ratio.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get  # noqa: E402
from repro.launch import costpass  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.jaxpr_cost import cost_of_fn  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.profiles import BASELINE, Profile, apply_profile_cfg, rules_for  # noqa: E402
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops  # noqa: E402


def measure(arch: str, shape_name: str, multi_pod: bool, profile: Profile) -> dict:
    cfg = apply_profile_cfg(get(arch), profile)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(cfg, shape, profile)

    mdt = "bfloat16" if profile.bf16_moments else None
    t0 = time.time()
    # trip-count-aware compute/memory (logical, mesh-independent)
    fn, args = costpass._build_step(cfg, shape, None, None, moment_dtype=mdt)
    c = cost_of_fn(fn, *args)

    # full compile: memory + raw collective schedule
    jt, args_m = costpass._build_step(cfg, shape, mesh, rules, moment_dtype=mdt)
    compiled = jt.lower(*args_m).compile()
    ma = compiled.memory_analysis()
    colls_full = parse_collectives(compiled.as_text())

    # differential collective correction (layer-scan trip counts)
    colls = {}
    for r in (1, 2):
        cfg_r, repeats = costpass._cfg_with_repeats(cfg, r)
        jt_r, args_r = costpass._build_step(cfg_r, shape, mesh, rules, moment_dtype=mdt)
        colls[r] = parse_collectives(jt_r.lower(*args_r).compile().as_text())
    _, R = costpass._cfg_with_repeats(cfg, 1)
    coll_bytes = 0
    coll_by_op = {}
    for op in set(colls[1]) | set(colls[2]):
        b1 = colls[1].get(op, {}).get("bytes", 0)
        b2 = colls[2].get(op, {}).get("bytes", 0)
        coll_by_op[op] = max(b1 + (R - 1) * (b2 - b1), 0)
        coll_bytes += coll_by_op[op]

    compute_s = c.flops / n_chips / PEAK_FLOPS
    memory_s = c.bytes / n_chips / HBM_BW
    memory_flash_s = c.bytes_flash / n_chips / HBM_BW
    coll_s = coll_bytes / ICI_BW
    # bottleneck judged with the flash-fused memory term: the S² score
    # tiles are VMEM-resident in the fused TPU attention kernel (chunk
    # 1024²·f32 = 4 MiB < 16 MiB VMEM) — see jaxpr_cost.Cost.tile_bytes
    terms = {"compute": compute_s, "memory": memory_flash_s, "collective": coll_s}
    mf = model_flops(get(arch), shape)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "profile": profile.name,
        "levers": {
            k: getattr(profile, k)
            for k in (
                "attn_heads", "moe_ep", "moe_resident", "moe_gather", "dp_only",
                "bf16_moments", "logits_vocab", "no_fsdp", "time_chunk",
            )
        },
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_flash_s": memory_flash_s,
        "collective_s": coll_s,
        "bottleneck": max(terms, key=terms.get),
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": compute_s / max(terms.values()),
        "useful_ratio": mf / c.flops,
        "collective_gb_per_dev": coll_bytes / 1e9,
        "collective_by_op_gb": {k: v / 1e9 for k, v in sorted(coll_by_op.items(), key=lambda kv: -kv[1])},
        "hbm_gb_per_dev": (ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes) / 1e9,
        "temp_gb_per_dev": ma.temp_size_in_bytes / 1e9,
        "wall_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--levers", default="", help="comma list; empty = baseline")
    ap.add_argument("--time-chunk", type=int, default=0)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--tag", default="")
    ap.add_argument("--log", default="results/perf_iterations.jsonl")
    args = ap.parse_args()

    levers = [l for l in args.levers.split(",") if l]
    kw = {l: True for l in levers if l != "time_chunk"}
    if "time_chunk" in levers or args.time_chunk:
        kw["time_chunk"] = args.time_chunk or 256
    prof = Profile(args.tag or (("+".join(levers)) or "baseline"), **kw)
    rec = measure(args.arch, args.shape, args.multi_pod, prof)
    rec["hypothesis"] = args.hypothesis
    os.makedirs(os.path.dirname(args.log), exist_ok=True)
    with open(args.log, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
