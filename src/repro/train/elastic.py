"""Elastic scaling + failure recovery orchestration.

Two recovery tiers (DESIGN §8):

1. **Coded fast path** — ``CodedStateGuard`` keeps a Cauchy parity of the
   full training state across K logical DP replicas (one all-to-all encode,
   C2 = Θ(√K/p)); any ≤ K−1 simultaneously lost replicas are rebuilt
   bit-exactly from survivors without touching disk.
2. **Disk slow path** — ``save_checkpoint``/``restore_checkpoint``; restore
   accepts different shardings, so scaling the mesh up/down between runs is
   just re-placement (elastic scaling).

In this container the "replicas" are logical (state is sharded into K limb
shards); on a real cluster the same arrays live on distinct hosts and the
encode runs over the DP mesh axis (coded/rs_checkpoint.encode_parity_collective).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.coded.rs_checkpoint import (
    ParityPlan,
    build_parity_plan,
    encode_parity,
    recover_lost,
    shard_state_limbs,
    unshard_state_limbs,
)


@dataclass
class CodedStateGuard:
    K: int
    p: int = 1
    plan: ParityPlan = None  # type: ignore
    _shards: np.ndarray | None = None
    _parity: np.ndarray | None = None
    _meta: object = None
    step: int = -1

    def __post_init__(self):
        if self.plan is None:
            self.plan = build_parity_plan(self.K, self.p)

    def snapshot(self, state, step: int):
        """Encode parity of the current state (call every coded_every steps)."""
        shards, meta = shard_state_limbs(state, self.K)
        if not hasattr(self, "_encode_jit"):
            import jax as _jax

            self._encode_jit = _jax.jit(lambda s: encode_parity(s, self.plan))
        parity = self._encode_jit(shards)
        self._shards = np.asarray(shards, dtype=np.uint64)
        self._parity = np.asarray(parity, dtype=np.uint64)
        self._meta = meta
        self.step = step

    def fail_and_recover(self, lost: list[int]):
        """Simulate losing `lost` replicas (their x AND parity shards) and
        rebuild the full state bit-exactly from the survivors."""
        assert self._shards is not None, "no snapshot taken"
        surv_x = {k: self._shards[k] for k in range(self.K) if k not in lost}
        surv_p = {k: self._parity[k] for k in range(self.K) if k not in lost}
        rec = recover_lost(self.plan, lost, surv_x, surv_p)
        full = self._shards.copy()
        for k in lost:
            full[k] = rec[k]
        return (
            unshard_state_limbs(jnp.asarray(full.astype(np.uint32)), self._meta),
            self.step,
        )

    @property
    def overhead_elements(self) -> int:
        """Parity HBM overhead per replica, in limbs (= 1/K of state)."""
        return 0 if self._parity is None else int(self._parity.shape[1])


def reshard_state(state, shardings):
    """Elastic re-placement of a state pytree under new shardings."""
    return jax.tree.map(jax.device_put, state, shardings)
