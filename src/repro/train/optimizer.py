"""AdamW (from scratch — no optax in this environment) with:

* fp32 or bf16 moments (``moment_dtype``) — bf16 halves optimizer HBM for
  the 480B/671B dry-runs (recorded in EXPERIMENTS.md §Dry-run),
* global-norm gradient clipping,
* linear-warmup + cosine-decay schedule,
* decoupled weight decay.

State is a pytree mirroring params: {"m": ..., "v": ..., "step": ()}.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "float32" | "bfloat16"


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(cfg: OptConfig, params):
    mdt = jnp.float32 if cfg.moment_dtype == "float32" else jnp.bfloat16
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(cfg: OptConfig, param_shapes):
    """ShapeDtypeStructs mirroring init_state (dry-run, no allocation)."""
    mdt = jnp.float32 if cfg.moment_dtype == "float32" else jnp.bfloat16
    sd = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {
        "m": jax.tree.map(sd, param_shapes),
        "v": jax.tree.map(sd, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        jax.tree.reduce(
            lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), tree, 0.0
        )
    )


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.float32 if cfg.moment_dtype == "float32" else jnp.bfloat16

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
