"""Synthetic deterministic LM data pipeline.

Produces an infinite stream of (tokens, labels) batches, deterministic in
(seed, step, shard) — so a restarted/rescaled job resumes mid-stream exactly
(the checkpoint stores only the step counter). Per-host sharding follows the
data-parallel submesh; a background prefetch thread keeps ``prefetch`` steps
ready (straggler smoothing on the input side).

The generator is a mixture of Zipf-distributed unigrams and short repeated
motifs, giving a non-trivial learnable distribution (loss decreases — used
by examples/train_lm.py).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.3
    motif_len: int = 8
    motif_prob: float = 0.5
    n_motifs: int = 64


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        v = cfg.vocab_size
        self.motifs = rng.integers(0, v, size=(dcfg.n_motifs, dcfg.motif_len))
        # Zipf over the vocab (renormalized, truncated)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-dcfg.zipf_a)
        self.p = p / p.sum()

    def batch(self, step: int, batch_size: int, seq_len: int, shard: int = 0, n_shards: int = 1):
        """Deterministic batch for (step, shard)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step, shard, n_shards])
        )
        B = batch_size
        toks = rng.choice(self.cfg.vocab_size, size=(B, seq_len), p=self.p)
        # overlay motifs
        n_spans = max(1, seq_len // (4 * self.dcfg.motif_len))
        for b in range(B):
            for _ in range(n_spans):
                if rng.random() < self.dcfg.motif_prob:
                    m = self.motifs[rng.integers(self.dcfg.n_motifs)]
                    start = rng.integers(0, max(1, seq_len - self.dcfg.motif_len))
                    toks[b, start : start + self.dcfg.motif_len] = m
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks.copy()}


class Prefetcher:
    """Background-thread prefetch of deterministic batches."""

    def __init__(self, ds: SyntheticLM, batch_size: int, seq_len: int, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            s = start_step
            while not self._stop.is_set():
                b = ds.batch(s, batch_size, seq_len)
                try:
                    self.q.put((s, b), timeout=1.0)
                    s += 1
                except queue.Full:
                    continue

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def next(self):
        step, b = self.q.get()
        return step, {k: jnp.asarray(v) for k, v in b.items()}

    def close(self):
        self._stop.set()
