from .checkpoint import latest_step, restore_checkpoint, save_checkpoint  # noqa: F401
from .data import DataConfig, Prefetcher, SyntheticLM  # noqa: F401
from .optimizer import OptConfig, apply_updates, init_state, state_specs  # noqa: F401
from .train_loop import (  # noqa: F401
    batch_shardings,
    cache_shardings,
    make_ctx,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_state_shardings,
    param_shardings,
)
from .elastic import CodedStateGuard, reshard_state  # noqa: F401
