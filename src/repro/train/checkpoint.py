"""Sharded checkpoint save/restore with elastic resharding.

Format: one ``.npz`` per top-level state group + ``manifest.json`` with the
pytree structure, shapes, dtypes and step. Arrays are saved logically
complete (test-scale); ``restore`` re-places them under ANY mesh/sharding —
that re-placement IS the elastic-scaling path (restore on a different DP/TP
factorization just changes the NamedShardings). At real scale the same
manifest format holds per-shard files (shard_id fields are already in the
manifest schema).

The coded fast path (coded/rs_checkpoint.py) complements this: disk
checkpoints every N steps, in-HBM Cauchy parity every n << N steps.
"""

from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out[name] = leaf
    return out


def save_checkpoint(path: str, state: Any, step: int, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    named = _flatten_with_names(state)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    np.savez(os.path.join(path, f"state_{step:08d}.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "treedef": str(treedef),
        "format": "logical-full-v1",
        "shard_id": 0,
        "n_shards": 1,
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(f[len("state_") : -len(".npz")])
        for f in os.listdir(path)
        if f.startswith("state_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore_checkpoint(path: str, like: Any, step: int | None = None, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings → device_put under the (possibly different) mesh."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(os.path.join(path, f"state_{step:08d}.npz"))
    leaves, treedef = jax.tree.flatten(like)
    names = list(_flatten_with_names(like).keys())
    out = []
    shard_flat = jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    for name, leaf, shard in zip(names, leaves, shard_flat):
        arr = data[name]
        want_dtype = np.dtype(leaf.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16) round-trips as void
            arr = arr.view(want_dtype)
        a = jnp.asarray(arr.astype(want_dtype) if arr.dtype != want_dtype else arr)
        if shard is not None:
            a = jax.device_put(a, shard)
        out.append(a)
    return jax.tree.unflatten(treedef, out), step
