"""Train/serve step factories with mesh-aware shardings.

``make_train_step`` builds the jit-able step used by both the real trainer
(examples/train_lm.py) and the multi-pod dry-run (launch/dryrun.py): the
SAME function lowers on 1 CPU device or on the 512-chip production mesh —
only the shardings differ.

Gradient accumulation: ``accum > 1`` splits the batch's leading dim into
microbatches and lax.scan's over them (sequential, memory-bounded).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingRules, named_sharding
from repro.models.layers import NO_CTX, Ctx
from repro.models.inputs import batch_dims
from . import optimizer as opt


def make_ctx(mesh=None, rules: ShardingRules | None = None) -> Ctx:
    return Ctx(mesh, rules or ShardingRules()) if mesh is not None else NO_CTX


def make_train_step(model, opt_cfg: opt.OptConfig, mesh=None, rules=None, accum: int = 1):
    ctx = make_ctx(mesh, rules)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
            )

            def mb_step(carry, mb):
                acc_g, acc_l = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc_g = jax.tree.map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), m

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(mb_step, (zero_g, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_state, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        return new_params, new_state, {**metrics, **om, "loss": loss}

    return train_step


def make_decode_step(model, mesh=None, rules=None):
    ctx = make_ctx(mesh, rules)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx)

    return decode_step


def make_prefill_step(model, mesh=None, rules=None, into_cache: bool = False):
    """Prefill step factory.

    ``into_cache=False`` (legacy, dry-run contract): ``(params, batch) →
    logits`` — full forward over the prompt, no cache.

    ``into_cache=True`` (serving contract): ``(params, cache, tokens (1, L),
    slot, plen) → (last_logits (1, V_padded), cache)`` — ONE forward pass
    writes the prompt's per-layer K/V into row ``slot`` of the batched
    decode cache and returns the logits of position ``plen - 1``, i.e. the
    first generated token's distribution. This replaces the per-token
    prompt refeed: jit it once per length bucket L and the prompt costs one
    graph launch instead of ``plen`` decode steps.
    """
    ctx = make_ctx(mesh, rules)

    if into_cache:

        def prefill_cache(params, cache, tokens, slot, plen):
            logits, cache = model.prefill_into_cache(params, cache, tokens, slot, ctx)
            idx = jnp.reshape(jnp.maximum(plen - 1, 0), (1, 1, 1))
            last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
            return last, cache

        return prefill_cache

    def prefill(params, batch):
        logits, aux, _ = model.forward(params, batch, ctx)
        return logits

    return prefill


# ---------------------------------------------------------------------------
# shardings (dry-run + real placement share these)
# ---------------------------------------------------------------------------


def param_shardings(model, mesh, rules: ShardingRules):
    shapes, dims = model.param_specs()
    return _tree_shard(mesh, rules, shapes, dims)


def _tree_shard(mesh, rules, shapes, dims):
    def is_dims(x):
        return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)

    flat_s = jax.tree.flatten(shapes)[0]
    flat_d, treedef = jax.tree.flatten(dims, is_leaf=is_dims)
    assert len(flat_s) == len(flat_d), (len(flat_s), len(flat_d))
    out = [
        named_sharding(mesh, rules, d, s.shape) for s, d in zip(flat_s, flat_d)
    ]
    return jax.tree.unflatten(treedef, out)


def opt_state_shardings(opt_cfg, model, mesh, rules: ShardingRules):
    pshard = _tree_shard(mesh, rules, *model.param_specs())
    return {
        "m": pshard,
        "v": pshard,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(model, mesh, rules: ShardingRules, kind="train"):
    dims = batch_dims(model.cfg, kind)
    return {
        k: named_sharding(mesh, rules, d) for k, d in dims.items()
    }


def cache_shardings(model, mesh, rules: ShardingRules, cache_shapes):
    dims = model.cache_dims()
    return _tree_shard(mesh, rules, cache_shapes, dims)
