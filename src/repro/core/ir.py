"""Unified ScheduleIR: ONE round-schedule representation for every encode
algorithm.

The paper's central observation is that a single universal round structure
computes any generator matrix by only varying intermediate coefficients.
This module makes that structure a first-class compiler artifact: every
schedule plan (prepare-shoot, butterfly, draw-loose, allgather, ring,
hierarchical, multilevel, two-level/multi-level DFT) **compiles to** the same
IR via a per-family ``plan.to_ir()`` lowering, and everything downstream —
simulation (``core.simulator.interpret``), message-map lowering and α-β
pricing (``topo.lower.lower_ir``), and mesh execution
(``dist.collectives.ir_encode_jit``) — consumes the IR generically. Adding an
algorithm is now ONE compile function instead of four implementations.

The IR is a straight-line program over ``K`` processors, each holding a
slot-indexed buffer of field elements. Processor ``k`` starts with its packet
in slot ``INPUT_SLOT`` and must end with its encode output in slot
``ScheduleIR.out_slot``. Two step kinds alternate freely:

* :class:`CommRound` — one synchronous p-port communication round: a set of
  :class:`Transfer` records. A transfer ships the source slots of its
  ``slots`` selector from ``src`` to ``dst``; the receiver multiplies each
  element by the matching ``coeffs`` entry (1 when absent) and either
  accumulates into (``mode="add"``) or overwrites (``mode="store"``) the
  destination slot. All sends read the pre-round buffer (synchronous
  semantics). ``port`` tags which of the sender's p ports carries the
  message — transfers sharing (port, slots, mode) form one uniform
  permutation, the unit a mesh executor turns into one ``ppermute``.
* :class:`LocalOp` — a per-processor linear contraction (no communication):
  the buffer is REPLACED by ``{out_slots[i]: Σ_j coeffs[k, i, j] ·
  buf[in_slots[j]]}``. This is where the generator-matrix coefficients live
  (w-variable initialization, butterfly twiddle combines, draw-phase scales);
  ``coeffs=None`` marks a structure-only IR (message maps derivable,
  interpretation not).

Rewrite passes operate on the IR: :func:`fuse_trivial_rounds` here (drop
empty rounds / no-op transfers / identity local ops — exactness is immediate
because every removed step is semantically the identity), and the
topology-aware ``repro.topo.passes.remap_digits`` (torus-native butterfly via
:func:`relabel`).

Paper-notation glossary: ``K`` processors, ``p`` ports per round, ``C1`` =
round count = number of CommRounds, ``C2`` = Σ over rounds of the largest
transfer's element count — both read off the IR by ``ir_messages``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .field import M31, Field
from .schedule import (
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    butterfly_group_perms,
    gather_rounds,
)

INPUT_SLOT = 0


@dataclass(frozen=True)
class Transfer:
    """One message of a communication round (see module doc)."""

    src: int
    dst: int
    port: int  # which of the sender's p ports carries this message
    slots: tuple[tuple[int, int], ...]  # (src_slot, dst_slot) pairs, wire order
    coeffs: tuple[int, ...] | None = None  # per-slot receive coefficient (None = 1s)
    mode: str = "add"  # "add": dst += c·v   |   "store": dst = c·v

    @property
    def elems(self) -> int:
        return len(self.slots)


@dataclass(frozen=True)
class CommRound:
    transfers: tuple[Transfer, ...]


@dataclass(frozen=True, eq=False)
class LocalOp:
    """buffer := {out_slots[i]: Σ_j coeffs[k, i, j] · buf[in_slots[j]]}
    (REPLACES the buffer; missing input slots read as 0).

    ``update=True`` switches to read-modify-write semantics: the op writes
    only its out_slots and every other live slot survives untouched — the
    form :func:`~repro.topo.passes.pipeline_rounds` needs for its combine
    steps (``o ← o + τ(o)``), which must not clobber in-flight slots.
    ``overlap=True`` marks an op whose inputs are independent of the NEXT
    comm round, i.e. the executor may issue it concurrently with (or fused
    into the same dispatch as) that round's ppermute; it never changes the
    op's value semantics, only scheduling/pricing."""

    out_slots: tuple[int, ...]
    in_slots: tuple[int, ...]
    coeffs: np.ndarray | None  # (K, n_out, n_in) field elements; None = structure-only
    update: bool = False
    overlap: bool = False


@dataclass(frozen=True, eq=False)
class ScheduleIR:
    """A compiled round schedule (see module doc). ``placement`` maps logical
    processor k → executing device (None = identity); passes that relabel the
    machine (e.g. ``remap_digits``) compose it so inputs/outputs stay in
    logical order through :func:`~repro.core.simulator.interpret`."""

    algorithm: str
    K: int
    p: int
    steps: tuple  # CommRound | LocalOp
    placement: tuple[int, ...] | None = None
    out_slot: int = 0

    def rounds(self):
        return [s for s in self.steps if isinstance(s, CommRound)]

    @property
    def c1(self) -> int:
        return len(self.rounds())

    @property
    def c2(self) -> int:
        return sum(
            max(t.elems for t in r.transfers) for r in self.rounds() if r.transfers
        )


def ir_messages(ir: ScheduleIR) -> list[dict]:
    """Per-round ``{(src, dst): elements}`` message maps — the SAME shape the
    cost-exact simulator records in ``SimStats.round_messages`` and
    ``topo.lower`` prices on a topology."""
    out = []
    for r in ir.rounds():
        validate_round(r)
        out.append({(t.src, t.dst): t.elems for t in r.transfers})
    return out


def validate_round(rnd: CommRound) -> None:
    """The shared per-round well-formedness check (used by both the message
    deriver and the interpreter): no empty rounds (the §I model never
    schedules one — run fuse_trivial_rounds first) and at most one message
    per ordered (src, dst) pair."""
    if not rnd.transfers:
        raise ValueError(
            "empty communication round (the §I model never schedules one) "
            "— run fuse_trivial_rounds first"
        )
    seen = set()
    for t in rnd.transfers:
        if (t.src, t.dst) in seen:
            raise ValueError(
                f"two transfers share pair ({t.src}, {t.dst}) in one round"
            )
        seen.add((t.src, t.dst))


# ---------------------------------------------------------------------------
# port groups — the ppermute decomposition of a round
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class PortGroup:
    """Transfers of one round sharing (port, slots, mode): a (partial)
    permutation with uniform slot structure — exactly one ``ppermute``."""

    port: int
    slots: tuple[tuple[int, int], ...]
    mode: str
    pairs: tuple[tuple[int, int], ...]  # (src, dst)
    coeffs_by_dst: dict | None  # dst → per-slot coeff tuple (None = all 1)


def round_port_groups(rnd: CommRound) -> list[PortGroup]:
    grouped: dict = {}
    order = []
    for t in rnd.transfers:
        key = (t.port, t.slots, t.mode)
        if key not in grouped:
            grouped[key] = []
            order.append(key)
        grouped[key].append(t)
    out = []
    for key in order:
        ts = grouped[key]
        srcs = [t.src for t in ts]
        dsts = [t.dst for t in ts]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            raise ValueError(f"port group {key[0]} is not a permutation")
        coeffs = None
        if any(t.coeffs is not None for t in ts):
            coeffs = {t.dst: t.coeffs for t in ts}
        out.append(
            PortGroup(
                port=key[0],
                slots=key[1],
                mode=key[2],
                pairs=tuple((t.src, t.dst) for t in ts),
                coeffs_by_dst=coeffs,
            )
        )
    return out


def ir_permute_count(ir: ScheduleIR) -> int:
    """Number of ppermutes a mesh executor needs: one per port group."""
    return sum(len(round_port_groups(r)) for r in ir.rounds())


# ---------------------------------------------------------------------------
# rewrite passes (topology-free; remap_digits lives in repro.topo.passes)
# ---------------------------------------------------------------------------


def fuse_trivial_rounds(ir: ScheduleIR) -> ScheduleIR:
    """Drop no-op structure: transfers with no slots, rounds with no
    transfers (trivial levels lower to nothing), and identity LocalOps
    (out == in with an identity coefficient matrix — e.g. the all-ones
    twiddle of a trivial DFT level). A LocalOp REPLACES the buffer, so an
    identity op is only a no-op when every possibly-live slot is among its
    out_slots (otherwise it also truncates) — the pass tracks live slots
    and removes only provably-identity steps, keeping it exact by
    construction for ANY IR (asserted in tests/test_ir.py)."""
    steps = []
    live = {INPUT_SLOT}
    for step in ir.steps:
        if isinstance(step, CommRound):
            ts = tuple(t for t in step.transfers if t.slots)
            if ts:
                live |= {ds for t in ts for _, ds in t.slots}
                steps.append(CommRound(ts) if len(ts) != len(step.transfers) else step)
            continue
        if (
            step.coeffs is not None
            and step.out_slots == step.in_slots
            and (step.update or live <= set(step.out_slots))
            and np.array_equal(
                np.asarray(step.coeffs),
                np.broadcast_to(
                    np.eye(len(step.out_slots), dtype=np.uint64),
                    np.asarray(step.coeffs).shape,
                ),
            )
        ):
            continue  # identity contraction over every live slot
        live = live | set(step.out_slots) if step.update else set(step.out_slots)
        steps.append(step)
    return replace(ir, steps=tuple(steps))


def relabel(ir: ScheduleIR, perm) -> ScheduleIR:
    """Relabel the machine: processor k's program runs on device ``perm[k]``.
    Transfers move with their endpoints, LocalOp coefficient rows move with
    their processor, and ``placement`` composes so logical inputs/outputs are
    unchanged. The workhorse of layout passes like ``topo.passes.remap_digits``."""
    perm = np.asarray(perm, dtype=np.int64)
    K = ir.K
    if sorted(perm.tolist()) != list(range(K)):
        raise ValueError("perm must be a permutation of range(K)")
    inv = np.empty(K, dtype=np.int64)
    inv[perm] = np.arange(K)
    steps = []
    for step in ir.steps:
        if isinstance(step, CommRound):
            steps.append(
                CommRound(
                    tuple(
                        replace(t, src=int(perm[t.src]), dst=int(perm[t.dst]))
                        for t in step.transfers
                    )
                )
            )
        else:
            coeffs = step.coeffs[inv] if step.coeffs is not None else None
            steps.append(replace(step, coeffs=coeffs))
    old = (
        np.asarray(ir.placement, dtype=np.int64)
        if ir.placement is not None
        else np.arange(K)
    )
    return replace(ir, steps=tuple(steps), placement=tuple(int(v) for v in perm[old]))


def round_writes(rnd: CommRound) -> set:
    """(processor, slot) pairs a round's deliveries write."""
    return {(t.dst, ds) for t in rnd.transfers for _, ds in t.slots}


def round_reads(rnd: CommRound) -> set:
    """(processor, slot) pairs a round's sends read."""
    return {(t.src, ss) for t in rnd.transfers for ss, _ in t.slots}


def round_hazard_free(rnd: CommRound) -> bool:
    """True when no transfer reads a (processor, slot) that any delivery of
    the same round writes. Synchronous semantics make the round's result
    order-independent across sub-round boundaries exactly in this case, so a
    hazard-free round may be split into sub-rounds (each send still reads the
    value it read before) without changing the computed function."""
    return not (round_writes(rnd) & round_reads(rnd))


def merge_comm_rounds(a: CommRound, b: CommRound, p: int) -> CommRound | None:
    """Merge two adjacent rounds into one, or return None when the merge
    would change semantics or break the p-port model. Legal iff:

    * no RAW hazard — nothing ``b`` sends reads a slot ``a`` delivers into
      at the sender (in the merged round b's sends read the PRE-round buffer,
      while originally they read the post-``a`` buffer);
    * no (src, dst) pair repeats across the two rounds;
    * per-processor send and receive counts of the union stay ≤ p.

    ``b``'s ports are retagged past ``a``'s so port groups (and hence the
    executor's ppermute count) are preserved; delivery order (a's transfers
    first) matches the original two-round order, so store/add overwrite
    semantics at shared destination slots are unchanged."""
    if round_reads(b) & round_writes(a):
        return None
    pairs = [(t.src, t.dst) for t in a.transfers] + [
        (t.src, t.dst) for t in b.transfers
    ]
    if len(set(pairs)) != len(pairs):
        return None
    sends: dict = {}
    recvs: dict = {}
    for s, d in pairs:
        sends[s] = sends.get(s, 0) + 1
        recvs[d] = recvs.get(d, 0) + 1
    if max(sends.values()) > p or max(recvs.values()) > p:
        return None
    off = max(t.port for t in a.transfers)
    retagged = tuple(replace(t, port=t.port + off) for t in b.transfers)
    return CommRound(a.transfers + retagged)


# ---------------------------------------------------------------------------
# subgroup embedding (draw-loose, two-level/multi-level DFT stages)
# ---------------------------------------------------------------------------


def embed_parallel(sub: ScheduleIR, K: int, maps) -> list:
    """Embed disjoint parallel copies of ``sub`` (one per index map in
    ``maps``: local index → global processor) into a K-processor step list,
    merged round-by-round — parallel subgroups share rounds, exactly the
    paper's §V-B composition. LocalOps must cover every processor (the maps
    partition range(K))."""
    maps = [np.asarray(m, dtype=np.int64) for m in maps]
    seen = np.concatenate(maps) if maps else np.empty(0, np.int64)
    if sorted(seen.tolist()) != list(range(K)):
        raise ValueError("maps must partition range(K)")
    if sub.placement is not None:
        raise ValueError("cannot embed an already-placed IR")
    steps: list = []
    for step in sub.steps:
        if isinstance(step, CommRound):
            transfers = []
            for gmap in maps:
                for t in step.transfers:
                    transfers.append(
                        replace(t, src=int(gmap[t.src]), dst=int(gmap[t.dst]))
                    )
            steps.append(CommRound(tuple(transfers)))
        else:
            coeffs = None
            if step.coeffs is not None:
                coeffs = np.zeros(
                    (K,) + step.coeffs.shape[1:], dtype=step.coeffs.dtype
                )
                for gmap in maps:
                    coeffs[gmap] = step.coeffs
            steps.append(replace(step, coeffs=coeffs))
    return steps


# ---------------------------------------------------------------------------
# per-family compilers (core plans; topo plans compile in repro.topo)
# ---------------------------------------------------------------------------


def to_ir(plan, **kw) -> ScheduleIR:
    """Generic dispatch: every schedule plan carries its own ``to_ir``."""
    fn = getattr(plan, "to_ir", None)
    if fn is None:
        raise TypeError(f"{type(plan).__name__} does not compile to ScheduleIR")
    return fn(**kw)


def ir_prepare_shoot(
    plan: PrepareShootPlan, A=None, *, q: int = M31
) -> ScheduleIR:
    """§IV Algorithm 1. Mirrors the message-passing semantics exactly,
    including the small-K edge cases (self-sends skipped, duplicate
    destinations collapsed, dead slots never shipped): prepare rounds store
    the whole residue buffer, one LocalOp forms the w variables with the
    first-coverage mask, shoot rounds add the live digit-t slices."""
    from .schedule import digit_reduction_slots, live_slots

    K, p, m, n = plan.K, plan.p, plan.m, plan.n
    field = Field(q)
    steps: list = []
    # ---- prepare: residue offsets held are identical at every k -----------
    offsets = {0}
    for shifts in plan.prepare_shifts:
        held = tuple(sorted(offsets))
        transfers = []
        pairs_seen = set()
        for k in range(K):
            for rho, s in enumerate(shifts, start=1):
                dst = (k + s) % K
                if dst == k or (k, dst) in pairs_seen:
                    continue  # self-send / duplicate destination (K ≤ m regime)
                pairs_seen.add((k, dst))
                transfers.append(
                    Transfer(
                        src=k,
                        dst=dst,
                        port=rho,
                        slots=tuple((u, (u + s) % K) for u in held),
                        mode="store",
                    )
                )
        steps.append(CommRound(tuple(transfers)))
        base = set(offsets)
        for s in shifts:
            if s % K:
                offsets |= {(o + s) % K for o in base}
    # ---- w-init: first-coverage contraction over the residue buffer -------
    n_off = min(m, K)
    in_slots = tuple(range(n_off))
    coeffs = None
    if A is not None:
        A = field.asarray(A)
        coeffs = np.zeros((K, n, n_off), dtype=np.uint64)
        k_idx = np.arange(K)
        for off in range(m):  # offsets ≥ K alias offset off % K (same residue)
            j = off % K
            for l in range(n):
                if l * m + off < K:  # first-coverage mask (DESIGN §11)
                    rows = (k_idx - off) % K
                    cols = (k_idx + l * m) % K
                    coeffs[:, l, j] = field.add(coeffs[:, l, j], A[rows, cols])
    steps.append(LocalOp(out_slots=tuple(range(n)), in_slots=in_slots, coeffs=coeffs))
    # ---- shoot: digit-reduction toward slot 0, live slots only ------------
    n_live = live_slots(plan)
    for t, shifts in enumerate(plan.shoot_shifts, start=1):
        transfers = []
        for rho, s in enumerate(shifts, start=1):
            dst_slots, src_slots = digit_reduction_slots(n, p, t, rho)
            pairs = [
                (int(ld), int(ls))
                for ld, ls in zip(dst_slots, src_slots)
                if ls < n_live
            ]
            if not pairs:
                continue
            for k in range(K):
                transfers.append(
                    Transfer(
                        src=k,
                        dst=(k + s) % K,
                        port=rho,
                        slots=tuple((ls, ld) for ld, ls in pairs),
                        mode="add",
                    )
                )
        steps.append(CommRound(tuple(transfers)))
    return ScheduleIR("prepare-shoot", K, p, tuple(steps))


def ir_butterfly(plan: ButterflyPlan, inverse: bool = False) -> ScheduleIR:
    """§V-A radix-(p+1) butterfly: round t ships the single Q value to the p
    digit-t partners (receive coefficient = the sender-digit twiddle), then a
    LocalOp folds the own-digit term into the accumulator."""
    K, p, radix = plan.K, plan.p, plan.radix
    ACC = 1
    steps: list = []
    order = range(plan.H - 1, -1, -1) if inverse else range(plan.H)
    k_idx = np.arange(K)
    for t in order:
        tw = plan.inv_twiddles[t] if inverse else plan.twiddles[t]
        step_sz = radix**t
        digit = (k_idx // step_sz) % radix
        transfers = []
        for d, dst_map in enumerate(butterfly_group_perms(K, radix, t), start=1):
            for src in range(K):
                dst = int(dst_map[src])
                transfers.append(
                    Transfer(
                        src=src,
                        dst=dst,
                        port=d,
                        slots=((0, ACC),),
                        coeffs=(int(tw[dst, digit[src]]),),
                        mode="add",
                    )
                )
        steps.append(CommRound(tuple(transfers)))
        own = np.zeros((K, 1, 2), dtype=np.uint64)
        own[:, 0, 0] = tw[k_idx, digit]
        own[:, 0, 1] = 1
        steps.append(LocalOp(out_slots=(0,), in_slots=(0, ACC), coeffs=own))
    return ScheduleIR("butterfly", K, p, tuple(steps))


def ir_draw_loose(plan: DrawLoosePlan) -> ScheduleIR:
    """§V-B: Z parallel M-point prepare-and-shoots over stride-Z subgroups
    (merged round-by-round), the local α^rev scale, then M parallel Z-point
    butterflies over contiguous groups."""
    K, M, Z = plan.K, plan.M, plan.Z
    steps: list = []
    if plan.draw_plan is not None:
        sub = ir_prepare_shoot(plan.draw_plan, plan.draw_matrix, q=plan.q)
        steps += embed_parallel(sub, K, [j + Z * np.arange(M) for j in range(Z)])
    scale = np.zeros((K, 1, 1), dtype=np.uint64)
    scale[:, 0, 0] = plan.local_scale
    steps.append(LocalOp(out_slots=(0,), in_slots=(0,), coeffs=scale))
    if plan.loose_plan is not None:
        sub = ir_butterfly(plan.loose_plan)
        steps += embed_parallel(sub, K, [Z * i + np.arange(Z) for i in range(M)])
    return ScheduleIR("draw-loose", K, plan.p, tuple(steps))


def ir_allgather(K: int, p: int, A=None, *, q: int = M31) -> ScheduleIR:
    """The (p+1)-ary doubling all-gather baseline + one local contraction —
    C1 = ⌈log_{p+1}K⌉ but C2 = Θ(K/p), the cost-model foil."""
    steps: list = []
    for ports in gather_rounds(K, p):
        transfers = []
        for rho, (s, cnt) in enumerate(ports, start=1):
            for k in range(K):
                transfers.append(
                    Transfer(
                        src=k,
                        dst=(k + s) % K,
                        port=rho,
                        slots=tuple((u, s + u) for u in range(cnt)),
                        mode="store",
                    )
                )
        steps.append(CommRound(tuple(transfers)))
    steps.append(LocalOp(tuple([0]), tuple(range(K)), _combine_coeffs(K, A, q)))
    return ScheduleIR("allgather", K, p, tuple(steps))


def _combine_coeffs(K: int, A, q: int):
    """coeffs[k, 0, d] = A[(k-d) % K, k] — the full local combine of a
    gather-everything schedule (allgather, ring) over offset-d slots."""
    if A is None:
        return None
    field = Field(q)
    A = field.asarray(A)
    k = np.arange(K)
    coeffs = np.zeros((K, 1, K), dtype=np.uint64)
    for d in range(K):
        coeffs[:, 0, d] = A[(k - d) % K, k]
    return coeffs
