"""Generator-matrix constructions (host tier, exact).

All functions return numpy ``uint64`` canonical-representative matrices.
Conventions follow the paper: ``(x_0..x_{K-1}) @ A = (x̃_0..x̃_{K-1})``,
i.e. processor k's coded packet is defined by *column* k of A.
"""

from __future__ import annotations

import numpy as np

from .field import Field, radix_valuation


def vandermonde(field: Field, points, nrows: int | None = None) -> np.ndarray:
    """A[i, j] = points[j] ** i, shape (nrows, len(points))."""
    pts = field.asarray(points)
    n = nrows if nrows is not None else pts.shape[0]
    rows = [np.ones_like(pts)]
    for _ in range(1, n):
        rows.append(field.mul(rows[-1], pts))
    return np.stack(rows, axis=0)


def dft_matrix(field: Field, K: int) -> np.ndarray:
    """The K×K DFT matrix D_K (Eq. 4); requires K | q-1."""
    beta = field.root_of_unity(K)
    return vandermonde(field, field.pow(np.full(K, beta, dtype=np.uint64), np.arange(K)))


def distinct_points(field: Field, K: int, seed: int = 0) -> np.ndarray:
    """K distinct nonzero evaluation points (deterministic)."""
    if K > field.q - 1:
        raise ValueError("need K <= q-1 distinct nonzero points")
    rng = np.random.default_rng(seed)
    # powers of the generator at random distinct exponents — distinct, nonzero
    exps = rng.choice(field.q - 1, size=K, replace=False)
    g = np.full(K, field.generator, dtype=np.uint64)
    return field.pow(g, exps)


def lagrange_matrix(field: Field, alphas, omegas) -> np.ndarray:
    """A[k, j] = Φ_k(α_j) with Φ_k(z) = Π_{i≠k} (z-ω_i)/(ω_k-ω_i)  (§VI).

    Maps point-values f(ω_k) to point-values f(α_j):  x̃ = x @ A.
    """
    alphas = field.asarray(alphas)
    omegas = field.asarray(omegas)
    K = omegas.shape[0]
    # numerator_j(k) = Π_{i≠k} (α_j - ω_i); denominator(k) = Π_{i≠k} (ω_k - ω_i)
    A = np.zeros((K, alphas.shape[0]), dtype=np.uint64)
    denom = np.ones(K, dtype=np.uint64)
    for i in range(K):
        diff = field.sub(omegas, omegas[i])
        diff = np.where(np.arange(K) == i, np.uint64(1), diff)
        denom = field.mul(denom, diff)
    denom_inv = field.inv(denom)
    for k in range(K):
        num = np.ones_like(alphas)
        for i in range(K):
            if i == k:
                continue
            num = field.mul(num, field.sub(alphas, omegas[i]))
        A[k] = field.mul(num, denom_inv[k])
    return A


def cauchy_matrix(field: Field, K: int, N: int | None = None, seed: int = 0) -> np.ndarray:
    """A[i, j] = 1/(x_i + y_j) with all x_i, y_j distinct and x_i + y_j ≠ 0.

    EVERY square submatrix of a Cauchy matrix is invertible — the guarantee
    the coded-checkpoint recovery needs (any f lost shards recoverable from
    any f surviving parity equations). Cauchy generators are the paper's own
    §VII 'future work'; computing one is a direct application of the
    universal prepare-and-shoot algorithm (it computes ANY matrix)."""
    N = N or K
    if K + N > field.q:
        raise ValueError("need K+N distinct field elements")
    xs = np.arange(1, K + 1, dtype=np.uint64)
    ys = np.arange(K + 1, K + N + 1, dtype=np.uint64)
    s = field.add(xs[:, None], ys[None, :])
    return field.inv(s)


def random_matrix(field: Field, K: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, field.q, size=(K, K), dtype=np.uint64)


def random_vector(field: Field, shape, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, field.q, size=shape, dtype=np.uint64)


def digit_reverse(k: int, radix: int, ndigits: int) -> int:
    """Reverse the base-``radix`` digits of k (ndigits wide)."""
    out = 0
    for _ in range(ndigits):
        out = out * radix + k % radix
        k //= radix
    return out


def digit_reversal_permutation(K: int, radix: int) -> np.ndarray:
    H = radix_valuation(K, radix)
    if radix**H != K:
        raise ValueError(f"K={K} is not a power of radix={radix}")
    return np.array([digit_reverse(k, radix, H) for k in range(K)], dtype=np.int64)


def butterfly_target_matrix(field: Field, K: int, radix: int) -> np.ndarray:
    """The matrix the DFT butterfly actually computes: rev-row-permuted D_K.

    out[k] = Σ_j x_j β^{rev(j)·k}  ⇔  A[j, k] = β^{rev(j) k}.
    Row permutation of D_K ⇒ still an MDS/Vandermonde generator (DESIGN §3).
    """
    D = dft_matrix(field, K)
    rev = digit_reversal_permutation(K, radix)
    return D[rev, :]


def dft_matrix_float(K: int) -> np.ndarray:
    """Orthonormal complex DFT for the float-field instantiation
    (gradient coding): perfectly conditioned."""
    j = np.arange(K)
    W = np.exp(-2j * np.pi * np.outer(j, j) / K) / np.sqrt(K)
    return W
