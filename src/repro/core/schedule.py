"""Static round schedules for the paper's algorithms.

A *schedule* is everything that is independent of the input packets: which
processor talks to which (uniform shifts per round — TPU-native, DESIGN §3),
how buffers are laid out, and (for the specific algorithms) the precomputed
coefficient/twiddle tables with their Shoup duals.

Everything here is host-side numpy / python int; the jnp executors in
``prepare_shoot.py`` / ``draw_loose.py`` and the shard_map collectives in
``dist/collectives.py`` consume these plans as compile-time constants.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .bounds import ceil_log, ps_params
from .field import M31, Field, shoup_precompute
from .matrices import digit_reversal_permutation


# ---------------------------------------------------------------------------
# prepare-and-shoot schedule (§IV)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrepareShootPlan:
    K: int
    p: int
    L: int
    Tp: int
    Ts: int
    m: int
    n: int
    # prepare round t (1-based) sends the whole buffer to k + rho*m/(p+1)^t
    prepare_shifts: tuple[tuple[int, ...], ...]  # [round][rho-1] -> shift
    # shoot round t sends digit-t slices to k + rho*m*(p+1)^(t-1)
    shoot_shifts: tuple[tuple[int, ...], ...]
    # prepare buffer slot u holds x_{k - prepare_offsets[u]} at phase end
    prepare_offsets: tuple[int, ...]

    @property
    def c1(self) -> int:
        return self.Tp + self.Ts

    @property
    def c2(self) -> int:
        return (self.m - 1) // self.p + (self.n - 1) // self.p

    def to_ir(self, A=None, *, q: int = M31):
        from .ir import ir_prepare_shoot

        return ir_prepare_shoot(self, A, q=q)


def gather_rounds(N: int, p: int) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Round schedule fully gathering N cyclic packets: each round every
    processor sends a prefix of its (contiguous-offset) buffer to p partners.

    Returns per round a tuple of ``(shift, count)`` ports: send buffer slots
    [0, count) to processor k+shift (mod N). After round r the buffer holds
    offsets [0, min((p+1)^r, N)) — ⌈log_{p+1}N⌉ rounds total, C2 = Σ max
    count ≈ (N−1)/p (the optimal p-port all-gather of bounds.py). Shared by
    the allgather baseline and the hierarchical/multilevel intra phases.
    """
    rounds = []
    b = 1
    while b < N:
        ports = []
        for rho in range(1, p + 1):
            cnt = min(b, N - rho * b)
            if cnt > 0:
                ports.append((rho * b, cnt))
        rounds.append(tuple(ports))
        b = min(b * (p + 1), N)
    return tuple(rounds)


def plan_prepare_shoot(K: int, p: int) -> PrepareShootPlan:
    L, Tp, Ts, m, n = ps_params(K, p)
    prepare_shifts = []
    for t in range(1, Tp + 1):
        step = m // (p + 1) ** t
        prepare_shifts.append(tuple(rho * step for rho in range(1, p + 1)))
    shoot_shifts = []
    for t in range(1, Ts + 1):
        step = m * (p + 1) ** (t - 1)
        shoot_shifts.append(tuple(rho * step for rho in range(1, p + 1)))
    # offsets: buffer grows by concatenation [self, recv_1, .., recv_p] each
    # round; slot (rho*c + u) after round t holds offset rho*step_t + delta(u).
    offsets = [0]
    for t in range(1, Tp + 1):
        step = m // (p + 1) ** t
        base = list(offsets)
        for rho in range(1, p + 1):
            offsets.extend(rho * step + d for d in base)
    assert sorted(offsets) == list(range(m)), "prepare tree must cover [0, m)"
    return PrepareShootPlan(
        K=K,
        p=p,
        L=L,
        Tp=Tp,
        Ts=Ts,
        m=m,
        n=n,
        prepare_shifts=tuple(prepare_shifts),
        shoot_shifts=tuple(shoot_shifts),
        prepare_offsets=tuple(offsets),
    )


def coeff_mask(plan: PrepareShootPlan) -> np.ndarray:
    """First-coverage mask (DESIGN §11): contribution (slot u, variable l)
    is kept iff  l*m + prepare_offsets[u] < K.

    Every source residue j = (l*m + offset) mod K then contributes to each
    destination exactly once:  y_k = sum_{j=0}^{K-1} x_{k-j} A[k-j, k] = x~_k.
    This subsumes the paper's Eq. 2 set semantics and Eq. 3 overlap
    correction, and is exact for every K <= m*n (the paper's correction
    needs (n-1)m < K, which fails e.g. for its own Fig. 3 parameters).
    Shape (m, n) bool.
    """
    offs = np.asarray(plan.prepare_offsets)[:, None]
    l = np.arange(plan.n)[None, :]
    return (l * plan.m + offs) < plan.K


def live_slots(plan: PrepareShootPlan) -> int:
    """Number of live w variables: slot l is entirely masked (all-zero, never
    worth sending) iff l*m >= K. Live slots are l in [0, ceil(K/m))."""
    return -(-plan.K // plan.m)


def digit_reduction_slots(n: int, p: int, t: int, rho: int):
    """(dst_slots, src_slots) of the §IV digit-reduction over ``n`` slots,
    round ``t`` (1-based), port ``rho``: receiver slot l (digit_t = 0, lower
    digits 0) absorbs sender slot l + rho·(p+1)^{t-1}. The single source of
    truth for the shoot/inter-shoot slot algebra (dist.collectives and
    topo.hierarchical delegate here)."""
    radix = p + 1
    stride = radix ** (t - 1)
    l = np.arange(n)
    src = l + rho * stride
    valid = (src < n) & ((l // stride) % radix == 0) & (l % stride == 0)
    return l[valid], src[valid]


def digit_reduction_message_size(n: int, n_live: int, p: int, t: int, rho: int) -> int:
    """Live elements shipped on port rho in round t: the digit-reduction's
    sender slots below ``n_live`` (slots l ≥ n_live are identically zero)."""
    radix = p + 1
    stride = radix ** (t - 1)
    return sum(
        1
        for l in range(n)
        if (l // stride) % radix == rho and l % stride == 0 and l < n_live
    )


def shoot_round_message_size(plan: PrepareShootPlan, t: int, rho: int) -> int:
    """Elements sent on port rho in shoot round t (1-based): the live slots
    {l : digit_t(l) = rho, lower digits 0, l*m < K}."""
    return digit_reduction_message_size(plan.n, live_slots(plan), plan.p, t, rho)


def counted_c2(plan: PrepareShootPlan) -> int:
    """Exact C2 with live-slot accounting: equals the Theorem-1 closed form
    when m*n == K and is <= it otherwise (dead slots are never sent)."""
    c2 = (plan.m - 1) // plan.p  # prepare: Lemma 3
    for t in range(1, plan.Ts + 1):
        c2 += max(
            shoot_round_message_size(plan, t, rho) for rho in range(1, plan.p + 1)
        )
    return c2


def shoot_coeff_tensor(plan: PrepareShootPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[(k - prepare_offsets[u]) mod K, (k + l*m) mod K].

    The w-variable initialization (Algorithm 1 line 1) becomes the modular
    contraction  w[k, l] = Σ_u buf[k, u] * coef[k, u, l]  — the gf_matmul
    hot spot. Built host-side with static indices (A may be a runtime array
    in the jnp path; there we gather with the same indices instead).
    """
    K, m, n = plan.K, plan.m, plan.n
    k = np.arange(K)[:, None, None]
    u = np.asarray(plan.prepare_offsets)[None, :, None]
    l = np.arange(n)[None, None, :]
    rows = (k - u) % K
    cols = (k + l * m) % K
    return np.asarray(A)[rows, cols]


def shoot_coeff_indices(plan: PrepareShootPlan) -> tuple[np.ndarray, np.ndarray]:
    """(rows, cols) index tensors for gathering the coef tensor from a
    runtime A inside jit."""
    K, m, n = plan.K, plan.m, plan.n
    k = np.arange(K)[:, None, None]
    u = np.asarray(plan.prepare_offsets)[None, :, None]
    l = np.arange(n)[None, None, :]
    rows = (k - u) % K
    cols = (k + l * m) % K
    rows, cols = np.broadcast_arrays(rows, cols)
    return rows, cols


# ---------------------------------------------------------------------------
# DFT butterfly schedule (§V-A)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ButterflyPlan:
    K: int
    p: int  # radix = p+1
    H: int
    q: int
    beta: int  # primitive K-th root of unity
    # round t ∈ [0, H): processor k combines the radix values of its digit-t
    # group with coefficients twiddle[t][k, rho] = gamma(k mod (p+1)^{t+1})^rho
    twiddles: tuple[np.ndarray, ...]  # uint32 (K, radix)
    twiddles_shoup: tuple[np.ndarray, ...]  # uint32 (K, radix)
    inv_twiddles: tuple[np.ndarray, ...]  # inverse-butterfly coefficients
    inv_twiddles_shoup: tuple[np.ndarray, ...]
    digit_rev: np.ndarray  # the row permutation the butterfly applies

    @property
    def radix(self) -> int:
        return self.p + 1

    @property
    def c1(self) -> int:
        return self.H

    @property
    def c2(self) -> int:
        return self.H

    def to_ir(self, inverse: bool = False):
        from .ir import ir_butterfly

        return ir_butterfly(self, inverse=inverse)


def plan_butterfly(K: int, p: int, q: int) -> ButterflyPlan:
    """Build the radix-(p+1) butterfly for K = (p+1)^H over GF(q).

    Requires K | q-1 (so a primitive K-th root of unity exists).
    Round-t coefficient for receiver k, sender-digit rho (Eq. 9):
        twiddle[t][k, rho] = gamma_{k_t k_{t-1}..k_0} ^ rho
    with gamma_{d_{h-1}..d_0} = (beta^{Σ d_i (p+1)^i})^{(p+1)^{H-h}} (Eq. 5).
    """
    radix = p + 1
    H = ceil_log(K, radix)
    if radix**H != K:
        raise ValueError(f"K={K} is not a power of {radix}")
    f = Field(q)
    beta = f.root_of_unity(K)
    k = np.arange(K, dtype=np.int64)
    twiddles, tw_shoup, inv_tw, inv_tw_shoup = [], [], [], []
    for t in range(H):
        h = t + 1  # gamma index uses digits 0..t → level h = t+1
        low = k % (radix ** (t + 1))  # k_t..k_0 as an integer
        # gamma = (beta^low)^{(p+1)^{H-h}}
        gamma = f.pow(f.pow(np.full(K, beta, dtype=np.uint64), low), radix ** (H - h))
        tw = np.stack([f.pow(gamma, rho) for rho in range(radix)], axis=1)
        twiddles.append(tw.astype(np.uint32))
        tw_shoup.append(shoup_precompute(tw, q))
        # inverse round: per digit-t group, the radix×radix matrix
        # A_k^{(t)}[r, rho] = gamma(digit_t←r)^rho is Vandermonde (Eq. 11);
        # invert it per group and hand each processor its row.
        group_lo = k % (radix**t)
        group_hi = k // (radix ** (t + 1))
        inv_rows = np.zeros((K, radix), dtype=np.uint64)
        # group members share (group_hi, group_lo); member r has digit_t = r
        base = (group_hi * radix) * (radix**t) + group_lo  # digit_t = 0 member
        uniq = np.unique(base)
        for b in uniq:
            members = b + np.arange(radix) * (radix**t)
            V = tw[members, :]  # V[r, rho] = gamma_r^rho
            Vinv = f.inv_matrix(V)
            # Q(k_r, t) = Σ_rho Vinv[r, rho] Q(k_rho, t+1)
            inv_rows[members, :] = Vinv
        inv_tw.append(inv_rows.astype(np.uint32))
        inv_tw_shoup.append(shoup_precompute(inv_rows, q))
    return ButterflyPlan(
        K=K,
        p=p,
        H=H,
        q=q,
        beta=int(beta),
        twiddles=tuple(twiddles),
        twiddles_shoup=tuple(tw_shoup),
        inv_twiddles=tuple(inv_tw),
        inv_twiddles_shoup=tuple(inv_tw_shoup),
        digit_rev=digit_reversal_permutation(K, radix),
    )


def butterfly_group_perms(K: int, radix: int, t: int) -> list[np.ndarray]:
    """For each d ∈ [1, radix): permutation dst[k] = k with digit t
    incremented by d (mod radix) — the ppermute pairs of round t."""
    k = np.arange(K, dtype=np.int64)
    step = radix**t
    digit = (k // step) % radix
    perms = []
    for d in range(1, radix):
        dst = k + ((digit + d) % radix - digit) * step
        perms.append(dst)
    return perms


# ---------------------------------------------------------------------------
# draw-and-loose decomposition (§V-B)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DrawLoosePlan:
    """K = M · Z, Z = (p+1)^H; processor P_{i,j} = j + Z·i.

    draw:  Z parallel M×M prepare-and-shoot encodes over stride-Z subgroups
           computing V[w, i] = alpha_i^{Z·w}, then local ·alpha_i^{rev(j)}.
    loose: M parallel Z-point radix-(p+1) butterflies over contiguous groups.

    Digit-reversal bookkeeping (DESIGN §3): the butterfly of §V-A maps inputs
    v to out[j] = Σ_ℓ v_{rev(ℓ)} ω^{ℓ j}. Feeding it in[j] = f_{rev(j)}(α_i)
    yields the TRUE evaluations x̃_{i,j} = Σ_ℓ f_ℓ(α_i) ω^{ℓ j}. We get
    in[j] = f_{rev(j)}(α_i) for free by declaring that processor P_{w,j}'s
    packet is source symbol x_{w, rev(j)} (a relabeling, i.e. a fixed ROW
    permutation of the Vandermonde generator — the paper's "up to
    permutation"). Concretely: the generator computed is
        G[k, c] = points[c] ** source_perm[k],
    source_perm[k] = Z·(k//Z) + rev(k mod Z), points[c] = α_{c//Z}·ω^{c mod Z},
    and the draw-phase local multiplier at processor k is α_{k//Z}^{rev(k mod Z)}.
    """

    K: int
    p: int
    M: int
    H: int
    Z: int
    q: int
    alphas: np.ndarray  # (M,) subgroup evaluation points alpha_i
    omega: int  # primitive Z-th root of unity (beta_j = omega^j)
    draw_plan: PrepareShootPlan | None  # None when M == 1
    draw_matrix: np.ndarray  # (M, M) V[w, i] = alpha_i^{Z w}
    loose_plan: ButterflyPlan | None  # None when H == 0
    points: np.ndarray  # (K,) evaluation point of processor c: alpha_{c//Z}·omega^{c%Z}
    source_perm: np.ndarray  # (K,) coefficient index held by processor k
    local_scale: np.ndarray  # (K,) uint32 draw-phase multiplier alpha_i^{rev(j)}
    local_scale_shoup: np.ndarray  # (K,) uint32

    @property
    def c1(self) -> int:
        c = self.loose_plan.H if self.loose_plan else 0
        if self.draw_plan:
            c += self.draw_plan.c1
        return c

    @property
    def c2(self) -> int:
        c = self.loose_plan.H if self.loose_plan else 0
        if self.draw_plan:
            c += self.draw_plan.c2
        return c

    def to_ir(self):
        from .ir import ir_draw_loose

        return ir_draw_loose(self)


def plan_draw_loose(K: int, p: int, q: int, seed: int = 0) -> DrawLoosePlan:
    """Factor K = M·(p+1)^H with H maximal s.t. (p+1)^H | gcd(K, q-1),
    choose injective phi (random distinct exponents) per §V-B."""
    radix = p + 1
    f = Field(q)
    H = 0
    while K % radix ** (H + 1) == 0 and (q - 1) % radix ** (H + 1) == 0:
        H += 1
    Z = radix**H
    M = K // Z
    omega = f.root_of_unity(Z) if Z > 1 else 1
    # alpha_i = g^{phi(i)}, phi injective into [0, (q-1)/Z - 1]; exponents are
    # multiples of nothing special — distinctness of alpha_i*omega^j follows
    # because alpha exponents are distinct mod (q-1)/Z (paper §V-B).
    rng = np.random.default_rng(seed)
    space = (q - 1) // Z
    if M > space:
        raise ValueError("cannot choose M distinct alpha exponents")
    exps = rng.choice(space, size=M, replace=False)
    alphas = f.pow(np.full(M, f.generator, dtype=np.uint64), exps)
    draw_plan = plan_prepare_shoot(M, p) if M > 1 else None
    # V[w, i] = alpha_i^{Z·w}
    aZ = f.pow(alphas, Z)
    V = np.stack([f.pow(aZ, w) for w in range(M)], axis=0)
    loose_plan = plan_butterfly(Z, p, q) if H > 0 else None
    i = np.arange(K) // Z
    jj = np.arange(K) % Z
    points = f.mul(alphas[i], f.pow(np.full(K, omega, dtype=np.uint64), jj))
    if len(np.unique(points)) != K:
        raise RuntimeError("evaluation points not distinct — bad phi choice")
    rev = loose_plan.digit_rev if loose_plan is not None else np.arange(Z)
    source_perm = Z * i + rev[jj]
    local_scale = f.pow(alphas[i], rev[jj]).astype(np.uint32)
    return DrawLoosePlan(
        K=K,
        p=p,
        M=M,
        H=H,
        Z=Z,
        q=q,
        alphas=alphas,
        omega=int(omega),
        draw_plan=draw_plan,
        draw_matrix=V,
        loose_plan=loose_plan,
        points=points,
        source_perm=source_perm,
        local_scale=local_scale,
        local_scale_shoup=shoup_precompute(local_scale, q),
    )


def draw_loose_target_matrix(plan: DrawLoosePlan) -> np.ndarray:
    """The K×K generator actually computed: G[k, c] = points[c]^source_perm[k]
    — a fixed row permutation of the Vandermonde matrix on ``plan.points``
    (still MDS; the paper's 'up to permutation')."""
    from .matrices import vandermonde

    f = Field(plan.q)
    V = vandermonde(f, plan.points)  # V[r, c] = points[c]^r
    return V[plan.source_perm, :]
