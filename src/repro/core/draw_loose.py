"""Array-level (jnp) executors for the specific algorithms (§V, §VI):

* radix-(p+1) DFT butterfly (forward + inverse) — Theorems 2, Lemma 5
* draw-and-loose for general Vandermonde matrices — Theorem 3, Lemma 6
* Lagrange matrices via inverse-Vandermonde ∘ forward-Vandermonde — Theorem 4

All twiddles/coefficients are schedule constants with Shoup duals (uint32-only
products). ``jnp.take`` with the per-round digit-group permutations is the
local stand-in for the mesh ``ppermute`` (see dist/collectives.py).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .field import Field, madd, shoup_mul
from .schedule import (
    ButterflyPlan,
    DrawLoosePlan,
    butterfly_group_perms,
    plan_butterfly,
    plan_draw_loose,
)
from .prepare_shoot import encode_universal


def _bcast(coef, npay):
    return coef.reshape(coef.shape + (1,) * npay)


def butterfly_apply(
    v: jnp.ndarray, plan: ButterflyPlan, inverse: bool = False
) -> jnp.ndarray:
    """v: (K, *payload) uint32 → out[k] = Σ_j v_{rev(j)} β^{jk} (forward).

    Round t: out[k] = Σ_ρ tw[k, ρ] · v[k with digit_t = ρ]  (Eq. 9/10).
    """
    K, radix, H, q = plan.K, plan.radix, plan.H, plan.q
    npay = v.ndim - 1
    rounds = range(H - 1, -1, -1) if inverse else range(H)
    step_pow = [radix**t for t in range(H)]
    k = np.arange(K)
    for t in rounds:
        tw = plan.inv_twiddles[t] if inverse else plan.twiddles[t]
        tw_sh = plan.inv_twiddles_shoup[t] if inverse else plan.twiddles_shoup[t]
        step = step_pow[t]
        digit = (k // step) % radix
        acc = None
        for rho in range(radix):
            src = k + (rho - digit) * step  # k with digit_t replaced by rho
            term = shoup_mul(
                jnp.take(v, jnp.asarray(src), axis=0),
                _bcast(jnp.asarray(tw[:, rho]), npay),
                _bcast(jnp.asarray(tw_sh[:, rho]), npay),
                q,
            )
            acc = term if acc is None else madd(acc, term, q)
        v = acc
    return v


def encode_dft(x: jnp.ndarray, plan: ButterflyPlan) -> jnp.ndarray:
    """Computes x @ G with G = D_K[rev, :] (butterfly_target_matrix)."""
    return butterfly_apply(x, plan)


def decode_dft(y: jnp.ndarray, plan: ButterflyPlan) -> jnp.ndarray:
    """Inverse of encode_dft (Lemma 5), same C1 = C2 = H."""
    return butterfly_apply(y, plan, inverse=True)


def encode_draw_loose(x: jnp.ndarray, plan: DrawLoosePlan) -> jnp.ndarray:
    """Computes x @ G with G = Vandermonde(points)[source_perm, :]
    (draw_loose_target_matrix). x: (K, *payload)."""
    K, M, Z, q = plan.K, plan.M, plan.Z, plan.q
    payload = x.shape[1:]
    npay = len(payload)
    v = x.reshape(M, Z, *payload)  # processor j + Z*i → [i, j]

    # ---- draw: Z parallel M×M prepare-and-shoots (batched over j) ---------
    if plan.draw_plan is not None:
        # treat (Z, *payload) as the payload of an M-processor encode
        F = encode_universal(v, plan.draw_matrix, p=plan.p, q=q, plan=plan.draw_plan)
    else:
        F = v
    # local scale α_i^{rev(j)} (no communication)
    scale = plan.local_scale.reshape(M, Z)
    scale_sh = plan.local_scale_shoup.reshape(M, Z)
    F = shoup_mul(
        F, _bcast(jnp.asarray(scale), npay), _bcast(jnp.asarray(scale_sh), npay), q
    )

    # ---- loose: M parallel Z-point butterflies (batched over i) -----------
    if plan.loose_plan is not None:
        Ft = jnp.moveaxis(F, 0, 1)  # (Z, M, *payload)
        out = butterfly_apply(Ft, plan.loose_plan)
        out = jnp.moveaxis(out, 1, 0)
    else:
        out = F
    return out.reshape(K, *payload)


def decode_draw_loose(y: jnp.ndarray, plan: DrawLoosePlan) -> jnp.ndarray:
    """Inverse of encode_draw_loose (Lemma 6): inverse butterfly, divide the
    local scale, then prepare-and-shoot with the INVERSE draw matrix."""
    K, M, Z, q = plan.K, plan.M, plan.Z, plan.q
    payload = y.shape[1:]
    npay = len(payload)
    f = Field(q)
    v = y.reshape(M, Z, *payload)
    if plan.loose_plan is not None:
        vt = jnp.moveaxis(v, 0, 1)
        vt = butterfly_apply(vt, plan.loose_plan, inverse=True)
        v = jnp.moveaxis(vt, 1, 0)
    inv_scale = f.inv(plan.local_scale.astype(np.uint64)).astype(np.uint32)
    from .field import shoup_precompute

    v = shoup_mul(
        v,
        _bcast(jnp.asarray(inv_scale.reshape(M, Z)), npay),
        _bcast(jnp.asarray(shoup_precompute(inv_scale, q).reshape(M, Z)), npay),
        q,
    )
    if plan.draw_plan is not None:
        Vinv = f.inv_matrix(plan.draw_matrix)
        v = encode_universal(v, Vinv, p=plan.p, q=q, plan=plan.draw_plan)
    return v.reshape(K, *payload)


def encode_lagrange(
    x: jnp.ndarray, plan_omega: DrawLoosePlan, plan_alpha: DrawLoosePlan
) -> jnp.ndarray:
    """Theorem 4: processors hold point-values f(ω'_k) of an implicit degree-
    (K-1) polynomial (ω' = plan_omega.points); each obtains f(α'_k)
    (α' = plan_alpha.points). The source permutations of the two plans cancel
    (same K, p, q ⇒ same digit-reversal), so the composite computes the TRUE
    Lagrange matrix lagrange_matrix(field, plan_alpha.points, plan_omega.points).
    """
    if (plan_omega.K, plan_omega.p, plan_omega.q) != (
        plan_alpha.K,
        plan_alpha.p,
        plan_alpha.q,
    ):
        raise ValueError("plans must share (K, p, q)")
    coeffs = decode_draw_loose(x, plan_omega)
    return encode_draw_loose(coeffs, plan_alpha)


__all__ = [
    "butterfly_apply",
    "encode_dft",
    "decode_dft",
    "encode_draw_loose",
    "decode_draw_loose",
    "encode_lagrange",
    "plan_butterfly",
    "plan_draw_loose",
    "butterfly_group_perms",
]
