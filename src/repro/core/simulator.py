"""Cost-exact synchronous p-port network simulator (paper §I model) — now a
single generic :func:`interpret` over :class:`~repro.core.ir.ScheduleIR`.

Every algorithm family compiles to the same IR (``core/ir.py``), and ONE
interpreter executes any IR message-by-message under the exact §I
constraints: every round is validated against the p-port limits (each
processor sends ≤ p and receives ≤ p messages, no self-messages) and C1/C2
are counted exactly as defined:

    C1 = number of rounds
    C2 = Σ_t max_{messages m in round t} len(m)     (field elements)

The per-family ``simulate_*`` entry points are thin wrappers over
``interpret(plan.to_ir(...))`` — kept for API compatibility and because they
assert bit-exactness against the matrix oracle whenever the generator is at
hand (the transition guarantee of the IR refactor). This is what
EXPERIMENTS.md's paper-claims tables are produced from; the array-level jnp
executors in ``prepare_shoot.py`` / ``draw_loose.py`` are cross-checked
against both this interpreter and the matrix oracle.
"""

from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .field import Field
from .ir import INPUT_SLOT, CommRound, LocalOp, ScheduleIR, validate_round
from .schedule import ButterflyPlan, DrawLoosePlan, PrepareShootPlan


@dataclass
class SimStats:
    K: int
    p: int
    C1: int = 0
    C2: int = 0
    round_sizes: list = dc_field(default_factory=list)
    total_elements: int = 0  # Σ over all messages (not just max) — extra info
    # per-round message map {(src, dst): elements} — the exact communication
    # pattern; equals ``ir_messages(plan.to_ir())`` message-for-message (the
    # lowering repro.topo.lower prices on a topology)
    round_messages: list = dc_field(default_factory=list)


class SyncSimulator:
    """Executes one communication round at a time, enforcing the model."""

    def __init__(self, K: int, p: int):
        self.stats = SimStats(K=K, p=p)

    def exchange(self, messages: dict) -> dict:
        """messages: {(src, dst): list_of_elements}. Returns them 'delivered'.

        Empty rounds are not allowed (the model counts a round only when
        communication happens; algorithms never schedule empty rounds).
        """
        K, p = self.stats.K, self.stats.p
        if not messages:
            raise ValueError("empty communication round")
        out_count: dict[int, int] = {}
        in_count: dict[int, int] = {}
        for (src, dst), payload in messages.items():
            if src == dst:
                raise ValueError(f"self-message at processor {src}")
            if not (0 <= src < K and 0 <= dst < K):
                raise ValueError("processor index out of range")
            if len(payload) == 0:
                raise ValueError("empty message")
            out_count[src] = out_count.get(src, 0) + 1
            in_count[dst] = in_count.get(dst, 0) + 1
        if max(out_count.values()) > p:
            raise ValueError(f"a processor sends more than p={p} messages")
        if max(in_count.values()) > p:
            raise ValueError(f"a processor receives more than p={p} messages")
        d = max(len(v) for v in messages.values())
        self.stats.C1 += 1
        self.stats.C2 += d
        self.stats.round_sizes.append(d)
        self.stats.total_elements += sum(len(v) for v in messages.values())
        self.stats.round_messages.append(
            {pair: len(v) for pair, v in messages.items()}
        )
        return messages


# ---------------------------------------------------------------------------
# THE interpreter: any ScheduleIR, message-by-message, cost-exact
# ---------------------------------------------------------------------------


def interpret(
    ir: ScheduleIR, x: np.ndarray, field: Field, *, tracer=None, topo=None
) -> tuple[np.ndarray, SimStats]:
    """Execute ``ir`` on input ``x`` (shape (K,), uint64 canonical mod q)
    under the p-port constraints; returns (output, stats). Inputs and
    outputs are in LOGICAL processor order — ``ir.placement`` (set by layout
    passes like ``topo.passes.remap_digits``) is applied at the boundary.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) opts into per-round
    spans mirroring the mesh executor's instrumentation: one span per
    CommRound with its round index, transfer count, and largest message
    (host wall time here measures the interpreter itself, not a network —
    useful for tracing schedule structure, not for calibration); ``topo``
    (a :class:`repro.topo.model.Topology`) additionally stamps the α-β
    model's ``predicted_us`` on each round span."""
    K = ir.K
    x = field.asarray(np.asarray(x))
    if x.shape != (K,):
        raise ValueError(f"x must have shape ({K},), got {x.shape}")
    place = (
        np.asarray(ir.placement, dtype=np.int64)
        if ir.placement is not None
        else np.arange(K)
    )
    sim = SyncSimulator(K, ir.p)
    zero = np.uint64(0)
    buf: list[dict] = [{} for _ in range(K)]
    for k in range(K):
        buf[place[k]][INPUT_SLOT] = x[k]
    from contextlib import nullcontext

    root = (
        tracer.span("interpret", algorithm=ir.algorithm, K=K, p=ir.p)
        if tracer is not None
        else nullcontext()
    )
    round_no = -1
    with root:
        for step in ir.steps:
            if isinstance(step, CommRound):
                validate_round(step)
                round_no += 1
                msgs: dict = {}
                modes: dict = {}
                for t in step.transfers:
                    payload = []
                    for i, (ss, ds) in enumerate(t.slots):
                        c = t.coeffs[i] if t.coeffs is not None else 1
                        payload.append((ds, c, buf[t.src].get(ss, zero)))
                    msgs[(t.src, t.dst)] = payload
                    modes[(t.src, t.dst)] = t.mode
                span = nullcontext()
                if tracer is not None:
                    attrs = {
                        "algorithm": ir.algorithm,
                        "comm_round": round_no,
                        "transfers": len(step.transfers),
                        "slots": max(len(v) for v in msgs.values()),
                        "payload_elems": 1,
                    }
                    if topo is not None:
                        from repro.topo.model import schedule_time

                        attrs["predicted_us"] = (
                            schedule_time(
                                topo, [{p_: len(v) for p_, v in msgs.items()}]
                            ).total
                            * 1e6
                        )
                    span = tracer.span(f"round[{round_no}]", **attrs)
                with span:
                    delivered = sim.exchange(msgs)
                    for pair, payload in delivered.items():
                        dst = pair[1]
                        store = modes[pair] == "store"
                        for ds, c, v in payload:
                            if c != 1:
                                v = field.mul(np.uint64(c), v)
                            if store:
                                buf[dst][ds] = v
                            else:
                                buf[dst][ds] = field.add(
                                    buf[dst].get(ds, zero), v
                                )
            elif isinstance(step, LocalOp):
                if step.coeffs is None:
                    raise ValueError(
                        "structure-only IR (LocalOp.coeffs=None) cannot be "
                        "interpreted — recompile with the generator matrix"
                    )
                n_in = len(step.in_slots)
                cols = np.zeros((K, n_in), dtype=np.uint64)
                for j, s in enumerate(step.in_slots):
                    for k in range(K):
                        cols[k, j] = buf[k].get(s, zero)
                out = np.zeros((K, len(step.out_slots)), dtype=np.uint64)
                for j in range(n_in):
                    out = field.add(
                        out, field.mul(step.coeffs[:, :, j], cols[:, j][:, None])
                    )
                for k in range(K):
                    if step.update:
                        for i, s in enumerate(step.out_slots):
                            buf[k][s] = out[k, i]
                    else:
                        buf[k] = {s: out[k, i] for i, s in enumerate(step.out_slots)}
            else:  # pragma: no cover
                raise TypeError(f"unknown IR step {type(step).__name__}")
    result = np.array(
        [buf[place[k]].get(ir.out_slot, zero) for k in range(K)], dtype=np.uint64
    )
    return result, sim.stats


# ---------------------------------------------------------------------------
# per-family wrappers (compile → interpret; oracle-asserted when A is known)
# ---------------------------------------------------------------------------


def simulate_prepare_shoot(
    x: np.ndarray, A: np.ndarray, plan: PrepareShootPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """x: (K,) uint64, A: (K,K) uint64 over ``field``. Returns (x̃, stats)."""
    out, stats = interpret(plan.to_ir(A, q=field.q), x, field)
    np.testing.assert_array_equal(out, field.matmul(field.asarray(x), A))
    return out, stats


def simulate_butterfly(
    v: np.ndarray, plan: ButterflyPlan, field: Field, inverse: bool = False
) -> tuple[np.ndarray, SimStats]:
    """Round t: every processor broadcasts its Q to the p digit-t partners
    and combines the radix received values (own + p) with the twiddle row."""
    return interpret(plan.to_ir(inverse=inverse), v, field)


def simulate_draw_loose(
    x: np.ndarray, plan: DrawLoosePlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Draw phase (Z parallel M-sized prepare-and-shoots, merged round-by-
    round so the port constraints are checked globally), the local scale,
    then the loose phase (M parallel Z-point butterflies, also merged)."""
    return interpret(plan.to_ir(), x, field)
