"""Cost-exact synchronous p-port network simulator (paper §I model).

Independent host-side re-implementation of the algorithms via explicit
message passing: every round is validated against the p-port constraints
(each processor sends ≤ p and receives ≤ p messages, one per port, no
self-messages) and C1/C2 are counted exactly as defined:

    C1 = number of rounds
    C2 = Σ_t max_{messages m in round t} len(m)     (field elements)

This is what EXPERIMENTS.md's paper-claims tables are produced from; the
array-level jnp executors in ``prepare_shoot.py`` / ``draw_loose.py`` are
cross-checked against both this simulator and the matrix oracle.
"""

from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .field import Field
from .schedule import (
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    butterfly_group_perms,
)


@dataclass
class SimStats:
    K: int
    p: int
    C1: int = 0
    C2: int = 0
    round_sizes: list = dc_field(default_factory=list)
    total_elements: int = 0  # Σ over all messages (not just max) — extra info
    # per-round message map {(src, dst): elements} — the exact communication
    # pattern, consumed by repro.topo.lower to cross-check its analytically
    # lowered schedules (hop counts, link contention) against the simulation
    round_messages: list = dc_field(default_factory=list)


class SyncSimulator:
    """Executes one communication round at a time, enforcing the model."""

    def __init__(self, K: int, p: int):
        self.stats = SimStats(K=K, p=p)

    def exchange(self, messages: dict) -> dict:
        """messages: {(src, dst): list_of_elements}. Returns them 'delivered'.

        Empty rounds are not allowed (the model counts a round only when
        communication happens; algorithms never schedule empty rounds).
        """
        K, p = self.stats.K, self.stats.p
        if not messages:
            raise ValueError("empty communication round")
        out_count: dict[int, int] = {}
        in_count: dict[int, int] = {}
        for (src, dst), payload in messages.items():
            if src == dst:
                raise ValueError(f"self-message at processor {src}")
            if not (0 <= src < K and 0 <= dst < K):
                raise ValueError("processor index out of range")
            if len(payload) == 0:
                raise ValueError("empty message")
            out_count[src] = out_count.get(src, 0) + 1
            in_count[dst] = in_count.get(dst, 0) + 1
        if max(out_count.values()) > p:
            raise ValueError(f"a processor sends more than p={p} messages")
        if max(in_count.values()) > p:
            raise ValueError(f"a processor receives more than p={p} messages")
        d = max(len(v) for v in messages.values())
        self.stats.C1 += 1
        self.stats.C2 += d
        self.stats.round_sizes.append(d)
        self.stats.total_elements += sum(len(v) for v in messages.values())
        self.stats.round_messages.append(
            {pair: len(v) for pair, v in messages.items()}
        )
        return messages


# ---------------------------------------------------------------------------
# prepare-and-shoot on the simulator (§IV, Algorithm 1)
# ---------------------------------------------------------------------------


def simulate_prepare_shoot(
    x: np.ndarray, A: np.ndarray, plan: PrepareShootPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """x: (K,) uint64, A: (K,K) uint64 over ``field``. Returns (x̃, stats)."""
    K, p, m, n = plan.K, plan.p, plan.m, plan.n
    sim = SyncSimulator(K, p)
    x = field.asarray(x)
    A = field.asarray(A)

    # ---- prepare: every processor forwards its whole storage each round ----
    # (shifts that collapse mod K — only in the K <= p+1 regime — are
    # skipped: a self-send or duplicate-destination send carries no info)
    storage: list[dict[int, np.uint64]] = [{k: x[k]} for k in range(K)]
    for shifts in plan.prepare_shifts:
        msgs = {}
        for k in range(K):
            items = sorted(storage[k].items())
            for s in shifts:
                dst = (k + s) % K
                if dst != k:
                    msgs[(k, dst)] = items
        delivered = sim.exchange(msgs)
        for (src, dst), items in delivered.items():
            for r, val in items:
                storage[dst][r] = val
    # every processor k now holds x_r for r ∈ R_k^- (as a set)
    for k in range(K):
        expect = {(k - l) % K for l in range(m)}
        assert set(storage[k]) == expect, f"prepare coverage wrong at {k}"

    # ---- shoot: initialize w_{k, k+l·m} with the first-coverage mask -------
    # (keep contribution of offset u toward variable l iff l*m + u < K;
    #  exact for all K, p — see schedule.coeff_mask / DESIGN §11)
    w: list[dict[int, np.uint64]] = []
    for k in range(K):
        wk = {}
        for l in range(n):
            col = (k + l * m) % K
            acc = np.uint64(0)
            for u in range(m):
                if l * m + u < K:
                    r = (k - u) % K
                    acc = field.add(acc, field.mul(storage[k][r], A[r, col]))
            wk[l] = acc
        w.append(wk)

    radix = p + 1
    n_live = -(-K // m)  # slots l with l*m >= K are all-zero: never sent
    for t, shifts in enumerate(plan.shoot_shifts, start=1):
        stride = radix ** (t - 1)
        msgs = {}
        for k in range(K):
            for rho, s in enumerate(shifts, start=1):
                dst = (k + s) % K
                ls = [
                    l
                    for l in range(n_live)
                    if (l // stride) % radix == rho and l % stride == 0
                ]
                if ls:
                    msgs[(k, dst)] = [(l, w[k][l]) for l in ls]
        delivered = sim.exchange(msgs)
        for (src, dst), items in delivered.items():
            for l, val in items:
                lp = l - ((l // stride) % radix) * stride
                w[dst][lp] = field.add(w[dst][lp], val)

    out = np.array([w[k][0] for k in range(K)], dtype=np.uint64)
    return out, sim.stats


# ---------------------------------------------------------------------------
# DFT butterfly on the simulator (§V-A)
# ---------------------------------------------------------------------------


def simulate_butterfly(
    v: np.ndarray, plan: ButterflyPlan, field: Field, inverse: bool = False
) -> tuple[np.ndarray, SimStats]:
    """Round t: every processor broadcasts its Q to the p digit-t partners
    and combines the radix received values (own + p) with the twiddle row."""
    K, p, H, radix = plan.K, plan.p, plan.H, plan.radix
    sim = SyncSimulator(K, p)
    q = field.asarray(v).copy()
    rounds = range(H - 1, -1, -1) if inverse else range(H)
    for t in rounds:
        perms = butterfly_group_perms(K, radix, t)
        msgs = {}
        for k in range(K):
            for dst_map in perms:
                msgs[(k, int(dst_map[k]))] = [q[k]]
        delivered = sim.exchange(msgs)
        received = {k: {} for k in range(K)}
        step = radix**t
        for k in range(K):
            received[k][(k // step) % radix] = q[k]
        for (src, dst), payload in delivered.items():
            received[dst][(src // step) % radix] = payload[0]
        tw = plan.inv_twiddles[t] if inverse else plan.twiddles[t]
        new_q = np.zeros_like(q)
        for k in range(K):
            acc = np.uint64(0)
            for rho in range(radix):
                acc = field.add(acc, field.mul(np.uint64(tw[k, rho]), received[k][rho]))
            new_q[k] = acc
        q = new_q
    return q, sim.stats


# ---------------------------------------------------------------------------
# draw-and-loose on the simulator (§V-B) — subgroup composition
# ---------------------------------------------------------------------------


def simulate_draw_loose(
    x: np.ndarray, plan: DrawLoosePlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Runs the draw phase (Z parallel M-sized prepare-and-shoots, merged
    round-by-round so port constraints are checked globally) then the loose
    phase (M parallel Z-point butterflies). For simplicity each sub-phase is
    simulated on its own simulator and the stats are combined — the parallel
    subgroup operations share rounds (disjoint processor groups), so C1/C2
    are those of a single subgroup's run (the max across groups, which are
    identical by symmetry)."""
    K, M, Z = plan.K, plan.M, plan.Z
    f = field
    x = f.asarray(x)
    stats = SimStats(K=K, p=plan.p)

    # draw phase: subgroup j = processors {j + Z*i}, runs M×M prepare-and-shoot
    F = np.zeros(K, dtype=np.uint64)
    if plan.draw_plan is not None:
        draw_stats = None
        for j in range(Z):
            idx = j + Z * np.arange(M)
            sub_out, st = simulate_prepare_shoot(x[idx], plan.draw_matrix, plan.draw_plan, f)
            F[idx] = sub_out
            draw_stats = st
        stats.C1 += draw_stats.C1
        stats.C2 += draw_stats.C2
        stats.round_sizes += draw_stats.round_sizes
    else:
        F[:] = x
    # local scale α_i^{rev(j)} — no communication
    F = f.mul(F, plan.local_scale.astype(np.uint64))

    # loose phase: group i = processors {Z*i + j}, runs Z-point butterfly
    out = np.zeros(K, dtype=np.uint64)
    if plan.loose_plan is not None:
        loose_stats = None
        for i in range(M):
            idx = Z * i + np.arange(Z)
            sub_out, st = simulate_butterfly(F[idx], plan.loose_plan, f)
            out[idx] = sub_out
            loose_stats = st
        stats.C1 += loose_stats.C1
        stats.C2 += loose_stats.C2
        stats.round_sizes += loose_stats.round_sizes
    else:
        out[:] = F
    return out, stats
