"""Array-level (jnp) executor for the universal prepare-and-shoot algorithm.

Vectorized over the processor axis: ``x`` has shape ``(K, *payload)`` and the
whole K-processor algorithm runs as one program. Every ``jnp.roll`` along
axis 0 is exactly one ``ppermute`` in the distributed version
(``dist/collectives.py`` reuses the same round structure 1:1) — this module
is both the single-host reference and the local-semantics oracle for the
mesh collective.

Correctness note: the w-variable initialization applies the *first-coverage
mask* — contribution (slot u, variable l) is kept iff l·m + offset(u) < K —
which makes the algorithm exact for every (K, p) with no Eq. 3 correction
(see schedule.coeff_mask and DESIGN.md §11).

Two coefficient paths:

* ``A`` as a runtime array (any matrix, the *universal* promise): the
  coefficient tensor is gathered from A inside jit and products use the
  uint32-only generic ``mmul``.
* ``A`` as a host numpy array: coefficients and their Shoup duals are baked
  in as compile-time constants (~2 multiplies instead of ~10 uint32 ops per
  product — the beyond-paper fast path, see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .field import M31, Field, madd, mmul, shoup_mul, shoup_precompute
from .schedule import (
    PrepareShootPlan,
    coeff_mask,
    plan_prepare_shoot,
    shoot_coeff_indices,
    shoot_coeff_tensor,
)


def _bcast(coef, ndim_payload):
    """Append payload broadcast dims to a coefficient array."""
    return coef.reshape(coef.shape + (1,) * ndim_payload)


def prepare_phase(x: jnp.ndarray, plan: PrepareShootPlan) -> jnp.ndarray:
    """x: (K, *payload) → buf: (K, m, *payload), buf[k, u] = x_{k - offsets[u]}.

    Round t concatenates [self, roll(s_1), .., roll(s_p)] — message size
    (p+1)^{t-1} per port, matching Lemma 3's C2 accounting.
    """
    K = plan.K
    buf = x[:, None]
    for shifts in plan.prepare_shifts:
        parts = [buf]
        for s in shifts:
            parts.append(jnp.roll(buf, s % K, axis=0))  # receive from k - s
        buf = jnp.concatenate(parts, axis=1)
    return buf


def shoot_init(
    buf: jnp.ndarray,
    plan: PrepareShootPlan,
    A: jnp.ndarray | np.ndarray,
    q: int,
) -> jnp.ndarray:
    """w[k, l] = Σ_u buf[k, u] · mask[u,l] · A[(k-off_u)%K, (k+l·m)%K] (mod q).

    This modular contraction is the gf_matmul kernel hot spot; here it is the
    pure-jnp form (kernels/gf_matmul/ops.py provides the Pallas-backed drop-in
    used by benchmarks).
    """
    mask = coeff_mask(plan)  # (m, n) bool
    npay = buf.ndim - 2
    if isinstance(A, np.ndarray):  # host path: constants + Shoup
        coef_np = (shoot_coeff_tensor(plan, A) * mask[None, :, :]).astype(np.uint32)
        coef_sh = jnp.asarray(shoup_precompute(coef_np, q))
        coef = jnp.asarray(coef_np)

        def prods(u, l):
            return shoup_mul(
                buf[:, u],
                _bcast(coef[:, u, l], npay),
                _bcast(coef_sh[:, u, l], npay),
                q,
            )

    else:
        rows, cols = shoot_coeff_indices(plan)
        coef = A[jnp.asarray(rows), jnp.asarray(cols)].astype(jnp.uint32)
        coef = jnp.where(jnp.asarray(mask)[None, :, :], coef, jnp.uint32(0))

        def prods(u, l):
            return mmul(buf[:, u], _bcast(coef[:, u, l], npay), q)

    m, n = plan.m, plan.n
    cols_out = []
    for l in range(n):
        acc = prods(0, l)
        for u in range(1, m):
            acc = madd(acc, prods(u, l), q)
        cols_out.append(acc)
    return jnp.stack(cols_out, axis=1)


def shoot_rounds(w: jnp.ndarray, plan: PrepareShootPlan, q: int) -> jnp.ndarray:
    """Tree-reduce toward w[:, 0] (Algorithm 1 lines 2-10)."""
    K, p = plan.K, plan.p
    radix = p + 1
    n = plan.n
    for t, shifts in enumerate(plan.shoot_shifts, start=1):
        stride = radix ** (t - 1)
        acc = w
        for rho, s in enumerate(shifts, start=1):
            shifted = jnp.roll(w, s % K, axis=0)  # from k - s
            # live targets l (digit_t = 0, lower digits 0) absorb slot
            # l + rho*stride from the sender
            src_l = np.arange(n) + rho * stride
            valid = (
                (src_l < n)
                & ((np.arange(n) // stride) % radix == 0)
                & (np.arange(n) % stride == 0)
            )
            src_l = np.where(valid, src_l, 0)
            contrib = jnp.take(shifted, jnp.asarray(src_l), axis=1)
            mask = jnp.asarray(valid)
            contrib = jnp.where(
                _bcast(mask[None, :], w.ndim - 2), contrib, jnp.uint32(0)
            )
            acc = madd(acc, contrib, q)
        w = acc
    return w


def encode_universal(
    x: jnp.ndarray,
    A: jnp.ndarray | np.ndarray,
    *,
    p: int = 1,
    q: int = M31,
    plan: PrepareShootPlan | None = None,
) -> jnp.ndarray:
    """All-to-all encode of ANY K×K matrix A: out[k] = (x @ A)[k] over GF(q).

    x: (K, *payload) uint32 canonical; A: (K, K) uint32. The function is
    jit-compatible (all schedule decisions are static).
    """
    K = x.shape[0]
    if plan is None:
        plan = plan_prepare_shoot(K, p)
    buf = prepare_phase(x, plan)
    w = shoot_init(buf, plan, A, q)
    w = shoot_rounds(w, plan, q)
    return w[:, 0]


def encode_oracle(x: np.ndarray, A: np.ndarray, q: int = M31) -> np.ndarray:
    """Host oracle: (x @ A) mod q, exact, supports payload dims (K, *payload)."""
    f = Field(q)
    x = f.asarray(x)
    A = f.asarray(A)
    if x.ndim == 1:
        return f.matmul(x[None, :], A)[0]
    flat = x.reshape(x.shape[0], -1)
    out = f.matmul(flat.T, A).T  # (payload, K) @ (K, K) → transpose back
    return out.reshape(x.shape)
