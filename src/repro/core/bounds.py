"""Lower bounds and closed-form costs (Lemmas 1–2, Theorems 1–4) + cost model.

C1 = number of rounds; C2 = Σ_t d_t (largest message, in field elements, of
round t). Total time = C1·β + C2·τ (§I).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def ceil_log(K: int, base: int) -> int:
    """⌈log_base K⌉ computed exactly with integers."""
    if K <= 1:
        return 0
    t, v = 0, 1
    while v < K:
        v *= base
        t += 1
    return t


def ps_params(K: int, p: int):
    """prepare-and-shoot phase split (§IV): L = max{(p+1)^L < K};
    L even → (Tp, Ts) = (L/2+1, L/2); L odd → ((L+1)/2, (L+1)/2).
    Returns (L, Tp, Ts, m, n) with m=(p+1)^Tp, n=(p+1)^Ts.

    NOTE: the paper additionally assumes (n-1)m < K ≤ nm, which fails for
    many (K, p) — including its own Fig. 3 example (K=65, p=2, where
    (n-1)m = 72 ≥ 65 and the Eq. 3 correction would need packets outside
    R_k^-). Our executors use a *first-coverage coefficient mask*
    (keep contribution (ℓ, u) iff ℓ·m + offset(u) < K) that subsumes both
    Eq. 2's set semantics and Eq. 3's correction and is exact for every
    K ≤ nm — so the balanced split (and its C2) is always usable.
    See DESIGN.md §11 and EXPERIMENTS.md §Paper-claims.
    """
    if K < 2:
        return (0, 0, 0, 1, 1)
    L = 0
    while (p + 1) ** (L + 1) < K:
        L += 1
    if L % 2 == 0:
        Tp, Ts = L // 2 + 1, L // 2
    else:
        Tp = Ts = (L + 1) // 2
    m, n = (p + 1) ** Tp, (p + 1) ** Ts
    assert K <= n * m, (K, p, L, Tp, Ts, m, n)
    return (L, Tp, Ts, m, n)


# -- lower bounds -----------------------------------------------------------


def lemma1_c1_lower(K: int, p: int) -> int:
    """Any universal algorithm has C1 >= ⌈log_{p+1} K⌉."""
    return ceil_log(K, p + 1)


def lemma2_c2_lower(K: int, p: int) -> float:
    """Any universal algorithm has C2 >= the positive root of
    p²T² − p(p−2)T + 2(1−K) >= 0  (exact form from the Lemma-2 proof)."""
    a = p * p
    b = -p * (p - 2)
    c = 2 * (1 - K)
    return (-b + math.sqrt(b * b - 4 * a * c)) / (2 * a)


# -- prepare-and-shoot (Theorem 1) -----------------------------------------


def theorem1_c1(K: int, p: int) -> int:
    return ceil_log(K, p + 1)


def theorem1_c2(K: int, p: int) -> int:
    """C2 of prepare-and-shoot as the sum of Lemma 3 + Lemma 4:
    ((p+1)^Tp - 1)/p + ((p+1)^Ts - 1)/p.

    NOTE (EXPERIMENTS.md §Paper-claims): for odd L this equals Theorem 1's
    stated (2(p+1)^{(L+1)/2}−2)/p. For even L, Theorem 1 prints
    ((p+1)^{L/2+1}−2)/p, which is inconsistent with its own Lemmas 3+4
    (it drops the (p+1)^{L/2} shoot term); we validate against the
    lemma-consistent value and flag the discrepancy as a paper typo.
    """
    _, Tp, Ts, m, n = ps_params(K, p)
    return (m - 1) // p + (n - 1) // p


def theorem1_c2_as_printed(K: int, p: int) -> int:
    """The value as literally printed in Theorem 1 (see note above)."""
    L, *_ = ps_params(K, p)
    if L % 2 == 1:
        return (2 * (p + 1) ** ((L + 1) // 2) - 2) // p
    return ((p + 1) ** (L // 2 + 1) - 2) // p


# -- DFT butterfly (Theorem 2) ----------------------------------------------


def theorem2_c1_c2(K: int, p: int) -> tuple[int, int]:
    """C1 = C2 = log_{p+1} K, strictly optimal; requires K = (p+1)^H."""
    H = ceil_log(K, p + 1)
    if (p + 1) ** H != K:
        raise ValueError(f"K={K} is not a power of p+1={p + 1}")
    return H, H


# -- draw-and-loose (Theorem 3) ----------------------------------------------


def theorem3_c1_c2(K: int, p: int, M: int, H: int) -> tuple[int, int]:
    """K = M·(p+1)^H: C1 = ⌈log_{p+1}K⌉, C2 = H + Ψ(M), Ψ = theorem1_c2."""
    Z = (p + 1) ** H
    if M * Z != K:
        raise ValueError("K != M * (p+1)^H")
    psi = 1 if M <= p + 1 and M > 1 else theorem1_c2(M, p)
    if M == 1:
        psi = 0
    return ceil_log(K, p + 1), H + psi


# -- Lagrange (Theorem 4) ----------------------------------------------------


def theorem4_c1_c2(K: int, p: int, M: int, H: int) -> tuple[int, int]:
    """Inverse Vandermonde(ω) + forward Vandermonde(α): costs add."""
    c1, c2 = theorem3_c1_c2(K, p, M, H)
    return 2 * c1, 2 * c2


# -- cost model ---------------------------------------------------------------


@dataclass(frozen=True)
class CostModel:
    """Total time C1·β + C2·τ (§I). Defaults: TPU v5e ICI — β ≈ 1 µs
    per-hop message startup, τ = payload_bytes / 50 GB/s per element."""

    beta: float = 1e-6
    tau: float = 4.0 / 50e9  # one uint32 field element over one ICI link

    def time(self, c1: int, c2: int, payload_elems: int = 1) -> float:
        return c1 * self.beta + c2 * payload_elems * self.tau


def allgather_baseline_c1_c2(K: int, p: int) -> tuple[int, int]:
    """Baseline: ring/tree all-gather of all K packets then local combine.

    Optimal all-gather in the p-port model: C1 = ⌈log_{p+1}K⌉ rounds with
    message sizes growing (p+1)-fold: C2 = ((p+1)^{⌈log⌉} - 1)/p ≈ K/p —
    exponentially worse than prepare-and-shoot's O(√K/p)."""
    t = ceil_log(K, p + 1)
    return t, ((p + 1) ** t - 1) // p


def direct_baseline_c1_c2(K: int, p: int) -> tuple[int, int]:
    """Baseline: every processor sends its packet directly to all K-1
    targets (coefficient applied at the receiver): ⌈(K-1)/p⌉ rounds of
    1-element messages."""
    t = math.ceil((K - 1) / p)
    return t, t
