"""Finite-field arithmetic for all-to-all encode.

Two concrete primes (DESIGN.md §3):

* ``M31 = 2**31 - 1`` — Mersenne; default storage-code field (reduction is
  two shift-adds).
* ``NTT = 15 * 2**27 + 1 = 2013265921`` — 2-adic valuation 27, so radix-2
  DFT subgroups (butterflies) exist for any power-of-two encode-axis size
  up to ``2**27``.

Two implementation tiers:

* **Host tier** (numpy ``uint64``): exact 62-bit products, used for matrix
  construction, schedule/twiddle precomputation, decoding and the cost-exact
  synchronous-network simulator.
* **Device tier** (``jnp`` ``uint32`` only): every product goes through
  16-bit limb decomposition so identical code lowers for TPU (no 64-bit
  multiplier on the VPU/MXU fast path) and runs inside Pallas kernel bodies.
  The device tier never creates a 64-bit value.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

M31 = (1 << 31) - 1  # 2147483647
NTT = 15 * (1 << 27) + 1  # 2013265921

_MASK31 = np.uint64(M31)

# q - 1 factorizations (verified in tests) — needed for primitive-root checks.
_GROUP_FACTORS = {
    M31: (2, 3, 7, 11, 31, 151, 331),
    NTT: (2, 3, 5),
}

# Standard generators of the multiplicative groups (verified in tests).
_GENERATORS = {M31: 7, NTT: 31}

__all__ = [
    "M31",
    "NTT",
    "Field",
    "madd",
    "msub",
    "mneg",
    "mmul_m31",
    "umulhi32",
    "barrett32",
    "shoup_precompute",
    "shoup_mul",
    "mmul",
    "two_adic_valuation",
    "radix_valuation",
]


# --------------------------------------------------------------------------
# Host tier: exact numpy uint64 field arithmetic
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Field:
    """GF(q) for a prime q < 2**31, exact host-side arithmetic.

    All array arguments are numpy arrays (or python ints) of nonnegative
    integers; results are canonical representatives in ``[0, q)`` as
    ``uint64``.
    """

    q: int = M31

    def __post_init__(self):
        if not (2 < self.q < (1 << 31)):
            raise ValueError(f"q={self.q} out of supported range (3, 2^31)")

    # -- element ops -------------------------------------------------------
    def asarray(self, x) -> np.ndarray:
        a = np.asarray(x, dtype=np.uint64)
        return a % np.uint64(self.q)

    def add(self, a, b):
        return (self.asarray(a) + self.asarray(b)) % np.uint64(self.q)

    def sub(self, a, b):
        return (self.asarray(a) + np.uint64(self.q) - self.asarray(b)) % np.uint64(self.q)

    def neg(self, a):
        return (np.uint64(self.q) - self.asarray(a)) % np.uint64(self.q)

    def mul(self, a, b):
        # products of two < 2^31 values fit in 62 bits < uint64.
        return (self.asarray(a) * self.asarray(b)) % np.uint64(self.q)

    def pow(self, a, e) -> np.ndarray:
        """Element-wise a**e mod q (e: python int or int array >= 0)."""
        a = self.asarray(a)
        e_arr = np.broadcast_arrays(np.asarray(e, dtype=np.int64), a.astype(np.int64))[0].copy()
        result = np.ones_like(a)
        base = a.copy()
        e_work = e_arr.astype(np.uint64).copy()
        while np.any(e_work > 0):
            odd = (e_work & np.uint64(1)).astype(bool)
            result = np.where(odd, self.mul(result, base), result)
            e_work >>= np.uint64(1)
            if np.any(e_work > 0):
                base = self.mul(base, base)
        return result

    def inv(self, a) -> np.ndarray:
        """Element-wise multiplicative inverse (Fermat)."""
        a = self.asarray(a)
        if np.any(a == 0):
            raise ZeroDivisionError("inverse of 0 in GF(q)")
        return self.pow(a, self.q - 2)

    # -- linear algebra ----------------------------------------------------
    def matmul(self, A, B) -> np.ndarray:
        """Exact (A @ B) mod q. Blocks the contraction so uint64 never overflows.

        Each product < q^2 < 2^62; we can add up to 3 such terms within
        uint64 (2^64 / 2^62 = 4), so reduce every 3 accumulands.
        """
        A = self.asarray(A)
        B = self.asarray(B)
        if A.ndim == 1:
            A = A[None, :]
            squeeze = True
        else:
            squeeze = False
        n = A.shape[-1]
        q = np.uint64(self.q)
        out = np.zeros((*A.shape[:-1], B.shape[-1]), dtype=np.uint64)
        step = 3
        for s in range(0, n, step):
            chunk = np.einsum(
                "...k,kj->...j", A[..., s : s + step], B[s : s + step], dtype=np.uint64
            )
            out = (out + chunk % q) % q
        return out[0] if squeeze else out

    def solve(self, A, b) -> np.ndarray:
        """Solve A x = b mod q by Gaussian elimination (A square invertible)."""
        A = self.asarray(A).copy()
        b = self.asarray(b).copy()
        n = A.shape[0]
        if b.ndim == 1:
            b = b[:, None]
            squeeze = True
        else:
            squeeze = False
        q = np.uint64(self.q)
        for col in range(n):
            piv_candidates = np.nonzero(A[col:, col])[0]
            if piv_candidates.size == 0:
                raise np.linalg.LinAlgError("singular matrix over GF(q)")
            piv = col + int(piv_candidates[0])
            if piv != col:
                A[[col, piv]] = A[[piv, col]]
                b[[col, piv]] = b[[piv, col]]
            inv_p = self.inv(A[col, col])
            A[col] = self.mul(A[col], inv_p)
            b[col] = self.mul(b[col], inv_p)
            for row in range(n):
                if row != col and A[row, col] != 0:
                    factor = A[row, col]
                    A[row] = (A[row] + (q - factor) * A[col] % q) % q
                    b[row] = (b[row] + (q - factor) * b[col] % q) % q
        x = b
        return x[:, 0] if squeeze else x

    def inv_matrix(self, A) -> np.ndarray:
        A = self.asarray(A)
        return self.solve(A, np.eye(A.shape[0], dtype=np.uint64))

    # -- group structure ---------------------------------------------------
    @property
    def generator(self) -> int:
        if self.q in _GENERATORS:
            return _GENERATORS[self.q]
        return self._find_generator()

    def _find_generator(self) -> int:
        factors = self._factor_group_order()
        order = self.q - 1
        for g in range(2, self.q):
            if all(pow(g, order // f, self.q) != 1 for f in factors):
                return g
        raise RuntimeError("no generator found (q not prime?)")

    def _factor_group_order(self):
        if self.q in _GROUP_FACTORS:
            return _GROUP_FACTORS[self.q]
        n = self.q - 1
        factors = []
        d = 2
        while d * d <= n:
            if n % d == 0:
                factors.append(d)
                while n % d == 0:
                    n //= d
            d += 1
        if n > 1:
            factors.append(n)
        return tuple(factors)

    def root_of_unity(self, n: int) -> int:
        """A primitive n-th root of unity; requires n | q-1."""
        if (self.q - 1) % n != 0:
            raise ValueError(f"{n} does not divide q-1={self.q - 1}")
        beta = pow(self.generator, (self.q - 1) // n, self.q)
        return beta


def two_adic_valuation(n: int) -> int:
    v = 0
    while n % 2 == 0:
        n //= 2
        v += 1
    return v


def radix_valuation(n: int, r: int) -> int:
    """Largest h with r**h | n."""
    v = 0
    while n % r == 0:
        n //= r
        v += 1
    return v


# --------------------------------------------------------------------------
# Device tier: uint32-only modular arithmetic (jnp; also valid inside Pallas)
# --------------------------------------------------------------------------
#
# Everything below uses only uint32 add/sub/mul/shift, with documented
# no-overflow ranges, so it lowers to TPU (and Pallas) without 64-bit ints.


def _u32(x):
    return jnp.asarray(x, dtype=jnp.uint32)


def madd(a, b, q: int):
    """(a + b) mod q for canonical a, b < q < 2^31. Sum < 2^32: no overflow."""
    s = _u32(a) + _u32(b)
    return jnp.where(s >= q, s - jnp.uint32(q), s)


def msub(a, b, q: int):
    """(a - b) mod q for canonical a, b < q."""
    a = _u32(a)
    b = _u32(b)
    return jnp.where(a >= b, a - b, a + (jnp.uint32(q) - b))


def mneg(a, q: int):
    a = _u32(a)
    return jnp.where(a == 0, a, jnp.uint32(q) - a)


def _limbs(a):
    a = _u32(a)
    return a >> jnp.uint32(16), a & jnp.uint32(0xFFFF)


def umulhi32(a, b):
    """High 32 bits of the 64-bit product a*b, for BOTH a, b < 2^31.

    Derivation (all uint32, no overflow):
      a = a1*2^16 + a0 with a1 < 2^15;  b = b1*2^16 + b0 with b1 < 2^15
      m0 = a0*b0 < 2^32;  m1 = a0*b1 + a1*b0 <= 2*(2^16-1)(2^15-1) < 2^32
      full = m2*2^32 + m1*2^16 + m0;  w = m1 + (m0 >> 16) < 2^32
      hi = m2 + (w >> 16)   (exact: (w & 0xffff)*2^16 + (m0 & 0xffff) < 2^32)
    For operands that may reach 2^32 use :func:`umulhi32_full`.
    """
    a1, a0 = _limbs(a)
    b1, b0 = _limbs(b)
    m0 = a0 * b0
    m1 = a0 * b1 + a1 * b0
    m2 = a1 * b1
    w = m1 + (m0 >> jnp.uint32(16))
    return m2 + (w >> jnp.uint32(16))


def mmul_m31(a, b):
    """(a * b) mod M31 for canonical a, b < M31, uint32-only.

    Uses 2^31 ≡ 1 (mod M31). With m0/m1/m2 the 16-bit-limb partial products:
      full = m2*2^32 + m1*2^16 + m0
      m2*2^32 ≡ 2*m2;  m1*2^16 = (m1>>15)*2^31 + (m1&0x7fff)*2^16
                       ≡ (m1>>15) + (m1&0x7fff)*2^16
      m0 ≡ (m0>>31) + (m0 & M31)
    Each grouped partial sum stays < 2^32 (ranges in comments).
    """
    a1, a0 = _limbs(a)
    b1, b0 = _limbs(b)
    m0 = a0 * b0  # < 2^32
    m1 = a0 * b1 + a1 * b0  # < 2^32 (a1,b1 < 2^15)
    m2 = a1 * b1  # < 2^30
    q = jnp.uint32(M31)
    # u = 2*m2 + (m1 >> 15) + (m0 >> 31)  < 2^31 + 2^17 + 1  < 2^32
    u = (m2 << jnp.uint32(1)) + (m1 >> jnp.uint32(15)) + (m0 >> jnp.uint32(31))
    # v = (m1 & 0x7fff) * 2^16 + (m0 & M31)  < 2^31 + 2^31 = 2^32 (just fits)
    v = ((m1 & jnp.uint32(0x7FFF)) << jnp.uint32(16)) + (m0 & q)
    # fold each of u, v once: x ≡ (x >> 31) + (x & M31), result <= 2^31
    u = (u >> jnp.uint32(31)) + (u & q)
    v = (v >> jnp.uint32(31)) + (v & q)
    u = jnp.where(u >= q, u - q, u)  # < M31
    v = jnp.where(v >= q, v - q, v)  # < M31
    s = u + v  # < 2^32
    return jnp.where(s >= q, s - q, s)


def shoup_precompute(c, q: int) -> np.ndarray:
    """Host-side: c' = floor(c * 2^32 / q) for constant multiplicand c < q."""
    c = np.asarray(c, dtype=np.uint64)
    return ((c << np.uint64(32)) // np.uint64(q)).astype(np.uint32)


def shoup_mul(a, c, c_pre, q: int):
    """(a * c) mod q with Shoup-precomputed c' = floor(c*2^32/q).

    t = floor(a * c' / 2^32) satisfies floor(a*c/q) - 1 <= t <= floor(a*c/q),
    so r = a*c - t*q ∈ [0, 2q), computed with wrapping uint32 (exact because
    the true r < 2q < 2^32). c' can reach 2^32 so the carry-safe umulhi is
    required.
    """
    a = _u32(a)
    c = _u32(c)
    c_pre = _u32(c_pre)
    t = umulhi32_full(a, c_pre)
    r = a * c - t * jnp.uint32(q)  # wrapping arithmetic; true value < 2q
    return jnp.where(r >= q, r - jnp.uint32(q), r)


@functools.lru_cache(maxsize=None)
def _barrett_consts(q: int):
    m = ((1 << 32) // q) & 0xFFFFFFFF  # floor(2^32/q); q > 2 so fits uint32
    r16 = (1 << 16) % q
    r32 = (1 << 32) % q
    r16_pre = int(shoup_precompute(r16, q))
    r32_pre = int(shoup_precompute(r32, q))
    return m, r16, r32, r16_pre, r32_pre


def barrett32(x, q: int):
    """x mod q for any uint32 x (q < 2^31): one Barrett step + one csub.

    t = floor(x * floor(2^32/q) / 2^32) >= floor(x/q) - 1, so r = x - t*q
    ∈ [0, 2q) < 2^32.
    """
    m, *_ = _barrett_consts(q)
    x = _u32(x)
    t = umulhi32_full(x, jnp.uint32(m))
    r = x - t * jnp.uint32(q)
    return jnp.where(r >= q, r - jnp.uint32(q), r)


def umulhi32_full(a, b):
    """High 32 bits of a*b for ANY uint32 a, b (handles m1 carry).

    m1 = a0*b1 + a1*b0 can overflow uint32 when both a1, b1 >= 2^15; compute
    the two cross terms separately and propagate carries explicitly.
    """
    a = _u32(a)
    b = _u32(b)
    a1, a0 = _limbs(a)
    b1, b0 = _limbs(b)
    m0 = a0 * b0
    c1 = a0 * b1  # < 2^32
    c2 = a1 * b0  # < 2^32
    m2 = a1 * b1
    w = c1 + (m0 >> jnp.uint32(16))  # < 2^32 (c1 <= (2^16-1)^2)
    carry = jnp.where(w > (jnp.uint32(0xFFFFFFFF) - c2), jnp.uint32(1), jnp.uint32(0))
    w = w + c2  # wrapping; carry tracked above
    return m2 + (w >> jnp.uint32(16)) + (carry << jnp.uint32(16))


def mmul(a, b, q: int):
    """(a * b) mod q for canonical a, b < q, any prime q < 2^31, uint32-only.

    Fast path for Mersenne-31; otherwise 16-bit-limb schoolbook with Barrett
    folds and Shoup multiplies by the constants 2^16 mod q and 2^32 mod q.
    """
    if q == M31:
        return mmul_m31(a, b)
    _, r16, r32, r16_pre, r32_pre = _barrett_consts(q)
    a1, a0 = _limbs(a)
    b1, b0 = _limbs(b)
    m0 = a0 * b0
    m1 = a0 * b1 + a1 * b0  # a,b < q < 2^31 so a1,b1 < 2^15: fits (see mmul_m31)
    m2 = a1 * b1
    t0 = barrett32(m0, q)
    t1 = shoup_mul(barrett32(m1, q), jnp.uint32(r16), jnp.uint32(r16_pre), q)
    t2 = shoup_mul(barrett32(m2, q), jnp.uint32(r32), jnp.uint32(r32_pre), q)
    return madd(madd(t0, t1, q), t2, q)
