"""Public all-to-all encode API with algorithm auto-selection.

``a2a_encode`` picks the cheapest applicable algorithm for the requested
generator (paper Remark 5: draw-and-loose degrades gracefully to universal
prepare-and-shoot when the field/size structure gives H = 0):

* DFT matrix, K = (p+1)^H, K | q-1      → butterfly       (C2 = log_{p+1}K)
* Vandermonde on structured points      → draw-and-loose  (C2 = H + Ψ(M))
* anything else (the universal promise) → prepare-and-shoot (C2 = O(√K/p))

Returns the encoded array and a ``CostReport`` with the paper-exact C1/C2
and the cost-model time C1·β + C2·τ.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from . import bounds
from .bounds import CostModel
from .draw_loose import encode_dft, encode_draw_loose
from .field import M31, NTT, Field
from .prepare_shoot import encode_universal
from .schedule import (
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)


@dataclass(frozen=True)
class CostReport:
    algorithm: str
    K: int
    p: int
    c1: int
    c2: int
    c1_lower: int
    c2_lower: float
    time: float

    @property
    def c1_optimal(self) -> bool:
        return self.c1 <= self.c1_lower


def _report(alg: str, K: int, p: int, c1: int, c2: int, model: CostModel) -> CostReport:
    return CostReport(
        algorithm=alg,
        K=K,
        p=p,
        c1=c1,
        c2=c2,
        c1_lower=bounds.lemma1_c1_lower(K, p),
        c2_lower=bounds.lemma2_c2_lower(K, p),
        time=model.time(c1, c2),
    )


def plan_for(
    kind: str, K: int, p: int = 1, q: int = M31, seed: int = 0
):
    """kind ∈ {'general', 'vandermonde', 'dft'} → the schedule plan.

    'dft' requires K = (p+1)^H and K | q-1 (use q=NTT for power-of-two K).
    'vandermonde' factors K = M (p+1)^H and may degrade to universal (H=0).
    """
    if kind == "general":
        return plan_prepare_shoot(K, p)
    if kind == "dft":
        return plan_butterfly(K, p, q)
    if kind == "vandermonde":
        return plan_draw_loose(K, p, q, seed=seed)
    raise ValueError(f"unknown kind {kind!r}")


def default_q_for(K: int, p: int) -> int:
    """Prefer the NTT prime when it unlocks butterfly structure for this
    (K, p); otherwise Mersenne-31 (cheapest reduction)."""
    radix = p + 1
    h_ntt = 0
    k = K
    while k % radix == 0 and (NTT - 1) % radix ** (h_ntt + 1) == 0:
        k //= radix
        h_ntt += 1
    h_m31 = 0
    k = K
    while k % radix == 0 and (M31 - 1) % radix ** (h_m31 + 1) == 0:
        k //= radix
        h_m31 += 1
    return NTT if h_ntt > h_m31 else M31


def a2a_encode(
    x: jnp.ndarray,
    A: jnp.ndarray | np.ndarray | None = None,
    *,
    plan: PrepareShootPlan | ButterflyPlan | DrawLoosePlan | None = None,
    p: int = 1,
    q: int = M31,
    cost_model: CostModel | None = None,
) -> tuple[jnp.ndarray, CostReport]:
    """Encode x (shape (K, *payload), uint32 canonical mod q).

    Either pass a generator matrix ``A`` (universal path), or a prebuilt
    specific ``plan`` (butterfly / draw-and-loose / prepare-and-shoot).
    """
    model = cost_model or CostModel()
    K = x.shape[0]
    if plan is not None:
        if isinstance(plan, ButterflyPlan):
            out = encode_dft(x, plan)
            return out, _report("butterfly", K, plan.p, plan.c1, plan.c2, model)
        if isinstance(plan, DrawLoosePlan):
            out = encode_draw_loose(x, plan)
            return out, _report("draw-and-loose", K, plan.p, plan.c1, plan.c2, model)
        if isinstance(plan, PrepareShootPlan):
            if A is None:
                raise ValueError("universal plan needs the matrix A")
            out = encode_universal(x, A, p=plan.p, q=q, plan=plan)
            return out, _report("prepare-and-shoot", K, plan.p, plan.c1, plan.c2, model)
        raise TypeError(type(plan))
    if A is None:
        raise ValueError("need A or a plan")
    ps = plan_prepare_shoot(K, p)
    out = encode_universal(x, A, p=p, q=q, plan=ps)
    return out, _report("prepare-and-shoot", K, p, ps.c1, ps.c2, model)


def rs_generator(field: Field, K: int, n_total: int, seed: int = 0) -> np.ndarray:
    """K×n_total Reed-Solomon generator (Vandermonde on distinct points) for
    the coded-checkpoint application (Remark 1: N > K targets)."""
    from .matrices import distinct_points, vandermonde

    pts = distinct_points(field, n_total, seed=seed)
    return vandermonde(field, pts, nrows=K)
