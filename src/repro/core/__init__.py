# The paper's primary contribution: the all-to-all encode collective
# (Wang & Raviv, "All-to-All Encode in Synchronous Systems", 2022).
#
# - field.py          GF(q) arithmetic: exact host tier + uint32-only device tier
# - matrices.py       Vandermonde / DFT / Lagrange generator constructions
# - schedule.py       static round schedules (prepare/shoot, butterfly, draw/loose)
# - bounds.py         Lemmas 1-2 lower bounds, Theorems 1-4 closed forms, cost model
# - simulator.py      cost-exact synchronous p-port network simulator
# - prepare_shoot.py  universal algorithm, array-level jnp executor
# - draw_loose.py     specific algorithms (butterfly, draw-and-loose, Lagrange)
# - encode.py         public a2a_encode API with auto-selection

from .bounds import CostModel  # noqa: F401
from .encode import CostReport, a2a_encode, default_q_for, plan_for, rs_generator  # noqa: F401
from .field import M31, NTT, Field  # noqa: F401
from .schedule import (  # noqa: F401
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)
