# The paper's primary contribution: the all-to-all encode collective
# (Wang & Raviv, "All-to-All Encode in Synchronous Systems", 2022).
#
# - field.py          GF(q) arithmetic: exact host tier + uint32-only device tier
# - matrices.py       Vandermonde / DFT / Lagrange generator constructions
# - schedule.py       static round schedules (prepare/shoot, butterfly, draw/loose)
# - bounds.py         Lemmas 1-2 lower bounds, Theorems 1-4 closed forms, cost model
# - ir.py             unified ScheduleIR: every plan compiles to one round-
#                     schedule representation (+ rewrite passes)
# - simulator.py      cost-exact p-port interpreter for any ScheduleIR
# - prepare_shoot.py  universal algorithm, array-level jnp executor
# - draw_loose.py     specific algorithms (butterfly, draw-and-loose, Lagrange)
# - encode.py         public a2a_encode API with auto-selection

from .bounds import CostModel  # noqa: F401
from .encode import CostReport, a2a_encode, default_q_for, plan_for, rs_generator  # noqa: F401
from .field import M31, NTT, Field  # noqa: F401
from .ir import (  # noqa: F401
    CommRound,
    LocalOp,
    ScheduleIR,
    Transfer,
    fuse_trivial_rounds,
    ir_messages,
    ir_permute_count,
    relabel,
    to_ir,
)
from .schedule import (  # noqa: F401
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    plan_butterfly,
    plan_draw_loose,
    plan_prepare_shoot,
)
from .simulator import SimStats, SyncSimulator, interpret  # noqa: F401
