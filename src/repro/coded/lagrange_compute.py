"""Lagrange Coded Computing (LCC) example — the paper's §VI use case
[Yu et al., AISTATS'19].

Task: K workers hold data blocks X_1..X_K; compute f(X_i) = X_i @ W for all
i, tolerating stragglers. LCC encodes the blocks as evaluations of the
degree-(K−1) polynomial u(z) with u(ω_i) = X_i at N ≥ K points α_j — which
is EXACTLY the all-to-all-encode of a Lagrange matrix (Theorem 4: inverse
Vandermonde then forward Vandermonde, both by draw-and-loose). Worker j
computes f(u(α_j)) = u(α_j) @ W — evaluations of the degree-(K−1) polynomial
f∘u — and any K results interpolate back to f(X_i) = (f∘u)(ω_i).

Everything is exact over GF(q) (data quantized to field elements), so the
decode is bit-exact.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core.draw_loose import encode_lagrange
from repro.core.field import M31, NTT, Field
from repro.core.matrices import lagrange_matrix
from repro.core.schedule import plan_draw_loose


@dataclass(frozen=True)
class LCCPlan:
    K: int
    p: int
    q: int
    plan_omega: object
    plan_alpha: object

    @property
    def omega_points(self):
        return self.plan_omega.points

    @property
    def alpha_points(self):
        return self.plan_alpha.points


def build_lcc(K: int, p: int = 1, q: int = NTT) -> LCCPlan:
    return LCCPlan(
        K=K,
        p=p,
        q=q,
        plan_omega=plan_draw_loose(K, p, q, seed=101),
        plan_alpha=plan_draw_loose(K, p, q, seed=202),
    )


def lcc_encode(plan: LCCPlan, X: jnp.ndarray) -> jnp.ndarray:
    """X: (K, *block) field elements with X[i] held by worker i as u(ω_i).
    Returns the encoded blocks u(α_j) at each worker — one all-to-all encode
    of the Lagrange matrix (Theorem 4 cost)."""
    return encode_lagrange(X, plan.plan_omega, plan.plan_alpha)


def lcc_compute_and_decode(
    plan: LCCPlan, encoded: np.ndarray, W: np.ndarray, responders: list[int]
) -> np.ndarray:
    """Each responder j supplies Y_j = u(α_j) @ W (mod q); interpolate back
    to f(X_i) for all i from any K responses."""
    f = Field(plan.q)
    K = plan.K
    if len(responders) < K:
        raise ValueError(f"need ≥{K} responders")
    responders = sorted(responders)[:K]
    Y = np.stack([f.matmul(np.asarray(encoded[j], dtype=np.uint64), W) for j in responders])
    # interpolate degree-(K-1) polynomial f∘u from K evaluations at α_j,
    # evaluate at ω_i: one Lagrange matrix application
    L = lagrange_matrix(
        f,
        plan.omega_points,
        np.asarray(plan.alpha_points)[responders],
    )  # maps values at surviving α's → values at ω's
    flat = Y.reshape(K, -1)
    out = f.matmul(flat.T, L).T
    return out.reshape((K,) + Y.shape[1:])
