"""Lagrange Coded Computing (LCC) — the paper's §VI use case
[Yu et al., AISTATS'19], extended to true (N, K) erasure codes.

Task: K workers hold data blocks X_1..X_K; compute f(X_i) = X_i @ W for all
i, tolerating stragglers. LCC encodes the blocks as evaluations of the
degree-(K−1) polynomial u(z) with u(ω_i) = X_i at N ≥ K points α_j — which
is EXACTLY the all-to-all-encode of a Lagrange matrix (Theorem 4: inverse
Vandermonde then forward Vandermonde, both by draw-and-loose). Worker j
computes f(u(α_j)) = u(α_j) @ W — evaluations of the degree-(K−1) polynomial
f∘u — and any K results interpolate back to f(X_i) = (f∘u)(ω_i).

Two regimes:

* ``R == 0`` (N = K, the original §VI example): the square Lagrange
  generator runs through the Theorem 4 draw-and-loose composite
  (inverse-Vandermonde ∘ forward-Vandermonde).
* ``R > 0`` (N = K + R coded replicas, the serving tier's straggler /
  fault-tolerance regime): the K data rows are zero-padded to N
  processors and encoded with the **padded Lagrange/Vandermonde
  generator** A (A[:K, :] = lagrange_matrix(α_0..α_{N−1}, ω_0..ω_{K−1}),
  rows K..N−1 zero) in ONE universal prepare-and-shoot all-to-all encode
  — on a mesh, the same generator executes through ``ir_encode_jit``
  (see :func:`lcc_encode_collective`). Any K of the N coded shards
  reconstruct every X_i bit-exactly (:func:`lcc_decode`).

Everything is exact over GF(q) (data quantized to field elements), so the
decode is bit-exact.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core.draw_loose import encode_lagrange
from repro.core.field import M31, NTT, Field
from repro.core.matrices import distinct_points, lagrange_matrix
from repro.core.prepare_shoot import encode_universal
from repro.core.schedule import plan_draw_loose, plan_prepare_shoot


@dataclass(frozen=True)
class LCCPlan:
    K: int
    p: int
    q: int
    plan_omega: object
    plan_alpha: object
    #: parity shards beyond K — N = K + R total coded replicas
    R: int = 0
    #: N evaluation points α_0..α_{N−1} when R > 0 (else plan_alpha.points)
    alphas: np.ndarray | None = None

    @property
    def N(self) -> int:
        return self.K + self.R

    @property
    def omega_points(self):
        return self.plan_omega.points

    @property
    def alpha_points(self):
        return self.alphas if self.alphas is not None else self.plan_alpha.points


def build_lcc(K: int, p: int = 1, q: int = NTT, R: int = 0) -> LCCPlan:
    """LCC plan for K data shards and N = K + R coded shards.

    R = 0 reproduces the original square (N = K) §VI example; R > 0 adds
    parity evaluation points so any K-of-N shards decode."""
    if R < 0:
        raise ValueError(f"R must be ≥ 0, got {R}")
    plan_omega = plan_draw_loose(K, p, q, seed=101)
    if R == 0:
        return LCCPlan(
            K=K, p=p, q=q,
            plan_omega=plan_omega,
            plan_alpha=plan_draw_loose(K, p, q, seed=202),
        )
    f = Field(q)
    return LCCPlan(
        K=K, p=p, q=q,
        plan_omega=plan_omega,
        plan_alpha=None,
        R=R,
        alphas=distinct_points(f, K + R, seed=202),
    )


def lcc_generator(plan: LCCPlan) -> np.ndarray:
    """The (N, N) all-to-all-encode generator of the LCC code: row k < K is
    the Lagrange row Φ_k evaluated at every α_j (a column-scaled Vandermonde
    in the ω basis), rows K..N−1 are zero (they multiply the padding).
    ``x_padded @ A`` = the N coded shards."""
    f = Field(plan.q)
    N = plan.N
    A = np.zeros((N, N), dtype=np.uint64)
    A[: plan.K, :] = lagrange_matrix(
        f, np.asarray(plan.alpha_points), np.asarray(plan.omega_points)
    )
    return A


def lcc_pad(plan: LCCPlan, X) -> jnp.ndarray:
    """Zero-pad (K, *payload) data to the (N, *payload) processor count the
    padded generator expects (a no-op at R = 0)."""
    X = jnp.asarray(X)
    if X.shape[0] != plan.K:
        raise ValueError(f"X must have K={plan.K} rows, got {X.shape[0]}")
    if plan.R == 0:
        return X
    return jnp.concatenate(
        [X, jnp.zeros((plan.R,) + X.shape[1:], X.dtype)], axis=0
    )


def lcc_encode(plan: LCCPlan, X: jnp.ndarray) -> jnp.ndarray:
    """X: (K, *block) field elements with X[i] held by worker i as u(ω_i).
    Returns the N = K + R coded blocks u(α_j), one per worker.

    N = K: one all-to-all encode of the Lagrange matrix via the Theorem 4
    draw-and-loose composite. N > K: one universal prepare-and-shoot encode
    of the padded Lagrange generator over N processors (jit-compatible)."""
    if plan.R == 0:
        return encode_lagrange(X, plan.plan_omega, plan.plan_alpha)
    xp = lcc_pad(plan, X)
    return encode_universal(xp, lcc_generator(plan), p=plan.p, q=plan.q)


def lcc_encode_collective(mesh, axis: str, plan: LCCPlan, **kw):
    """Mesh path: jitted (N, *payload) → (N, *payload) encode of the padded
    Lagrange generator, communication = ppermute rounds on ``axis`` (size N)
    — the prepare-and-shoot ScheduleIR executed through
    ``dist.collectives.ir_encode_jit``. Input rows K..N−1 must be the zero
    padding (:func:`lcc_pad`)."""
    from repro.dist.collectives import ps_encode_jit

    K_axis = int(mesh.shape[axis])
    if K_axis != plan.N:
        raise ValueError(
            f"mesh axis {axis!r} has {K_axis} devices, need N={plan.N}"
        )
    fn, _ = ps_encode_jit(mesh, axis, lcc_generator(plan), p=plan.p, q=plan.q, **kw)
    return fn


def _validate_responders(plan: LCCPlan, responders) -> list[int]:
    responders = [int(r) for r in responders]
    if len(set(responders)) != len(responders):
        raise ValueError(f"duplicate responders: {sorted(responders)}")
    bad = [r for r in responders if not 0 <= r < plan.N]
    if bad:
        raise ValueError(f"responders {bad} outside [0, {plan.N})")
    if len(responders) < plan.K:
        raise ValueError(
            f"need ≥{plan.K} responders to interpolate a degree-"
            f"{plan.K - 1} polynomial, have {len(responders)}"
        )
    return sorted(responders)[: plan.K]


def lcc_decode(plan: LCCPlan, values: np.ndarray, responders) -> np.ndarray:
    """Reconstruct all K data blocks from any K-of-N coded shards.

    ``values[i]`` is the coded shard held by worker ``responders[i]``
    (u(α_{responders[i]})); raises ValueError on fewer than K responders,
    duplicates, or out-of-range indices — never returns garbage."""
    f = Field(plan.q)
    K = plan.K
    order = {int(r): i for i, r in enumerate(responders)}
    chosen = _validate_responders(plan, responders)
    Y = np.stack(
        [np.asarray(values[order[r]], dtype=np.uint64) % f.q for r in chosen]
    )
    # interpolate the degree-(K−1) polynomial from its values at the K
    # surviving α's, evaluate at every ω: one Lagrange matrix application
    L = lagrange_matrix(
        f, np.asarray(plan.omega_points), np.asarray(plan.alpha_points)[chosen]
    )
    flat = Y.reshape(K, -1)
    out = f.matmul(flat.T, L).T
    return out.reshape((K,) + Y.shape[1:])


def lcc_compute_and_decode(
    plan: LCCPlan, encoded: np.ndarray, W: np.ndarray, responders: list[int]
) -> np.ndarray:
    """Each responder j supplies Y_j = u(α_j) @ W (mod q); interpolate back
    to f(X_i) for all i from any K responses (linearity of f: the responses
    are evaluations of the degree-(K−1) polynomial f∘u)."""
    f = Field(plan.q)
    responders = [int(r) for r in responders]
    Y = np.stack(
        [f.matmul(np.asarray(encoded[j], dtype=np.uint64), W) for j in responders]
    )
    return lcc_decode(plan, Y, responders)


__all__ = [
    "LCCPlan",
    "build_lcc",
    "lcc_generator",
    "lcc_pad",
    "lcc_encode",
    "lcc_encode_collective",
    "lcc_decode",
    "lcc_compute_and_decode",
]
