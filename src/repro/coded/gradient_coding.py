"""Coded gradient aggregation for straggler mitigation (float field).

Cyclic-repetition gradient coding (Tandon et al., ICML'17 construction,
randomized coefficients): K workers, each computes gradients of r = s+1
data shards (cyclic assignment) and transmits ONE coded combination

    c_i = Σ_{j ∈ supp(i)} B[i, j] · g_j ,   supp(i) = {i, i+1, .., i+s} mod K.

The full-batch gradient Σ_j g_j is recoverable from ANY K−s workers: solve
aᵀ B[S] = 1ᵀ for the surviving rows S (solvable w.p. 1 for random B — the
solve is checked at build time for every survivor pattern size via random
sampling, and at decode time by residual check).

This is the all-to-all-encode view of gradient coding: B is just another
generator matrix; over a mesh the combination is the same ppermute schedule
with float payloads (orthonormal-DFT variants available via
``dft_matrix_float`` for conditioning).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GradCodingPlan:
    K: int
    s: int  # max stragglers tolerated
    B: np.ndarray  # (K, K) float64 coding matrix, row i supported on supp(i)

    @property
    def r(self) -> int:  # replication factor
        return self.s + 1


def build_grad_coding(K: int, s: int, seed: int = 0) -> GradCodingPlan:
    """Tandon et al. cyclic construction (their Alg. 2): pick H ∈ R^{s×K}
    random with columns summing to 0 (so H·1 = 0); row i of B has support
    {i..i+s}, B[i,i] = 1 and the rest solve H[:, supp\\{i}]·x = −H[:, i] —
    hence B·Hᵀ = 0. Any K−s rows of B are a.s. linearly independent and span
    null(H) ∋ 1, which is exactly the decodability condition."""
    if s == 0:
        return GradCodingPlan(K=K, s=0, B=np.eye(K))
    rng = np.random.default_rng(seed)
    H = rng.normal(size=(s, K))
    H[:, -1] = -H[:, :-1].sum(axis=1)  # columns sum to zero → H·1 = 0
    B = np.zeros((K, K))
    for i in range(K):
        sup = [(i + d) % K for d in range(s + 1)]
        rest = sup[1:]
        x = np.linalg.solve(H[:, rest], -H[:, i])
        B[i, i] = 1.0
        B[i, rest] = x
    return GradCodingPlan(K=K, s=s, B=B)


def decode_vector(plan: GradCodingPlan, survivors: list[int]) -> np.ndarray:
    """a (len survivors) with aᵀ B[survivors] = 1ᵀ (least squares, residual
    checked)."""
    Bs = plan.B[sorted(survivors)]
    a, res, rank, _ = np.linalg.lstsq(Bs.T, np.ones(plan.K), rcond=None)
    err = np.linalg.norm(Bs.T @ a - 1.0)
    if err > 1e-6:
        raise RuntimeError(
            f"survivor set {survivors} cannot decode (residual {err:.2e}); "
            f"more than s={plan.s} stragglers?"
        )
    return a


def worker_combine(plan: GradCodingPlan, worker: int, shard_grads: dict[int, Any]):
    """c_i = Σ_{j∈supp} B[i,j]·g_j. shard_grads: {shard j → grad pytree}."""
    sup = [(worker + d) % plan.K for d in range(plan.s + 1)]
    coef = [plan.B[worker, j] for j in sup]

    def comb(*gs):
        return sum(c * g.astype(jnp.float32) for c, g in zip(coef, gs))

    return jax.tree.map(comb, *[shard_grads[j] for j in sup])


def aggregate(plan: GradCodingPlan, received: dict[int, Any]):
    """Recover Σ_j g_j from any ≥ K−s workers' combinations."""
    survivors = sorted(received)
    a = decode_vector(plan, survivors)

    def comb(*cs):
        return sum(ai * c for ai, c in zip(a, cs))

    return jax.tree.map(comb, *[received[i] for i in survivors])
