"""Erasure-coded optimizer/parameter state across the data-parallel axis —
the paper's all-to-all encode as the framework's fault-tolerance fast path
(DESIGN §2, §8; Remark 1 of the paper).

Scheme
------
Every DP replica k holds a distinct state shard x_k (ZeRO-style). Every
``coded_every`` steps the replicas run ONE all-to-all encode of the Cauchy
generator A (universal prepare-and-shoot — C1 = ⌈log_{p+1}K⌉ rounds,
C2 = Θ(√K/p) elements, vs Θ(K/p) for the all-gather a naive scheme needs):
replica k ends up holding the parity packet

    P_k = Σ_r x_r · A[r, k]        (in GF(2^31−1), exact)

in spare HBM. Loss of any set F of ≤ K−|F| nodes destroys {x_k, P_k : k∈F};
the survivors recover every lost x_r bit-exactly by solving the f×f Cauchy
subsystem  Σ_{r∈F} x_r A[r, j] = P_j − Σ_{r∉F} x_r A[r, j]  for any f
surviving parity indices j (every square Cauchy submatrix is invertible).

Bit-exactness over floats: state is bitcast to 16-bit limbs (canonical
elements < 2^16 < q), encoded, and reassembled — no rounding anywhere.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.field import M31, Field
from repro.core.matrices import cauchy_matrix
from repro.core.prepare_shoot import encode_universal
from repro.core.schedule import counted_c2, plan_prepare_shoot


# ---------------------------------------------------------------------------
# bitcast <-> limbs
# ---------------------------------------------------------------------------


@dataclass
class LimbMeta:
    treedef: Any
    shapes: list[tuple[int, ...]]
    dtypes: list[Any]
    sizes_u16: list[int]
    total: int


def state_to_limbs(state) -> tuple[jnp.ndarray, LimbMeta]:
    """Pytree → (S,) uint32 array of 16-bit limbs (canonical mod-q elements)."""
    leaves, treedef = jax.tree.flatten(state)
    parts = []
    shapes, dtypes, sizes = [], [], []
    for leaf in leaves:
        arr = jnp.asarray(leaf)
        shapes.append(arr.shape)
        dtypes.append(arr.dtype)
        if arr.dtype == jnp.bool_:  # bitcast can't take bool directly
            arr = arr.astype(jnp.uint8)
        u8 = jax.lax.bitcast_convert_type(
            arr.reshape(-1), jnp.uint8
        ).reshape(-1)
        if u8.size % 2:
            u8 = jnp.pad(u8, (0, 1))
        u16 = u8[0::2].astype(jnp.uint32) | (u8[1::2].astype(jnp.uint32) << 8)
        sizes.append(int(u16.size))
        parts.append(u16)
    limbs = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.uint32)
    return limbs, LimbMeta(treedef, shapes, dtypes, sizes, int(limbs.size))


def limbs_to_state(limbs: jnp.ndarray, meta: LimbMeta):
    out = []
    off = 0
    for shape, dtype, size in zip(meta.shapes, meta.dtypes, meta.sizes_u16):
        u16 = limbs[off : off + size]
        off += size
        u8 = jnp.stack(
            [u16 & 0xFF, (u16 >> 8) & 0xFF], axis=1
        ).reshape(-1).astype(jnp.uint8)
        nbytes = int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize
        u8 = u8[:nbytes]
        if jnp.dtype(dtype) == jnp.bool_:
            arr = u8.astype(jnp.bool_).reshape(shape)
        else:
            itemsize = jnp.dtype(dtype).itemsize
            arr = jax.lax.bitcast_convert_type(u8.reshape(-1, itemsize), dtype).reshape(shape)
        out.append(arr)
    return jax.tree.unflatten(meta.treedef, out)


# ---------------------------------------------------------------------------
# parity plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParityPlan:
    K: int
    p: int
    q: int
    A: np.ndarray  # (K, K) Cauchy generator
    ps_plan: Any

    @property
    def c1(self) -> int:
        return self.ps_plan.c1

    @property
    def c2(self) -> int:
        return counted_c2(self.ps_plan)


def build_parity_plan(K: int, p: int = 1, q: int = M31) -> ParityPlan:
    f = Field(q)
    A = cauchy_matrix(f, K)
    return ParityPlan(K=K, p=p, q=q, A=A, ps_plan=plan_prepare_shoot(K, p))


def encode_parity(x_limbs: jnp.ndarray, plan: ParityPlan) -> jnp.ndarray:
    """Single-program path (tests / single host): x_limbs (K, S) → (K, S)
    parity packets, via the universal algorithm (host-A Shoup fast path)."""
    return encode_universal(x_limbs, plan.A, p=plan.p, q=plan.q, plan=plan.ps_plan)


def encode_parity_collective(mesh, axis, plan: ParityPlan):
    """Mesh path: returns a jitted (K, S)→(K, S) function whose communication
    is ppermute rounds on the DP axis/axes.

    ``axis`` may be a single mesh-axis name (flat prepare-and-shoot, the
    default) or a tuple of axis names outermost → innermost — the
    topology-aligned path ``launch.profiles.resolve_profile`` selects when
    the DP replicas span a hierarchy (two axes → two-level
    ``hierarchical_encode_jit``, more → recursive ``multilevel_encode_jit``);
    every variant is bit-exact (same modular sums, reassociated)."""
    from repro.dist.collectives import (
        hierarchical_encode_jit,
        multilevel_encode_jit,
        ps_encode_jit,
    )

    if isinstance(axis, (tuple, list)):
        axes = tuple(axis)
        if len(axes) == 1:
            fn, _ = ps_encode_jit(mesh, axes[0], plan.A, p=plan.p, q=plan.q)
        elif len(axes) == 2:
            fn, _ = hierarchical_encode_jit(
                mesh, axes[0], axes[1], plan.A, p=plan.p, q=plan.q
            )
        else:
            fn, _ = multilevel_encode_jit(mesh, axes, plan.A, p=plan.p, q=plan.q)
        return fn
    fn, _ = ps_encode_jit(mesh, axis, plan.A, p=plan.p, q=plan.q)
    return fn


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------


def recover_lost(
    plan: ParityPlan,
    lost: list[int],
    surviving_x: dict[int, np.ndarray],
    surviving_parity: dict[int, np.ndarray],
) -> dict[int, np.ndarray]:
    """Recover the lost replicas' limb arrays bit-exactly.

    surviving_x/parity: {replica index → (S,) uint32 limbs}. Needs
    |surviving_parity| ≥ |lost| (any subset works — Cauchy guarantee).
    """
    f = Field(plan.q)
    F = sorted(lost)
    J = sorted(surviving_parity)[: len(F)]
    if len(J) < len(F):
        raise ValueError(f"need ≥{len(F)} surviving parity shards, have {len(J)}")
    A = plan.A
    S = next(iter(surviving_parity.values())).shape[0]
    rhs = np.zeros((len(J), S), dtype=np.uint64)
    for ji, j in enumerate(J):
        acc = surviving_parity[j].astype(np.uint64) % f.q
        for r, xr in surviving_x.items():
            acc = f.sub(acc, f.mul(xr, A[r, j]))
        rhs[ji] = acc
    M = A[np.ix_(F, J)].T.astype(np.uint64)  # equations j × unknowns r
    sol = f.solve(M, rhs)  # (f, S)
    return {r: sol[i] for i, r in enumerate(F)}


# ---------------------------------------------------------------------------
# high-level: coded checkpoint of a training-state pytree across K replicas
# ---------------------------------------------------------------------------


def shard_state_limbs(state, K: int) -> tuple[jnp.ndarray, LimbMeta]:
    """Flatten state to limbs and split into K equal shards (pad to K)."""
    limbs, meta = state_to_limbs(state)
    S = -(-int(limbs.size) // K)
    limbs = jnp.pad(limbs, (0, S * K - limbs.size))
    return limbs.reshape(K, S), meta


def unshard_state_limbs(shards: jnp.ndarray, meta: LimbMeta):
    return limbs_to_state(shards.reshape(-1)[: meta.total], meta)
