# Coded-system applications of all-to-all encode (DESIGN §2):
#  - rs_checkpoint:     erasure-coded optimizer/param shards over the DP axis
#  - gradient_coding:   straggler-tolerant coded gradient aggregation
#  - lagrange_compute:  Lagrange Coded Computing (coded matmul) example
from .gradient_coding import aggregate, build_grad_coding, worker_combine  # noqa: F401
from .lagrange_compute import (  # noqa: F401
    LCCPlan,
    build_lcc,
    lcc_compute_and_decode,
    lcc_decode,
    lcc_encode,
    lcc_encode_collective,
    lcc_generator,
    lcc_pad,
)
from .rs_checkpoint import (  # noqa: F401
    build_parity_plan,
    encode_parity,
    encode_parity_collective,
    limbs_to_state,
    recover_lost,
    shard_state_limbs,
    state_to_limbs,
    unshard_state_limbs,
)
