"""shard_map across jax versions.

* jax < 0.6: ``jax.experimental.shard_map.shard_map`` with ``check_rep``;
* jax >= 0.6: public ``jax.shard_map`` where the kwarg became ``check_vma``
  (and older spellings were removed).

Replication/varying-manual-axes checking is disabled in both: the rep
checker in several jax versions rejects valid ppermute/psum mixtures inside
unrolled collective loops.
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.6 moved shard_map out of experimental
    from jax import shard_map as _raw_shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _raw_shard_map

_PARAMS = inspect.signature(_raw_shard_map).parameters
if "check_vma" in _PARAMS:
    _CHECK_KWARGS = {"check_vma": False}
elif "check_rep" in _PARAMS:
    _CHECK_KWARGS = {"check_rep": False}
else:  # pragma: no cover - future-proofing
    _CHECK_KWARGS = {}


def shard_map(f, mesh, in_specs, out_specs):
    return _raw_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_CHECK_KWARGS
    )
