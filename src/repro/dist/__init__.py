"""Distributed substrate: logical-axis sharding rules, shard_map collectives
for the paper's round schedules, and GPipe-style pipeline parallelism.

Layering (DESIGN §2): ``core`` computes plans (host numpy, compile-time);
``dist`` lowers them onto a jax mesh; ``models``/``train``/``serve`` consume
only :class:`ShardingRules` / :func:`constrain` / :func:`named_sharding` and
never talk to the mesh directly.
"""

from .sharding import (  # noqa: F401
    ShardingRules,
    constrain,
    named_sharding,
    spec_for,
)
from .collectives import (  # noqa: F401
    allgather_encode_jit,
    butterfly_jit,
    hierarchical_encode_jit,
    multilevel_encode_jit,
    ps_encode_jit,
)
from .pipeline import pipeline_apply, stack_stage_params  # noqa: F401
