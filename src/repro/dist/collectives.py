"""shard_map executors for the paper's all-to-all encode schedules.

One processor per mesh-axis slot: an array of global shape ``(K, *payload)``
is sharded ``P(axis)`` so device ``k`` holds packet ``x_k`` as a ``(1,
*payload)`` block. Every ``jnp.roll(..., s, axis=0)`` of the single-host
executors (core/prepare_shoot.py, core/draw_loose.py) becomes exactly one
``jax.lax.ppermute`` with the uniform shift ``src → (src + s) % K`` — the
round structure, coefficient tables and masks are consumed from the SAME
compile-time plans (core/schedule.py), so the mesh path and the single-host
oracle agree bit-for-bit by construction.

Communication discipline (tested via compiled HLO): the universal encode
lowers to ``collective-permute`` rounds only — C1 = Tp + Ts rounds with the
paper's Θ(√K/p) per-port volumes — never to a K-sized ``all-gather``.
:func:`allgather_encode_jit` is the deliberate baseline that DOES all-gather,
kept for benchmarks and as the cost-model foil.

All device arithmetic is the uint32-only tier of core/field.py (Shoup
multiplies by compile-time coefficient duals), so the same bodies lower for
CPU hosts and TPU.

Paper-notation glossary: ``K`` processors (= product of the mesh encode
axes), ``p`` ports per round (each ``ppermute`` is one port), ``C1`` rounds,
``C2`` per-port elements; ``I``/``G`` the two-level k_intra × k_inter split
of :func:`hierarchical_encode_jit`; *digit-reduction slots* — the §IV shoot
buffer layout (one slot per (p+1)-ary numeral of the remaining target
offset; round t zeroes digit t by shipping the slots with digit_t = ρ on
port ρ). :func:`multilevel_encode_jit` generalizes to any K = Π K_level
hierarchy: one gather over the innermost mesh axis, then one digit-reduction
shoot per outer axis, innermost first.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist._compat import shard_map as _smap
from repro.core.field import M31, NTT, madd, shoup_mul, shoup_precompute
from repro.core.schedule import (
    PrepareShootPlan,
    butterfly_group_perms,
    coeff_mask,
    digit_reduction_slots,
    plan_butterfly,
    plan_prepare_shoot,
    shoot_coeff_tensor,
)

__all__ = [
    "ps_encode_jit",
    "allgather_encode_jit",
    "butterfly_jit",
    "hierarchical_encode_jit",
    "multilevel_encode_jit",
    "shoot_round_slots",
    "expected_permute_count",
    "expected_hier_permute_count",
    "expected_multilevel_permute_count",
]


def _bcast(coef, npay: int):
    """Append payload broadcast dims to a coefficient array."""
    return coef.reshape(coef.shape + (1,) * npay)


def _shift_perm(K: int, s: int):
    """ppermute pairs realizing ``jnp.roll(x, s, axis=0)`` on the processor
    axis: receiver k gets the packet of k - s, i.e. src → (src + s) % K."""
    return [(src, (src + s) % K) for src in range(K)]


# ---------------------------------------------------------------------------
# universal prepare-and-shoot (§IV)
# ---------------------------------------------------------------------------


def shoot_round_slots(plan: PrepareShootPlan, t: int, rho: int):
    """(dst_slots, src_slots) for shoot round ``t`` (1-based), port ``rho``:
    receiver slot ``l`` (digit_t = 0, lower digits 0) absorbs sender slot
    ``l + rho·(p+1)^{t-1}``. Mirrors prepare_shoot.shoot_rounds exactly; the
    collective ships ONLY these slots (the paper's digit-t message slices).
    """
    return digit_reduction_slots(plan.n, plan.p, t, rho)


def expected_permute_count(plan: PrepareShootPlan) -> int:
    """Number of ppermute ops ps_encode_jit emits: p per prepare round plus
    one per non-empty (round, port) shoot slice — the plan/collective
    agreement contract checked in tests/test_dist_unit.py."""
    count = plan.Tp * plan.p
    for t in range(1, plan.Ts + 1):
        for rho in range(1, plan.p + 1):
            dst, _ = shoot_round_slots(plan, t, rho)
            if dst.size:
                count += 1
    return count


def ps_encode_jit(mesh, axis: str, A: np.ndarray, *, p: int = 1, q: int = M31):
    """Jitted mesh executor of the universal encode: ``out = x @ A`` over
    GF(q) for ANY K×K matrix A, K = mesh.shape[axis].

    Returns ``(fn, plan)``; ``fn`` maps a ``(K, *payload)`` uint32 array
    (sharded or shardable over ``axis``) to the encoded array of the same
    shape. A is a host array: the shoot coefficients and their Shoup duals
    are baked in as per-device compile-time constants.
    """
    K = int(mesh.shape[axis])
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(f"A must be ({K}, {K}) to match mesh axis {axis!r}, got {A.shape}")
    plan = plan_prepare_shoot(K, p)
    radix = p + 1
    m, n = plan.m, plan.n
    mask = coeff_mask(plan)  # (m, n) bool, first-coverage exactness
    coef = (shoot_coeff_tensor(plan, A) * mask[None, :, :]).astype(np.uint32)  # (K, m, n)
    coef_shoup = shoup_precompute(coef, q)

    def body(x, cf, cfs):
        # x: (1, *payload) — this device's packet; cf/cfs: (1, m, n)
        npay = x.ndim - 1
        # ---- prepare phase: Tp rounds, message = whole buffer (Lemma 3) ---
        buf = x[:, None]  # (1, 1, *payload)
        for shifts in plan.prepare_shifts:
            parts = [buf]
            for s in shifts:
                parts.append(jax.lax.ppermute(buf, axis, _shift_perm(K, s % K)))
            buf = jnp.concatenate(parts, axis=1)
        # ---- w-init: modular contraction with baked Shoup coefficients ----
        cols = []
        for l in range(n):
            acc = None
            for u in range(m):
                term = shoup_mul(
                    buf[:, u], _bcast(cf[:, u, l], npay), _bcast(cfs[:, u, l], npay), q
                )
                acc = term if acc is None else madd(acc, term, q)
            cols.append(acc)
        w = jnp.stack(cols, axis=1)  # (1, n, *payload)
        # ---- shoot phase: Ts rounds, digit-t slices only -----------------
        for t, shifts in enumerate(plan.shoot_shifts, start=1):
            acc = w
            for rho, s in enumerate(shifts, start=1):
                dst, src = shoot_round_slots(plan, t, rho)
                if dst.size == 0:
                    continue
                payload = jnp.take(w, jnp.asarray(src), axis=1)
                payload = jax.lax.ppermute(payload, axis, _shift_perm(K, s % K))
                # scatter the received slices into their target slots
                pos = np.full(n, dst.size, dtype=np.int64)
                pos[dst] = np.arange(dst.size)
                padded = jnp.concatenate(
                    [payload, jnp.zeros_like(w[:, :1])], axis=1
                )
                acc = madd(acc, jnp.take(padded, jnp.asarray(pos), axis=1), q)
            w = acc
        return w[:, 0]

    mapped = _smap(body, mesh, in_specs=(P(axis), P(axis), P(axis)), out_specs=P(axis))
    cf_dev = jnp.asarray(coef)
    cfs_dev = jnp.asarray(coef_shoup)
    fn = jax.jit(lambda x: mapped(x, cf_dev, cfs_dev))
    return fn, plan


def allgather_encode_jit(mesh, axis: str, A: np.ndarray, *, q: int = M31):
    """Baseline mesh encode: all-gather every packet, then each device
    contracts locally with its own column of A — C1 = O(log K) but
    C2 = Θ(K/p). Kept as the benchmark/cost-model foil for ps_encode_jit."""
    K = int(mesh.shape[axis])
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(f"A must be ({K}, {K}), got {A.shape}")
    # device k needs column A[:, k]: ship as a (K, K) array sharded on dim 0
    cols = np.ascontiguousarray(A.T).astype(np.uint32)  # cols[k, j] = A[j, k]
    cols_shoup = shoup_precompute(cols, q)

    def body(x, c, cs):
        # x: (1, *payload); c/cs: (1, K)
        npay = x.ndim - 1
        xs = jax.lax.all_gather(x, axis, axis=0, tiled=True)  # (K, *payload)
        acc = None
        for j in range(K):
            term = shoup_mul(xs[j], _bcast(c[0, j], npay), _bcast(cs[0, j], npay), q)
            acc = term if acc is None else madd(acc, term, q)
        return acc[None]

    mapped = _smap(body, mesh, in_specs=(P(axis), P(axis), P(axis)), out_specs=P(axis))
    c_dev = jnp.asarray(cols)
    cs_dev = jnp.asarray(cols_shoup)
    return jax.jit(lambda x: mapped(x, c_dev, cs_dev))


# ---------------------------------------------------------------------------
# two-level hierarchical encode (repro.topo.hierarchical) on a 2D mesh
# ---------------------------------------------------------------------------


def expected_hier_permute_count(plan) -> int:
    """ppermute budget of hierarchical_encode_jit: one per non-empty intra
    gather port plus one per inter (round, port) with live slots — the
    plan/collective agreement contract (mirrors expected_permute_count)."""
    from repro.topo.hierarchical import hier_shoot_message_size

    count = sum(len(ports) for ports in plan.intra_rounds)
    for t in range(1, len(plan.inter_shifts) + 1):
        for rho in range(1, plan.p + 1):
            if hier_shoot_message_size(plan, t, rho):
                count += 1
    return count


def hierarchical_encode_jit(
    mesh,
    inter_axis: str,
    intra_axis: str,
    A: np.ndarray,
    *,
    p: int = 1,
    q: int = M31,
):
    """Jitted two-level mesh executor of the universal encode: ``out = x @ A``
    over GF(q) for ANY K×K matrix A, K = mesh.shape[inter_axis] ×
    mesh.shape[intra_axis]; device (g, i) holds packet k = g·I + i.

    Three phases (repro.topo.hierarchical — the topology-aligned schedule):
    (p+1)-ary doubling all-gather over the fast ``intra_axis``, a local Shoup
    contraction against baked per-device coefficients, then the §IV
    digit-reduction shoot over the slow ``inter_axis``. Every round is
    ppermutes on exactly one mesh axis, so intra traffic never crosses the
    slow domain. Bit-exact vs. the single-level ``ps_encode_jit`` /
    ``encode_oracle`` (modular sums reassociate exactly).

    The two-level schedule is exactly the depth-2 case of the recursive one
    (``plan_multilevel(K, p, (I, G))`` lowers to the same rounds — asserted
    in tests), so the executor delegates to :func:`multilevel_encode_jit`.

    Returns ``(fn, plan)`` with plan a :class:`HierarchicalPlan`.
    """
    from repro.topo.hierarchical import plan_hierarchical

    G = int(mesh.shape[inter_axis])
    I = int(mesh.shape[intra_axis])
    K = G * I
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(
            f"A must be ({K}, {K}) to match mesh axes "
            f"({inter_axis!r}×{intra_axis!r}), got {A.shape}"
        )
    fn, _ = multilevel_encode_jit(mesh, (inter_axis, intra_axis), A, p=p, q=q)
    return fn, plan_hierarchical(K, p, k_intra=I)


# ---------------------------------------------------------------------------
# recursive multi-level encode (repro.topo.hierarchical) on an N-D mesh
# ---------------------------------------------------------------------------


def expected_multilevel_permute_count(plan) -> int:
    """ppermute budget of multilevel_encode_jit: one per non-empty intra
    gather port plus one per (level, round, port) with live slots — the
    plan/collective agreement contract (mirrors expected_hier_permute_count)."""
    from repro.topo.hierarchical import multilevel_message_size

    count = sum(len(ports) for ports in plan.intra_rounds)
    for j in range(1, len(plan.levels)):
        for t in range(1, len(plan.level_shifts[j - 1]) + 1):
            for rho in range(1, plan.p + 1):
                if multilevel_message_size(plan, j, t, rho):
                    count += 1
    return count


def multilevel_encode_jit(mesh, axes, A: np.ndarray, *, p: int = 1, q: int = M31):
    """Jitted N-level mesh executor of the universal encode: ``out = x @ A``
    over GF(q) for ANY K×K matrix A, K = Π mesh.shape[ax] over ``axes``.

    ``axes`` is ordered outermost (slowest links, e.g. ``"pod"``) →
    innermost (fastest, e.g. ``"chip"``), matching how ``P(tuple(axes))``
    shards the packet axis: the LAST mesh axis varies fastest, so device
    (c_{L−1}, …, c_1, c_0) holds packet k = c_0 + K_0·(c_1 + K_1·(…)).

    Phases (repro.topo.hierarchical — the recursive topology-aligned
    schedule): (p+1)-ary doubling all-gather over the innermost axis, a
    local Shoup contraction against baked per-device coefficients, then one
    §IV digit-reduction shoot per outer axis, innermost first — every round
    is ppermutes on exactly ONE mesh axis, so traffic never rides a slower
    level than its phase. Bit-exact vs. ``ps_encode_jit`` / ``encode_oracle``
    (modular sums reassociate exactly). With two axes this is exactly
    ``hierarchical_encode_jit``'s schedule.

    Returns ``(fn, plan)`` with plan a :class:`MultiLevelPlan`.
    """
    from repro.topo.hierarchical import (
        multilevel_coeff_tensor,
        multilevel_level_slots,
        plan_multilevel,
    )

    axes = tuple(axes)
    sizes = [int(mesh.shape[ax]) for ax in axes]
    K = 1
    for s in sizes:
        K *= s
    levels = tuple(reversed(sizes))  # innermost (last mesh axis) first
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(
            f"A must be ({K}, {K}) to match mesh axes {axes!r}, got {A.shape}"
        )
    plan = plan_multilevel(K, p, levels)
    K0, n = plan.levels[0], plan.n_slots
    coef = multilevel_coeff_tensor(plan, A).astype(np.uint32)  # (K, K0, n)
    coef_shoup = shoup_precompute(coef, q)
    intra_axis = axes[-1]
    # outer level j (1-based, innermost outer first) lives on mesh axis -1-j
    level_axis = {j: axes[-1 - j] for j in range(1, len(levels))}

    def body(x, cf, cfs):
        # x: (1, *payload) — this device's packet; cf/cfs: (1, K0, n)
        npay = x.ndim - 1
        # ---- intra gather over the innermost axis -------------------------
        buf = x[:, None]
        for ports in plan.intra_rounds:
            parts = [buf]
            for s, cnt in ports:
                parts.append(
                    jax.lax.ppermute(buf[:, :cnt], intra_axis, _shift_perm(K0, s))
                )
            buf = jnp.concatenate(parts, axis=1)
        # ---- local contraction into the per-level offset slots ------------
        cols = []
        for l in range(n):
            acc = None
            for u in range(K0):
                term = shoup_mul(
                    buf[:, u], _bcast(cf[:, u, l], npay), _bcast(cfs[:, u, l], npay), q
                )
                acc = term if acc is None else madd(acc, term, q)
            cols.append(acc)
        z = jnp.stack(cols, axis=1)  # (1, n, *payload)
        # ---- per-level shoot, innermost outer level first -----------------
        for j in range(1, len(plan.levels)):
            kj = plan.levels[j]
            for t, shifts in enumerate(plan.level_shifts[j - 1], start=1):
                acc = z
                for rho, s in enumerate(shifts, start=1):
                    dst, src = multilevel_level_slots(plan, j, t, rho)
                    if dst.size == 0:
                        continue
                    payload = jnp.take(z, jnp.asarray(src), axis=1)
                    payload = jax.lax.ppermute(
                        payload, level_axis[j], _shift_perm(kj, s % kj)
                    )
                    pos = np.full(n, dst.size, dtype=np.int64)
                    pos[dst] = np.arange(dst.size)
                    padded = jnp.concatenate(
                        [payload, jnp.zeros_like(z[:, :1])], axis=1
                    )
                    acc = madd(acc, jnp.take(padded, jnp.asarray(pos), axis=1), q)
                z = acc
        return z[:, 0]

    mapped = _smap(
        body, mesh, in_specs=(P(axes), P(axes), P(axes)), out_specs=P(axes)
    )
    cf_dev = jnp.asarray(coef)
    cfs_dev = jnp.asarray(coef_shoup)
    fn = jax.jit(lambda x: mapped(x, cf_dev, cfs_dev))
    return fn, plan


# ---------------------------------------------------------------------------
# radix-(p+1) DFT butterfly (§V-A)
# ---------------------------------------------------------------------------


def butterfly_jit(
    mesh, axis: str, *, p: int = 1, q: int = NTT, inverse: bool = False
):
    """Jitted mesh butterfly: forward computes ``x @ butterfly_target_matrix``
    (the digit-reversed K-point DFT), inverse undoes it exactly (Lemma 5).

    Returns ``(fn, plan)``. Round t exchanges within digit-t groups via
    radix-1 ppermutes and combines with the plan's (inverse) twiddles —
    C1 = C2 = H rounds/elements, mirroring core/draw_loose.butterfly_apply.
    """
    K = int(mesh.shape[axis])
    plan = plan_butterfly(K, p, q)
    radix = plan.radix
    k = np.arange(K)
    order = range(plan.H - 1, -1, -1) if inverse else range(plan.H)
    rounds = []
    for t in order:
        tw = plan.inv_twiddles[t] if inverse else plan.twiddles[t]
        tw_sh = plan.inv_twiddles_shoup[t] if inverse else plan.twiddles_shoup[t]
        step = radix**t
        digit = (k // step) % radix
        perms = butterfly_group_perms(K, radix, t)  # dst arrays for d=1..radix-1
        # delta d: received value came from the group member with digit_t =
        # (digit_k - d) % radix; pick that sender's coefficient column.
        coefs, coefs_sh = [], []
        for d in range(radix):
            rho = (digit - d) % radix
            coefs.append(tw[k, rho].astype(np.uint32))
            coefs_sh.append(tw_sh[k, rho].astype(np.uint32))
        perm_pairs = [
            [(src, int(dst[src])) for src in range(K)] for dst in perms
        ]
        rounds.append((perm_pairs, np.stack(coefs), np.stack(coefs_sh)))

    # coefficient tensor: (H, radix, K) → shard on the K dim
    cf = np.stack([r[1] for r in rounds])
    cf_sh = np.stack([r[2] for r in rounds])

    def body(v, c, cs):
        # v: (1, *payload); c/cs: (H, radix, 1)
        npay = v.ndim - 1
        for r_i, (perm_pairs, _, _) in enumerate(rounds):
            acc = shoup_mul(
                v, _bcast(c[r_i, 0], npay), _bcast(cs[r_i, 0], npay), q
            )
            for d in range(1, radix):
                recv = jax.lax.ppermute(v, axis, perm_pairs[d - 1])
                term = shoup_mul(
                    recv, _bcast(c[r_i, d], npay), _bcast(cs[r_i, d], npay), q
                )
                acc = madd(acc, term, q)
            v = acc
        return v

    mapped = _smap(
        body, mesh, in_specs=(P(axis), P(None, None, axis), P(None, None, axis)),
        out_specs=P(axis),
    )
    c_dev = jnp.asarray(cf)
    cs_dev = jnp.asarray(cf_sh)
    fn = jax.jit(lambda x: mapped(x, c_dev, cs_dev))
    return fn, plan
