"""shard_map executors for the paper's all-to-all encode schedules — ONE
generic :func:`ir_encode_jit` that runs any :class:`~repro.core.ir.ScheduleIR`.

One processor per mesh slot: an array of global shape ``(K, *payload)`` is
sharded ``P(axes)`` so the device at flattened mesh index ``k`` holds packet
``x_k`` as a ``(1, *payload)`` block. Each :class:`~repro.core.ir.CommRound`
decomposes into its port groups (transfers sharing (port, slots, mode) — a
uniform permutation), and every port group becomes exactly one
``jax.lax.ppermute`` over the composite encode axes; each
:class:`~repro.core.ir.LocalOp` becomes a Shoup-multiplied modular
contraction against baked per-device coefficient constants. The per-family
``*_encode_jit`` entry points are now dispatches: they build the plan,
compile it with ``plan.to_ir()``, and hand the IR to the generic executor —
the round structure, coefficient tables, and masks all come from the SAME
compile-time plans as the host simulators, so the mesh path and the
single-host oracle agree bit-for-bit by construction.

Communication discipline (tested via compiled HLO): every IR round lowers to
``collective-permute`` only — never to a K-sized ``all-gather``. The
committed ppermute budgets (``expected_permute_count`` and friends) are
unchanged by the IR refactor and asserted at dispatch time
(``ir_permute_count(ir) ≤ budget``; equality in the non-degenerate regimes
the jaxpr tests pin).

:func:`allgather_encode_jit` is the deliberate baseline that DOES
all-gather, kept for benchmarks and as the cost-model foil.

All device arithmetic is the uint32-only tier of core/field.py (Shoup
multiplies by compile-time coefficient duals), so the same bodies lower for
CPU hosts and TPU.

Paper-notation glossary: ``K`` processors (= product of the mesh encode
axes), ``p`` ports per round (each ``ppermute`` is one port), ``C1`` rounds,
``C2`` per-port elements; ``I``/``G`` the two-level k_intra × k_inter split
of :func:`hierarchical_encode_jit`; *digit-reduction slots* — the §IV shoot
buffer layout (one slot per (p+1)-ary numeral of the remaining target
offset; round t zeroes digit t by shipping the slots with digit_t = ρ on
port ρ). :func:`multilevel_encode_jit` generalizes to any K = Π K_level
hierarchy: one gather over the innermost mesh axis, then one digit-reduction
shoot per outer axis, innermost first.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist._compat import shard_map as _smap
from repro.core.field import M31, NTT, madd, shoup_mul, shoup_precompute
from repro.core.ir import (
    INPUT_SLOT,
    CommRound,
    LocalOp,
    ScheduleIR,
    ir_permute_count,
    round_port_groups,
)
from repro.core.schedule import (
    PrepareShootPlan,
    digit_reduction_slots,
    plan_butterfly,
    plan_prepare_shoot,
)

__all__ = [
    "ir_encode_jit",
    "ps_encode_jit",
    "allgather_encode_jit",
    "butterfly_jit",
    "hierarchical_encode_jit",
    "multilevel_encode_jit",
    "shoot_round_slots",
    "expected_permute_count",
    "expected_hier_permute_count",
    "expected_multilevel_permute_count",
]


def _bcast(coef, npay: int):
    """Append payload broadcast dims to a coefficient array."""
    return coef.reshape(coef.shape + (1,) * npay)


KERNEL_MODES = ("jnp", "fused", "pallas")


def _resolve_kernels(kernels: str | None) -> str:
    """LocalOp lowering mode: ``None`` auto-selects the Pallas kernels on
    TPU and the batched-jnp fused lowering elsewhere; ``"jnp"`` is the
    legacy per-coefficient loop kept as the flagged fallback."""
    if kernels is None:
        return "pallas" if jax.default_backend() == "tpu" else "fused"
    if kernels not in KERNEL_MODES:
        raise ValueError(f"kernels must be one of {KERNEL_MODES} or None, got {kernels!r}")
    return kernels


def _lower_local(step: LocalOp, bake, kernels: str) -> dict:
    """Strength-reduce one LocalOp for the executor. Rows whose coefficients
    are uniform across devices split into three classes: all-zero rows write
    zeros, {0,1}-rows become pure madd chains (the pipeline pass's shadow
    copies and combines), and the remaining *general* rows are stacked into
    ONE batched contraction — a single Shoup-multiplied jnp expression in
    ``fused`` mode, or one ``gf_matmul``/``butterfly_mac`` kernel call in
    ``pallas`` mode. ``jnp`` keeps the legacy dense per-(i,j) loop."""
    c = np.asarray(step.coeffs)
    spec = {
        "update": step.update,
        "overlap": step.overlap,
        "zero": (),
        "adds": (),
        "gen": tuple(range(len(step.out_slots))),
        "coef_idx": None,
        "dense": kernels == "jnp",
    }
    if spec["dense"]:
        spec["coef_idx"] = bake(c)
        return spec
    ones = np.all(c == 1, axis=0)
    zeros = np.all(c == 0, axis=0)
    uniform01 = ones | zeros
    zero_rows, add_rows, gen_rows = [], [], []
    for i in range(c.shape[1]):
        if zeros[i].all():
            zero_rows.append(i)
        elif uniform01[i].all():
            add_rows.append((i, tuple(int(j) for j in np.nonzero(ones[i])[0])))
        else:
            gen_rows.append(i)
    spec["zero"] = tuple(zero_rows)
    spec["adds"] = tuple(add_rows)
    spec["gen"] = tuple(gen_rows)
    if gen_rows:
        spec["coef_idx"] = bake(c[:, gen_rows, :])
    return spec


# ---------------------------------------------------------------------------
# THE generic executor: any ScheduleIR whose rounds are mesh permutations
# ---------------------------------------------------------------------------


def ir_encode_jit(
    mesh,
    axes,
    ir: ScheduleIR,
    *,
    q: int = M31,
    tracer=None,
    topo=None,
    metrics=None,
    kernels: str | None = None,
):
    """Jitted mesh executor of any :class:`ScheduleIR`: device ``k`` (the
    flattened index over ``axes``, outermost first — exactly how ``P(axes)``
    shards the packet dimension) runs processor ``k``'s program.

    Every port group of every round is one ``ppermute`` over the composite
    ``axes`` (tuple axis names flatten row-major, matching the sharding);
    receive coefficients and LocalOp contractions are baked per-device Shoup
    constants sharded on their leading K dimension. ``mode="store"`` groups
    must cover every device (a partial permutation would zero-fill the rest);
    ``mode="add"`` groups may be partial — non-receivers add ppermute's
    zeros, a no-op.

    Inputs/outputs are in DEVICE order; for an IR with a non-identity
    ``placement`` (e.g. after ``topo.passes.remap_digits``) the caller
    permutes host-side: device ``placement[k]`` holds logical packet k.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) opts into per-round
    telemetry: instead of ONE fused jit over all rounds, each CommRound
    (and each LocalOp) becomes its own jitted dispatch bracketed by
    ``block_until_ready`` timestamps, producing exactly one span per
    CommRound carrying its metadata — round index, transfer count, slots on
    the wire, the α-β model's predicted µs on ``topo`` (default: the
    paper's flat network), and the busiest-link calibration features
    (level/msgs/elems) that ``repro.obs.feed`` refits α/β from. Measured
    round times also land in the ``metrics`` registry (default: the
    process-local ``repro.obs.metrics`` one) as ``encode.rounds``,
    ``encode.ppermutes``, ``encode.bytes_on_wire`` and
    ``encode.round_us{level=}``. With ``tracer=None`` (the default) the
    fused path — and its jaxpr, ppermute budget, and HLO discipline — is
    exactly as before; tracing changes dispatch granularity, never the
    computed function. An ``overlap=True`` LocalOp (emitted by
    ``topo.passes.pipeline_rounds``) is merged into the FOLLOWING comm
    round's dispatch, so its contraction is issued concurrently with the
    ppermute — the traced ``round[r]`` span carries ``overlap`` attrs.

    ``kernels`` selects the LocalOp lowering: ``"pallas"`` routes general
    rows through ``gf_matmul``/``butterfly_mac`` (``interpret=`` on non-TPU
    backends), ``"fused"`` uses ONE batched Shoup contraction per op,
    ``"jnp"`` keeps the legacy per-coefficient loop, and ``None`` picks
    ``"pallas"`` on TPU / ``"fused"`` elsewhere. All three are bit-exact
    (differential suite: tests/test_fused_encode.py).
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    kernels = _resolve_kernels(kernels)
    pallas_interp = jax.default_backend() != "tpu"
    K = 1
    for ax in axes:
        K *= int(mesh.shape[ax])
    if K != ir.K:
        raise ValueError(f"mesh axes {axes!r} give {K} devices, IR has {ir.K}")

    consts: list[np.ndarray] = []  # all (K, ...) — sharded on dim 0

    def bake(arr):
        arr = np.asarray(arr, dtype=np.uint32)
        consts.append(arr)
        consts.append(shoup_precompute(arr, q))
        return len(consts) - 2

    # ("comm", [(pairs, src_slots, dst_slots, mode, coef_idx)], round_no)
    # | ("local", out_slots, in_slots, coef_idx)
    ops = []
    round_no = -1
    for step in ir.steps:
        if isinstance(step, CommRound):
            round_no += 1
            groups = []
            for g in round_port_groups(step):
                if g.mode == "store" and len(g.pairs) != K:
                    raise ValueError(
                        "store-mode port group must cover every device "
                        f"(got {len(g.pairs)} of {K})"
                    )
                coef_idx = None
                if g.coeffs_by_dst is not None:
                    coef = np.ones((K, len(g.slots)), dtype=np.uint32)
                    for dst, cs in g.coeffs_by_dst.items():
                        if cs is not None:
                            coef[dst] = cs
                    coef_idx = bake(coef)
                groups.append(
                    (
                        g.pairs,
                        tuple(ss for ss, _ in g.slots),
                        tuple(ds for _, ds in g.slots),
                        g.mode,
                        coef_idx,
                    )
                )
            if groups:
                ops.append(("comm", groups, round_no))
        elif isinstance(step, LocalOp):
            if step.coeffs is None:
                raise ValueError(
                    "structure-only IR (LocalOp.coeffs=None) cannot execute — "
                    "recompile with the generator matrix"
                )
            ops.append(
                ("local", step.out_slots, step.in_slots,
                 _lower_local(step, bake, kernels))
            )
        else:  # pragma: no cover
            raise TypeError(f"unknown IR step {type(step).__name__}")

    def apply_op(op, buf, cs):
        """One IR step on a slot→array buffer dict (inside shard_map)."""
        first = next(iter(buf.values()))
        npay = first.ndim - 1
        zero = jnp.zeros_like(first)
        if op[0] == "comm":
            updates = []
            for pairs, src_slots, dst_slots, mode, coef_idx in op[1]:
                payload = jnp.stack(
                    [buf.get(s, zero) for s in src_slots], axis=1
                )  # (1, n_slots, *pay)
                recv = jax.lax.ppermute(payload, axes, pairs)
                if coef_idx is not None:
                    recv = shoup_mul(
                        recv,
                        _bcast(cs[coef_idx], npay),
                        _bcast(cs[coef_idx + 1], npay),
                        q,
                    )
                for i, ds in enumerate(dst_slots):
                    updates.append((ds, recv[:, i], mode))
            for ds, v, mode in updates:  # sends all read pre-round state
                buf[ds] = v if mode == "store" else (
                    madd(buf[ds], v, q) if ds in buf else v
                )
            return buf
        _, out_slots, in_slots, spec = op
        xs = [buf.get(s, zero) for s in in_slots]  # all reads pre-op
        new = dict(buf) if spec["update"] else {}
        if spec["dense"]:  # legacy "jnp" loop — the flagged fallback path
            c, csh = cs[spec["coef_idx"]], cs[spec["coef_idx"] + 1]
            for i, os_ in enumerate(out_slots):
                acc = None
                for j in range(len(in_slots)):
                    term = shoup_mul(
                        xs[j],
                        _bcast(c[:, i, j], npay),
                        _bcast(csh[:, i, j], npay),
                        q,
                    )
                    acc = term if acc is None else madd(acc, term, q)
                new[os_] = acc
            return new
        for i in spec["zero"]:
            new[out_slots[i]] = zero
        for i, js in spec["adds"]:
            acc = zero
            for j in js:
                acc = xs[j] if acc is zero else madd(acc, xs[j], q)
            new[out_slots[i]] = acc
        if spec["gen"]:
            c, csh = cs[spec["coef_idx"]], cs[spec["coef_idx"] + 1]
            stacked = jnp.stack(xs, axis=1)  # (1, n_in, *pay)
            if kernels == "pallas":
                from repro.kernels.butterfly.ops import butterfly_mac
                from repro.kernels.gf_matmul.ops import gf_matmul

                flat = stacked[0].reshape(len(in_slots), -1)  # (n_in, P)
                if len(spec["gen"]) == 1:
                    out = butterfly_mac(
                        flat[:, None, :], c[0], csh[0], q=q,
                        interpret=pallas_interp,
                    )  # (1, P)
                else:
                    out = gf_matmul(c[0], flat, q=q, interpret=pallas_interp)
                for r, i in enumerate(spec["gen"]):
                    new[out_slots[i]] = out[r].reshape(first.shape)
            else:  # "fused": madd-fold of row-batched Shoup multiplies —
                # each term is (1, n_gen, *pay) and folds immediately, so
                # XLA fuses the chain in one pass instead of materializing
                # the full (n_gen, n_in, *pay) product
                acc = None
                for j in range(len(in_slots)):
                    term = shoup_mul(
                        xs[j][:, None],
                        _bcast(c[:, :, j], npay),
                        _bcast(csh[:, :, j], npay),
                        q,
                    )
                    acc = term if acc is None else madd(acc, term, q)
                for r, i in enumerate(spec["gen"]):
                    new[out_slots[i]] = acc[:, r]
        return new

    cs_dev = [jnp.asarray(a) for a in consts]

    if tracer is None:
        def body(x, cs):
            buf = {INPUT_SLOT: x}
            for op in ops:
                buf = apply_op(op, buf, cs)
            return buf[ir.out_slot]

        mapped = _smap(
            body, mesh, in_specs=(P(axes), P(axes)), out_specs=P(axes)
        )
        return jax.jit(lambda x: mapped(x, cs_dev))
    return _traced_runner(
        mesh, axes, ir, ops, apply_op, cs_dev, tracer, topo, metrics
    )


def _traced_runner(mesh, axes, ir, ops, apply_op, cs_dev, tracer, topo, metrics):
    """The opt-in per-round dispatch path of :func:`ir_encode_jit`: one
    jitted shard_map per IR step, each bracketed by ``block_until_ready``
    timestamps inside a tracer span. Slot liveness is tracked statically so
    every step's buffer is a fixed tuple of (K, *payload) arrays; semantics
    match the fused body exactly (missing slots read as 0 in both paths)."""
    from repro.core.ir import ir_permute_count as _pc
    from repro.obs.metrics import get_registry
    from repro.topo.calibrate import round_features
    from repro.topo.model import FullyConnected, schedule_time

    if topo is None:
        topo = FullyConnected(ir.K)
    reg = metrics if metrics is not None else get_registry()

    # An overlap-tagged LocalOp (pipeline_rounds' P_r) merges into the NEXT
    # comm round's dispatch: one jitted step issues the contraction and the
    # ppermute together, so XLA can run them concurrently — the traced
    # round[r] span then covers (and shows) the overlap.
    grouped = []
    i = 0
    while i < len(ops):
        op = ops[i]
        if (
            op[0] == "local"
            and op[3]["overlap"]
            and i + 1 < len(ops)
            and ops[i + 1][0] == "comm"
        ):
            grouped.append((op, ops[i + 1]))
            i += 2
        else:
            grouped.append((op,))
            i += 1

    # static liveness: which slots hold data before each dispatch group
    specs = []  # (kind, in_slots, out_slots, group)
    live: tuple = (INPUT_SLOT,)
    for grp in grouped:
        cur = set(live)
        for op in grp:
            if op[0] == "comm":
                cur |= {ds for g in op[1] for ds in g[2]}
            elif op[3]["update"]:
                cur |= set(op[1])
            else:
                cur = set(op[1])
        outs = tuple(sorted(cur))
        kind = "comm" if any(op[0] == "comm" for op in grp) else "local"
        specs.append((kind, live, outs, grp))
        live = outs

    def make_step(grp, ins, outs):
        def step(bufs, cs):
            buf = dict(zip(ins, bufs))
            for op in grp:
                buf = apply_op(op, buf, cs)
            zero = jnp.zeros_like(bufs[0])
            return tuple(buf.get(s, zero) for s in outs)

        return jax.jit(
            _smap(step, mesh, in_specs=(P(axes), P(axes)), out_specs=P(axes))
        )

    step_fns = [make_step(grp, ins, outs) for _, ins, outs, grp in specs]

    # per-comm-group metadata: the round's message map and its derived stats
    comm_meta = {}
    for idx, (kind, _, _, grp) in enumerate(specs):
        if kind != "comm":
            continue
        op = next(o for o in grp if o[0] == "comm")
        msgs: dict = {}
        wire_slots = 0
        n_transfers = 0
        max_slots = 0
        for pairs, src_slots, _, _, _ in op[1]:
            n_transfers += len(pairs)
            wire_slots += len(pairs) * len(src_slots)
            max_slots = max(max_slots, len(src_slots))
            for s, d in pairs:
                msgs[(s, d)] = msgs.get((s, d), 0) + len(src_slots)
        feats = round_features([msgs], topo)
        overlap_op = next((o for o in grp if o[0] == "local"), None)
        comm_meta[idx] = {
            "round": op[2],
            "msgs_map": msgs,
            "transfers": n_transfers,
            "ppermutes": len(op[1]),
            "slots": max_slots,
            "wire_slots": wire_slots,
            "feature": feats[0] if feats else None,
            "overlap_out_slots": len(overlap_op[1]) if overlap_op else 0,
        }
    n_rounds = len(comm_meta)
    total_ppermutes = _pc(ir)

    def run(x):
        x = jnp.asarray(x)
        payload_elems = 1
        for d in x.shape[1:]:
            payload_elems *= int(d)
        with tracer.span(
            "ir_encode",
            algorithm=ir.algorithm,
            K=ir.K,
            p=ir.p,
            rounds=n_rounds,
            ppermutes=total_ppermutes,
            payload_elems=payload_elems,
        ):
            bufs = (x,)
            jax.block_until_ready(bufs)
            for idx, (kind, ins, outs, grp) in enumerate(specs):
                fn = step_fns[idx]
                if kind == "comm":
                    meta = comm_meta[idx]
                    pred_us = (
                        schedule_time(
                            topo, [meta["msgs_map"]], payload_elems
                        ).total
                        * 1e6
                    )
                    feat = meta["feature"]
                    attrs = {
                        "algorithm": ir.algorithm,
                        "comm_round": meta["round"],
                        "transfers": meta["transfers"],
                        "ppermutes": meta["ppermutes"],
                        "slots": meta["slots"],
                        "wire_slots": meta["wire_slots"],
                        "payload_elems": payload_elems,
                        "predicted_us": pred_us,
                    }
                    if meta["overlap_out_slots"]:
                        attrs["overlap"] = True
                        attrs["overlap_out_slots"] = meta["overlap_out_slots"]
                    if feat is not None:
                        attrs.update(
                            level=feat["level"],
                            msgs=feat["msgs"],
                            elems=feat["elems"],
                        )
                    with tracer.span(f"round[{meta['round']}]", **attrs) as sp:
                        bufs = fn(bufs, cs_dev)
                        jax.block_until_ready(bufs)
                    reg.counter("encode.rounds").inc()
                    reg.counter("encode.ppermutes").inc(meta["ppermutes"])
                    reg.counter("encode.bytes_on_wire").inc(
                        meta["wire_slots"] * payload_elems * 4
                    )
                    if feat is not None:
                        reg.histogram(
                            "encode.round_us", level=feat["level"]
                        ).observe(sp.dur_us)
                    else:
                        reg.histogram("encode.round_us").observe(sp.dur_us)
                else:
                    with tracer.span(f"local[{idx}]", kind="local"):
                        bufs = fn(bufs, cs_dev)
                        jax.block_until_ready(bufs)
            out_by_slot = dict(zip(outs, bufs)) if specs else {INPUT_SLOT: x}
            return out_by_slot.get(ir.out_slot, jnp.zeros_like(x))

    return run


# ---------------------------------------------------------------------------
# universal prepare-and-shoot (§IV)
# ---------------------------------------------------------------------------


def shoot_round_slots(plan: PrepareShootPlan, t: int, rho: int):
    """(dst_slots, src_slots) for shoot round ``t`` (1-based), port ``rho``:
    receiver slot ``l`` (digit_t = 0, lower digits 0) absorbs sender slot
    ``l + rho·(p+1)^{t-1}``. Mirrors prepare_shoot.shoot_rounds exactly; the
    collective ships ONLY these slots (the paper's digit-t message slices).
    """
    return digit_reduction_slots(plan.n, plan.p, t, rho)


def expected_permute_count(plan: PrepareShootPlan) -> int:
    """Number of ppermute ops ps_encode_jit emits: p per prepare round plus
    one per non-empty (round, port) shoot slice — the plan/collective
    agreement contract checked in tests/test_dist_unit.py. (The IR path
    emits exactly this in the regular m ≤ K regime and never more.)"""
    count = plan.Tp * plan.p
    for t in range(1, plan.Ts + 1):
        for rho in range(1, plan.p + 1):
            dst, _ = shoot_round_slots(plan, t, rho)
            if dst.size:
                count += 1
    return count


def _apply_pipeline(ir: ScheduleIR, pipeline: str, payload_elems: int = 1 << 16):
    """Apply a named ``topo.passes`` pipeline at dispatch time (e.g.
    ``pipeline="pipeline"`` for the software-pipelined rounds picked by the
    autotuner / a launch profile). Priced against a flat fabric at a
    representative payload; comm rounds are never touched, so the entry
    point's ppermute budget check still binds the rewritten IR."""
    if not pipeline:
        return ir
    from repro.topo.model import FullyConnected
    from repro.topo.passes import PIPELINES

    return PIPELINES[pipeline].apply(ir, FullyConnected(ir.K), payload_elems)


def _check_budget(ir: ScheduleIR, budget: int):
    n = ir_permute_count(ir)
    if n > budget:
        raise AssertionError(
            f"{ir.algorithm} IR needs {n} ppermutes, committed budget is {budget}"
        )


def ps_encode_jit(
    mesh,
    axis: str,
    A: np.ndarray,
    *,
    p: int = 1,
    q: int = M31,
    kernels: str | None = None,
    pipeline: str = "",
):
    """Jitted mesh executor of the universal encode: ``out = x @ A`` over
    GF(q) for ANY K×K matrix A, K = mesh.shape[axis].

    Returns ``(fn, plan)``; ``fn`` maps a ``(K, *payload)`` uint32 array
    (sharded or shardable over ``axis``) to the encoded array of the same
    shape. A is a host array: the IR's coefficients and their Shoup duals
    are baked in as per-device compile-time constants.
    """
    K = int(mesh.shape[axis])
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(f"A must be ({K}, {K}) to match mesh axis {axis!r}, got {A.shape}")
    plan = plan_prepare_shoot(K, p)
    ir = _apply_pipeline(plan.to_ir(A, q=q), pipeline)
    _check_budget(ir, expected_permute_count(plan))
    return ir_encode_jit(mesh, axis, ir, q=q, kernels=kernels), plan


def allgather_encode_jit(mesh, axis: str, A: np.ndarray, *, q: int = M31):
    """Baseline mesh encode: all-gather every packet, then each device
    contracts locally with its own column of A — C1 = O(log K) but
    C2 = Θ(K/p). Kept as the benchmark/cost-model foil for ps_encode_jit
    (deliberately NOT routed through ir_encode_jit: its point is the
    all-gather the IR path never emits)."""
    K = int(mesh.shape[axis])
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(f"A must be ({K}, {K}), got {A.shape}")
    # device k needs column A[:, k]: ship as a (K, K) array sharded on dim 0
    cols = np.ascontiguousarray(A.T).astype(np.uint32)  # cols[k, j] = A[j, k]
    cols_shoup = shoup_precompute(cols, q)

    def body(x, c, cs):
        # x: (1, *payload); c/cs: (1, K)
        npay = x.ndim - 1
        xs = jax.lax.all_gather(x, axis, axis=0, tiled=True)  # (K, *payload)
        acc = None
        for j in range(K):
            term = shoup_mul(xs[j], _bcast(c[0, j], npay), _bcast(cs[0, j], npay), q)
            acc = term if acc is None else madd(acc, term, q)
        return acc[None]

    mapped = _smap(body, mesh, in_specs=(P(axis), P(axis), P(axis)), out_specs=P(axis))
    c_dev = jnp.asarray(cols)
    cs_dev = jnp.asarray(cols_shoup)
    return jax.jit(lambda x: mapped(x, c_dev, cs_dev))


# ---------------------------------------------------------------------------
# two-level hierarchical encode on a 2D mesh
# ---------------------------------------------------------------------------


def expected_hier_permute_count(plan) -> int:
    """ppermute budget of hierarchical_encode_jit: one per non-empty intra
    gather port plus one per inter (round, port) with live slots — the
    plan/collective agreement contract (mirrors expected_permute_count)."""
    from repro.topo.hierarchical import hier_shoot_message_size

    count = sum(len(ports) for ports in plan.intra_rounds)
    for t in range(1, len(plan.inter_shifts) + 1):
        for rho in range(1, plan.p + 1):
            if hier_shoot_message_size(plan, t, rho):
                count += 1
    return count


def hierarchical_encode_jit(
    mesh,
    inter_axis: str,
    intra_axis: str,
    A: np.ndarray,
    *,
    p: int = 1,
    q: int = M31,
    kernels: str | None = None,
    pipeline: str = "",
):
    """Jitted two-level mesh executor of the universal encode: ``out = x @ A``
    over GF(q) for ANY K×K matrix A, K = mesh.shape[inter_axis] ×
    mesh.shape[intra_axis]; device (g, i) holds packet k = g·I + i.

    Three phases (repro.topo.hierarchical — the topology-aligned schedule):
    (p+1)-ary doubling all-gather over the fast ``intra_axis``, a local Shoup
    contraction against baked per-device coefficients, then the §IV
    digit-reduction shoot over the slow ``inter_axis``. Every port group is
    one ppermute, so intra traffic never crosses the slow domain. Bit-exact
    vs. the single-level ``ps_encode_jit`` / ``encode_oracle`` (modular sums
    reassociate exactly).

    The two-level schedule is exactly the depth-2 case of the recursive one
    (``plan_multilevel(K, p, (I, G))`` lowers to the same rounds — asserted
    in tests), so ``HierarchicalPlan.to_ir`` compiles through the multilevel
    IR builder and this dispatch shares :func:`ir_encode_jit` with
    everything else.

    Returns ``(fn, plan)`` with plan a :class:`HierarchicalPlan`.
    """
    from repro.topo.hierarchical import plan_hierarchical

    G = int(mesh.shape[inter_axis])
    I = int(mesh.shape[intra_axis])
    K = G * I
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(
            f"A must be ({K}, {K}) to match mesh axes "
            f"({inter_axis!r}×{intra_axis!r}), got {A.shape}"
        )
    plan = plan_hierarchical(K, p, k_intra=I)
    ir = _apply_pipeline(plan.to_ir(A, q=q), pipeline)
    _check_budget(ir, expected_hier_permute_count(plan))
    return (
        ir_encode_jit(mesh, (inter_axis, intra_axis), ir, q=q, kernels=kernels),
        plan,
    )


# ---------------------------------------------------------------------------
# recursive multi-level encode on an N-D mesh
# ---------------------------------------------------------------------------


def expected_multilevel_permute_count(plan) -> int:
    """ppermute budget of multilevel_encode_jit: one per non-empty intra
    gather port plus one per (level, round, port) with live slots — the
    plan/collective agreement contract (mirrors expected_hier_permute_count)."""
    from repro.topo.hierarchical import multilevel_message_size

    count = sum(len(ports) for ports in plan.intra_rounds)
    for j in range(1, len(plan.levels)):
        for t in range(1, len(plan.level_shifts[j - 1]) + 1):
            for rho in range(1, plan.p + 1):
                if multilevel_message_size(plan, j, t, rho):
                    count += 1
    return count


def multilevel_encode_jit(
    mesh,
    axes,
    A: np.ndarray,
    *,
    p: int = 1,
    q: int = M31,
    kernels: str | None = None,
    pipeline: str = "",
):
    """Jitted N-level mesh executor of the universal encode: ``out = x @ A``
    over GF(q) for ANY K×K matrix A, K = Π mesh.shape[ax] over ``axes``.

    ``axes`` is ordered outermost (slowest links, e.g. ``"pod"``) →
    innermost (fastest, e.g. ``"chip"``), matching how ``P(tuple(axes))``
    shards the packet axis: the LAST mesh axis varies fastest, so device
    (c_{L−1}, …, c_1, c_0) holds packet k = c_0 + K_0·(c_1 + K_1·(…)).

    Phases (repro.topo.hierarchical — the recursive topology-aligned
    schedule): (p+1)-ary doubling all-gather over the innermost axis, a
    local Shoup contraction against baked per-device coefficients, then one
    §IV digit-reduction shoot per outer axis, innermost first — every round
    permutes exactly ONE level's coordinate, so traffic never rides a slower
    level than its phase. Bit-exact vs. ``ps_encode_jit`` / ``encode_oracle``
    (modular sums reassociate exactly). With two axes this is exactly
    ``hierarchical_encode_jit``'s schedule; both are
    ``ir_encode_jit(mesh, axes, plan.to_ir(A))`` dispatches.

    Returns ``(fn, plan)`` with plan a :class:`MultiLevelPlan`.
    """
    from repro.topo.hierarchical import plan_multilevel

    axes = tuple(axes)
    sizes = [int(mesh.shape[ax]) for ax in axes]
    K = 1
    for s in sizes:
        K *= s
    levels = tuple(reversed(sizes))  # innermost (last mesh axis) first
    A = np.asarray(A)
    if A.shape != (K, K):
        raise ValueError(
            f"A must be ({K}, {K}) to match mesh axes {axes!r}, got {A.shape}"
        )
    plan = plan_multilevel(K, p, levels)
    ir = _apply_pipeline(plan.to_ir(A, q=q), pipeline)
    _check_budget(ir, expected_multilevel_permute_count(plan))
    return ir_encode_jit(mesh, axes, ir, q=q, kernels=kernels), plan


# ---------------------------------------------------------------------------
# radix-(p+1) DFT butterfly (§V-A)
# ---------------------------------------------------------------------------


def butterfly_jit(
    mesh,
    axis: str,
    *,
    p: int = 1,
    q: int = NTT,
    inverse: bool = False,
    kernels: str | None = None,
    pipeline: str = "",
):
    """Jitted mesh butterfly: forward computes ``x @ butterfly_target_matrix``
    (the digit-reversed K-point DFT), inverse undoes it exactly (Lemma 5).

    Returns ``(fn, plan)``. Round t exchanges within digit-t groups via p
    radix-1 ppermutes (one per port group of the butterfly IR) and combines
    with the plan's (inverse) twiddles — C1 = C2 = H rounds/elements,
    mirroring core/draw_loose.butterfly_apply.
    """
    K = int(mesh.shape[axis])
    plan = plan_butterfly(K, p, q)
    ir = _apply_pipeline(plan.to_ir(inverse=inverse), pipeline)
    _check_budget(ir, plan.H * p)
    return ir_encode_jit(mesh, axis, ir, q=q, kernels=kernels), plan
