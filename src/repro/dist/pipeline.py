"""GPipe-style pipeline parallelism over a mesh axis.

``stack_stage_params`` stacks the S per-stage param pytrees on a new leading
axis; sharding that axis over the pipeline mesh axis gives every device its
own stage's weights. ``pipeline_apply`` then runs the classic synchronous
GPipe schedule: N microbatches flow through S stages in N + S - 1 ticks,
with a single uniform ``ppermute`` (shift by +1 on the pipeline axis) moving
activations between neighbors each tick — the same TPU-native uniform-shift
communication discipline as the encode collectives (DESIGN §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist._compat import shard_map as _shard_map

__all__ = ["stack_stage_params", "pipeline_apply"]


def stack_stage_params(stage_params: list):
    """[params_0, .., params_{S-1}] → one pytree with a leading stage axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves, axis=0), *stage_params)


def pipeline_apply(stage_fn, stacked_params, x, *, mesh, axis: str):
    """Apply S = mesh.shape[axis] stages in sequence to every microbatch.

    ``stage_fn(params, mb)`` is one stage; ``stacked_params`` has leading
    dim S (see :func:`stack_stage_params`); ``x`` is ``(N, *mb_shape)`` —
    N microbatches. Returns ``(N, *mb_shape)`` with
    ``out[i] = stage_{S-1}(... stage_0(x[i]))``.

    Schedule: tick t ∈ [0, N+S-1): device d applies its stage to microbatch
    t - d (when in range), then shifts its activation to device d+1. Device
    S-1's results are psum-broadcast back so the output is replicated.
    """
    S = int(mesh.shape[axis])
    N = x.shape[0]

    def body(params, xx):
        params = jax.tree.map(lambda a: a[0], params)  # (1, ...) → stage params
        d = jax.lax.axis_index(axis)
        state = jnp.zeros(xx.shape[1:], xx.dtype)
        outs = jnp.zeros_like(xx)
        shift = [(i, (i + 1) % S) for i in range(S)]
        for t in range(N + S - 1):
            # stage 0 ingests microbatch t; others consume the neighbor's
            # activation (garbage during fill/drain never reaches `outs`)
            inp = jnp.where(d == 0, xx[t % N], state)
            y = stage_fn(params, inp.astype(xx.dtype))
            mb = t - (S - 1)
            if mb >= 0:
                outs = outs.at[mb].set(jnp.where(d == S - 1, y, outs[mb]))
            state = jax.lax.ppermute(y, axis, shift)
        # replicate the last stage's outputs to every device
        return jax.lax.psum(jnp.where(d == S - 1, outs, jnp.zeros_like(outs)), axis)

    mapped = _shard_map(body, mesh, in_specs=(P(axis), P()), out_specs=P())
    return mapped(stacked_params, x)
