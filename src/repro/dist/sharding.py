"""Logical-axis sharding rules and the divisibility-aware logical→physical
mapper (DESIGN §6).

Model/train/launch code annotates arrays with *logical* dim names
(``("batch", "seq", "d_model")``); :class:`ShardingRules` maps each logical
name to an ordered tuple of *mesh axis* names, and :func:`spec_for` lowers a
dims-tuple to a :class:`~jax.sharding.PartitionSpec` against a concrete mesh:

* mesh axes a rule names but the mesh doesn't have (e.g. ``pod`` on a
  single-pod mesh) are silently dropped — the same rules run on a laptop
  mesh and the 512-chip production mesh;
* a mesh axis is used at most once per spec (PartitionSpec invariant);
* when the array shape is known, an axis is only applied if the dim size is
  divisible by the axis size (GSPMD would otherwise pad or error) — a
  non-divisible dim degrades to replicated, never to a crash.

Rules are immutable; :meth:`ShardingRules.override` returns a derived rule
set, which is how per-shape presets (launch/rules.py) and optimization
profiles (launch/profiles.py) compose. Boolean *flags* (``attn_heads``,
``moe_gather``, ``logits_vocab``) ride along the rules object so the model
code can branch on profile levers without a second plumbing channel.
"""

from __future__ import annotations

from typing import Iterable, Mapping, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["ShardingRules", "spec_for", "named_sharding", "constrain", "DEFAULT_RULES"]


# Default logical→mesh-axis mapping: FSDP-flavored presets over the
# production axes ("pod", "data", "model"). Per-shape presets override
# ``seq``/``d_model``/``kv_seq`` (launch/rules.py); profiles override the
# MoE and batch entries (launch/profiles.py). Unknown names → replicated.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),
    "frames": (),
    # params: feature dims → model (tensor parallel), d_model FSDP'd only
    # when the per-shape preset asks for it
    "d_model": (),
    "d_ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    # MoE: expert weights FSDP over data on their d_model-like dim
    "experts": (),
    "expert_d": ("data",),
    "moe_ff": ("model",),
    # SSM / conv / encoder internals stay replicated by default
    "state": (),
    "conv": (),
    "enc_out": (),
}


def _normalize(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


class ShardingRules:
    """Immutable logical-dim → mesh-axes mapping plus profile flags."""

    __slots__ = ("_map", "_flags")

    def __init__(
        self,
        mapping: Mapping[str, Sequence[str]] | None = None,
        flags: Iterable[str] = (),
    ):
        base = dict(DEFAULT_RULES)
        if mapping:
            base.update({k: _normalize(v) for k, v in mapping.items()})
        object.__setattr__(self, "_map", base)
        object.__setattr__(self, "_flags", frozenset(flags))

    # -- derivation --------------------------------------------------------
    def override(self, **axes) -> "ShardingRules":
        """New rules with the given logical dims remapped.

        Values are mesh-axis tuples; a bare string means a 1-tuple and
        ``()``/``None`` means replicated.
        """
        new = dict(self._map)
        new.update({k: _normalize(v) for k, v in axes.items()})
        return ShardingRules(new, self._flags)

    def with_flags(self, flags: Iterable[str]) -> "ShardingRules":
        return ShardingRules(self._map, self._flags | set(flags))

    # -- queries -----------------------------------------------------------
    def axes_for(self, name: str) -> tuple[str, ...]:
        return self._map.get(name, ())

    def has(self, flag: str) -> bool:
        return flag in self._flags

    @property
    def flags(self) -> frozenset[str]:
        return self._flags

    def __eq__(self, other):
        return (
            isinstance(other, ShardingRules)
            and self._map == other._map
            and self._flags == other._flags
        )

    def __hash__(self):
        return hash((tuple(sorted(self._map.items())), self._flags))

    def __repr__(self):
        non_default = {
            k: v for k, v in self._map.items() if DEFAULT_RULES.get(k, ()) != v
        }
        return f"ShardingRules({non_default}, flags={sorted(self._flags)})"


def spec_for(mesh, rules: ShardingRules | None, dims, shape=None) -> PartitionSpec:
    """Lower a logical dims-tuple to a PartitionSpec on ``mesh``.

    ``dims`` entries are logical names or ``None`` (explicitly replicated).
    ``shape`` (optional) enables the divisibility check: a mesh axis is
    applied to dim ``i`` only if ``shape[i]`` is divisible by the product of
    the axis sizes applied so far times this axis's size. Only ``mesh.shape``
    and ``mesh.axis_names`` are consulted, so any mesh-like object works.
    """
    if rules is None:
        rules = ShardingRules()
    mesh_sizes = dict(mesh.shape)
    used: set[str] = set()
    entries = []
    for i, name in enumerate(dims):
        if name is None:
            entries.append(None)
            continue
        chosen: list[str] = []
        prod = 1
        cap = None if shape is None else int(shape[i])
        for ax in rules.axes_for(name):
            if ax not in mesh_sizes or ax in used:
                continue
            size = int(mesh_sizes[ax])
            if cap is not None and cap % (prod * size) != 0:
                continue
            chosen.append(ax)
            used.add(ax)
            prod *= size
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return PartitionSpec(*entries)


def named_sharding(mesh, rules: ShardingRules | None, dims, shape=None) -> NamedSharding:
    """NamedSharding for a logical dims-tuple (see :func:`spec_for`)."""
    return NamedSharding(mesh, spec_for(mesh, rules, dims, shape))


def constrain(x, mesh, rules: ShardingRules | None, dims):
    """with_sharding_constraint against the logical dims of ``x``.

    The array's own shape drives the divisibility check, so a constraint
    never makes a program un-lowerable — worst case it replicates.
    """
    return jax.lax.with_sharding_constraint(
        x, named_sharding(mesh, rules, dims, x.shape)
    )
