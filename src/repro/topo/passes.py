"""Topology-aware ScheduleIR rewrite passes.

:func:`remap_digits` is the torus-native butterfly from the ROADMAP: the
radix-(p+1) butterfly's digit-t partners sit at stride (p+1)^t, so on a 2D
torus the plain schedule pays multi-hop routes and link contention.
``topo/lower.py`` only *prices* that contention; this pass actually
reshuffles the schedule — it chooses a digit→mesh-dimension assignment and a
per-dimension cyclic Gray relabeling so that every round's partner exchange
runs between torus neighbors, then relabels the whole IR with
:func:`repro.core.ir.relabel` (the ``placement`` metadata keeps logical
inputs/outputs in place).

Why Gray codes: a ring of size radix² admits a cyclic radix-ary Gray
labeling in which incrementing EITHER digit moves to a ring neighbor (for
radix 2 this is the classic reflected Gray code on the 4-cycle: bit-0 flips
use edges {0-1, 2-3}, bit-1 flips use {1-2, 3-0}). Rings of size radix are
trivially neighbor-complete for radix ≤ 3. Hence for p = 1 every 2D torus
whose dimensions are 2 or 4 (e.g. 2×4 for K = 8, 4×4 for K = 16) gets a
hop-count-1 embedding for EVERY round — asserted in tests/test_ir.py. For
larger dimensions no dilation-1 embedding exists (a d-cube has d·2^{d-1}
edges, a 2^d-ring only 2^d), so the pass picks the assignment minimizing
total hops and lets the α-β price decide whether it wins.
"""

from __future__ import annotations

from itertools import combinations

import numpy as np

from repro.core.ir import ScheduleIR, relabel

from .model import Torus2D


def _gray_positions(n_digits: int, radix: int) -> np.ndarray:
    """pos_of_label for a ring of radix**n_digits positions: label ℓ (radix-
    ary digits) → ring position, cyclic-Gray for radix 2, identity otherwise
    (identity is neighbor-complete for a single digit when radix ≤ 3)."""
    size = radix**n_digits
    if radix == 2:
        pos_of_label = np.empty(size, dtype=np.int64)
        for pos in range(size):
            pos_of_label[pos ^ (pos >> 1)] = pos  # BRGC: label(pos) = pos ^ pos>>1
        return pos_of_label
    return np.arange(size, dtype=np.int64)


def _digit_values(K: int, radix: int, digits) -> np.ndarray:
    """(K,) integer formed by the given digit positions of each k (given
    order: first listed digit is least significant)."""
    k = np.arange(K, dtype=np.int64)
    out = np.zeros(K, dtype=np.int64)
    mult = 1
    for t in digits:
        out += ((k // radix**t) % radix) * mult
        mult *= radix
    return out


def _embedding(K: int, radix: int, col_digits, row_digits, cols: int) -> np.ndarray:
    """π: logical butterfly index → torus device r·cols + c, Gray-relabeled
    per dimension."""
    col_pos = _gray_positions(len(col_digits), radix)[
        _digit_values(K, radix, col_digits)
    ]
    row_pos = _gray_positions(len(row_digits), radix)[
        _digit_values(K, radix, row_digits)
    ]
    return row_pos * cols + col_pos


def _total_hops(ir: ScheduleIR, topo: Torus2D, perm: np.ndarray) -> int:
    total = 0
    for r in ir.rounds():
        for t in r.transfers:
            total += topo.hops(int(perm[t.src]), int(perm[t.dst]))
    return total


def remap_digits(ir: ScheduleIR, topo: Torus2D) -> ScheduleIR:
    """Rewrite a radix-(p+1) butterfly IR for a 2D torus: assign each digit
    to a torus dimension (enumerating assignments, minimizing total hops)
    and Gray-relabel each dimension's ring so digit increments land on
    neighbors. Returns the relabeled IR (``placement`` set); exactness is
    :func:`relabel`'s — the schedule is the same program on renamed
    processors."""
    if not isinstance(topo, Torus2D):
        raise TypeError("remap_digits targets Torus2D topologies")
    K, radix = ir.K, ir.p + 1
    if topo.n != K:
        raise ValueError(f"topology has {topo.n} processors, IR has {K}")

    def log_radix(n):
        h = 0
        while radix**h < n:
            h += 1
        return h if radix**h == n else None

    a = log_radix(topo.rows)
    b = log_radix(topo.cols)
    if a is None or b is None:
        raise ValueError(
            f"torus dims ({topo.rows}, {topo.cols}) are not powers of radix {radix}"
        )
    H = a + b
    if radix**H != K:
        raise ValueError(f"K={K} is not radix^(rows·cols digits)")
    best = None
    digit_sets = (
        combinations(range(H), b) if H <= 12 else [tuple(range(b))]
    )
    for col_digits in digit_sets:
        row_digits = tuple(t for t in range(H) if t not in col_digits)
        perm = _embedding(K, radix, col_digits, row_digits, topo.cols)
        hops = _total_hops(ir, topo, perm)
        if best is None or hops < best[0]:
            best = (hops, perm)
    return relabel(ir, best[1])


def max_round_hops(ir: ScheduleIR, topo) -> int:
    """Worst route length (links) of any transfer in any round — the
    hop-count-1 acceptance check for :func:`remap_digits`."""
    return max(
        (topo.hops(t.src, t.dst) for r in ir.rounds() for t in r.transfers),
        default=0,
    )
