"""Topology-aware ScheduleIR rewrite passes + the :class:`PassPipeline`
optimizer.

PR 4 unified every encode algorithm on one ScheduleIR; this module turns the
rewrite layer into a real optimizer. A :class:`Pass` is a named
``(ScheduleIR, Topology) -> ScheduleIR`` rewrite with an applicability
predicate; a :class:`PassPipeline` is a named composition of passes. The
autotuner (``topo.autotune``) enumerates every applicable pipeline per
compiled IR, prices the rewritten IR with the α-β estimator (fitted α/β when
calibration exists — see ``topo.calibrate.load_fitted_costs``), and records
the winning (algorithm, pipeline) pair.

Every pass is **exact**: the rewritten IR computes the same encode function,
proven against the oracle interpreter for every registered pipeline × every
algorithm family in ``tests/test_ir.py``. Exactness comes from construction:

* :func:`remap_digits` / :func:`align_subgroups` only relabel the machine
  (:func:`repro.core.ir.relabel` composes ``placement`` so logical
  inputs/outputs stay put);
* :func:`split_contended` only splits rounds proven hazard-free
  (:func:`repro.core.ir.round_hazard_free`) along port-group boundaries —
  every send still reads the value it read before, and the executor's
  ppermute count is preserved;
* :func:`fuse_rounds` only merges adjacent rounds when
  :func:`repro.core.ir.merge_comm_rounds` proves no read-after-write hazard,
  no duplicate (src, dst) pair, and the p-port budget holds.

Price-guarded passes (split, fuse, align) return the input IR unchanged when
no rewrite strictly improves the α-β price — under the default ``gamma = 0``
link model splitting can never win (max is subadditive), so
``split_contended`` only fires on fabrics whose :class:`~repro.topo.model.LinkCost`
carries a contention-degradation ``gamma > 0``.

:func:`remap_digits` is the torus-native butterfly from the ROADMAP: the
radix-(p+1) butterfly's digit-t partners sit at stride (p+1)^t, so on a torus
the plain schedule pays multi-hop routes and link contention. The pass picks
a digit→mesh-dimension assignment and a per-dimension cyclic Gray relabeling
so partner exchanges run between torus neighbors. Why Gray codes: a ring of
size radix² admits a cyclic radix-ary Gray labeling in which incrementing
EITHER digit moves to a ring neighbor (for radix 2 the classic reflected
Gray code on the 4-cycle). Rings of size radix are trivially
neighbor-complete for radix ≤ 3. Hence for p = 1 every torus whose
dimensions are 2 or 4 (e.g. 2×4 for K = 8, 4×4 for K = 16, 2×2×2 for K = 8
on a 3D torus) gets a hop-count-1 embedding for EVERY round — asserted in
tests/test_ir.py. For larger dimensions no dilation-1 embedding exists (a
d-cube has d·2^{d-1} edges, a 2^d-ring only 2^d), so the pass minimizes
total hops and lets the α-β price decide whether it wins. When the torus
dims are powers of 2 but not powers of the radix (and the radix itself is a
power of 2), the pass re-expresses the radix-(p+1) digits as binary digits
first — a radix-4 butterfly then embeds on a binary torus at ≤ 2 hops per
partner instead of not at all.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from itertools import combinations
from math import comb
from typing import Callable

import numpy as np

from repro.core.ir import (
    INPUT_SLOT,
    CommRound,
    LocalOp,
    ScheduleIR,
    ir_messages,
    merge_comm_rounds,
    relabel,
    round_hazard_free,
)

from .model import (
    MAC_SECONDS,
    Hierarchy,
    Topology,
    Torus2D,
    Torus3D,
    TwoLevel,
    local_op_unit_work,
    schedule_time,
)


def ir_compute_time(ir: ScheduleIR, topo: Topology, payload_elems: int = 1) -> float:
    """Seconds of local arithmetic on the IR's critical path, with the
    overlap credit: an ``overlap=True`` LocalOp runs concurrently with the
    NEXT comm round, so it only costs the part that does not hide under that
    round's wire time (``max(comm, work) − comm``). Comm time itself is NOT
    included — this is exactly the term :func:`ir_time` adds on top of
    :func:`~repro.topo.model.schedule_time`."""
    per_round = schedule_time(topo, ir_messages(ir), payload_elems).per_round
    total = 0.0
    pending = 0.0  # overlap-tagged work waiting for the next comm round
    ri = 0
    for step in ir.steps:
        if isinstance(step, CommRound):
            total += max(0.0, pending - per_round[ri])
            pending = 0.0
            ri += 1
            continue
        work = local_op_unit_work(step) * payload_elems * MAC_SECONDS
        if step.overlap:
            pending += work
        else:
            total += work
    return total + pending  # trailing overlap op has nothing to hide under


def ir_time(
    ir: ScheduleIR,
    topo: Topology,
    payload_elems: int = 1,
    *,
    include_compute: bool = True,
) -> float:
    """α-β + compute price of an IR on a topology (seconds) — the objective
    every price-guarded pass and the autotuner optimize. Comm is
    :func:`~repro.topo.model.schedule_time` over the message maps; local
    arithmetic adds :func:`ir_compute_time` (MAC-priced LocalOps, with
    ``overlap=True`` ops credited against the round they hide under)."""
    comm = schedule_time(topo, ir_messages(ir), payload_elems).total
    if not include_compute:
        return comm
    return comm + ir_compute_time(ir, topo, payload_elems)


# ---------------------------------------------------------------------------
# remap_digits: Gray-coded digit→dimension embedding for tori
# ---------------------------------------------------------------------------


def _gray_positions(n_digits: int, radix: int) -> np.ndarray:
    """pos_of_label for a ring of radix**n_digits positions: label ℓ (radix-
    ary digits) → ring position, cyclic-Gray for radix 2, identity otherwise
    (identity is neighbor-complete for a single digit when radix ≤ 3)."""
    size = radix**n_digits
    if radix == 2:
        pos_of_label = np.empty(size, dtype=np.int64)
        for pos in range(size):
            pos_of_label[pos ^ (pos >> 1)] = pos  # BRGC: label(pos) = pos ^ pos>>1
        return pos_of_label
    return np.arange(size, dtype=np.int64)


def _digit_values(K: int, radix: int, digits) -> np.ndarray:
    """(K,) integer formed by the given digit positions of each k (given
    order: first listed digit is least significant)."""
    k = np.arange(K, dtype=np.int64)
    out = np.zeros(K, dtype=np.int64)
    mult = 1
    for t in digits:
        out += ((k // radix**t) % radix) * mult
        mult *= radix
    return out


def _torus_dims(topo) -> tuple[int, ...]:
    """Torus dimension sizes, outermost → innermost, matching the device
    index k = Horner(dims): Torus2D k = r·cols + c, Torus3D k = (z·rows +
    r)·cols + c."""
    if isinstance(topo, Torus3D):
        return (topo.depth, topo.rows, topo.cols)
    if isinstance(topo, Torus2D):
        return (topo.rows, topo.cols)
    raise TypeError("remap_digits targets Torus2D / Torus3D topologies")


def _remap_radix(ir: ScheduleIR, topo) -> tuple[int, int] | None:
    """(radix, H) to run the digit embedding in, or None when the torus dims
    don't decompose. Prefers the butterfly's own radix p+1; falls back to
    binary digits when every dim is a power of 2 and so is the radix."""

    def log_b(n, b):
        h = 0
        while b**h < n:
            h += 1
        return h if b**h == n else None

    dims = _torus_dims(topo)
    for radix in dict.fromkeys([ir.p + 1, 2]):
        if radix < 2:
            continue
        if radix != ir.p + 1 and log_b(ir.p + 1, 2) is None:
            continue  # binary re-expression needs the radix to be a 2-power
        per_dim = [log_b(d, radix) for d in dims]
        if any(h is None for h in per_dim):
            continue
        H = sum(per_dim)
        if radix**H == ir.K:
            return radix, H
    return None


def _embedding(K: int, radix: int, assignment, dims) -> np.ndarray:
    """π: logical index → torus device, Gray-relabeled per dimension.
    ``assignment`` lists the digit positions owned by each dim (outermost →
    innermost, matching ``dims``)."""
    dev = np.zeros(K, dtype=np.int64)
    for digits, size in zip(assignment, dims):
        pos = _gray_positions(len(digits), radix)[_digit_values(K, radix, digits)]
        dev = dev * size + pos
    return dev


def _total_hops(ir: ScheduleIR, topo, perm: np.ndarray) -> int:
    total = 0
    for r in ir.rounds():
        for t in r.transfers:
            total += topo.hops(int(perm[t.src]), int(perm[t.dst]))
    return total


def _assignments(H: int, sizes):
    """All ways to partition digit positions 0..H−1 into per-dim groups of
    the given sizes (outermost dim first)."""

    def rec(remaining, sizes):
        if not sizes:
            yield ()
            return
        for chosen in combinations(remaining, sizes[0]):
            rest = tuple(x for x in remaining if x not in chosen)
            for tail in rec(rest, sizes[1:]):
                yield (chosen,) + tail

    yield from rec(tuple(range(H)), sizes)


def _assignment_count(H: int, sizes) -> int:
    out, rest = 1, H
    for s in sizes:
        out *= comb(rest, s)
        rest -= s
    return out


def remap_digits(ir: ScheduleIR, topo, exhaustive_limit: int = 4096) -> ScheduleIR:
    """Rewrite a digit-structured IR for a 2D/3D torus: assign each radix
    digit to a torus dimension (minimizing total hops) and Gray-relabel each
    dimension's ring so digit increments land on neighbors. Returns the
    relabeled IR (``placement`` set); exactness is :func:`relabel`'s — the
    same program on renamed processors. When the assignment space exceeds
    ``exhaustive_limit``, falls back to a greedy swap search from the
    contiguous assignment and warns that the search was bounded."""
    dims = _torus_dims(topo)
    K = ir.K
    if topo.n != K:
        raise ValueError(f"topology has {topo.n} processors, IR has {K}")
    picked = _remap_radix(ir, topo)
    if picked is None:
        raise ValueError(
            f"torus dims {dims} are not powers of radix {ir.p + 1} "
            "(nor uniformly binary)"
        )
    radix, H = picked

    def log_r(n):
        h = 0
        while radix**h < n:
            h += 1
        return h

    sizes = tuple(log_r(d) for d in dims)

    def hops_of(assignment):
        return _total_hops(ir, topo, _embedding(K, radix, assignment, dims))

    if _assignment_count(H, sizes) <= exhaustive_limit:
        best = min(_assignments(H, sizes), key=hops_of)
    else:
        # Greedy fallback: contiguous start (innermost dim owns the lowest
        # digits), then pairwise digit swaps across dims until no improvement.
        warnings.warn(
            f"remap_digits: {_assignment_count(H, sizes)} digit assignments "
            f"exceed exhaustive_limit={exhaustive_limit}; using greedy swap "
            "search — the embedding may be suboptimal",
            RuntimeWarning,
            stacklevel=2,
        )
        groups = []
        nxt = 0
        for s in reversed(sizes):  # innermost gets lowest digits
            groups.append(list(range(nxt, nxt + s)))
            nxt += s
        groups = list(reversed(groups))
        cur = hops_of(tuple(tuple(g) for g in groups))
        improved = True
        while improved:
            improved = False
            for i in range(len(groups)):
                for j in range(i + 1, len(groups)):
                    for a in range(len(groups[i])):
                        for b in range(len(groups[j])):
                            groups[i][a], groups[j][b] = groups[j][b], groups[i][a]
                            h = hops_of(tuple(tuple(g) for g in groups))
                            if h < cur:
                                cur = h
                                improved = True
                            else:
                                groups[i][a], groups[j][b] = (
                                    groups[j][b],
                                    groups[i][a],
                                )
        best = tuple(tuple(g) for g in groups)
    perm = _embedding(K, radix, best, dims)
    if np.array_equal(perm, np.arange(K)):
        return ir  # identity embedding — nothing to rewrite
    return relabel(ir, perm)


def max_round_hops(ir: ScheduleIR, topo) -> int:
    """Worst route length (links) of any transfer in any round — the
    hop-count-1 acceptance check for :func:`remap_digits`."""
    return max(
        (topo.hops(t.src, t.dst) for r in ir.rounds() for t in r.transfers),
        default=0,
    )


# ---------------------------------------------------------------------------
# split_contended: stagger a round's port groups when contention is priced
# ---------------------------------------------------------------------------


def _topo_gammas(topo: Topology) -> list[float]:
    costs = []
    for attr in ("cost", "intra", "inter"):
        c = getattr(topo, attr, None)
        if c is not None:
            costs.append(c.gamma)
    if isinstance(topo, Hierarchy):
        costs += [topo.level_cost(j).gamma for j in range(len(topo.levels))]
    return costs


def split_contended(
    ir: ScheduleIR, topo: Topology, payload_elems: int = 1
) -> ScheduleIR:
    """Break a contended round into staggered sub-rounds when the α-β price
    says the split wins. Splits ONLY along port-group boundaries (each group
    is one ppermute, so the executor's ppermute count is preserved) and ONLY
    rounds proven hazard-free, so every send still reads the value it read
    before — exact by construction. Per round, a dynamic program over
    contiguous group partitions picks the cheapest staggering; with the
    default ``gamma = 0`` link model the single-segment partition is always
    cheapest (max is subadditive) and the pass is a no-op."""
    steps = []
    changed = False
    for step in ir.steps:
        if not isinstance(step, CommRound):
            steps.append(step)
            continue
        order: list = []
        by_key: dict = {}
        for t in step.transfers:
            key = (t.port, t.slots, t.mode)
            if key not in by_key:
                by_key[key] = []
                order.append(key)
            by_key[key].append(t)
        parts = [tuple(by_key[k]) for k in order]
        g = len(parts)
        if g < 2 or not round_hazard_free(step):
            steps.append(step)
            continue

        def seg_cost(i, j):
            msgs = {(t.src, t.dst): t.elems for part in parts[i:j] for t in part}
            return schedule_time(topo, [msgs], payload_elems).total

        best = [0.0] * (g + 1)
        cut = [0] * (g + 1)
        for j in range(1, g + 1):
            best[j], cut[j] = min(
                (best[i] + seg_cost(i, j), i) for i in range(j)
            )
        whole = seg_cost(0, g)
        if best[g] >= whole * (1 - 1e-12):
            steps.append(step)
            continue
        bounds = []
        j = g
        while j > 0:
            bounds.append((cut[j], j))
            j = cut[j]
        for i, j in reversed(bounds):
            steps.append(
                CommRound(tuple(t for part in parts[i:j] for t in part))
            )
        changed = True
    if not changed:
        return ir
    from dataclasses import replace as _replace

    return _replace(ir, steps=tuple(steps))


# ---------------------------------------------------------------------------
# fuse_rounds: merge adjacent rounds within the p-port budget
# ---------------------------------------------------------------------------


def fuse_rounds(ir: ScheduleIR, topo: Topology, payload_elems: int = 1) -> ScheduleIR:
    """Merge adjacent CommRounds (no LocalOp between) when
    :func:`repro.core.ir.merge_comm_rounds` proves the merge legal (no RAW
    hazard, no duplicate pair, p-port budget holds) and the α-β price does
    not regress — cutting C1 by one α-charge per merge. Natural family IRs
    are mostly data-dependent round-to-round (each gather/reduction reads
    what the previous round delivered), so this pass chiefly re-packs the
    output of :func:`split_contended` and hand-built schedules."""
    out: list = []
    changed = False
    for step in ir.steps:
        if isinstance(step, CommRound) and out and isinstance(out[-1], CommRound):
            merged = merge_comm_rounds(out[-1], step, ir.p)
            if merged is not None:
                t_merged = schedule_time(
                    topo, ir_messages_of_rounds([merged]), payload_elems
                ).total
                t_split = schedule_time(
                    topo, ir_messages_of_rounds([out[-1], step]), payload_elems
                ).total
                if t_merged <= t_split * (1 + 1e-12):
                    out[-1] = merged
                    changed = True
                    continue
        out.append(step)
    if not changed:
        return ir
    from dataclasses import replace as _replace

    return _replace(ir, steps=tuple(out))


def ir_messages_of_rounds(rounds) -> list[dict]:
    """{(src, dst): elems} maps for bare CommRounds (no IR wrapper)."""
    return [{(t.src, t.dst): t.elems for t in r.transfers} for r in rounds]


# ---------------------------------------------------------------------------
# align_subgroups: level-aligned stride relabeling for hierarchies
# ---------------------------------------------------------------------------


def align_subgroups(
    ir: ScheduleIR, topo: Topology, payload_elems: int = 1
) -> ScheduleIR:
    """Relabel the machine by the stride↔block transpose that minimizes the
    α-β price on a hierarchical fabric. The draw-loose plan's heavy draw
    phase runs in stride-Z subgroups {j, j+Z, …} that a transpose
    π(j + Z·a) = j·M + a turns into CONTIGUOUS groups — i.e. intra-domain on
    a TwoLevel/Hierarchy — while the light loose butterflies move to the
    slow trunks. This is the ROADMAP's hierarchical draw-loose collapsed
    into a pipeline stage: same IR, level-aligned layout. The pass tries
    every divisor transpose of K (both directions arise as Z ↔ M) plus
    identity, prices each, and relabels only on strict improvement —
    exactness is :func:`relabel`'s."""
    K = ir.K
    base = ir_messages(ir)
    best_t = schedule_time(topo, base, payload_elems).total
    best_perm = None
    for Z in range(2, K):
        if K % Z:
            continue
        M = K // Z
        perm = np.empty(K, dtype=np.int64)
        for j in range(Z):
            for a in range(M):
                perm[j + Z * a] = j * M + a
        msgs = [
            {(int(perm[s]), int(perm[d])): e for (s, d), e in rnd.items()}
            for rnd in base
        ]
        t = schedule_time(topo, msgs, payload_elems).total
        if t < best_t * (1 - 1e-12):
            best_t, best_perm = t, perm
    if best_perm is None:
        return ir
    return relabel(ir, best_perm)


def _ir_slots(ir: ScheduleIR) -> set[int]:
    slots = {INPUT_SLOT, ir.out_slot}
    for step in ir.steps:
        if isinstance(step, CommRound):
            for t in step.transfers:
                for ss, ds in t.slots:
                    slots.add(ss)
                    slots.add(ds)
        else:
            slots.update(step.out_slots)
            slots.update(step.in_slots)
    return slots


def _observed_slots(steps, out_slot: int) -> set[int]:
    """Slots whose current value is observed by ``steps``: comm sources,
    add-mode destinations (the add reads what it lands on), LocalOp inputs,
    and the IR's final output slot."""
    obs = {out_slot}
    for st in steps:
        if isinstance(st, CommRound):
            for t in st.transfers:
                for ss, ds in t.slots:
                    obs.add(ss)
                    if t.mode == "add":
                        obs.add(ds)
        else:
            obs.update(st.in_slots)
    return obs


def _pipeline_split(L: LocalOp, comms, read_after, alloc, K: int):
    """Split one REPLACE-mode LocalOp followed by comm rounds ``comms`` into
    the software-pipelined form, or return None when there is nothing to
    defer. See :func:`pipeline_rounds` for the schedule produced."""
    R = len(comms)
    if R == 0 or not L.in_slots or not L.out_slots:
        return None
    reads = [{ss for t in c.transfers for ss, _ in t.slots} for c in comms]
    stores = [
        {ds for t in c.transfers if t.mode == "store" for _, ds in t.slots}
        for c in comms
    ]
    stage = {}
    for o in L.out_slots:
        s = R + 1
        for r in range(R):
            if o in reads[r]:
                s = r + 1
                break
        for r in range(s - 1):
            if o in stores[r]:  # clobbered before first read: don't defer
                s = 1
                break
        stage[o] = s
    if all(s == 1 for s in stage.values()):
        return None
    row_of = {o: i for i, o in enumerate(L.out_slots)}
    stage1 = tuple(o for o in L.out_slots if stage[o] == 1)
    deferred = tuple(o for o in L.out_slots if stage[o] > 1)
    sigma = {b: alloc() for b in L.in_slots}
    tau = {o: alloc() for o in deferred}
    n_in = len(L.in_slots)
    # A: shadow-copy every input to σ and zero what the original REPLACE
    # killed — deferred outputs (so in-flight adds land on zeros until the
    # combine) plus every later-observed slot outside the out set (REPLACE
    # semantics: those read as 0 after the original op). Coefficients are
    # known (identity block + zero rows) even on structure-only IRs, so the
    # α-β+compute model prices them as adds/free — not dense MACs.
    zeroed = tuple(
        dict.fromkeys(
            deferred
            + tuple(
                s
                for s in sorted(read_after)
                if s not in L.out_slots and s not in sigma.values()
            )
        )
    )
    n_a = n_in + len(zeroed)
    ca = np.zeros((K, n_a, n_in), dtype=np.uint64)
    for j in range(n_in):
        ca[:, j, j] = 1
    steps = [
        LocalOp(
            out_slots=tuple(sigma[b] for b in L.in_slots) + zeroed,
            in_slots=L.in_slots,
            coeffs=ca,
            update=True,
        )
    ]
    sig = tuple(sigma[b] for b in L.in_slots)
    if stage1:
        c1 = L.coeffs[:, [row_of[o] for o in stage1], :] if L.coeffs is not None else None
        steps.append(LocalOp(out_slots=stage1, in_slots=sig, coeffs=c1, update=True))
    for r in range(R):
        rows_r = tuple(o for o in deferred if stage[o] == r + 2)
        if rows_r:
            cp = (
                L.coeffs[:, [row_of[o] for o in rows_r], :]
                if L.coeffs is not None
                else None
            )
            steps.append(
                LocalOp(
                    out_slots=tuple(tau[o] for o in rows_r),
                    in_slots=sig,
                    coeffs=cp,
                    update=True,
                    overlap=True,
                )
            )
        steps.append(comms[r])
        if rows_r:
            fin = tuple(s for o in rows_r for s in (o, tau[o]))
            cf = np.zeros((K, len(rows_r), 2 * len(rows_r)), dtype=np.uint64)
            for i in range(len(rows_r)):
                cf[:, i, 2 * i] = 1
                cf[:, i, 2 * i + 1] = 1
            steps.append(
                LocalOp(out_slots=rows_r, in_slots=fin, coeffs=cf, update=True)
            )
    return steps


def pipeline_rounds(ir: ScheduleIR, topo: Topology, payload_elems: int = 1) -> ScheduleIR:
    """Software-pipeline a REPLACE-mode LocalOp across the comm rounds that
    follow it, so each round's ppermute overlaps the contraction producing
    the NEXT round's operands (the ROADMAP's comm/compute-overlap item).

    For a prologue contraction L whose output slot ``o`` is first read in
    comm round ``r`` (its *stage*), the heavy row for ``o`` need not run
    before round 1 — deferring it past earlier ADD-mode deliveries is exact
    because modular adds commute. The pass emits:

    * ``A`` (update): shadow-copy L's inputs to fresh σ slots (the double
      buffer) and zero the slots L's REPLACE would have killed, so in-flight
      adds land on zeros;
    * ``B`` (update): the stage-1 rows, computed from σ;
    * per round r: ``P_r`` (update, **overlap**) computing stage-(r+1) rows
      into fresh τ slots from σ — independent of round r, so the executor
      issues it concurrently with the ppermute — then the untouched comm
      round, then ``F_r`` (update) combining ``o ← o + τ(o)``.

    Comm rounds are emitted byte-identical, so the ppermute budget is
    preserved by construction. The pass is price-guarded against
    :func:`ir_time` (which credits ``overlap=True`` work against the round
    it hides under): the rewrite is kept only when strictly cheaper; the
    shadow copies and combines are uniform-0/1 rows the model prices as
    adds, while the deferred dense rows hide under the wire time."""
    from dataclasses import replace as _replace

    steps = list(ir.steps)
    counter = [max(_ir_slots(ir)) + 1]

    def alloc():
        v = counter[0]
        counter[0] += 1
        return v

    out_steps = []
    changed = False
    i = 0
    while i < len(steps):
        st = steps[i]
        if not (isinstance(st, LocalOp) and not st.update):
            out_steps.append(st)
            i += 1
            continue
        j = i + 1
        comms = []
        while j < len(steps) and isinstance(steps[j], CommRound):
            comms.append(steps[j])
            j += 1
        repl = _pipeline_split(
            st, comms, _observed_slots(steps[i + 1 :], ir.out_slot), alloc, ir.K
        )
        if repl is None:
            out_steps.append(st)
            i += 1
            continue
        out_steps.extend(repl)
        changed = True
        i = j
    if not changed:
        return ir
    cand = _replace(ir, steps=tuple(out_steps))
    if ir_time(cand, topo, payload_elems) < ir_time(ir, topo, payload_elems) * (
        1 - 1e-12
    ):
        return cand
    return ir


# ---------------------------------------------------------------------------
# Pass / PassPipeline registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Pass:
    """A named, exact ScheduleIR rewrite with an applicability predicate.
    ``fn(ir, topo, payload_elems)`` returns the rewritten IR (the SAME object
    when nothing changed); ``applies(ir, topo)`` is a cheap structural check
    the autotuner uses to skip hopeless candidates."""

    name: str
    fn: Callable[[ScheduleIR, Topology, int], ScheduleIR]
    applies: Callable[[ScheduleIR, Topology], bool]
    doc: str = ""

    def __call__(self, ir, topo, payload_elems: int = 1) -> ScheduleIR:
        return self.fn(ir, topo, payload_elems)


@dataclass(frozen=True)
class PassPipeline:
    """A named composition of passes, applied left to right. A pipeline is
    applicable when every member pass is; applying it to an applicable IR is
    exact because every member is."""

    name: str
    passes: tuple[Pass, ...]
    doc: str = ""

    def applicable(self, ir: ScheduleIR, topo: Topology) -> bool:
        return all(p.applies(ir, topo) for p in self.passes)

    def apply(self, ir: ScheduleIR, topo: Topology, payload_elems: int = 1):
        for p in self.passes:
            ir = p.fn(ir, topo, payload_elems)
        return ir


def _remap_applies(ir, topo) -> bool:
    return (
        isinstance(topo, (Torus2D, Torus3D))
        and topo.n == ir.K
        and _remap_radix(ir, topo) is not None
    )


def _split_applies(ir, topo) -> bool:
    if not any(g > 0 for g in _topo_gammas(topo)):
        return False  # additive model: splitting can never strictly win
    return any(
        len({(t.port, t.slots, t.mode) for t in r.transfers}) > 1
        and round_hazard_free(r)
        for r in ir.rounds()
    )


def _fuse_applies(ir, topo) -> bool:
    prev_comm = False
    for step in ir.steps:
        if isinstance(step, CommRound):
            if prev_comm:
                return True
            prev_comm = True
        else:
            prev_comm = False
    return False


def _align_applies(ir, topo) -> bool:
    # Scoped to the draw-loose family: its draw phase runs in stride-Z
    # subgroups that the transpose makes level-aligned (the ROADMAP's
    # hierarchical draw-loose). Other families are either already
    # level-aligned (hierarchical/multilevel compile FROM the hierarchy) or
    # have no subgroup structure a transpose could exploit.
    return (
        isinstance(topo, (TwoLevel, Hierarchy))
        and topo.n == ir.K
        and ir.K > 3
        and "draw-loose" in ir.algorithm
    )


def _pipeline_rounds_applies(ir, topo) -> bool:
    # A REPLACE-mode LocalOp directly followed by a comm round that does NOT
    # read all its outputs — i.e. at least one row is deferrable.
    steps = ir.steps
    for i, st in enumerate(steps[:-1]):
        if not (
            isinstance(st, LocalOp) and not st.update and st.in_slots and st.out_slots
        ):
            continue
        nxt = steps[i + 1]
        if not isinstance(nxt, CommRound):
            continue
        first_reads = {ss for t in nxt.transfers for ss, _ in t.slots}
        if any(o not in first_reads for o in st.out_slots):
            return True
    return False


PASSES: dict[str, Pass] = {
    p.name: p
    for p in [
        Pass(
            "remap-digits",
            lambda ir, topo, pe=1: remap_digits(ir, topo),
            _remap_applies,
            doc="Gray-coded digit→torus-dimension relabeling (2D/3D, radix→2 fallback)",
        ),
        Pass(
            "split-contended",
            split_contended,
            _split_applies,
            doc="stagger a hazard-free round's port groups when γ-priced contention loses",
        ),
        Pass(
            "fuse-rounds",
            fuse_rounds,
            _fuse_applies,
            doc="merge adjacent hazard-free rounds within the p-port budget (cuts C1)",
        ),
        Pass(
            "align-subgroups",
            align_subgroups,
            _align_applies,
            doc="stride↔block transpose putting heavy subgroups on fast intra links",
        ),
        Pass(
            "pipeline-rounds",
            pipeline_rounds,
            _pipeline_rounds_applies,
            doc="double-buffer a prologue contraction so each ppermute overlaps "
            "the contraction feeding the next round",
        ),
    ]
}

PIPELINES: dict[str, PassPipeline] = {
    pl.name: pl
    for pl in [
        PassPipeline("remap-digits", (PASSES["remap-digits"],)),
        PassPipeline("split-contended", (PASSES["split-contended"],)),
        PassPipeline("fuse-rounds", (PASSES["fuse-rounds"],)),
        PassPipeline("align-subgroups", (PASSES["align-subgroups"],)),
        PassPipeline(
            "split+fuse",
            (PASSES["split-contended"], PASSES["fuse-rounds"]),
            doc="stagger contended rounds, then re-pack what still fits",
        ),
        PassPipeline(
            "pipeline",
            (PASSES["pipeline-rounds"],),
            doc="software-pipelined rounds: comm overlaps the next round's contraction",
        ),
    ]
}


def pipelines_for(ir: ScheduleIR, topo: Topology) -> list[PassPipeline]:
    """Every registered pipeline whose passes all apply to (ir, topo) — the
    candidate set the autotuner prices."""
    return [pl for pl in PIPELINES.values() if pl.applicable(ir, topo)]
