"""Lower round schedules onto topologies: message maps, hops, contention.

Every algorithm's compile-time plan is expanded into the explicit per-round
message maps ``{(src, dst): elements}`` — the SAME shape the cost-exact
simulator records in ``SimStats.round_messages``, so each lowering is
cross-checkable message-for-message against the exact simulation (see
tests/test_topo.py). A :class:`LoweredSchedule` then prices itself on any
:class:`~repro.topo.model.Topology` via the α-β estimator: per-round hop
counts, per-link contention, and estimated wall time.

The lowerings mirror the simulators exactly, including the small-K edge
cases (self-sends skipped, duplicate destinations deduplicated, dead slots
never shipped) — an analytically recomputed schedule that disagrees with the
simulation by even one message is a bug, not an approximation.

Paper-notation glossary: ``K`` processors, ``p`` ports per round, ``C1`` =
round count, ``C2`` = Σ over rounds of the largest per-port message (field
elements); ``I``/``G`` the two-level k_intra × k_inter split; *digit-
reduction slots* — the §IV shoot's buffer layout, one slot per (p+1)-ary
numeral of the remaining target offset, round t zeroing digit t (see
``core.schedule.digit_reduction_slots``).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.bounds import ceil_log
from repro.core.schedule import (
    ButterflyPlan,
    DrawLoosePlan,
    PrepareShootPlan,
    butterfly_group_perms,
    shoot_round_message_size,
)

from .hierarchical import (
    HierarchicalPlan,
    MultiLevelPlan,
    RingPlan,
    TwoLevelDFTPlan,
    gather_rounds,
    hier_shoot_message_size,
    multilevel_dev_shift,
    multilevel_message_size,
    ring_rounds,
)
from .model import TimeEstimate, Topology, round_link_loads, schedule_time


@dataclass(frozen=True)
class LoweredSchedule:
    """An algorithm's communication pattern, ready to price on a topology."""

    algorithm: str
    K: int
    p: int
    rounds: tuple  # per round: {(src, dst): elements}

    @property
    def c1(self) -> int:
        return len(self.rounds)

    @property
    def c2(self) -> int:
        return sum(max(r.values()) for r in self.rounds if r)

    def link_loads(self, topo: Topology) -> list[dict]:
        """Per round: {link: (n_messages, elements)} — the contention map."""
        return [round_link_loads(topo, r) for r in self.rounds]

    def round_hops(self, topo: Topology) -> list[int]:
        """Per round: the longest route (in links) any message takes."""
        return [
            max((topo.hops(s, d) for (s, d) in r), default=0) for r in self.rounds
        ]

    def time(self, topo: Topology, payload_elems: int = 1) -> TimeEstimate:
        return schedule_time(topo, list(self.rounds), payload_elems)


# ---------------------------------------------------------------------------
# per-algorithm lowerings
# ---------------------------------------------------------------------------


def rounds_prepare_shoot(plan: PrepareShootPlan) -> list[dict]:
    """§IV prepare-and-shoot. Prepare round t forwards the whole storage
    (|distinct residues| elements — dict-keyed like the simulator, so
    collapsed shifts and self-sends vanish in the K ≤ m regime); shoot round
    t ships the live digit-t slices."""
    K, p = plan.K, plan.p
    rounds = []
    offsets = {0}  # residue offsets held — identical at every k by symmetry
    for shifts in plan.prepare_shifts:
        size = len(offsets)
        msgs = {}
        for k in range(K):
            for s in shifts:
                dst = (k + s) % K
                if dst != k:
                    msgs[(k, dst)] = size
        rounds.append(msgs)
        base = set(offsets)  # all sends use pre-round storage
        for s in shifts:
            if s % K:
                offsets |= {(o + s) % K for o in base}
    for t in range(1, plan.Ts + 1):
        msgs = {}
        for rho in range(1, p + 1):
            sz = shoot_round_message_size(plan, t, rho)
            if sz:
                s = plan.shoot_shifts[t - 1][rho - 1]
                for k in range(K):
                    msgs[(k, (k + s) % K)] = sz
        rounds.append(msgs)
    return rounds


def rounds_butterfly(plan: ButterflyPlan, inverse: bool = False) -> list[dict]:
    """§V-A butterfly: round t broadcasts 1 element to the p digit-t
    partners (the inverse runs the same pattern in reverse round order)."""
    K, radix = plan.K, plan.radix
    order = range(plan.H - 1, -1, -1) if inverse else range(plan.H)
    rounds = []
    for t in order:
        msgs = {}
        for dst_map in butterfly_group_perms(K, radix, t):
            for k in range(K):
                msgs[(k, int(dst_map[k]))] = 1
        rounds.append(msgs)
    return rounds


def rounds_draw_loose(plan: DrawLoosePlan) -> list[dict]:
    """§V-B: Z parallel M-point prepare-and-shoots over stride-Z subgroups
    (merged round-by-round — disjoint groups share rounds), then M parallel
    Z-point butterflies over contiguous groups."""
    Z, M = plan.Z, plan.M
    rounds = []
    if plan.draw_plan is not None:
        for sub_round in rounds_prepare_shoot(plan.draw_plan):
            msgs = {}
            for j in range(Z):
                for (src, dst), sz in sub_round.items():
                    msgs[(j + Z * src, j + Z * dst)] = sz
            rounds.append(msgs)
    if plan.loose_plan is not None:
        for sub_round in rounds_butterfly(plan.loose_plan):
            msgs = {}
            for i in range(M):
                for (src, dst), sz in sub_round.items():
                    msgs[(Z * i + src, Z * i + dst)] = sz
            rounds.append(msgs)
    return rounds


def rounds_allgather(K: int, p: int) -> list[dict]:
    """The optimal flat p-port all-gather baseline ((p+1)-ary doubling)."""
    rounds = []
    for ports in gather_rounds(K, p):
        msgs = {}
        for k in range(K):
            for s, cnt in ports:
                msgs[(k, (k + s) % K)] = cnt
        rounds.append(msgs)
    return rounds


def rounds_hierarchical(plan: HierarchicalPlan) -> list[dict]:
    """Two-level universal encode: intra doubling gather inside each group,
    then the inter digit-reduction shoot across groups (live slots only)."""
    K, p, I, G = plan.K, plan.p, plan.k_intra, plan.k_inter
    rounds = []
    for ports in plan.intra_rounds:
        msgs = {}
        for k in range(K):
            g, i = divmod(k, I)
            for s, cnt in ports:
                msgs[(k, g * I + (i + s) % I)] = cnt
        rounds.append(msgs)
    for t, shifts in enumerate(plan.inter_shifts, start=1):
        msgs = {}
        for rho, s in enumerate(shifts, start=1):
            sz = hier_shoot_message_size(plan, t, rho)
            if sz:
                for k in range(K):
                    g, i = divmod(k, I)
                    msgs[(k, ((g + s) % G) * I + i)] = sz
        rounds.append(msgs)
    return rounds


def rounds_multilevel(plan: MultiLevelPlan) -> list[dict]:
    """Recursive K = Π K_j encode: level-0 doubling gather, then one §IV
    digit-reduction shoot per outer level (innermost first), every message
    shifting exactly one level's coordinate (live slots only)."""
    K, K0 = plan.K, plan.levels[0]
    rounds = []
    for ports in plan.intra_rounds:
        msgs = {}
        for k in range(K):
            g, i = divmod(k, K0)
            for s, cnt in ports:
                msgs[(k, g * K0 + (i + s) % K0)] = cnt
        rounds.append(msgs)
    for j in range(1, len(plan.levels)):
        for t, shifts in enumerate(plan.level_shifts[j - 1], start=1):
            msgs = {}
            for rho, s in enumerate(shifts, start=1):
                sz = multilevel_message_size(plan, j, t, rho)
                if sz:
                    for k in range(K):
                        msgs[(k, multilevel_dev_shift(plan, k, j, s))] = sz
            rounds.append(msgs)
    return rounds


def rounds_two_level_dft(plan: TwoLevelDFTPlan) -> list[dict]:
    """Cooley–Tukey: intra butterfly within contiguous groups, then inter
    butterfly over stride-I columns (1 element per message throughout)."""
    I, G, radix = plan.k_intra, plan.k_inter, plan.p + 1
    rounds = []
    if I > 1:
        for t in range(ceil_log(I, radix)):
            msgs = {}
            for dst_map in butterfly_group_perms(I, radix, t):
                for g in range(G):
                    for i in range(I):
                        msgs[(g * I + i, g * I + int(dst_map[i]))] = 1
            rounds.append(msgs)
    if G > 1:
        for t in range(ceil_log(G, radix)):
            msgs = {}
            for dst_map in butterfly_group_perms(G, radix, t):
                for i in range(I):
                    for g in range(G):
                        msgs[(g * I + i, int(dst_map[g]) * I + i)] = 1
            rounds.append(msgs)
    return rounds


def lower(plan, inverse: bool = False) -> LoweredSchedule:
    """Lower any schedule plan to its explicit round message maps."""
    if isinstance(plan, PrepareShootPlan):
        return LoweredSchedule(
            "prepare-shoot", plan.K, plan.p, tuple(rounds_prepare_shoot(plan))
        )
    if isinstance(plan, ButterflyPlan):
        return LoweredSchedule(
            "butterfly", plan.K, plan.p, tuple(rounds_butterfly(plan, inverse))
        )
    if isinstance(plan, DrawLoosePlan):
        return LoweredSchedule(
            "draw-loose", plan.K, plan.p, tuple(rounds_draw_loose(plan))
        )
    if isinstance(plan, HierarchicalPlan):
        return LoweredSchedule(
            "hierarchical", plan.K, plan.p, tuple(rounds_hierarchical(plan))
        )
    if isinstance(plan, MultiLevelPlan):
        return LoweredSchedule(
            "multilevel", plan.K, plan.p, tuple(rounds_multilevel(plan))
        )
    if isinstance(plan, TwoLevelDFTPlan):
        return LoweredSchedule(
            "hierarchical-dft", plan.K, plan.p, tuple(rounds_two_level_dft(plan))
        )
    if isinstance(plan, RingPlan):
        return LoweredSchedule("ring", plan.K, plan.p, tuple(ring_rounds(plan)))
    raise TypeError(f"cannot lower {type(plan).__name__}")


def lower_allgather(K: int, p: int) -> LoweredSchedule:
    return LoweredSchedule("allgather", K, p, tuple(rounds_allgather(K, p)))
