"""Lower round schedules onto topologies: message maps, hops, contention.

ONE deriver: every plan compiles to :class:`~repro.core.ir.ScheduleIR`
(``plan.to_ir()``) and :func:`repro.core.ir.ir_messages` expands the IR into
the explicit per-round message maps ``{(src, dst): elements}`` — the SAME
shape the cost-exact interpreter records in ``SimStats.round_messages``, so
every lowering is cross-checkable message-for-message against the exact
simulation (see tests/test_topo.py and tests/test_ir.py). A
:class:`LoweredSchedule` then prices itself on any
:class:`~repro.topo.model.Topology` via the α-β estimator: per-round hop
counts, per-link contention, and estimated wall time.

The legacy per-family ``rounds_*`` helpers are thin wrappers over
``ir_messages(plan.to_ir())`` — the IR compilers mirror the simulators
exactly, including the small-K edge cases (self-sends skipped, duplicate
destinations deduplicated, dead slots never shipped): an analytically
recomputed schedule that disagrees with the simulation by even one message
is a bug, not an approximation.

Paper-notation glossary: ``K`` processors, ``p`` ports per round, ``C1`` =
round count, ``C2`` = Σ over rounds of the largest per-port message (field
elements); ``I``/``G`` the two-level k_intra × k_inter split; *digit-
reduction slots* — the §IV shoot's buffer layout, one slot per (p+1)-ary
numeral of the remaining target offset, round t zeroing digit t (see
``core.schedule.digit_reduction_slots``).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.ir import ScheduleIR, ir_allgather, ir_messages
from repro.core.schedule import ButterflyPlan, DrawLoosePlan, PrepareShootPlan

from .hierarchical import (
    HierarchicalPlan,
    MultiLevelPlan,
    RingPlan,
    TwoLevelDFTPlan,
    ring_rounds,  # noqa: F401  (compat re-export; itself IR-derived now)
)
from .model import TimeEstimate, Topology, round_link_loads, schedule_time


@dataclass(frozen=True)
class LoweredSchedule:
    """An algorithm's communication pattern, ready to price on a topology."""

    algorithm: str
    K: int
    p: int
    rounds: tuple  # per round: {(src, dst): elements}

    @property
    def c1(self) -> int:
        return len(self.rounds)

    @property
    def c2(self) -> int:
        return sum(max(r.values()) for r in self.rounds if r)

    def link_loads(self, topo: Topology) -> list[dict]:
        """Per round: {link: (n_messages, elements)} — the contention map."""
        return [round_link_loads(topo, r) for r in self.rounds]

    def round_hops(self, topo: Topology) -> list[int]:
        """Per round: the longest route (in links) any message takes."""
        return [
            max((topo.hops(s, d) for (s, d) in r), default=0) for r in self.rounds
        ]

    def time(self, topo: Topology, payload_elems: int = 1) -> TimeEstimate:
        return schedule_time(topo, list(self.rounds), payload_elems)


def lower_ir(ir: ScheduleIR) -> LoweredSchedule:
    """Any ScheduleIR → its priced message-map form (the ONE deriver)."""
    return LoweredSchedule(ir.algorithm, ir.K, ir.p, tuple(ir_messages(ir)))


def lower(plan, inverse: bool = False) -> LoweredSchedule:
    """Lower any schedule plan to its explicit round message maps by
    compiling it to ScheduleIR. Works for every plan with a ``to_ir`` —
    including new algorithms that never register a bespoke lowering."""
    if isinstance(plan, ButterflyPlan):
        return lower_ir(plan.to_ir(inverse=inverse))
    if not hasattr(plan, "to_ir"):
        raise TypeError(f"cannot lower {type(plan).__name__}")
    return lower_ir(plan.to_ir())


def lower_allgather(K: int, p: int) -> LoweredSchedule:
    return lower_ir(ir_allgather(K, p))


# ---------------------------------------------------------------------------
# per-algorithm compatibility wrappers (all route through the IR)
# ---------------------------------------------------------------------------


def rounds_prepare_shoot(plan: PrepareShootPlan) -> list[dict]:
    """§IV prepare-and-shoot (prepare forwards the whole residue buffer,
    shoot ships the live digit-t slices)."""
    return ir_messages(plan.to_ir())


def rounds_butterfly(plan: ButterflyPlan, inverse: bool = False) -> list[dict]:
    """§V-A butterfly: round t broadcasts 1 element to the p digit-t
    partners (the inverse runs the same pattern in reverse round order)."""
    return ir_messages(plan.to_ir(inverse=inverse))


def rounds_draw_loose(plan: DrawLoosePlan) -> list[dict]:
    """§V-B: Z parallel M-point prepare-and-shoots over stride-Z subgroups
    (merged round-by-round), then M parallel Z-point butterflies."""
    return ir_messages(plan.to_ir())


def rounds_allgather(K: int, p: int) -> list[dict]:
    """The optimal flat p-port all-gather baseline ((p+1)-ary doubling)."""
    return ir_messages(ir_allgather(K, p))


def rounds_hierarchical(plan: HierarchicalPlan) -> list[dict]:
    """Two-level universal encode: intra doubling gather inside each group,
    then the inter digit-reduction shoot across groups (live slots only)."""
    return ir_messages(plan.to_ir())


def rounds_multilevel(plan: MultiLevelPlan) -> list[dict]:
    """Recursive K = Π K_j encode: level-0 doubling gather, then one §IV
    digit-reduction shoot per outer level (innermost first)."""
    return ir_messages(plan.to_ir())


def rounds_two_level_dft(plan: TwoLevelDFTPlan) -> list[dict]:
    """Cooley–Tukey: intra butterfly within contiguous groups, then inter
    butterfly over stride-I columns (1 element per message throughout)."""
    return ir_messages(plan.to_ir())
