"""Declarative network topologies + α-β time estimation for round schedules.

The paper's cost model (§I) charges every round β and every element τ on a
flat synchronous p-port network where any processor can reach any other in
one hop. Real meshes are not flat: a TPU slice is a torus of fast ICI links,
a multi-slice job adds a slow DCI level on top (MaxText-style multi-pod), and
a ring only has neighbor links. This module describes those networks
declaratively and prices an arbitrary round schedule on them:

* a :class:`Topology` knows its directed links, the deterministic route
  (link sequence) between any two processors, and each link's α/β cost;
* :func:`schedule_time` maps a round schedule — ``list`` of rounds, each a
  ``{(src, dst): elements}`` message map, exactly the shape the cost-exact
  simulator records in ``SimStats.round_messages`` and ``topo.lower``
  produces analytically — onto the topology: every message occupies every
  link of its route, per-link time is serialized (#msgs·α + load·β), and a
  round lasts as long as its busiest link.

On :class:`FullyConnected` this collapses to the paper's model exactly:
``total = C1·α + C2·β·payload`` (each message has a private link).

Paper-notation glossary (used throughout ``repro.topo``):

* ``K``  — number of processors; each holds one packet ``x_k`` and must end
  with ``x̃_k = (x @ A)_k`` (paper §I).
* ``p``  — ports per processor: per round every processor sends ≤ p and
  receives ≤ p messages (the synchronous p-port model).
* ``C1`` — round count of a schedule; ``C2`` — Σ over rounds of the largest
  message (field elements per port) — the paper's two cost coordinates.
* ``α/β`` — per-link startup seconds / seconds per element (Hockney): the
  refinement this module adds on top of the paper's uniform round cost.
* ``I, G`` — the two-level factorization K = I·G (``k_intra`` × ``k_inter``);
  :class:`Hierarchy` generalizes to K = Π_j K_j with level 0 innermost
  (fastest links) and level L−1 outermost (slowest).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LinkCost:
    """Per-link α-β parameters: ``alpha`` seconds of per-message startup,
    ``beta`` seconds per field element crossing the link.

    ``gamma`` is an optional contention-degradation factor: when ``cnt``
    messages share the link in one round, the bandwidth term is inflated to
    ``elems·beta·(1 + gamma·(cnt − 1))`` — serialization overhead (packet
    interleaving, credit stalls) that grows with the number of concurrent
    flows. The default ``gamma = 0`` keeps the purely additive Hockney model,
    under which splitting a round can never strictly win (max is subadditive);
    a fabric with ``gamma > 0`` is what makes ``split_contended`` profitable."""

    alpha: float
    beta: float
    gamma: float = 0.0


# Defaults mirror core.bounds.CostModel: v5e ICI ≈ 1 µs startup, one uint32
# element over 50 GB/s; DCI (inter-slice) ≈ 10 µs startup, 5 GB/s.
ICI = LinkCost(alpha=1e-6, beta=4.0 / 50e9)
DCI = LinkCost(alpha=10e-6, beta=4.0 / 5e9)

# Local arithmetic: one modular multiply-accumulate (Shoup mul + add) per
# payload element, at ~50 Gelem/s VPU-class uint32 throughput. Coefficients
# that are uniformly 0 across processors cost nothing (the lowering drops the
# term) and uniformly-1 coefficients cost only an add (the lowering skips the
# multiply), priced at ADD_WEIGHT of a full MAC. Used by
# ``topo.passes.ir_time`` to price LocalOps and the overlap credit of
# ``pipeline_rounds``.
MAC_SECONDS = 2e-11
ADD_WEIGHT = 0.25


def local_op_unit_work(op) -> float:
    """MAC-equivalents *per payload element* of a ScheduleIR ``LocalOp``.

    With coefficients available this is exact w.r.t. the fused lowering's
    strength reduction: per (out, in) coefficient that is uniform across
    processors, 0 → free, 1 → ``ADD_WEIGHT``, anything else (or non-uniform)
    → one MAC. Structure-only ops (``coeffs=None``) are priced conservatively
    as a dense ``n_out × n_in`` contraction."""
    import numpy as np

    if op.coeffs is None:
        return float(len(op.out_slots) * len(op.in_slots))
    c = np.asarray(op.coeffs)
    ones = np.all(c == 1, axis=0)
    zeros = np.all(c == 0, axis=0)
    general = ~(ones | zeros)
    return float(general.sum()) + ADD_WEIGHT * float(ones.sum())


class Topology:
    """Base class: ``n`` processors, deterministic shortest-path routing.

    Subclasses define :meth:`route` (the ordered directed-link sequence a
    ``src → dst`` message traverses; each link is a hashable id) and
    :meth:`link_cost`.
    """

    n: int
    name: str = "topology"

    def route(self, src: int, dst: int) -> tuple:
        raise NotImplementedError

    def link_cost(self, link) -> LinkCost:
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))


def _ring_route(n: int, src: int, dst: int, tag):
    """Shorter-direction route on an n-ring; ties go forward. Links are
    ``(tag, u, v)`` with v = u±1 (mod n)."""
    fwd = (dst - src) % n
    links = []
    if fwd <= n - fwd:
        for h in range(fwd):
            u = (src + h) % n
            links.append((tag, u, (u + 1) % n))
    else:
        for h in range(n - fwd):
            u = (src - h) % n
            links.append((tag, u, (u - 1) % n))
    return tuple(links)


@dataclass(frozen=True)
class FullyConnected(Topology):
    """Today's implicit model: a private link per ordered pair — any uniform
    shift is one hop and messages never contend."""

    n: int
    cost: LinkCost = ICI
    name: str = "flat"

    def route(self, src, dst):
        if src == dst:
            return ()
        return (("flat", src, dst),)

    def link_cost(self, link):
        return self.cost


@dataclass(frozen=True)
class Ring(Topology):
    """Bidirectional ring: processor k links only to k±1. A shift-s message
    travels min(s, n−s) hops and contends with everything else crossing the
    same neighbor links."""

    n: int
    cost: LinkCost = ICI
    name: str = "ring"

    def route(self, src, dst):
        if src == dst:
            return ()
        return _ring_route(self.n, src, dst, "ring")

    def link_cost(self, link):
        return self.cost


@dataclass(frozen=True)
class Torus2D(Topology):
    """rows × cols torus with dimension-ordered (row-ring then col-ring)
    routing; processor k = r·cols + c."""

    rows: int
    cols: int
    cost: LinkCost = ICI
    name: str = "torus"

    @property
    def n(self):  # type: ignore[override]
        return self.rows * self.cols

    def route(self, src, dst):
        if src == dst:
            return ()
        sr, sc = divmod(src, self.cols)
        dr, dc = divmod(dst, self.cols)
        links = []
        # move along the row ring (vary column) at row sr, then the column ring
        for tag, u, v in _ring_route(self.cols, sc, dc, "x"):
            links.append(("x", sr, u, v))
        for tag, u, v in _ring_route(self.rows, sr, dr, "y"):
            links.append(("y", dc, u, v))
        return tuple(links)

    def link_cost(self, link):
        return self.cost


@dataclass(frozen=True)
class Torus3D(Topology):
    """depth × rows × cols torus (a TPU-style 3D mesh with wraparound) with
    dimension-ordered x → y → z routing; processor k = (z·rows + r)·cols + c.
    Links are per-ring, keyed by the fixed coordinates of the ring they sit
    on, so two messages moving along the same physical wire contend."""

    depth: int
    rows: int
    cols: int
    cost: LinkCost = ICI
    name: str = "torus3d"

    @property
    def n(self):  # type: ignore[override]
        return self.depth * self.rows * self.cols

    def coords(self, k: int) -> tuple[int, int, int]:
        zr, c = divmod(k, self.cols)
        z, r = divmod(zr, self.rows)
        return z, r, c

    def route(self, src, dst):
        if src == dst:
            return ()
        sz, sr, sc = self.coords(src)
        dz, dr, dc = self.coords(dst)
        links = []
        # x (column index) at fixed (z=sz, r=sr), then y at (z=sz, c=dc),
        # then z at (r=dr, c=dc) — dimension-ordered, deadlock-free
        for tag, u, v in _ring_route(self.cols, sc, dc, "x"):
            links.append(("x", sz, sr, u, v))
        for tag, u, v in _ring_route(self.rows, sr, dr, "y"):
            links.append(("y", sz, dc, u, v))
        for tag, u, v in _ring_route(self.depth, sz, dz, "z"):
            links.append(("z", dr, dc, u, v))
        return tuple(links)

    def link_cost(self, link):
        return self.cost


@dataclass(frozen=True)
class TwoLevel(Topology):
    """K = K_inter × K_intra two-level hierarchy (multi-slice model):
    processor k = g·K_intra + i sits in group g. Within a group every ordered
    pair has a private fast link (ICI); between groups g ≠ g' ALL traffic
    shares one slow trunk per ordered group pair (DCI) — the contention the
    hierarchical schedule is designed to avoid."""

    k_intra: int
    k_inter: int
    intra: LinkCost = ICI
    inter: LinkCost = DCI
    name: str = "two-level"

    @property
    def n(self):  # type: ignore[override]
        return self.k_intra * self.k_inter

    def group(self, k: int) -> int:
        return k // self.k_intra

    def route(self, src, dst):
        if src == dst:
            return ()
        gs, gd = self.group(src), self.group(dst)
        if gs == gd:
            return (("intra", src, dst),)
        return (("inter", gs, gd),)

    def link_cost(self, link):
        return self.intra if link[0] == "intra" else self.inter


def default_level_costs(
    n_levels: int, lo: LinkCost = ICI, hi: LinkCost = DCI
) -> tuple[LinkCost, ...]:
    """Per-level α/β defaults for an ``n_levels``-deep :class:`Hierarchy`:
    innermost = ``lo`` (ICI), outermost = ``hi`` (DCI), intermediate levels
    geometrically interpolated (so a 2-level hierarchy prices exactly like
    TwoLevel and a 3-level chip < slice < pod gets a √(lo·hi) slice tier)."""
    if n_levels <= 1:
        return (lo,) * max(n_levels, 1)
    costs = [lo]
    for j in range(1, n_levels - 1):
        f = j / (n_levels - 1)
        costs.append(
            LinkCost(
                alpha=lo.alpha * (hi.alpha / lo.alpha) ** f,
                beta=lo.beta * (hi.beta / lo.beta) ** f,
            )
        )
    costs.append(hi)
    return tuple(costs)


@dataclass(frozen=True)
class Hierarchy(Topology):
    """K = Π_j K_j recursive hierarchy (chip < slice < pod < …): processor
    k has mixed-radix coordinates (c_0, …, c_{L−1}) with level 0 least
    significant — ``k = c_0 + K_0·(c_1 + K_1·(c_2 + …))``. Level 0 siblings
    (same coordinates above level 0) have a private fast link per ordered
    pair; two processors whose highest differing coordinate is level j ≥ 1
    share ONE trunk per ordered pair of level-j domains under their common
    parent — the same contention model as :class:`TwoLevel`, applied
    recursively. ``Hierarchy(levels=(I, G))`` prices identically to
    ``TwoLevel(k_intra=I, k_inter=G)``.

    ``levels`` is innermost (fastest) → outermost (slowest); ``costs`` is the
    matching per-level α/β tuple (default: :func:`default_level_costs`)."""

    levels: tuple[int, ...]
    costs: tuple[LinkCost, ...] | None = None
    name: str = "hierarchy"

    def __post_init__(self):
        if not self.levels or any(k < 1 for k in self.levels):
            raise ValueError(f"levels must be positive, got {self.levels}")
        if self.costs is not None and len(self.costs) != len(self.levels):
            raise ValueError(
                f"need one LinkCost per level: {len(self.costs)} costs "
                f"for {len(self.levels)} levels"
            )

    @property
    def n(self):  # type: ignore[override]
        out = 1
        for k in self.levels:
            out *= k
        return out

    def coords(self, k: int) -> tuple[int, ...]:
        """Mixed-radix digits of processor k, level 0 first."""
        out = []
        for sz in self.levels:
            out.append(k % sz)
            k //= sz
        return tuple(out)

    def level_cost(self, j: int) -> LinkCost:
        costs = self.costs if self.costs is not None else default_level_costs(
            len(self.levels)
        )
        return costs[j]

    def route(self, src, dst):
        if src == dst:
            return ()
        cs, cd = self.coords(src), self.coords(dst)
        j = max(i for i in range(len(self.levels)) if cs[i] != cd[i])
        if j == 0:
            return (("lvl", 0, src, dst),)
        # one trunk per ordered (src-domain, dst-domain) pair of level-j
        # siblings under their common parent — ALL their traffic shares it
        parent = tuple(cs[j + 1 :])
        return (("lvl", j, parent, cs[j], cd[j]),)

    def link_cost(self, link):
        return self.level_cost(link[1])


# ---------------------------------------------------------------------------
# α-β estimator
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TimeEstimate:
    total: float  # seconds
    per_round: tuple[float, ...]
    max_contention: int  # max #messages sharing one link in any round
    max_link_elems: int  # max elements crossing one link in any round

    @property
    def rounds(self) -> int:
        return len(self.per_round)


def round_link_loads(topo: Topology, messages: dict) -> dict:
    """{link: (n_messages, elements)} for one round's message map."""
    loads: dict = {}
    for (src, dst), elems in messages.items():
        for link in topo.route(src, dst):
            cnt, tot = loads.get(link, (0, 0))
            loads[link] = (cnt + 1, tot + elems)
    return loads


def schedule_time(
    topo: Topology, rounds: list, payload_elems: int = 1
) -> TimeEstimate:
    """Price a round schedule on ``topo``. Each round: every link serializes
    its traffic (#msgs·α + elements·payload·β) and the round lasts as long as
    its busiest link; rounds are synchronous so totals add."""
    per_round = []
    max_cont = 0
    max_load = 0
    for messages in rounds:
        loads = round_link_loads(topo, messages)
        t = 0.0
        for link, (cnt, elems) in loads.items():
            c = topo.link_cost(link)
            bw = elems * payload_elems * c.beta * (1.0 + c.gamma * (cnt - 1))
            t = max(t, cnt * c.alpha + bw)
            max_cont = max(max_cont, cnt)
            max_load = max(max_load, elems)
        per_round.append(t)
    return TimeEstimate(
        total=sum(per_round),
        per_round=tuple(per_round),
        max_contention=max_cont,
        max_link_elems=max_load,
    )


def make_topology(
    name: str,
    K: int,
    *,
    k_intra: int | None = None,
    levels: tuple[int, ...] | None = None,
    intra: LinkCost = ICI,
    inter: LinkCost = DCI,
) -> Topology:
    """Factory for the CLI / autotuner: name ∈ {flat, ring, torus, torus3d,
    two-level, hierarchy}. ``hierarchy`` takes ``levels`` (innermost →
    outermost, Π levels = K; default: balanced three-level split of K);
    ``torus3d`` reuses ``levels`` as (cols, rows, depth) dims (default:
    balanced factorization)."""
    if name == "flat":
        return FullyConnected(K, cost=intra)
    if name == "ring":
        return Ring(K, cost=intra)
    if name == "torus":
        rows = k_intra or _near_square(K)
        if K % rows:
            raise ValueError(f"torus needs rows | K, got rows={rows}, K={K}")
        return Torus2D(rows, K // rows, cost=intra)
    if name == "torus3d":
        dims = tuple(levels) if levels else default_levels(K, 3)
        if len(dims) != 3:
            raise ValueError(f"torus3d needs 3 dims, got {dims}")
        cols, rows, depth = dims
        if cols * rows * depth != K:
            raise ValueError(f"torus3d needs Π dims = K: {dims} vs K={K}")
        return Torus3D(depth=depth, rows=rows, cols=cols, cost=intra)
    if name == "two-level":
        ki = k_intra or _near_square(K)
        if K % ki:
            raise ValueError(f"two-level needs k_intra | K, got {ki}, K={K}")
        return TwoLevel(k_intra=ki, k_inter=K // ki, intra=intra, inter=inter)
    if name == "hierarchy":
        lv = tuple(levels) if levels else default_levels(K)
        prod = 1
        for k in lv:
            prod *= k
        if prod != K:
            raise ValueError(f"hierarchy needs Π levels = K: {lv} vs K={K}")
        return Hierarchy(levels=lv, costs=default_level_costs(len(lv), intra, inter))
    raise ValueError(f"unknown topology {name!r}")


def default_levels(K: int, n_levels: int = 3) -> tuple[int, ...]:
    """Balanced ``n_levels``-way factorization of K, innermost largest
    (biggest domain on the fastest links): peel the most balanced divisor
    off the outside repeatedly. Unsplittable remainders collapse to trivial
    OUTERMOST levels (K prime → (K, 1, 1)), so level 0 is never trivial."""
    outer = []  # outermost-first factors peeled so far
    rest = K
    for j in range(n_levels - 1, 0, -1):
        if rest <= 1:
            break
        # outermost factor ≈ rest^(1/(j+1)); take the largest divisor ≤ that
        target = round(rest ** (1.0 / (j + 1)))
        d = 1
        for cand in range(2, rest + 1):
            if rest % cand == 0 and cand <= max(target, 2):
                d = cand
        if d == 1 or d == rest:  # no useful split left: keep rest innermost
            break
        outer.append(d)
        rest //= d
    out = [rest] + list(reversed(outer))  # innermost first
    out += [1] * (n_levels - len(out))
    return tuple(out)


def _near_square(K: int) -> int:
    """Largest divisor of K not exceeding √K."""
    best = 1
    d = 1
    while d * d <= K:
        if K % d == 0:
            best = d
        d += 1
    return best
