"""Two-level (hierarchical) all-to-all encode schedules.

K = K_inter × K_intra processors, k = g·K_intra + i: group ``g`` is the fast
domain (intra-slice ICI), crossing groups is slow (inter-slice DCI). The flat
prepare-and-shoot schedule shifts by ±m/(p+1)^t regardless of group
boundaries, so on a two-level network most of its messages pile onto the
inter-group trunks. The schedules here keep each phase inside one level:

* **hierarchical prepare-and-shoot** (universal, any matrix A):

  1. *intra gather* — (p+1)-ary doubling all-gather inside each group
     (⌈log_{p+1}K_intra⌉ rounds, fast links only);
  2. *local contraction* — device (g, i) forms partial sums
     ``z[l] = Σ_u x_{g, i-u} · A[(g, i-u), ((g+l)%G, i)]`` for every target
     group offset l (no communication);
  3. *inter shoot* — the paper's §IV digit-reduction over the group axis
     (⌈log_{p+1}K_inter⌉ rounds, one slow message per port per round).

  C1 = ⌈log I⌉ + ⌈log G⌉ (≤ ⌈log K⌉ + 1), C2 = Θ((I + G)/p) — the flat
  √K·2/p when I ≈ G ≈ √K, but with every gather element on fast links.

* **two-level DFT** (Cooley–Tukey): when A is the DFT matrix and
  K_intra, K_inter are powers of p+1 dividing q−1, the multiplicative
  structure β^{nk} = ω_I^{n1·k1} · β^{n2·k1} · ω_G^{n2·k2} splits the encode
  into an intra butterfly, a local twiddle, and an inter butterfly —
  C2 = log I + log G elements total, no intermediate inflation. Inputs and
  outputs are relabeled ("up to permutation", exactly as draw-and-loose):
  device (g, i) holds source coefficient G·rev_I(i) + rev_G(g) and finishes
  with X[i + I·g]; :func:`two_level_dft_matrix` is the effective generator.

* **ring schedule** (per the ring-networks line of work): on a ring the
  optimal universal strategy is neighbor-only traffic — a bidirectional
  store-and-forward all-gather (⌈(K−1)/2⌉ rounds of 1-element messages to
  k±1) followed by a local combine. No multi-hop messages, so zero link
  contention.

* **recursive multi-level encode** (universal, any matrix, any K = Π K_j):
  the generalization of the two-level schedule to an arbitrary hierarchy
  ``levels = (K_0, …, K_{L−1})`` (innermost/fastest first). Phases:

  1. *intra gather* over the level-0 domain (size K_0, fastest links);
  2. *local contraction* — device with coordinates (c, i) forms one partial
     sum per **per-level offset vector** l = (l_1, …, l_{L−1}), destined for
     the device at ((c_1+l_1) mod K_1, …, (c_{L−1}+l_{L−1}) mod K_{L−1}, i).
     Component-wise modular offsets (instead of the two-level (g+l) mod G)
     are what keep every later shift inside ONE level — no mixed-radix
     carries ever cross a level boundary;
  3. *per-level digit-reduction shoot*, innermost outer level first: level j
     runs ⌈log_{p+1}K_j⌉ §IV digit-reduction rounds over the l_j component,
     every message traveling on level-j links only. Reducing cheap levels
     first matters: the level-j messages still carry Π_{j″>j} K_{j″} live
     outer combinations, so the bulky reductions ride the fast links.

  C1 = ⌈log K_0⌉ + Σ_{j≥1} ⌈log K_j⌉; Σ_j (K_j−1)/p ≤ C2 with the level-j
  term scaled by the live outer combinations Π_{j″>j} K_{j″} — exactly the
  two-level formulas when L = 2, and ``plan_multilevel(K, p, (I, G))``
  lowers to the SAME rounds as ``plan_hierarchical(K, p, I)`` (trivial
  K_j = 1 levels contribute zero rounds, zero slots).

Everything is validated on the cost-exact :class:`SyncSimulator`: the
``simulate_*`` functions here run the schedules message-by-message under the
p-port constraints and return bit-exact outputs plus measured C1/C2 and
per-round message maps (which ``topo.lower`` cross-checks analytically).

Paper-notation glossary: ``K`` processors, ``p`` ports/round, ``C1`` rounds,
``C2`` max-elements-per-port summed over rounds; ``I = k_intra`` / ``G =
k_inter`` the two-level split; *digit-reduction slots* — the §IV shoot keeps
one buffer slot per (p+1)-ary numeral of the remaining target offset and
each round zeroes one digit by shipping the slots with digit_t = ρ to port
ρ's partner (see ``core.schedule.digit_reduction_slots``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.bounds import ceil_log
from repro.core.field import Field
from repro.core.matrices import digit_reversal_permutation
from repro.core.schedule import (
    butterfly_group_perms,
    digit_reduction_message_size,
    digit_reduction_slots,
    plan_butterfly,
)
from repro.core.simulator import SimStats, SyncSimulator


# ---------------------------------------------------------------------------
# (p+1)-ary doubling all-gather rounds (shared by the intra phase and the
# flat all-gather baseline lowering)
# ---------------------------------------------------------------------------


def gather_rounds(N: int, p: int) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Round schedule fully gathering N cyclic packets: each round every
    processor sends a prefix of its (contiguous-offset) buffer to p partners.

    Returns per round a tuple of ``(shift, count)`` ports: send buffer slots
    [0, count) to processor k+shift (mod N). After round r the buffer holds
    offsets [0, min((p+1)^r, N)) — ⌈log_{p+1}N⌉ rounds total, C2 = Σ max
    count ≈ (N−1)/p (the optimal p-port all-gather of bounds.py).
    """
    rounds = []
    b = 1
    while b < N:
        ports = []
        for rho in range(1, p + 1):
            cnt = min(b, N - rho * b)
            if cnt > 0:
                ports.append((rho * b, cnt))
        rounds.append(tuple(ports))
        b = min(b * (p + 1), N)
    return tuple(rounds)


# ---------------------------------------------------------------------------
# hierarchical prepare-and-shoot plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HierarchicalPlan:
    """Static schedule for the two-level universal encode (see module doc)."""

    K: int
    p: int
    k_intra: int  # I — fast-domain size
    k_inter: int  # G — slow-domain size
    intra_rounds: tuple  # gather_rounds(k_intra, p)
    inter_shifts: tuple[tuple[int, ...], ...]  # group-unit shifts per round
    n_inter: int  # (p+1)^Ts slot count, Ts = ⌈log_{p+1} G⌉

    @property
    def c1(self) -> int:
        return len(self.intra_rounds) + len(self.inter_shifts)

    @property
    def c2(self) -> int:
        c = sum(max((cnt for _, cnt in ports), default=0) for ports in self.intra_rounds)
        for t in range(1, len(self.inter_shifts) + 1):
            c += max(
                hier_shoot_message_size(self, t, rho) for rho in range(1, self.p + 1)
            )
        return c

    @property
    def algorithm(self) -> str:
        return "hierarchical"


def plan_hierarchical(K: int, p: int, k_intra: int) -> HierarchicalPlan:
    if k_intra < 1 or K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    G = K // k_intra
    Ts = ceil_log(G, p + 1)
    inter_shifts = tuple(
        tuple(rho * (p + 1) ** (t - 1) for rho in range(1, p + 1))
        for t in range(1, Ts + 1)
    )
    return HierarchicalPlan(
        K=K,
        p=p,
        k_intra=k_intra,
        k_inter=G,
        intra_rounds=gather_rounds(k_intra, p),
        inter_shifts=inter_shifts,
        n_inter=(p + 1) ** Ts,
    )


def hier_shoot_slots(n: int, p: int, t: int, rho: int):
    """(dst_slots, src_slots) for inter-shoot round ``t`` (1-based), port
    ``rho`` over ``n`` slots — delegates to the §IV digit-reduction."""
    return digit_reduction_slots(n, p, t, rho)


def hier_shoot_message_size(plan: HierarchicalPlan, t: int, rho: int) -> int:
    """Live elements shipped on port rho in inter round t: slots with
    digit_t = rho, lower digits 0, below the live count G (slots l ≥ G are
    identically zero — they are never worth sending)."""
    return digit_reduction_message_size(
        plan.n_inter, plan.k_inter, plan.p, t, rho
    )


def hierarchical_coeff_tensor(plan: HierarchicalPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[g·I + (i−u)%I, ((g+l)%G)·I + i] masked to live
    target-group offsets l < G; k = g·I + i. The local-contraction analogue
    of ``schedule.shoot_coeff_tensor`` (built host-side, baked into jit)."""
    K, I, G, n = plan.K, plan.k_intra, plan.k_inter, plan.n_inter
    k = np.arange(K)
    g, i = k // I, k % I
    u = np.arange(I)
    l = np.arange(n)
    rows = g[:, None] * I + (i[:, None] - u[None, :]) % I  # (K, I)
    cols = ((g[:, None] + l[None, :]) % G) * I + i[:, None]  # (K, n)
    coef = np.asarray(A)[rows[:, :, None], cols[:, None, :]]  # (K, I, n)
    return coef * (l < G)[None, None, :]


def simulate_hierarchical(
    x: np.ndarray, A: np.ndarray, plan: HierarchicalPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Message-passing execution under the p-port constraints; bit-exact
    ``x @ A`` for ANY matrix A. Returns (x̃, stats)."""
    K, p, I, G = plan.K, plan.p, plan.k_intra, plan.k_inter
    sim = SyncSimulator(K, p)
    x = field.asarray(x)
    A = field.asarray(A)

    # ---- intra gather: storage[k][u] = x_{g, (i-u) % I} -------------------
    storage: list[list] = [[x[k]] for k in range(K)]
    for ports in plan.intra_rounds:
        msgs = {}
        for k in range(K):
            g, i = divmod(k, I)
            for s, cnt in ports:
                dst = g * I + (i + s) % I
                msgs[(k, dst)] = storage[k][:cnt]
        delivered = sim.exchange(msgs)
        new = [list(st) for st in storage]
        for k in range(K):
            g, i = divmod(k, I)
            for s, cnt in ports:  # append in port order → contiguous offsets
                src = g * I + (i - s) % I
                new[k].extend(delivered[(src, k)])
        storage = new
    for k in range(K):
        assert len(storage[k]) == I, "intra gather must cover the group"

    # ---- local contraction: z[l] = partial sum for group (g+l) % G --------
    w = np.zeros((K, plan.n_inter), dtype=np.uint64)
    for k in range(K):
        g, i = divmod(k, I)
        for l in range(G):
            col = ((g + l) % G) * I + i
            acc = np.uint64(0)
            for u in range(I):
                r = g * I + (i - u) % I
                acc = field.add(acc, field.mul(storage[k][u], A[r, col]))
            w[k, l] = acc

    # ---- inter shoot: digit-reduce the group offset toward slot 0 ---------
    radix = p + 1
    for t, shifts in enumerate(plan.inter_shifts, start=1):
        stride = radix ** (t - 1)
        msgs = {}
        for k in range(K):
            g, i = divmod(k, I)
            for rho, s in enumerate(shifts, start=1):
                ls = [
                    l
                    for l in range(plan.n_inter)
                    if (l // stride) % radix == rho and l % stride == 0 and l < G
                ]
                if ls:
                    dst = ((g + s) % G) * I + i
                    msgs[(k, dst)] = [(l, w[k, l]) for l in ls]
        delivered = sim.exchange(msgs)
        for (src, dst), items in delivered.items():
            for l, val in items:
                w[dst, l - ((l // stride) % radix) * stride] = field.add(
                    w[dst, l - ((l // stride) % radix) * stride], val
                )

    out = np.array([w[k, 0] for k in range(K)], dtype=np.uint64)
    return out, sim.stats


# ---------------------------------------------------------------------------
# recursive multi-level plan (K = Π K_j, see module doc)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MultiLevelPlan:
    """Static schedule for the recursive K = Π K_j universal encode:
    intra gather over level 0, local contraction into one slot per per-level
    offset vector, then one digit-reduction shoot per outer level (innermost
    first). ``levels`` is innermost → outermost; ``slot_bases[j-1]`` is the
    (p+1)^⌈log K_j⌉ padded slot space of outer level j."""

    K: int
    p: int
    levels: tuple[int, ...]
    intra_rounds: tuple  # gather_rounds(levels[0], p)
    level_shifts: tuple  # [j-1][t-1][rho-1] → shift in level-j coordinate units
    slot_bases: tuple[int, ...]  # per outer level j: n_j = (p+1)^Ts_j
    n_slots: int  # Π slot_bases

    @property
    def c1(self) -> int:
        return len(self.intra_rounds) + sum(len(ts) for ts in self.level_shifts)

    @property
    def c2(self) -> int:
        c = sum(max((cnt for _, cnt in ports), default=0) for ports in self.intra_rounds)
        for j in range(1, len(self.levels)):
            for t in range(1, len(self.level_shifts[j - 1]) + 1):
                c += max(
                    multilevel_message_size(self, j, t, rho)
                    for rho in range(1, self.p + 1)
                )
        return c

    @property
    def algorithm(self) -> str:
        return "multilevel"


def plan_multilevel(K: int, p: int, levels) -> MultiLevelPlan:
    levels = tuple(int(k) for k in levels)
    prod = 1
    for k in levels:
        prod *= k
    if not levels or prod != K or any(k < 1 for k in levels):
        raise ValueError(f"levels must be positive with Π levels = K: {levels}, K={K}")
    radix = p + 1
    level_shifts = []
    slot_bases = []
    n_slots = 1
    for kj in levels[1:]:
        ts = ceil_log(kj, radix)
        level_shifts.append(
            tuple(
                tuple(rho * radix ** (t - 1) for rho in range(1, p + 1))
                for t in range(1, ts + 1)
            )
        )
        slot_bases.append(radix**ts)
        n_slots *= radix**ts
    return MultiLevelPlan(
        K=K,
        p=p,
        levels=levels,
        intra_rounds=gather_rounds(levels[0], p),
        level_shifts=tuple(level_shifts),
        slot_bases=tuple(slot_bases),
        n_slots=n_slots,
    )


def _slot_digits(plan: MultiLevelPlan) -> np.ndarray:
    """(n_slots, L−1) per-outer-level digits of each slot index (level 1
    least significant, base ``slot_bases[j-1]``)."""
    L1 = len(plan.levels) - 1
    out = np.zeros((plan.n_slots, L1), dtype=np.int64)
    l = np.arange(plan.n_slots)
    for j in range(L1):
        out[:, j] = l % plan.slot_bases[j]
        l = l // plan.slot_bases[j]
    return out


def multilevel_live_mask(plan: MultiLevelPlan) -> np.ndarray:
    """(n_slots,) bool: slot live iff every per-level digit < K_j (dead
    slots are identically zero and never shipped)."""
    digits = _slot_digits(plan)
    outer = np.asarray(plan.levels[1:], dtype=np.int64)
    return np.all(digits < outer[None, :], axis=1) if outer.size else np.ones(
        plan.n_slots, dtype=bool
    )


def multilevel_level_slots(plan: MultiLevelPlan, j: int, t: int, rho: int):
    """(dst_slots, src_slots) global slot indices of outer level ``j``
    (1-based), reduction round ``t`` (1-based), port ``rho``. Senders: the
    level-j digit has digit_t = ρ with lower digits 0 and is a live
    coordinate (< K_j); levels below j are already fully reduced (digit 0);
    levels above j still hold any live coordinate. Receiver slot: the same
    index with the level-j digit lowered by ρ·(p+1)^{t-1}."""
    radix = plan.p + 1
    stride = radix ** (t - 1)
    digits = _slot_digits(plan)
    dj = digits[:, j - 1]
    ok = (dj // stride) % radix == rho
    ok &= dj % stride == 0
    ok &= dj < plan.levels[j]
    for j2 in range(1, j):
        ok &= digits[:, j2 - 1] == 0
    for j2 in range(j + 1, len(plan.levels)):
        ok &= digits[:, j2 - 1] < plan.levels[j2]
    src = np.nonzero(ok)[0]
    slot_stride = 1
    for j2 in range(1, j):
        slot_stride *= plan.slot_bases[j2 - 1]
    dst = src - rho * stride * slot_stride
    return dst, src


def multilevel_message_size(plan: MultiLevelPlan, j: int, t: int, rho: int) -> int:
    """Live elements shipped on port ρ in level-j reduction round t."""
    return int(multilevel_level_slots(plan, j, t, rho)[1].size)


def _outer_coords(plan: MultiLevelPlan) -> np.ndarray:
    """(K, L−1) outer coordinates of every device (level 1 first)."""
    L1 = len(plan.levels) - 1
    out = np.zeros((plan.K, L1), dtype=np.int64)
    c = np.arange(plan.K) // plan.levels[0]
    for j in range(L1):
        out[:, j] = c % plan.levels[j + 1]
        c = c // plan.levels[j + 1]
    return out


def multilevel_dev_shift(plan: MultiLevelPlan, k: int, j: int, s: int) -> int:
    """Device id after shifting the level-j coordinate of device k by s."""
    stride = 1
    for kj in plan.levels[:j]:
        stride *= kj
    cj = (k // stride) % plan.levels[j]
    return k + (((cj + s) % plan.levels[j]) - cj) * stride


def multilevel_coeff_tensor(plan: MultiLevelPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[row, col] with row = device (same outer coords,
    (i−u) mod K_0) and col = device (outer coords shifted component-wise by
    slot l's per-level digits, same i), masked to live slots — the
    multi-level analogue of :func:`hierarchical_coeff_tensor`."""
    K, K0, n = plan.K, plan.levels[0], plan.n_slots
    k = np.arange(K)
    i = k % K0
    u = np.arange(K0)
    rows = ((k // K0) * K0)[:, None] + (i[:, None] - u[None, :]) % K0  # (K, K0)
    oc = _outer_coords(plan)  # (K, L-1)
    digits = _slot_digits(plan)  # (n, L-1)
    t_outer = np.zeros((K, n), dtype=np.int64)
    mult = 1
    for j, kj in enumerate(plan.levels[1:]):
        t_outer += ((oc[:, j][:, None] + digits[:, j][None, :]) % kj) * mult
        mult *= kj
    cols = t_outer * K0 + i[:, None]  # (K, n)
    coef = np.asarray(A)[rows[:, :, None], cols[:, None, :]]  # (K, K0, n)
    return coef * multilevel_live_mask(plan)[None, None, :]


def simulate_multilevel(
    x: np.ndarray, A: np.ndarray, plan: MultiLevelPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Message-passing execution of the recursive schedule under the p-port
    constraints; bit-exact ``x @ A`` for ANY matrix A and ANY factorization.
    Returns (x̃, stats)."""
    K, p, K0 = plan.K, plan.p, plan.levels[0]
    sim = SyncSimulator(K, p)
    x = field.asarray(x)
    A = field.asarray(A)

    # ---- intra gather over level 0: storage[k][u] = x at (i-u) % K0 -------
    storage: list[list] = [[x[k]] for k in range(K)]
    for ports in plan.intra_rounds:
        msgs = {}
        for k in range(K):
            g, i = divmod(k, K0)
            for s, cnt in ports:
                msgs[(k, g * K0 + (i + s) % K0)] = storage[k][:cnt]
        delivered = sim.exchange(msgs)
        new = [list(st) for st in storage]
        for k in range(K):
            g, i = divmod(k, K0)
            for s, cnt in ports:
                src = g * K0 + (i - s) % K0
                new[k].extend(delivered[(src, k)])
        storage = new
    for k in range(K):
        assert len(storage[k]) == K0, "intra gather must cover the level-0 domain"

    # ---- local contraction into the per-level offset slots ----------------
    coef = multilevel_coeff_tensor(plan, A)
    w = np.zeros((K, plan.n_slots), dtype=np.uint64)
    live = multilevel_live_mask(plan)
    for k in range(K):
        for l in np.nonzero(live)[0]:
            acc = np.uint64(0)
            for u in range(K0):
                acc = field.add(acc, field.mul(storage[k][u], coef[k, u, l]))
            w[k, int(l)] = acc

    # ---- per-level digit-reduction shoot, innermost outer level first -----
    for j in range(1, len(plan.levels)):
        for t, shifts in enumerate(plan.level_shifts[j - 1], start=1):
            msgs = {}
            for k in range(K):
                for rho, s in enumerate(shifts, start=1):
                    dst_slots, src_slots = multilevel_level_slots(plan, j, t, rho)
                    if src_slots.size == 0:
                        continue
                    dst_dev = multilevel_dev_shift(plan, k, j, s)
                    msgs[(k, dst_dev)] = [
                        (int(ld), w[k, int(ls)])
                        for ld, ls in zip(dst_slots, src_slots)
                    ]
            delivered = sim.exchange(msgs)
            for (src, dst), items in delivered.items():
                for ld, val in items:
                    w[dst, ld] = field.add(w[dst, ld], val)

    out = np.array([w[k, 0] for k in range(K)], dtype=np.uint64)
    return out, sim.stats


# ---------------------------------------------------------------------------
# ring-optimized universal schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RingPlan:
    """Neighbor-only all-gather + local combine: the bandwidth-optimal
    universal schedule on a ring (1 hop per message, zero contention)."""

    K: int
    p: int  # p ≥ 2 → bidirectional (⌈(K−1)/2⌉ rounds); p = 1 → K−1 rounds

    @property
    def c1(self) -> int:
        if self.K <= 1:
            return 0
        return self.K - 1 if self.p == 1 else -(-(self.K - 1) // 2)

    @property
    def c2(self) -> int:
        return self.c1  # one element per port per round

    @property
    def algorithm(self) -> str:
        return "ring"


def plan_ring(K: int, p: int) -> RingPlan:
    return RingPlan(K=K, p=p)


def ring_rounds(plan: RingPlan) -> list[dict]:
    """Per-round message maps {(src, dst): elements} of the ring schedule
    (the lowering format of topo.lower / SimStats.round_messages)."""
    K = plan.K
    rounds: list[dict] = []
    if K <= 1:
        return rounds
    if plan.p == 1:
        for _ in range(K - 1):
            rounds.append({(k, (k + 1) % K): 1 for k in range(K)})
        return rounds
    r = -(-(K - 1) // 2)
    for j in range(1, r + 1):
        msgs = {(k, (k + 1) % K): 1 for k in range(K)}
        if not (j == r and (K - 1) % 2 == 1):  # odd remainder: fwd only
            msgs.update({(k, (k - 1) % K): 1 for k in range(K)})
        rounds.append(msgs)
    return rounds


def simulate_ring_encode(
    x: np.ndarray, A: np.ndarray, plan: RingPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Store-and-forward execution of the ring schedule; exact for any A."""
    K = plan.K
    sim = SyncSimulator(K, plan.p)
    x = field.asarray(x)
    A = field.asarray(A)
    have = {k: {k: x[k]} for k in range(K)}
    for j, msgs in enumerate(ring_rounds(plan), start=1):
        payloads = {}
        for (src, dst) in msgs:
            # forward stream carries x_{src-(j-1)}, backward x_{src+(j-1)}
            r = (src - (j - 1)) % K if dst == (src + 1) % K else (src + (j - 1)) % K
            payloads[(src, dst)] = [(r, have[src][r])]
        delivered = sim.exchange(payloads)
        for (src, dst), items in delivered.items():
            for r, val in items:
                have[dst][r] = val
    out = np.zeros(K, dtype=np.uint64)
    for k in range(K):
        assert len(have[k]) == K, "ring gather must cover all packets"
        acc = np.uint64(0)
        for r in range(K):
            acc = field.add(acc, field.mul(have[k][r], A[r, k]))
        out[k] = acc
    return out, sim.stats


# ---------------------------------------------------------------------------
# two-level Cooley–Tukey DFT
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TwoLevelDFTPlan:
    """β^{nk} factorization for K = I·G (see module doc): intra butterfly →
    local twiddle → inter butterfly. Relabelings: device (g, i) holds source
    coefficient ``input_coeff[k]`` and finishes with X[``output_index[k]``]."""

    K: int
    p: int
    k_intra: int
    k_inter: int
    q: int
    input_coeff: np.ndarray  # (K,) n = G·rev_I(i) + rev_G(g)
    output_index: np.ndarray  # (K,) i + I·g
    twiddle: np.ndarray  # (K,) β^{rev_G(g)·i} applied between the stages

    @property
    def c1(self) -> int:
        return ceil_log(self.k_intra, self.p + 1) + ceil_log(self.k_inter, self.p + 1)

    @property
    def c2(self) -> int:
        return self.c1  # both stages are butterflies: 1 element per round

    @property
    def algorithm(self) -> str:
        return "hierarchical-dft"


def plan_two_level_dft(K: int, p: int, q: int, k_intra: int) -> TwoLevelDFTPlan:
    """Requires K | q−1 and k_intra, K/k_intra powers of p+1 (each stage is a
    radix-(p+1) butterfly)."""
    if K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    I, G = k_intra, K // k_intra
    radix = p + 1
    for sz in (I, G):
        if radix ** ceil_log(sz, radix) != sz:
            raise ValueError(f"stage size {sz} is not a power of {radix}")
    if (q - 1) % K:
        raise ValueError(f"K={K} must divide q-1={q - 1}")
    f = Field(q)
    beta = f.root_of_unity(K)
    rev_i = digit_reversal_permutation(I, radix) if I > 1 else np.zeros(1, np.int64)
    rev_g = digit_reversal_permutation(G, radix) if G > 1 else np.zeros(1, np.int64)
    k = np.arange(K)
    g, i = k // I, k % I
    input_coeff = G * rev_i[i] + rev_g[g]
    output_index = i + I * g
    twiddle = f.pow(np.full(K, beta, dtype=np.uint64), rev_g[g] * i)
    return TwoLevelDFTPlan(
        K=K,
        p=p,
        k_intra=I,
        k_inter=G,
        q=q,
        input_coeff=input_coeff,
        output_index=output_index,
        twiddle=twiddle,
    )


def two_level_dft_matrix(plan: TwoLevelDFTPlan) -> np.ndarray:
    """The effective generator: M[k, k'] = D_K[input_coeff[k],
    output_index[k']] — a row/col permutation of the DFT matrix (still MDS),
    so ``simulate_two_level_dft(x) == x @ M`` bit-exactly."""
    from repro.core.matrices import dft_matrix

    D = dft_matrix(Field(plan.q), plan.K)
    return D[plan.input_coeff][:, plan.output_index]


def simulate_two_level_dft(
    x: np.ndarray, plan: TwoLevelDFTPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Both butterfly stages message-by-message on one simulator: every
    group's (resp. stride-column's) butterfly shares rounds, so C1 = C2 =
    log I + log G is measured globally under the p-port constraints."""
    K, p, I, G = plan.K, plan.p, plan.k_intra, plan.k_inter
    radix = p + 1
    sim = SyncSimulator(K, p)
    v = field.asarray(x).copy()

    def run_stage(bf_plan, n_local, to_global):
        """One butterfly over every parallel subgroup at once; ``to_global``
        maps (subgroup, local index) → processor id."""
        nonlocal v
        n_sub = K // n_local
        for t in range(bf_plan.H):
            perms = butterfly_group_perms(n_local, radix, t)
            msgs = {}
            for sub in range(n_sub):
                for lk in range(n_local):
                    src = to_global(sub, lk)
                    for dst_map in perms:
                        msgs[(src, to_global(sub, int(dst_map[lk])))] = [v[src]]
            delivered = sim.exchange(msgs)
            step = radix**t
            tw = bf_plan.twiddles[t]
            new_v = v.copy()
            for sub in range(n_sub):
                received = {}
                for lk in range(n_local):
                    received.setdefault(lk, {})[(lk // step) % radix] = v[
                        to_global(sub, lk)
                    ]
                for lk in range(n_local):
                    gk = to_global(sub, lk)
                    for dst_map in perms:
                        received[int(dst_map[lk])][(lk // step) % radix] = v[gk]
                for lk in range(n_local):
                    acc = np.uint64(0)
                    for rho in range(radix):
                        acc = field.add(
                            acc,
                            field.mul(np.uint64(tw[lk, rho]), received[lk][rho]),
                        )
                    new_v[to_global(sub, lk)] = acc
            v = new_v

    if I > 1:
        run_stage(plan_butterfly(I, p, plan.q), I, lambda sub, lk: sub * I + lk)
    v = field.mul(v, plan.twiddle)
    if G > 1:
        run_stage(plan_butterfly(G, p, plan.q), G, lambda sub, lk: lk * I + sub)
    return v, sim.stats
