"""Two-level (hierarchical) all-to-all encode schedules.

K = K_inter × K_intra processors, k = g·K_intra + i: group ``g`` is the fast
domain (intra-slice ICI), crossing groups is slow (inter-slice DCI). The flat
prepare-and-shoot schedule shifts by ±m/(p+1)^t regardless of group
boundaries, so on a two-level network most of its messages pile onto the
inter-group trunks. The schedules here keep each phase inside one level:

* **hierarchical prepare-and-shoot** (universal, any matrix A):

  1. *intra gather* — (p+1)-ary doubling all-gather inside each group
     (⌈log_{p+1}K_intra⌉ rounds, fast links only);
  2. *local contraction* — device (g, i) forms partial sums
     ``z[l] = Σ_u x_{g, i-u} · A[(g, i-u), ((g+l)%G, i)]`` for every target
     group offset l (no communication);
  3. *inter shoot* — the paper's §IV digit-reduction over the group axis
     (⌈log_{p+1}K_inter⌉ rounds, one slow message per port per round).

  C1 = ⌈log I⌉ + ⌈log G⌉ (≤ ⌈log K⌉ + 1), C2 = Θ((I + G)/p) — the flat
  √K·2/p when I ≈ G ≈ √K, but with every gather element on fast links.

* **two-level DFT** (Cooley–Tukey): when A is the DFT matrix and
  K_intra, K_inter are powers of p+1 dividing q−1, the multiplicative
  structure β^{nk} = ω_I^{n1·k1} · β^{n2·k1} · ω_G^{n2·k2} splits the encode
  into an intra butterfly, a local twiddle, and an inter butterfly —
  C2 = log I + log G elements total, no intermediate inflation. Inputs and
  outputs are relabeled ("up to permutation", exactly as draw-and-loose):
  device (g, i) holds source coefficient G·rev_I(i) + rev_G(g) and finishes
  with X[i + I·g]; :func:`two_level_dft_matrix` is the effective generator.

* **ring schedule** (per the ring-networks line of work): on a ring the
  optimal universal strategy is neighbor-only traffic — a bidirectional
  store-and-forward all-gather (⌈(K−1)/2⌉ rounds of 1-element messages to
  k±1) followed by a local combine. No multi-hop messages, so zero link
  contention.

Everything is validated on the cost-exact :class:`SyncSimulator`: the
``simulate_*`` functions here run the schedules message-by-message under the
p-port constraints and return bit-exact outputs plus measured C1/C2 and
per-round message maps (which ``topo.lower`` cross-checks analytically).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.bounds import ceil_log
from repro.core.field import Field
from repro.core.matrices import digit_reversal_permutation
from repro.core.schedule import (
    butterfly_group_perms,
    digit_reduction_message_size,
    digit_reduction_slots,
    plan_butterfly,
)
from repro.core.simulator import SimStats, SyncSimulator


# ---------------------------------------------------------------------------
# (p+1)-ary doubling all-gather rounds (shared by the intra phase and the
# flat all-gather baseline lowering)
# ---------------------------------------------------------------------------


def gather_rounds(N: int, p: int) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Round schedule fully gathering N cyclic packets: each round every
    processor sends a prefix of its (contiguous-offset) buffer to p partners.

    Returns per round a tuple of ``(shift, count)`` ports: send buffer slots
    [0, count) to processor k+shift (mod N). After round r the buffer holds
    offsets [0, min((p+1)^r, N)) — ⌈log_{p+1}N⌉ rounds total, C2 = Σ max
    count ≈ (N−1)/p (the optimal p-port all-gather of bounds.py).
    """
    rounds = []
    b = 1
    while b < N:
        ports = []
        for rho in range(1, p + 1):
            cnt = min(b, N - rho * b)
            if cnt > 0:
                ports.append((rho * b, cnt))
        rounds.append(tuple(ports))
        b = min(b * (p + 1), N)
    return tuple(rounds)


# ---------------------------------------------------------------------------
# hierarchical prepare-and-shoot plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HierarchicalPlan:
    """Static schedule for the two-level universal encode (see module doc)."""

    K: int
    p: int
    k_intra: int  # I — fast-domain size
    k_inter: int  # G — slow-domain size
    intra_rounds: tuple  # gather_rounds(k_intra, p)
    inter_shifts: tuple[tuple[int, ...], ...]  # group-unit shifts per round
    n_inter: int  # (p+1)^Ts slot count, Ts = ⌈log_{p+1} G⌉

    @property
    def c1(self) -> int:
        return len(self.intra_rounds) + len(self.inter_shifts)

    @property
    def c2(self) -> int:
        c = sum(max((cnt for _, cnt in ports), default=0) for ports in self.intra_rounds)
        for t in range(1, len(self.inter_shifts) + 1):
            c += max(
                hier_shoot_message_size(self, t, rho) for rho in range(1, self.p + 1)
            )
        return c

    @property
    def algorithm(self) -> str:
        return "hierarchical"


def plan_hierarchical(K: int, p: int, k_intra: int) -> HierarchicalPlan:
    if k_intra < 1 or K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    G = K // k_intra
    Ts = ceil_log(G, p + 1)
    inter_shifts = tuple(
        tuple(rho * (p + 1) ** (t - 1) for rho in range(1, p + 1))
        for t in range(1, Ts + 1)
    )
    return HierarchicalPlan(
        K=K,
        p=p,
        k_intra=k_intra,
        k_inter=G,
        intra_rounds=gather_rounds(k_intra, p),
        inter_shifts=inter_shifts,
        n_inter=(p + 1) ** Ts,
    )


def hier_shoot_slots(n: int, p: int, t: int, rho: int):
    """(dst_slots, src_slots) for inter-shoot round ``t`` (1-based), port
    ``rho`` over ``n`` slots — delegates to the §IV digit-reduction."""
    return digit_reduction_slots(n, p, t, rho)


def hier_shoot_message_size(plan: HierarchicalPlan, t: int, rho: int) -> int:
    """Live elements shipped on port rho in inter round t: slots with
    digit_t = rho, lower digits 0, below the live count G (slots l ≥ G are
    identically zero — they are never worth sending)."""
    return digit_reduction_message_size(
        plan.n_inter, plan.k_inter, plan.p, t, rho
    )


def hierarchical_coeff_tensor(plan: HierarchicalPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[g·I + (i−u)%I, ((g+l)%G)·I + i] masked to live
    target-group offsets l < G; k = g·I + i. The local-contraction analogue
    of ``schedule.shoot_coeff_tensor`` (built host-side, baked into jit)."""
    K, I, G, n = plan.K, plan.k_intra, plan.k_inter, plan.n_inter
    k = np.arange(K)
    g, i = k // I, k % I
    u = np.arange(I)
    l = np.arange(n)
    rows = g[:, None] * I + (i[:, None] - u[None, :]) % I  # (K, I)
    cols = ((g[:, None] + l[None, :]) % G) * I + i[:, None]  # (K, n)
    coef = np.asarray(A)[rows[:, :, None], cols[:, None, :]]  # (K, I, n)
    return coef * (l < G)[None, None, :]


def simulate_hierarchical(
    x: np.ndarray, A: np.ndarray, plan: HierarchicalPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Message-passing execution under the p-port constraints; bit-exact
    ``x @ A`` for ANY matrix A. Returns (x̃, stats)."""
    K, p, I, G = plan.K, plan.p, plan.k_intra, plan.k_inter
    sim = SyncSimulator(K, p)
    x = field.asarray(x)
    A = field.asarray(A)

    # ---- intra gather: storage[k][u] = x_{g, (i-u) % I} -------------------
    storage: list[list] = [[x[k]] for k in range(K)]
    for ports in plan.intra_rounds:
        msgs = {}
        for k in range(K):
            g, i = divmod(k, I)
            for s, cnt in ports:
                dst = g * I + (i + s) % I
                msgs[(k, dst)] = storage[k][:cnt]
        delivered = sim.exchange(msgs)
        new = [list(st) for st in storage]
        for k in range(K):
            g, i = divmod(k, I)
            for s, cnt in ports:  # append in port order → contiguous offsets
                src = g * I + (i - s) % I
                new[k].extend(delivered[(src, k)])
        storage = new
    for k in range(K):
        assert len(storage[k]) == I, "intra gather must cover the group"

    # ---- local contraction: z[l] = partial sum for group (g+l) % G --------
    w = np.zeros((K, plan.n_inter), dtype=np.uint64)
    for k in range(K):
        g, i = divmod(k, I)
        for l in range(G):
            col = ((g + l) % G) * I + i
            acc = np.uint64(0)
            for u in range(I):
                r = g * I + (i - u) % I
                acc = field.add(acc, field.mul(storage[k][u], A[r, col]))
            w[k, l] = acc

    # ---- inter shoot: digit-reduce the group offset toward slot 0 ---------
    radix = p + 1
    for t, shifts in enumerate(plan.inter_shifts, start=1):
        stride = radix ** (t - 1)
        msgs = {}
        for k in range(K):
            g, i = divmod(k, I)
            for rho, s in enumerate(shifts, start=1):
                ls = [
                    l
                    for l in range(plan.n_inter)
                    if (l // stride) % radix == rho and l % stride == 0 and l < G
                ]
                if ls:
                    dst = ((g + s) % G) * I + i
                    msgs[(k, dst)] = [(l, w[k, l]) for l in ls]
        delivered = sim.exchange(msgs)
        for (src, dst), items in delivered.items():
            for l, val in items:
                w[dst, l - ((l // stride) % radix) * stride] = field.add(
                    w[dst, l - ((l // stride) % radix) * stride], val
                )

    out = np.array([w[k, 0] for k in range(K)], dtype=np.uint64)
    return out, sim.stats


# ---------------------------------------------------------------------------
# ring-optimized universal schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RingPlan:
    """Neighbor-only all-gather + local combine: the bandwidth-optimal
    universal schedule on a ring (1 hop per message, zero contention)."""

    K: int
    p: int  # p ≥ 2 → bidirectional (⌈(K−1)/2⌉ rounds); p = 1 → K−1 rounds

    @property
    def c1(self) -> int:
        if self.K <= 1:
            return 0
        return self.K - 1 if self.p == 1 else -(-(self.K - 1) // 2)

    @property
    def c2(self) -> int:
        return self.c1  # one element per port per round

    @property
    def algorithm(self) -> str:
        return "ring"


def plan_ring(K: int, p: int) -> RingPlan:
    return RingPlan(K=K, p=p)


def ring_rounds(plan: RingPlan) -> list[dict]:
    """Per-round message maps {(src, dst): elements} of the ring schedule
    (the lowering format of topo.lower / SimStats.round_messages)."""
    K = plan.K
    rounds: list[dict] = []
    if K <= 1:
        return rounds
    if plan.p == 1:
        for _ in range(K - 1):
            rounds.append({(k, (k + 1) % K): 1 for k in range(K)})
        return rounds
    r = -(-(K - 1) // 2)
    for j in range(1, r + 1):
        msgs = {(k, (k + 1) % K): 1 for k in range(K)}
        if not (j == r and (K - 1) % 2 == 1):  # odd remainder: fwd only
            msgs.update({(k, (k - 1) % K): 1 for k in range(K)})
        rounds.append(msgs)
    return rounds


def simulate_ring_encode(
    x: np.ndarray, A: np.ndarray, plan: RingPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Store-and-forward execution of the ring schedule; exact for any A."""
    K = plan.K
    sim = SyncSimulator(K, plan.p)
    x = field.asarray(x)
    A = field.asarray(A)
    have = {k: {k: x[k]} for k in range(K)}
    for j, msgs in enumerate(ring_rounds(plan), start=1):
        payloads = {}
        for (src, dst) in msgs:
            # forward stream carries x_{src-(j-1)}, backward x_{src+(j-1)}
            r = (src - (j - 1)) % K if dst == (src + 1) % K else (src + (j - 1)) % K
            payloads[(src, dst)] = [(r, have[src][r])]
        delivered = sim.exchange(payloads)
        for (src, dst), items in delivered.items():
            for r, val in items:
                have[dst][r] = val
    out = np.zeros(K, dtype=np.uint64)
    for k in range(K):
        assert len(have[k]) == K, "ring gather must cover all packets"
        acc = np.uint64(0)
        for r in range(K):
            acc = field.add(acc, field.mul(have[k][r], A[r, k]))
        out[k] = acc
    return out, sim.stats


# ---------------------------------------------------------------------------
# two-level Cooley–Tukey DFT
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TwoLevelDFTPlan:
    """β^{nk} factorization for K = I·G (see module doc): intra butterfly →
    local twiddle → inter butterfly. Relabelings: device (g, i) holds source
    coefficient ``input_coeff[k]`` and finishes with X[``output_index[k]``]."""

    K: int
    p: int
    k_intra: int
    k_inter: int
    q: int
    input_coeff: np.ndarray  # (K,) n = G·rev_I(i) + rev_G(g)
    output_index: np.ndarray  # (K,) i + I·g
    twiddle: np.ndarray  # (K,) β^{rev_G(g)·i} applied between the stages

    @property
    def c1(self) -> int:
        return ceil_log(self.k_intra, self.p + 1) + ceil_log(self.k_inter, self.p + 1)

    @property
    def c2(self) -> int:
        return self.c1  # both stages are butterflies: 1 element per round

    @property
    def algorithm(self) -> str:
        return "hierarchical-dft"


def plan_two_level_dft(K: int, p: int, q: int, k_intra: int) -> TwoLevelDFTPlan:
    """Requires K | q−1 and k_intra, K/k_intra powers of p+1 (each stage is a
    radix-(p+1) butterfly)."""
    if K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    I, G = k_intra, K // k_intra
    radix = p + 1
    for sz in (I, G):
        if radix ** ceil_log(sz, radix) != sz:
            raise ValueError(f"stage size {sz} is not a power of {radix}")
    if (q - 1) % K:
        raise ValueError(f"K={K} must divide q-1={q - 1}")
    f = Field(q)
    beta = f.root_of_unity(K)
    rev_i = digit_reversal_permutation(I, radix) if I > 1 else np.zeros(1, np.int64)
    rev_g = digit_reversal_permutation(G, radix) if G > 1 else np.zeros(1, np.int64)
    k = np.arange(K)
    g, i = k // I, k % I
    input_coeff = G * rev_i[i] + rev_g[g]
    output_index = i + I * g
    twiddle = f.pow(np.full(K, beta, dtype=np.uint64), rev_g[g] * i)
    return TwoLevelDFTPlan(
        K=K,
        p=p,
        k_intra=I,
        k_inter=G,
        q=q,
        input_coeff=input_coeff,
        output_index=output_index,
        twiddle=twiddle,
    )


def two_level_dft_matrix(plan: TwoLevelDFTPlan) -> np.ndarray:
    """The effective generator: M[k, k'] = D_K[input_coeff[k],
    output_index[k']] — a row/col permutation of the DFT matrix (still MDS),
    so ``simulate_two_level_dft(x) == x @ M`` bit-exactly."""
    from repro.core.matrices import dft_matrix

    D = dft_matrix(Field(plan.q), plan.K)
    return D[plan.input_coeff][:, plan.output_index]


def simulate_two_level_dft(
    x: np.ndarray, plan: TwoLevelDFTPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Both butterfly stages message-by-message on one simulator: every
    group's (resp. stride-column's) butterfly shares rounds, so C1 = C2 =
    log I + log G is measured globally under the p-port constraints."""
    K, p, I, G = plan.K, plan.p, plan.k_intra, plan.k_inter
    radix = p + 1
    sim = SyncSimulator(K, p)
    v = field.asarray(x).copy()

    def run_stage(bf_plan, n_local, to_global):
        """One butterfly over every parallel subgroup at once; ``to_global``
        maps (subgroup, local index) → processor id."""
        nonlocal v
        n_sub = K // n_local
        for t in range(bf_plan.H):
            perms = butterfly_group_perms(n_local, radix, t)
            msgs = {}
            for sub in range(n_sub):
                for lk in range(n_local):
                    src = to_global(sub, lk)
                    for dst_map in perms:
                        msgs[(src, to_global(sub, int(dst_map[lk])))] = [v[src]]
            delivered = sim.exchange(msgs)
            step = radix**t
            tw = bf_plan.twiddles[t]
            new_v = v.copy()
            for sub in range(n_sub):
                received = {}
                for lk in range(n_local):
                    received.setdefault(lk, {})[(lk // step) % radix] = v[
                        to_global(sub, lk)
                    ]
                for lk in range(n_local):
                    gk = to_global(sub, lk)
                    for dst_map in perms:
                        received[int(dst_map[lk])][(lk // step) % radix] = v[gk]
                for lk in range(n_local):
                    acc = np.uint64(0)
                    for rho in range(radix):
                        acc = field.add(
                            acc,
                            field.mul(np.uint64(tw[lk, rho]), received[lk][rho]),
                        )
                    new_v[to_global(sub, lk)] = acc
            v = new_v

    if I > 1:
        run_stage(plan_butterfly(I, p, plan.q), I, lambda sub, lk: sub * I + lk)
    v = field.mul(v, plan.twiddle)
    if G > 1:
        run_stage(plan_butterfly(G, p, plan.q), G, lambda sub, lk: lk * I + sub)
    return v, sim.stats
