"""Two-level (hierarchical) all-to-all encode schedules.

K = K_inter × K_intra processors, k = g·K_intra + i: group ``g`` is the fast
domain (intra-slice ICI), crossing groups is slow (inter-slice DCI). The flat
prepare-and-shoot schedule shifts by ±m/(p+1)^t regardless of group
boundaries, so on a two-level network most of its messages pile onto the
inter-group trunks. The schedules here keep each phase inside one level:

* **hierarchical prepare-and-shoot** (universal, any matrix A):

  1. *intra gather* — (p+1)-ary doubling all-gather inside each group
     (⌈log_{p+1}K_intra⌉ rounds, fast links only);
  2. *local contraction* — device (g, i) forms partial sums
     ``z[l] = Σ_u x_{g, i-u} · A[(g, i-u), ((g+l)%G, i)]`` for every target
     group offset l (no communication);
  3. *inter shoot* — the paper's §IV digit-reduction over the group axis
     (⌈log_{p+1}K_inter⌉ rounds, one slow message per port per round).

  C1 = ⌈log I⌉ + ⌈log G⌉ (≤ ⌈log K⌉ + 1), C2 = Θ((I + G)/p) — the flat
  √K·2/p when I ≈ G ≈ √K, but with every gather element on fast links.

* **two-level DFT** (Cooley–Tukey): when A is the DFT matrix and
  K_intra, K_inter are powers of p+1 dividing q−1, the multiplicative
  structure β^{nk} = ω_I^{n1·k1} · β^{n2·k1} · ω_G^{n2·k2} splits the encode
  into an intra butterfly, a local twiddle, and an inter butterfly —
  C2 = log I + log G elements total, no intermediate inflation. Inputs and
  outputs are relabeled ("up to permutation", exactly as draw-and-loose):
  device (g, i) holds source coefficient G·rev_I(i) + rev_G(g) and finishes
  with X[i + I·g]; :func:`two_level_dft_matrix` is the effective generator.

* **ring schedule** (per the ring-networks line of work): on a ring the
  optimal universal strategy is neighbor-only traffic — a bidirectional
  store-and-forward all-gather (⌈(K−1)/2⌉ rounds of 1-element messages to
  k±1) followed by a local combine. No multi-hop messages, so zero link
  contention.

* **recursive multi-level encode** (universal, any matrix, any K = Π K_j):
  the generalization of the two-level schedule to an arbitrary hierarchy
  ``levels = (K_0, …, K_{L−1})`` (innermost/fastest first). Phases:

  1. *intra gather* over the level-0 domain (size K_0, fastest links);
  2. *local contraction* — device with coordinates (c, i) forms one partial
     sum per **per-level offset vector** l = (l_1, …, l_{L−1}), destined for
     the device at ((c_1+l_1) mod K_1, …, (c_{L−1}+l_{L−1}) mod K_{L−1}, i).
     Component-wise modular offsets (instead of the two-level (g+l) mod G)
     are what keep every later shift inside ONE level — no mixed-radix
     carries ever cross a level boundary;
  3. *per-level digit-reduction shoot*, innermost outer level first: level j
     runs ⌈log_{p+1}K_j⌉ §IV digit-reduction rounds over the l_j component,
     every message traveling on level-j links only. Reducing cheap levels
     first matters: the level-j messages still carry Π_{j″>j} K_{j″} live
     outer combinations, so the bulky reductions ride the fast links.

  C1 = ⌈log K_0⌉ + Σ_{j≥1} ⌈log K_j⌉; Σ_j (K_j−1)/p ≤ C2 with the level-j
  term scaled by the live outer combinations Π_{j″>j} K_{j″} — exactly the
  two-level formulas when L = 2, and ``plan_multilevel(K, p, (I, G))``
  lowers to the SAME rounds as ``plan_hierarchical(K, p, I)`` (trivial
  K_j = 1 levels contribute zero rounds, zero slots).

Everything is validated on the cost-exact :class:`SyncSimulator`: the
``simulate_*`` functions here run the schedules message-by-message under the
p-port constraints and return bit-exact outputs plus measured C1/C2 and
per-round message maps (which ``topo.lower`` cross-checks analytically).

Paper-notation glossary: ``K`` processors, ``p`` ports/round, ``C1`` rounds,
``C2`` max-elements-per-port summed over rounds; ``I = k_intra`` / ``G =
k_inter`` the two-level split; *digit-reduction slots* — the §IV shoot keeps
one buffer slot per (p+1)-ary numeral of the remaining target offset and
each round zeroes one digit by shipping the slots with digit_t = ρ to port
ρ's partner (see ``core.schedule.digit_reduction_slots``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.bounds import ceil_log
from repro.core.field import M31, Field
from repro.core.matrices import digit_reversal_permutation
from repro.core.schedule import (
    digit_reduction_message_size,
    digit_reduction_slots,
    gather_rounds,  # noqa: F401  (re-export; the IR compilers share it)
    plan_butterfly,
)
from repro.core.simulator import SimStats, interpret


# (p+1)-ary doubling all-gather rounds now live in core.schedule (the IR
# compilers in core/ir.py share them); re-exported here for compatibility.


# ---------------------------------------------------------------------------
# hierarchical prepare-and-shoot plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HierarchicalPlan:
    """Static schedule for the two-level universal encode (see module doc)."""

    K: int
    p: int
    k_intra: int  # I — fast-domain size
    k_inter: int  # G — slow-domain size
    intra_rounds: tuple  # gather_rounds(k_intra, p)
    inter_shifts: tuple[tuple[int, ...], ...]  # group-unit shifts per round
    n_inter: int  # (p+1)^Ts slot count, Ts = ⌈log_{p+1} G⌉

    @property
    def c1(self) -> int:
        return len(self.intra_rounds) + len(self.inter_shifts)

    @property
    def c2(self) -> int:
        c = sum(max((cnt for _, cnt in ports), default=0) for ports in self.intra_rounds)
        for t in range(1, len(self.inter_shifts) + 1):
            c += max(
                hier_shoot_message_size(self, t, rho) for rho in range(1, self.p + 1)
            )
        return c

    @property
    def algorithm(self) -> str:
        return "hierarchical"

    def to_ir(self, A=None, *, q: int = M31):
        """The two-level schedule is exactly the depth-2 case of the
        recursive one (asserted round-for-round in tests), so it compiles
        through the same multilevel IR builder."""
        from dataclasses import replace

        ml = plan_multilevel(self.K, self.p, (self.k_intra, self.k_inter))
        return replace(ml.to_ir(A, q=q), algorithm="hierarchical")


def plan_hierarchical(K: int, p: int, k_intra: int) -> HierarchicalPlan:
    if k_intra < 1 or K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    G = K // k_intra
    Ts = ceil_log(G, p + 1)
    inter_shifts = tuple(
        tuple(rho * (p + 1) ** (t - 1) for rho in range(1, p + 1))
        for t in range(1, Ts + 1)
    )
    return HierarchicalPlan(
        K=K,
        p=p,
        k_intra=k_intra,
        k_inter=G,
        intra_rounds=gather_rounds(k_intra, p),
        inter_shifts=inter_shifts,
        n_inter=(p + 1) ** Ts,
    )


def hier_shoot_slots(n: int, p: int, t: int, rho: int):
    """(dst_slots, src_slots) for inter-shoot round ``t`` (1-based), port
    ``rho`` over ``n`` slots — delegates to the §IV digit-reduction."""
    return digit_reduction_slots(n, p, t, rho)


def hier_shoot_message_size(plan: HierarchicalPlan, t: int, rho: int) -> int:
    """Live elements shipped on port rho in inter round t: slots with
    digit_t = rho, lower digits 0, below the live count G (slots l ≥ G are
    identically zero — they are never worth sending)."""
    return digit_reduction_message_size(
        plan.n_inter, plan.k_inter, plan.p, t, rho
    )


def hierarchical_coeff_tensor(plan: HierarchicalPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[g·I + (i−u)%I, ((g+l)%G)·I + i] masked to live
    target-group offsets l < G; k = g·I + i. The local-contraction analogue
    of ``schedule.shoot_coeff_tensor`` (built host-side, baked into jit)."""
    K, I, G, n = plan.K, plan.k_intra, plan.k_inter, plan.n_inter
    k = np.arange(K)
    g, i = k // I, k % I
    u = np.arange(I)
    l = np.arange(n)
    rows = g[:, None] * I + (i[:, None] - u[None, :]) % I  # (K, I)
    cols = ((g[:, None] + l[None, :]) % G) * I + i[:, None]  # (K, n)
    coef = np.asarray(A)[rows[:, :, None], cols[:, None, :]]  # (K, I, n)
    return coef * (l < G)[None, None, :]


def simulate_hierarchical(
    x: np.ndarray, A: np.ndarray, plan: HierarchicalPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Message-passing execution under the p-port constraints (generic IR
    interpreter); bit-exact ``x @ A`` for ANY matrix A. Returns (x̃, stats)."""
    out, stats = interpret(plan.to_ir(A, q=field.q), x, field)
    np.testing.assert_array_equal(out, field.matmul(field.asarray(x), A))
    return out, stats


# ---------------------------------------------------------------------------
# recursive multi-level plan (K = Π K_j, see module doc)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MultiLevelPlan:
    """Static schedule for the recursive K = Π K_j universal encode:
    intra gather over level 0, local contraction into one slot per per-level
    offset vector, then one digit-reduction shoot per outer level (innermost
    first). ``levels`` is innermost → outermost; ``slot_bases[j-1]`` is the
    (p+1)^⌈log K_j⌉ padded slot space of outer level j."""

    K: int
    p: int
    levels: tuple[int, ...]
    intra_rounds: tuple  # gather_rounds(levels[0], p)
    level_shifts: tuple  # [j-1][t-1][rho-1] → shift in level-j coordinate units
    slot_bases: tuple[int, ...]  # per outer level j: n_j = (p+1)^Ts_j
    n_slots: int  # Π slot_bases

    @property
    def c1(self) -> int:
        return len(self.intra_rounds) + sum(len(ts) for ts in self.level_shifts)

    @property
    def c2(self) -> int:
        c = sum(max((cnt for _, cnt in ports), default=0) for ports in self.intra_rounds)
        for j in range(1, len(self.levels)):
            for t in range(1, len(self.level_shifts[j - 1]) + 1):
                c += max(
                    multilevel_message_size(self, j, t, rho)
                    for rho in range(1, self.p + 1)
                )
        return c

    @property
    def algorithm(self) -> str:
        return "multilevel"

    def to_ir(self, A=None, *, q: int = M31):
        return _multilevel_ir(self, A, q=q)


def plan_multilevel(K: int, p: int, levels) -> MultiLevelPlan:
    levels = tuple(int(k) for k in levels)
    prod = 1
    for k in levels:
        prod *= k
    if not levels or prod != K or any(k < 1 for k in levels):
        raise ValueError(f"levels must be positive with Π levels = K: {levels}, K={K}")
    radix = p + 1
    level_shifts = []
    slot_bases = []
    n_slots = 1
    for kj in levels[1:]:
        ts = ceil_log(kj, radix)
        level_shifts.append(
            tuple(
                tuple(rho * radix ** (t - 1) for rho in range(1, p + 1))
                for t in range(1, ts + 1)
            )
        )
        slot_bases.append(radix**ts)
        n_slots *= radix**ts
    return MultiLevelPlan(
        K=K,
        p=p,
        levels=levels,
        intra_rounds=gather_rounds(levels[0], p),
        level_shifts=tuple(level_shifts),
        slot_bases=tuple(slot_bases),
        n_slots=n_slots,
    )


def _slot_digits(plan: MultiLevelPlan) -> np.ndarray:
    """(n_slots, L−1) per-outer-level digits of each slot index (level 1
    least significant, base ``slot_bases[j-1]``)."""
    L1 = len(plan.levels) - 1
    out = np.zeros((plan.n_slots, L1), dtype=np.int64)
    l = np.arange(plan.n_slots)
    for j in range(L1):
        out[:, j] = l % plan.slot_bases[j]
        l = l // plan.slot_bases[j]
    return out


def multilevel_live_mask(plan: MultiLevelPlan) -> np.ndarray:
    """(n_slots,) bool: slot live iff every per-level digit < K_j (dead
    slots are identically zero and never shipped)."""
    digits = _slot_digits(plan)
    outer = np.asarray(plan.levels[1:], dtype=np.int64)
    return np.all(digits < outer[None, :], axis=1) if outer.size else np.ones(
        plan.n_slots, dtype=bool
    )


def multilevel_level_slots(plan: MultiLevelPlan, j: int, t: int, rho: int):
    """(dst_slots, src_slots) global slot indices of outer level ``j``
    (1-based), reduction round ``t`` (1-based), port ``rho``. Senders: the
    level-j digit has digit_t = ρ with lower digits 0 and is a live
    coordinate (< K_j); levels below j are already fully reduced (digit 0);
    levels above j still hold any live coordinate. Receiver slot: the same
    index with the level-j digit lowered by ρ·(p+1)^{t-1}."""
    radix = plan.p + 1
    stride = radix ** (t - 1)
    digits = _slot_digits(plan)
    dj = digits[:, j - 1]
    ok = (dj // stride) % radix == rho
    ok &= dj % stride == 0
    ok &= dj < plan.levels[j]
    for j2 in range(1, j):
        ok &= digits[:, j2 - 1] == 0
    for j2 in range(j + 1, len(plan.levels)):
        ok &= digits[:, j2 - 1] < plan.levels[j2]
    src = np.nonzero(ok)[0]
    slot_stride = 1
    for j2 in range(1, j):
        slot_stride *= plan.slot_bases[j2 - 1]
    dst = src - rho * stride * slot_stride
    return dst, src


def multilevel_message_size(plan: MultiLevelPlan, j: int, t: int, rho: int) -> int:
    """Live elements shipped on port ρ in level-j reduction round t."""
    return int(multilevel_level_slots(plan, j, t, rho)[1].size)


def _outer_coords(plan: MultiLevelPlan) -> np.ndarray:
    """(K, L−1) outer coordinates of every device (level 1 first)."""
    L1 = len(plan.levels) - 1
    out = np.zeros((plan.K, L1), dtype=np.int64)
    c = np.arange(plan.K) // plan.levels[0]
    for j in range(L1):
        out[:, j] = c % plan.levels[j + 1]
        c = c // plan.levels[j + 1]
    return out


def multilevel_dev_shift(plan: MultiLevelPlan, k: int, j: int, s: int) -> int:
    """Device id after shifting the level-j coordinate of device k by s."""
    stride = 1
    for kj in plan.levels[:j]:
        stride *= kj
    cj = (k // stride) % plan.levels[j]
    return k + (((cj + s) % plan.levels[j]) - cj) * stride


def multilevel_coeff_tensor(plan: MultiLevelPlan, A: np.ndarray) -> np.ndarray:
    """coef[k, u, l] = A[row, col] with row = device (same outer coords,
    (i−u) mod K_0) and col = device (outer coords shifted component-wise by
    slot l's per-level digits, same i), masked to live slots — the
    multi-level analogue of :func:`hierarchical_coeff_tensor`."""
    K, K0, n = plan.K, plan.levels[0], plan.n_slots
    k = np.arange(K)
    i = k % K0
    u = np.arange(K0)
    rows = ((k // K0) * K0)[:, None] + (i[:, None] - u[None, :]) % K0  # (K, K0)
    oc = _outer_coords(plan)  # (K, L-1)
    digits = _slot_digits(plan)  # (n, L-1)
    t_outer = np.zeros((K, n), dtype=np.int64)
    mult = 1
    for j, kj in enumerate(plan.levels[1:]):
        t_outer += ((oc[:, j][:, None] + digits[:, j][None, :]) % kj) * mult
        mult *= kj
    cols = t_outer * K0 + i[:, None]  # (K, n)
    coef = np.asarray(A)[rows[:, :, None], cols[:, None, :]]  # (K, K0, n)
    return coef * multilevel_live_mask(plan)[None, None, :]


def _multilevel_ir(plan: MultiLevelPlan, A=None, *, q: int = M31):
    """Compile the recursive schedule to ScheduleIR: level-0 doubling gather
    (store mode, contiguous offsets), one LocalOp contraction into the
    per-level offset slots (live-masked coefficients), then one §IV
    digit-reduction CommRound per (outer level, round), innermost first."""
    from repro.core.ir import CommRound, LocalOp, ScheduleIR, Transfer

    K, p, K0 = plan.K, plan.p, plan.levels[0]
    steps: list = []
    for ports in plan.intra_rounds:
        transfers = []
        for rho, (s, cnt) in enumerate(ports, start=1):
            for k in range(K):
                g, i = divmod(k, K0)
                transfers.append(
                    Transfer(
                        src=k,
                        dst=g * K0 + (i + s) % K0,
                        port=rho,
                        slots=tuple((u, s + u) for u in range(cnt)),
                        mode="store",
                    )
                )
        steps.append(CommRound(tuple(transfers)))
    coeffs = None
    if A is not None:
        coef = multilevel_coeff_tensor(plan, Field(q).asarray(A))  # (K, K0, n)
        coeffs = np.ascontiguousarray(np.swapaxes(coef, 1, 2))  # (K, n, K0)
    steps.append(
        LocalOp(tuple(range(plan.n_slots)), tuple(range(K0)), coeffs)
    )
    for j in range(1, len(plan.levels)):
        for t, shifts in enumerate(plan.level_shifts[j - 1], start=1):
            transfers = []
            for rho, s in enumerate(shifts, start=1):
                dst_slots, src_slots = multilevel_level_slots(plan, j, t, rho)
                if src_slots.size == 0:
                    continue
                slots = tuple(
                    (int(ls), int(ld)) for ld, ls in zip(dst_slots, src_slots)
                )
                for k in range(K):
                    transfers.append(
                        Transfer(
                            src=k,
                            dst=multilevel_dev_shift(plan, k, j, s),
                            port=rho,
                            slots=slots,
                            mode="add",
                        )
                    )
            steps.append(CommRound(tuple(transfers)))
    return ScheduleIR("multilevel", K, p, tuple(steps))


def simulate_multilevel(
    x: np.ndarray, A: np.ndarray, plan: MultiLevelPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Message-passing execution of the recursive schedule under the p-port
    constraints (generic IR interpreter); bit-exact ``x @ A`` for ANY matrix
    A and ANY factorization. Returns (x̃, stats)."""
    out, stats = interpret(plan.to_ir(A, q=field.q), x, field)
    np.testing.assert_array_equal(out, field.matmul(field.asarray(x), A))
    return out, stats


# ---------------------------------------------------------------------------
# ring-optimized universal schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RingPlan:
    """Neighbor-only all-gather + local combine: the bandwidth-optimal
    universal schedule on a ring (1 hop per message, zero contention)."""

    K: int
    p: int  # p ≥ 2 → bidirectional (⌈(K−1)/2⌉ rounds); p = 1 → K−1 rounds

    @property
    def c1(self) -> int:
        if self.K <= 1:
            return 0
        return self.K - 1 if self.p == 1 else -(-(self.K - 1) // 2)

    @property
    def c2(self) -> int:
        return self.c1  # one element per port per round

    @property
    def algorithm(self) -> str:
        return "ring"

    def to_ir(self, A=None, *, q: int = M31):
        return _ring_ir(self, A, q=q)


def plan_ring(K: int, p: int) -> RingPlan:
    return RingPlan(K=K, p=p)


def _ring_ir(plan: RingPlan, A=None, *, q: int = M31):
    """Compile the neighbor-only schedule: round j's forward stream carries
    the offset-(j−1) packet to k+1 (stored at offset j), the backward stream
    the offset-(K−j+1) packet to k−1 (stored at offset K−j); one final
    LocalOp combines all K offsets against the receiver's column of A."""
    from repro.core.ir import CommRound, LocalOp, ScheduleIR, Transfer, _combine_coeffs

    K = plan.K
    steps: list = []

    def fwd(j):
        return [
            Transfer(k, (k + 1) % K, port=1, slots=((j - 1, j),), mode="store")
            for k in range(K)
        ]

    def bwd(j):
        return [
            Transfer(
                k,
                (k - 1) % K,
                port=2,
                slots=(((K - j + 1) % K, K - j),),
                mode="store",
            )
            for k in range(K)
        ]

    if K > 1:
        if plan.p == 1:
            for j in range(1, K):
                steps.append(CommRound(tuple(fwd(j))))
        else:
            r = -(-(K - 1) // 2)
            for j in range(1, r + 1):
                ts = fwd(j)
                if not (j == r and (K - 1) % 2 == 1):  # odd remainder: fwd only
                    ts += bwd(j)
                steps.append(CommRound(tuple(ts)))
    steps.append(LocalOp((0,), tuple(range(K)), _combine_coeffs(K, A, q)))
    return ScheduleIR("ring", K, plan.p, tuple(steps))


def ring_rounds(plan: RingPlan) -> list[dict]:
    """Per-round message maps {(src, dst): elements} of the ring schedule
    (the lowering format of topo.lower / SimStats.round_messages)."""
    from repro.core.ir import ir_messages

    return ir_messages(plan.to_ir())


def simulate_ring_encode(
    x: np.ndarray, A: np.ndarray, plan: RingPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Store-and-forward execution of the ring schedule; exact for any A."""
    out, stats = interpret(plan.to_ir(A, q=field.q), x, field)
    np.testing.assert_array_equal(out, field.matmul(field.asarray(x), A))
    return out, stats


# ---------------------------------------------------------------------------
# two-level Cooley–Tukey DFT
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TwoLevelDFTPlan:
    """β^{nk} factorization for K = I·G (see module doc): intra butterfly →
    local twiddle → inter butterfly. Relabelings: device (g, i) holds source
    coefficient ``input_coeff[k]`` and finishes with X[``output_index[k]``]."""

    K: int
    p: int
    k_intra: int
    k_inter: int
    q: int
    input_coeff: np.ndarray  # (K,) n = G·rev_I(i) + rev_G(g)
    output_index: np.ndarray  # (K,) i + I·g
    twiddle: np.ndarray  # (K,) β^{rev_G(g)·i} applied between the stages

    @property
    def c1(self) -> int:
        return ceil_log(self.k_intra, self.p + 1) + ceil_log(self.k_inter, self.p + 1)

    @property
    def c2(self) -> int:
        return self.c1  # both stages are butterflies: 1 element per round

    @property
    def algorithm(self) -> str:
        return "hierarchical-dft"

    def to_ir(self):
        from repro.core.ir import LocalOp, ScheduleIR, embed_parallel, ir_butterfly

        I, G, K = self.k_intra, self.k_inter, self.K
        steps: list = []
        if I > 1:
            sub = ir_butterfly(plan_butterfly(I, self.p, self.q))
            steps += embed_parallel(
                sub, K, [g * I + np.arange(I) for g in range(G)]
            )
        tw = np.zeros((K, 1, 1), dtype=np.uint64)
        tw[:, 0, 0] = self.twiddle
        steps.append(LocalOp((0,), (0,), tw))
        if G > 1:
            sub = ir_butterfly(plan_butterfly(G, self.p, self.q))
            steps += embed_parallel(
                sub, K, [np.arange(G) * I + i for i in range(I)]
            )
        return ScheduleIR("hierarchical-dft", K, self.p, tuple(steps))


def plan_two_level_dft(K: int, p: int, q: int, k_intra: int) -> TwoLevelDFTPlan:
    """Requires K | q−1 and k_intra, K/k_intra powers of p+1 (each stage is a
    radix-(p+1) butterfly)."""
    if K % k_intra:
        raise ValueError(f"k_intra={k_intra} must divide K={K}")
    I, G = k_intra, K // k_intra
    radix = p + 1
    for sz in (I, G):
        if radix ** ceil_log(sz, radix) != sz:
            raise ValueError(f"stage size {sz} is not a power of {radix}")
    if (q - 1) % K:
        raise ValueError(f"K={K} must divide q-1={q - 1}")
    f = Field(q)
    beta = f.root_of_unity(K)
    rev_i = digit_reversal_permutation(I, radix) if I > 1 else np.zeros(1, np.int64)
    rev_g = digit_reversal_permutation(G, radix) if G > 1 else np.zeros(1, np.int64)
    k = np.arange(K)
    g, i = k // I, k % I
    input_coeff = G * rev_i[i] + rev_g[g]
    output_index = i + I * g
    twiddle = f.pow(np.full(K, beta, dtype=np.uint64), rev_g[g] * i)
    return TwoLevelDFTPlan(
        K=K,
        p=p,
        k_intra=I,
        k_inter=G,
        q=q,
        input_coeff=input_coeff,
        output_index=output_index,
        twiddle=twiddle,
    )


def two_level_dft_matrix(plan: TwoLevelDFTPlan) -> np.ndarray:
    """The effective generator: M[k, k'] = D_K[input_coeff[k],
    output_index[k']] — a row/col permutation of the DFT matrix (still MDS),
    so ``simulate_two_level_dft(x) == x @ M`` bit-exactly."""
    from repro.core.matrices import dft_matrix

    D = dft_matrix(Field(plan.q), plan.K)
    return D[plan.input_coeff][:, plan.output_index]


def simulate_two_level_dft(
    x: np.ndarray, plan: TwoLevelDFTPlan, field: Field
) -> tuple[np.ndarray, SimStats]:
    """Both butterfly stages message-by-message on one interpreter: every
    group's (resp. stride-column's) butterfly shares rounds, so C1 = C2 =
    log I + log G is measured globally under the p-port constraints."""
    return interpret(plan.to_ir(), x, field)


# ---------------------------------------------------------------------------
# recursive multi-level Cooley–Tukey DFT (K = Π K_level)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MultiLevelDFTPlan:
    """Recursive Cooley–Tukey factorization over ``levels`` (innermost
    first, each a power of p+1, Π = K): one radix-(p+1) butterfly stage per
    level over that level's coordinate, with a diagonal twiddle applied
    before each stage — C1 = C2 = Σ_j log_{p+1} K_j = log_{p+1} K, the
    structured analogue of :class:`MultiLevelPlan`.

    Built by iterating the verified two-level identity β^{nk} = ω_I^{n1·k1} ·
    β^{n2·k1} · ω_G^{n2·k2}: the inter factor DFT_G is itself factored over
    ``levels[1:]`` (the field's canonical roots nest exactly —
    ``root_of_unity(G) = root_of_unity(K)^I``). Relabelings compose "up to
    permutation" exactly as in the two-level case: device k holds source
    coefficient ``input_coeff[k]`` and finishes with X[``output_index[k]``];
    :func:`multilevel_dft_matrix` is the effective generator.

    This plan has NO bespoke simulator/lowering/executor: it compiles
    straight to ScheduleIR (``to_ir``), so simulation is
    ``core.simulator.interpret``, pricing is ``topo.lower.lower``, and mesh
    execution is ``dist.collectives.ir_encode_jit``."""

    K: int
    p: int
    q: int
    levels: tuple[int, ...]
    input_coeff: np.ndarray  # (K,)
    output_index: np.ndarray  # (K,)
    stage_twiddles: tuple  # per level: (K,) uint64 diagonal applied pre-stage

    @property
    def c1(self) -> int:
        return sum(ceil_log(v, self.p + 1) for v in self.levels)

    @property
    def c2(self) -> int:
        return self.c1  # every stage is a butterfly: 1 element per round

    @property
    def algorithm(self) -> str:
        return "multilevel-dft"

    def to_ir(self):
        from repro.core.ir import LocalOp, ScheduleIR, embed_parallel, ir_butterfly

        K, p, q = self.K, self.p, self.q
        steps: list = []
        stride = 1
        for j, nj in enumerate(self.levels):
            tw = np.zeros((K, 1, 1), dtype=np.uint64)
            tw[:, 0, 0] = self.stage_twiddles[j]
            steps.append(LocalOp((0,), (0,), tw))
            if nj > 1:
                sub = ir_butterfly(plan_butterfly(nj, p, q))
                maps = []
                for hi in range(K // (stride * nj)):
                    for lo in range(stride):
                        maps.append(hi * stride * nj + lo + np.arange(nj) * stride)
                steps += embed_parallel(sub, K, maps)
            stride *= nj
        return ScheduleIR("multilevel-dft", K, p, tuple(steps))


def plan_multilevel_dft(K: int, p: int, q: int, levels) -> MultiLevelDFTPlan:
    """Requires K | q−1 and every level a power of p+1 (trivial levels of
    size 1 are allowed — their stage has zero rounds and an all-ones twiddle
    that ``fuse_trivial_rounds`` removes)."""
    levels = tuple(int(v) for v in levels)
    radix = p + 1
    prod = 1
    for v in levels:
        prod *= v
    if not levels or prod != K or any(v < 1 for v in levels):
        raise ValueError(f"levels must be positive with Π levels = K: {levels}, K={K}")
    for v in levels:
        if radix ** ceil_log(v, radix) != v:
            raise ValueError(f"level size {v} is not a power of {radix}")
    if K > 1 and (q - 1) % K:
        raise ValueError(f"K={K} must divide q-1={q - 1}")
    f = Field(q)

    def build(lvls):
        n = 1
        for v in lvls:
            n *= v
        if len(lvls) == 1:
            I = lvls[0]
            rev = (
                digit_reversal_permutation(I, radix)
                if I > 1
                else np.zeros(1, dtype=np.int64)
            )
            return (
                rev.astype(np.int64),
                np.arange(I, dtype=np.int64),
                [np.ones(I, dtype=np.uint64)],
            )
        I = lvls[0]
        G = n // I
        sub_in, sub_out, sub_tw = build(lvls[1:])
        rev_i = (
            digit_reversal_permutation(I, radix)
            if I > 1
            else np.zeros(1, dtype=np.int64)
        )
        k = np.arange(n)
        g, i = k // I, k % I
        input_coeff = G * rev_i[i] + sub_in[g]
        output_index = i + I * sub_out[g]
        if n > 1:
            beta = f.root_of_unity(n)
            cross = f.pow(np.full(n, beta, dtype=np.uint64), sub_in[g] * i)
        else:
            cross = np.ones(n, dtype=np.uint64)
        tws = [np.ones(n, dtype=np.uint64), f.mul(cross, sub_tw[0][g])]
        for j in range(1, len(sub_tw)):
            tws.append(sub_tw[j][g].astype(np.uint64))
        return input_coeff, output_index, tws

    input_coeff, output_index, tws = build(levels)
    return MultiLevelDFTPlan(
        K=K,
        p=p,
        q=q,
        levels=levels,
        input_coeff=np.asarray(input_coeff, dtype=np.int64),
        output_index=np.asarray(output_index, dtype=np.int64),
        stage_twiddles=tuple(np.asarray(t, dtype=np.uint64) for t in tws),
    )


def multilevel_dft_matrix(plan: MultiLevelDFTPlan) -> np.ndarray:
    """The effective generator: M[k, k'] = D_K[input_coeff[k],
    output_index[k']] — a row/col permutation of the DFT matrix (still MDS),
    so ``interpret(plan.to_ir(), x, f) == x @ M`` bit-exactly."""
    from repro.core.matrices import dft_matrix

    D = dft_matrix(Field(plan.q), plan.K)
    return D[plan.input_coeff][:, plan.output_index]
