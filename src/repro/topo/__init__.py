# Topology-aware layer over the flat p-port model (ROADMAP: "as fast as the
# hardware allows" on real, hierarchical networks).
#
# - model.py         declarative topologies (flat, ring, torus, two-level) +
#                    α-β time estimation of arbitrary round schedules
# - lower.py         plan → explicit per-round message maps, hop counts,
#                    link contention (cross-checked vs. the exact simulator)
# - hierarchical.py  two-level prepare-and-shoot, Cooley–Tukey two-level DFT,
#                    ring-optimized schedule + their exact simulators
# - autotune.py      per-(K, p, payload, topology) algorithm selection with
#                    a measured-override calibration hook
#
# The mesh executor for the hierarchical schedule lives in
# dist/collectives.hierarchical_encode_jit (dist lowers plans, as always).

from .autotune import Candidate, TuneResult, autotune, candidates_for  # noqa: F401
from .hierarchical import (  # noqa: F401
    HierarchicalPlan,
    RingPlan,
    TwoLevelDFTPlan,
    hierarchical_coeff_tensor,
    plan_hierarchical,
    plan_ring,
    plan_two_level_dft,
    simulate_hierarchical,
    simulate_ring_encode,
    simulate_two_level_dft,
    two_level_dft_matrix,
)
from .lower import LoweredSchedule, lower, lower_allgather  # noqa: F401
from .model import (  # noqa: F401
    DCI,
    ICI,
    FullyConnected,
    LinkCost,
    Ring,
    TimeEstimate,
    Topology,
    Torus2D,
    TwoLevel,
    make_topology,
    schedule_time,
)
