# Topology-aware layer over the flat p-port model (ROADMAP: "as fast as the
# hardware allows" on real, hierarchical networks).
#
# - model.py         declarative topologies (flat, ring, torus, two-level,
#                    recursive hierarchy) + α-β time estimation of arbitrary
#                    round schedules
# - lower.py         plan → explicit per-round message maps, hop counts,
#                    link contention (cross-checked vs. the exact simulator)
# - hierarchical.py  two-level prepare-and-shoot, recursive multi-level
#                    encode (K = Π K_j), Cooley–Tukey two-level DFT,
#                    ring-optimized schedule + their exact simulators
# - autotune.py      per-(K, p, payload, topology) algorithm selection with
#                    a measured-override calibration hook
#
# The mesh executors for the hierarchical schedules live in
# dist/collectives.hierarchical_encode_jit (2D) and
# dist/collectives.multilevel_encode_jit (N-D) — dist lowers plans, as always.

from .autotune import Candidate, TuneResult, autotune, candidates_for  # noqa: F401
from .hierarchical import (  # noqa: F401
    HierarchicalPlan,
    MultiLevelPlan,
    RingPlan,
    TwoLevelDFTPlan,
    hierarchical_coeff_tensor,
    multilevel_coeff_tensor,
    multilevel_level_slots,
    multilevel_live_mask,
    multilevel_message_size,
    plan_hierarchical,
    plan_multilevel,
    plan_ring,
    plan_two_level_dft,
    simulate_hierarchical,
    simulate_multilevel,
    simulate_ring_encode,
    simulate_two_level_dft,
    two_level_dft_matrix,
)
from .lower import LoweredSchedule, lower, lower_allgather  # noqa: F401
from .model import (  # noqa: F401
    DCI,
    ICI,
    FullyConnected,
    Hierarchy,
    LinkCost,
    Ring,
    TimeEstimate,
    Topology,
    Torus2D,
    TwoLevel,
    default_level_costs,
    default_levels,
    make_topology,
    schedule_time,
)
