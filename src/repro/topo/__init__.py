# Topology-aware layer over the flat p-port model (ROADMAP: "as fast as the
# hardware allows" on real, hierarchical networks).
#
# - model.py         declarative topologies (flat, ring, torus, two-level,
#                    recursive hierarchy) + α-β time estimation of arbitrary
#                    round schedules
# - lower.py         ScheduleIR → explicit per-round message maps, hop
#                    counts, link contention (cross-checked vs. the exact
#                    interpreter); every plan lowers through plan.to_ir()
# - hierarchical.py  two-level prepare-and-shoot, recursive multi-level
#                    encode (K = Π K_j), Cooley–Tukey two-level AND
#                    multi-level DFT, ring-optimized schedule — all compiled
#                    to ScheduleIR and simulated by core.simulator.interpret
# - passes.py        the pass-pipeline optimizer: named, composable IR
#                    rewrites with applicability predicates (remap_digits,
#                    split_contended, fuse_rounds, align_subgroups) and the
#                    PassPipeline registry the autotuner enumerates
# - calibrate.py     least-squares per-level α/β from measured sweeps +
#                    load_fitted_costs (persisted calibration → LinkCosts)
# - autotune.py      per-(K, p, payload, topology) selection by enumerating
#                    and pricing (algorithm, pipeline) ScheduleIR candidates,
#                    with a measured-override hook
#
# The ONE mesh executor for any IR is dist/collectives.ir_encode_jit; the
# per-algorithm *_encode_jit entry points dispatch through it.

from .autotune import Candidate, TuneResult, autotune, candidates_for  # noqa: F401
from .calibrate import (  # noqa: F401
    fit_level_costs,
    load_fitted_costs,
    round_features,
)
from .hierarchical import (  # noqa: F401
    HierarchicalPlan,
    MultiLevelDFTPlan,
    MultiLevelPlan,
    RingPlan,
    TwoLevelDFTPlan,
    hierarchical_coeff_tensor,
    multilevel_coeff_tensor,
    multilevel_dft_matrix,
    multilevel_level_slots,
    multilevel_live_mask,
    multilevel_message_size,
    plan_hierarchical,
    plan_multilevel,
    plan_multilevel_dft,
    plan_ring,
    plan_two_level_dft,
    simulate_hierarchical,
    simulate_multilevel,
    simulate_ring_encode,
    simulate_two_level_dft,
    two_level_dft_matrix,
)
from .lower import LoweredSchedule, lower, lower_allgather, lower_ir  # noqa: F401
from .model import (  # noqa: F401
    DCI,
    ICI,
    FullyConnected,
    Hierarchy,
    LinkCost,
    Ring,
    TimeEstimate,
    Topology,
    Torus2D,
    Torus3D,
    TwoLevel,
    default_level_costs,
    default_levels,
    make_topology,
    schedule_time,
)
from .passes import (  # noqa: F401
    PASSES,
    PIPELINES,
    Pass,
    PassPipeline,
    align_subgroups,
    fuse_rounds,
    ir_time,
    max_round_hops,
    pipelines_for,
    remap_digits,
    split_contended,
)
