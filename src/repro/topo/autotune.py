"""Algorithm autotuner: pick the fastest encode schedule for a scenario.

Given (K, p, payload bytes, topology, generator kind) the tuner **enumerates
ScheduleIRs**: every applicable plan is compiled with ``plan.to_ir()``,
cleaned by ``fuse_trivial_rounds``, optionally rewritten by topology-aware
passes (``remap_digits`` on a 2D torus), and priced on the topology through
its IR message maps with the α-β estimator. The cheapest wins. Because
candidates are IRs rather than hand-registered callables, a new algorithm
participates the moment its plan compiles — no per-family lowering or
simulator registration. Related work shows the winner genuinely flips with
topology (ring networks favor neighbor-only schedules; two-level meshes
favor level-aligned ones), which is exactly what the estimator captures
through per-link contention.

Applicability matrix (the "universal promise" vs. structured generators):

* ``general``      — prepare-shoot, hierarchical, multilevel, allgather, ring
* ``vandermonde``  — the above + draw-loose
* ``dft``          — all of the above + butterfly + two-level and
  multi-level DFT

A candidate is an **(algorithm, pipeline)** pair: beyond the un-rewritten
compile of every applicable plan, the tuner asks the pass registry
(``topo.passes.pipelines_for``) which :class:`~repro.topo.passes.PassPipeline`
applies to each compiled IR, applies it, prices the rewritten IR, and ranks
everything together. A pipelined candidate is named
``"<algorithm>+<pipeline>"`` (e.g. ``"butterfly+remap-digits"`` on a torus,
``"draw-loose+align-subgroups"`` on a hierarchy — the ROADMAP's
hierarchical draw-loose is exactly that pipeline stage, not a separate
algorithm family) and records the pipeline name in ``Candidate.pipeline``.

The ``multilevel`` / ``multilevel-dft`` candidates appear when the topology
is a :class:`~repro.topo.model.Hierarchy` whose level product matches K: the
plan factorization is taken from the topology itself, so the schedule's
phases align with the hardware's levels by construction.

A ``measured`` override hook replaces predicted times with wall-clock
numbers (e.g. from benchmarks/bench_topology.py) without changing the
selection logic; ``topo.calibrate.fit_level_costs`` turns the same sweeps
into fitted per-level α/β.

Paper-notation glossary: ``K`` processors, ``p`` ports, ``C1`` rounds,
``C2`` per-port elements (paper §I); ``I``/``G`` the two-level k_intra ×
k_inter split; ``digit-reduction slots`` the §IV shoot buffer layout (one
slot per (p+1)-ary numeral of the remaining target offset).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.field import M31
from repro.core.ir import ScheduleIR, fuse_trivial_rounds, ir_allgather
from repro.core.schedule import plan_butterfly, plan_draw_loose, plan_prepare_shoot

from .hierarchical import (
    plan_hierarchical,
    plan_multilevel,
    plan_multilevel_dft,
    plan_ring,
    plan_two_level_dft,
)
from .lower import LoweredSchedule, lower_ir
from .model import Hierarchy, TimeEstimate, Topology, Torus2D, TwoLevel

GENERATOR_KINDS = ("general", "vandermonde", "dft")

# deterministic tie-break: structured algorithms first (they generalize
# less), flat-canonical schedules before their topology-rewritten or
# multi-level equivalents
_PREFERENCE = (
    "butterfly",
    "hierarchical-dft",
    "multilevel-dft",
    "draw-loose",
    "prepare-shoot",
    "hierarchical",
    "multilevel",
    "ring",
    "allgather",
)


def _preference_rank(base_algorithm: str) -> int:
    """Tie-break rank; unknown names (plugins, renamed families) sort last
    instead of raising — the historical ``_PREFERENCE.index`` blew up with
    ValueError on any name outside the hardcoded tuple."""
    try:
        return _PREFERENCE.index(base_algorithm)
    except ValueError:
        return len(_PREFERENCE)


@dataclass(frozen=True)
class Candidate:
    algorithm: str  # full name: "<base>" or "<base>+<pipeline>"
    plan: object  # schedule plan (None for the plan-less allgather baseline)
    ir: ScheduleIR  # the compiled (and pass-rewritten) schedule
    lowered: LoweredSchedule
    estimate: TimeEstimate
    measured_time: float | None = None
    pipeline: str = ""  # PassPipeline name; "" = un-rewritten compile
    base_algorithm: str = ""  # plan family name without the pipeline suffix

    @property
    def c1(self) -> int:
        return self.lowered.c1

    @property
    def c2(self) -> int:
        return self.lowered.c2

    @property
    def predicted_time(self) -> float:
        return self.estimate.total

    @property
    def time(self) -> float:
        return self.measured_time if self.measured_time is not None else self.estimate.total


@dataclass(frozen=True)
class TuneResult:
    chosen: Candidate
    candidates: tuple[Candidate, ...]  # sorted fastest-first

    @property
    def algorithm(self) -> str:
        return self.chosen.algorithm


def _split_for(topo: Topology, K: int) -> int:
    """k_intra for the two-level hierarchical schedules: the topology's own
    fast-domain size when it has one (for a Hierarchy, everything below the
    outermost level), else the most balanced divisor."""
    if isinstance(topo, TwoLevel) and K % topo.k_intra == 0:
        return topo.k_intra
    if isinstance(topo, Hierarchy) and topo.n == K and K % topo.levels[-1] == 0:
        return K // topo.levels[-1]
    from .model import _near_square

    return _near_square(K)


def _levels_for(topo: Topology, K: int) -> tuple[int, ...] | None:
    """Factorization for the multi-level candidates: the Hierarchy's own
    levels when they multiply to K and at least two are non-trivial."""
    if isinstance(topo, Hierarchy) and topo.n == K:
        if sum(1 for k in topo.levels if k > 1) >= 2:
            return topo.levels
    return None


def _priced(ir: ScheduleIR, low: LoweredSchedule, topo: Topology, payload_elems: int):
    """Comm estimate from the lowered schedule plus the MAC-priced local
    compute (with ``pipeline_rounds``' overlap credit) — ``total`` carries
    both terms, ``per_round`` stays comm-only (the round-count contracts the
    tests pin)."""
    from .passes import ir_compute_time

    est = low.time(topo, payload_elems)
    extra = ir_compute_time(ir, topo, payload_elems)
    return replace(est, total=est.total + extra) if extra else est


def candidates_for(
    K: int,
    p: int,
    topo: Topology,
    *,
    q: int = M31,
    payload_elems: int = 1,
    generator: str = "general",
    seed: int = 0,
    pipelines: bool = True,
) -> list[Candidate]:
    if generator not in GENERATOR_KINDS:
        raise ValueError(f"generator must be one of {GENERATOR_KINDS}")

    def cand(plan, ir=None):
        ir = fuse_trivial_rounds(ir if ir is not None else plan.to_ir())
        low = lower_ir(ir)
        return Candidate(
            algorithm=low.algorithm,
            plan=plan,
            ir=ir,
            lowered=low,
            estimate=_priced(ir, low, topo, payload_elems),
            base_algorithm=low.algorithm,
        )

    out = [
        cand(plan_prepare_shoot(K, p)),
        cand(None, ir=ir_allgather(K, p)),
        cand(plan_ring(K, p)),
    ]
    k_intra = _split_for(topo, K)
    if 1 < k_intra < K:
        out.append(cand(plan_hierarchical(K, p, k_intra)))
    levels = _levels_for(topo, K)
    if levels is not None:
        out.append(cand(plan_multilevel(K, p, levels)))
    if generator in ("vandermonde", "dft"):
        try:
            out.append(cand(plan_draw_loose(K, p, q, seed=seed)))
        except (ValueError, RuntimeError):
            pass  # field too small / no valid phi — not applicable
    if generator == "dft":
        try:
            out.append(cand(plan_butterfly(K, p, q)))
        except ValueError:
            pass  # K not a power of p+1 or K ∤ q-1
        for ki in dict.fromkeys((k_intra, _dft_split(K, p))):
            if ki is None or not (1 < ki < K):
                continue
            try:
                out.append(cand(plan_two_level_dft(K, p, q, ki)))
                break
            except ValueError:
                continue
        if levels is not None:
            try:
                out.append(cand(plan_multilevel_dft(K, p, q, levels)))
            except ValueError:
                pass  # levels not powers of p+1 or K ∤ q-1
    if pipelines:
        out += _pipeline_candidates(out, topo, payload_elems)
    return out


def _pipeline_candidates(
    base: list[Candidate], topo: Topology, payload_elems: int
) -> list[Candidate]:
    """One extra candidate per (base candidate, applicable pipeline) whose
    rewrite actually changed the IR — the (algorithm, pipeline) half of the
    search space. The base plan is kept so downstream consumers (profiles,
    mesh executors) can recompile ``plan.to_ir(A)`` and re-apply the named
    pipeline with coefficients baked in."""
    from .passes import pipelines_for

    out = []
    for c in base:
        for pl in pipelines_for(c.ir, topo):
            try:
                rewritten = pl.apply(c.ir, topo, payload_elems)
            except ValueError:
                continue  # predicate passed but the rewrite found no embedding
            if rewritten is c.ir:
                continue  # no-op on this IR — pricing it would duplicate base
            rewritten = replace(
                rewritten, algorithm=f"{c.base_algorithm}+{pl.name}"
            )
            low = lower_ir(rewritten)
            out.append(
                Candidate(
                    algorithm=rewritten.algorithm,
                    plan=c.plan,
                    ir=rewritten,
                    lowered=low,
                    estimate=_priced(rewritten, low, topo, payload_elems),
                    pipeline=pl.name,
                    base_algorithm=c.base_algorithm,
                )
            )
    return out


def _dft_split(K: int, p: int) -> int | None:
    """Balanced K = I·G with both factors powers of p+1 (needs K a power)."""
    from repro.core.bounds import ceil_log

    radix = p + 1
    H = ceil_log(K, radix)
    if radix**H != K or H < 2:
        return None
    return radix ** (H // 2)


def autotune(
    K: int,
    p: int,
    payload_bytes: int,
    topo: Topology,
    *,
    q: int = M31,
    generator: str = "general",
    measured: dict[str, float] | None = None,
    seed: int = 0,
    pipelines: bool = True,
) -> TuneResult:
    """Pick the cheapest applicable (algorithm, pipeline) pair for this
    scenario. ``measured`` maps full candidate name → measured seconds,
    overriding the α-β prediction."""
    payload_elems = max(1, payload_bytes // 4)
    cands = candidates_for(
        K,
        p,
        topo,
        q=q,
        payload_elems=payload_elems,
        generator=generator,
        seed=seed,
        pipelines=pipelines,
    )
    if measured:
        cands = [
            replace(c, measured_time=measured.get(c.algorithm, c.measured_time))
            for c in cands
        ]
    # ties: any un-rewritten compile before any pipelined rewrite (a pipeline
    # must strictly win on price to be chosen), then the preferred family
    ranked = sorted(
        cands,
        key=lambda c: (
            c.time,
            c.pipeline != "",
            _preference_rank(c.base_algorithm or c.algorithm),
            c.pipeline,
        ),
    )
    return TuneResult(chosen=ranked[0], candidates=tuple(ranked))
