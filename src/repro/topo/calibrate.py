"""Bandwidth-measured calibration: fit per-level α/β from wall-time sweeps.

The α-β predictions in ``topo/model.py`` use v5e-ish constants; real
hardware should fit its own. For a level-aligned schedule on a
:class:`~repro.topo.model.Hierarchy`, each round's traffic rides exactly one
level, so a measured wall time decomposes linearly:

    wall ≈ Σ_rounds  (msgs_on_busiest_link · α_level  +
                      elems_on_busiest_link · payload · β_level)

:func:`round_features` extracts the per-round (level, msgs, elems) rows from
any lowered schedule, and :func:`fit_level_costs` least-squares the stacked
sweep (multiple algorithms × payload sizes, e.g. the ``calibration`` block
``benchmarks/bench_topology.py`` writes into ``results/BENCH_topology.json``)
into one :class:`~repro.topo.model.LinkCost` per level — ready to pass as
``Hierarchy(levels, costs=fitted)`` or compare against
``default_level_costs``. This is the ROADMAP's "fit per-level α/β from
sweeps instead of the v5e constants" item.

Two measurement sources feed the same fit: the offline aggregate sweep
(whole-encode wall times × analytic :func:`round_features` rows, as the
benchmark's ``calibration.samples``) and the live traced path —
``dist.collectives.ir_encode_jit(tracer=...)`` stamps each round span with
its (level, msgs, elems) feature, and ``repro.obs.feed`` turns those spans
into per-round measurements, refits, and persists exactly where
:func:`load_fitted_costs` reads.
"""

from __future__ import annotations

import json
import os

import numpy as np

from .model import Hierarchy, LinkCost, round_link_loads

#: default location of the persisted calibration block (repo-root relative)
DEFAULT_CALIBRATION_PATH = os.path.join(
    os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    ),
    "results",
    "BENCH_topology.json",
)


def round_features(rounds, topo: Hierarchy) -> list[dict]:
    """Per round: ``{"level": j, "msgs": a, "elems": e}`` — the busiest link
    of the round's highest occupied level (level-aligned schedules touch one
    level per round; for mixed rounds the slowest level dominates). These
    are the per-round rows the calibration fit consumes; ``elems`` is in
    schedule units (multiply by payload elements when fitting)."""
    out = []
    for msgs in rounds:
        loads = round_link_loads(topo, msgs)
        if not loads:
            continue
        top = max(link[1] for link in loads)
        cnt, elems = max(
            (v for link, v in loads.items() if link[1] == top),
            key=lambda v: (v[1], v[0]),
        )
        out.append({"level": int(top), "msgs": int(cnt), "elems": int(elems)})
    return out


def fit_level_costs(measurements, n_levels: int) -> tuple[LinkCost, ...]:
    """Least-squares (α_j, β_j) per level from measured wall times.

    ``measurements``: iterable of dicts with

    * ``"wall_s"`` — measured seconds for one (algorithm, payload) run;
    * ``"payload_elems"`` — field elements per schedule unit;
    * ``"rounds"`` — the :func:`round_features` rows of that schedule.

    Solves ``wall ≈ Σ_j A_j·α_j + E_j·β_j`` with A_j = Σ msgs over level-j
    rounds and E_j = Σ elems·payload; needs ≥ 2·n_levels independent samples
    (sweep payload sizes). Coefficients are clipped to a small positive
    floor — a physical link never has negative cost."""
    rows, y = [], []
    for m in measurements:
        feat = np.zeros(2 * n_levels)
        pay = float(m.get("payload_elems", 1))
        for r in m["rounds"]:
            j = int(r["level"])
            if not 0 <= j < n_levels:
                raise ValueError(f"round level {j} outside [0, {n_levels})")
            feat[2 * j] += r["msgs"]
            feat[2 * j + 1] += r["elems"] * pay
        rows.append(feat)
        y.append(float(m["wall_s"]))
    X = np.asarray(rows)
    y = np.asarray(y)
    if X.shape[0] < 2 * n_levels:
        raise ValueError(
            f"need ≥ {2 * n_levels} samples to fit {n_levels} levels, got {X.shape[0]}"
        )
    theta, *_ = np.linalg.lstsq(X, y, rcond=None)
    theta = np.maximum(theta, 1e-12)
    return tuple(
        LinkCost(alpha=float(theta[2 * j]), beta=float(theta[2 * j + 1]))
        for j in range(n_levels)
    )


def load_fitted_costs(path: str | None = None) -> tuple[LinkCost, ...] | None:
    """Load the fitted per-level α/β that ``benchmarks/bench_topology.py``
    persists under ``calibration.fitted_level_costs`` in
    ``results/BENCH_topology.json`` (or any file of the same shape).

    Returns one :class:`~repro.topo.model.LinkCost` per level (innermost
    first) — ready for ``Hierarchy(levels, costs=fitted)`` so the autotuner
    and ``launch.profiles.resolve_profile`` price candidates with measured
    constants instead of the v5e defaults. Returns ``None`` when the file or
    its calibration block is absent (no benchmark has run yet); falls back
    to re-fitting from the persisted raw ``samples`` when only those exist."""
    path = path if path is not None else DEFAULT_CALIBRATION_PATH
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            record = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None
    cal = record.get("calibration") or {}
    rows = cal.get("fitted_level_costs")
    if rows:
        try:
            by_level = {int(r["level"]): r for r in rows}
            return tuple(
                LinkCost(
                    alpha=float(by_level[j]["alpha_s"]),
                    beta=float(by_level[j]["beta_s_per_elem"]),
                )
                for j in range(len(by_level))
            )
        except (KeyError, TypeError, ValueError):
            return None
    samples = cal.get("samples")
    if samples:
        n_levels = 1 + max(
            int(r["level"]) for m in samples for r in m.get("rounds", ())
        )
        try:
            return fit_level_costs(samples, n_levels)
        except (KeyError, ValueError):
            return None
    return None
