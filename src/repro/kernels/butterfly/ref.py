"""Pure-jnp oracle for the fused butterfly-round MAC kernel.

One draw-and-loose/DFT round at a single processor group is
    out = Σ_ρ tw[:, ρ] · parts[ρ]   (mod q)
with ``parts[ρ]``: (B, *payload) the value received from the digit-ρ group
member and ``tw``: (B, radix) the twiddle row (schedule constants).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.field import madd, shoup_mul


def butterfly_mac_ref(
    parts: jnp.ndarray,  # (radix, B, P) uint32
    tw: jnp.ndarray,  # (B, radix) uint32
    tw_sh: jnp.ndarray,  # (B, radix) uint32
    q: int,
) -> jnp.ndarray:
    radix = parts.shape[0]
    acc = None
    for r in range(radix):
        term = shoup_mul(parts[r], tw[:, r : r + 1], tw_sh[:, r : r + 1], q)
        acc = term if acc is None else madd(acc, term, q)
    return acc
