"""Public jit'd wrapper for the fused butterfly-round MAC kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import butterfly_mac_pallas
from .ref import butterfly_mac_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def butterfly_mac(
    parts: jnp.ndarray,  # (radix, B, *payload) uint32
    tw: jnp.ndarray,  # (B, radix) uint32
    tw_sh: jnp.ndarray,  # (B, radix) uint32
    *,
    q: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """out[b, ...] = Σ_ρ tw[b, ρ] · parts[ρ, b, ...] (mod q); pads/reshapes
    payload to the kernel's 2D tiling."""
    radix, B = parts.shape[0], parts.shape[1]
    payload = parts.shape[2:]
    flat = parts.reshape(radix, B, -1)
    P = flat.shape[-1]
    bb = min(256, _round_up(B, 8))
    bp = min(512, _round_up(P, 128))
    pb = (-B) % bb
    pp = (-P) % bp
    flat = jnp.pad(flat, ((0, 0), (0, pb), (0, pp)))
    twp = jnp.pad(tw.astype(jnp.uint32), ((0, pb), (0, 0)))
    twsp = jnp.pad(tw_sh.astype(jnp.uint32), ((0, pb), (0, 0)))
    out = butterfly_mac_pallas(
        flat.astype(jnp.uint32), twp, twsp, q=q, block_b=bb, block_p=bp,
        interpret=interpret,
    )
    return out[:B, :P].reshape(B, *payload)


def butterfly_mac_reference(parts, tw, tw_sh, *, q):
    flat = parts.reshape(parts.shape[0], parts.shape[1], -1)
    out = butterfly_mac_ref(flat, tw, tw_sh, q)
    return out.reshape(parts.shape[1:])
