"""Pallas TPU kernel: fused butterfly-round multiply-accumulate.

One radix-(p+1) butterfly round computes, per processor row b and payload
column n:   out[b, n] = Σ_ρ tw[b, ρ] · parts[ρ, b, n]   (mod q).

Fusing the radix Shoup-multiplies and modular adds into one kernel avoids
``radix - 1`` HBM round-trips of the (B, P) intermediate that the naive
composition materializes (the memory-roofline win measured in
benchmarks/bench_kernels.py). All arithmetic is uint32-only (Shoup with
precomputed duals; no 64-bit values), so the body lowers for TPU VPU lanes.

Tiling: grid (B/bb, P/bp); twiddle blocks are (bb, radix) and broadcast over
the payload grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _butterfly_kernel(parts_ref, tw_ref, tw_sh_ref, out_ref, *, q: int, radix: int):
    acc = None
    for r in range(radix):
        a = parts_ref[r]  # (bb, bp) uint32
        c = tw_ref[:, r : r + 1]  # (bb, 1)
        c_pre = tw_sh_ref[:, r : r + 1]
        # Shoup multiply (see core.field.shoup_mul; inlined for the kernel)
        a1, a0 = a >> 16, a & 0xFFFF
        b1, b0 = c_pre >> 16, c_pre & 0xFFFF
        m0 = a0 * b0
        c1 = a0 * b1
        c2 = a1 * b0
        hi2 = a1 * b1
        w = c1 + (m0 >> 16)
        carry = jnp.where(w > jnp.uint32(0xFFFFFFFF) - c2, jnp.uint32(1), jnp.uint32(0))
        w = w + c2
        t = hi2 + (w >> 16) + (carry << 16)
        r_ = a * c - t * jnp.uint32(q)
        term = jnp.where(r_ >= q, r_ - jnp.uint32(q), r_)
        if acc is None:
            acc = term
        else:
            s = acc + term
            acc = jnp.where(s >= q, s - jnp.uint32(q), s)
    out_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("q", "block_b", "block_p", "interpret")
)
def butterfly_mac_pallas(
    parts: jnp.ndarray,  # (radix, B, P) uint32
    tw: jnp.ndarray,  # (B, radix) uint32
    tw_sh: jnp.ndarray,  # (B, radix) uint32
    *,
    q: int,
    block_b: int = 256,
    block_p: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    radix, B, P = parts.shape
    assert B % block_b == 0 and P % block_p == 0, (parts.shape, block_b, block_p)
    grid = (B // block_b, P // block_p)
    return pl.pallas_call(
        functools.partial(_butterfly_kernel, q=q, radix=radix),
        grid=grid,
        in_specs=[
            pl.BlockSpec((radix, block_b, block_p), lambda i, j: (0, i, j)),
            pl.BlockSpec((block_b, radix), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, radix), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, P), jnp.uint32),
        interpret=interpret,
    )(parts, tw, tw_sh)
