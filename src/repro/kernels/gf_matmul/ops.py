"""Public jit'd wrappers for the GF(q) matmul Pallas kernel.

Handles zero-padding to block multiples (zeros are absorbing for mod-q
accumulation), small-shape fallbacks, and a vmapped batched form used by the
shoot-phase initialization (w[k] = buf[k] @ coef[k]).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .kernel import gf_matmul_pallas
from .ref import gf_matmul_ref


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(
    jax.jit, static_argnames=("q", "block_m", "block_n", "block_k", "interpret")
)
def gf_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    q: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = (A @ B) mod q for arbitrary (M, K) x (K, N) uint32 inputs.

    Shapes are padded up to block multiples; for tiny operands (< one block)
    the block sizes shrink to the padded shape (still 8/128-aligned when
    possible).
    """
    M, K = a.shape
    _, N = b.shape
    if M == 0 or N == 0 or K == 0:
        # empty operand (e.g. a slot emptied by fuse_trivial_rounds): the
        # mod-q sum over zero terms is zero — don't pad up into the kernel
        return jnp.zeros((M, N), dtype=jnp.uint32)
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 8))
    ap = _pad_to(a.astype(jnp.uint32), bm, bk)
    bp = _pad_to(b.astype(jnp.uint32), bk, bn)
    out = gf_matmul_pallas(
        ap, bp, q=q, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:M, :N]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def gf_matmul_batched(
    a: jnp.ndarray, b: jnp.ndarray, *, q: int, interpret: bool = True
) -> jnp.ndarray:
    """Batched C[i] = (A[i] @ B[i]) mod q via vmap over the Pallas kernel.

    a: (B, M, K), b: (B, K, N). Used for the shoot-phase init where every
    processor contracts its prepare buffer against its own coefficient tile.
    """
    B, M, K = a.shape
    _, _, N = b.shape
    if M == 0 or N == 0 or K == 0:
        return jnp.zeros((B, M, N), dtype=jnp.uint32)
    bm = min(128, _round_up(M, 8))
    bn = min(128, _round_up(N, 128))
    bk = min(512, _round_up(K, 8))
    ap = jax.vmap(lambda x: _pad_to(x, bm, bk))(a.astype(jnp.uint32))
    bp = jax.vmap(lambda x: _pad_to(x, bk, bn))(b.astype(jnp.uint32))
    fn = functools.partial(
        gf_matmul_pallas, q=q, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    out = jax.vmap(fn)(ap, bp)
    return out[:, :M, :N]


def gf_matmul_reference(a, b, *, q):
    """Alias of the pure-jnp oracle (testing convenience)."""
    return gf_matmul_ref(a, b, q)


def encode_direct(x: jnp.ndarray, G: jnp.ndarray | np.ndarray, *, q: int, interpret: bool = True):
    """Direct (non-collective) encode baseline: X @ G mod q via the kernel.

    x: (S, K) payload-major state limbs; G: (K, N) generator. This is the
    per-node compute of the coded-checkpoint path.
    """
    return gf_matmul(x, jnp.asarray(np.asarray(G, dtype=np.uint32)), q=q, interpret=interpret)
