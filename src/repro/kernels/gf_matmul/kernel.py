"""Pallas TPU kernel: GF(q) modular matmul via byte-limb MXU decomposition.

TPU adaptation (DESIGN §3/§7): the MXU has no 64-bit integer path, so a
direct ``(a*b) % q`` contraction cannot use it. Instead each uint32 operand
is split into four 8-bit limbs; the product becomes

    A·B = Σ_{c=0}^{6} D_c · 2^{8c},   D_c = Σ_{i+j=c} A_i · B_j

where each ``A_i · B_j`` is a uint8×uint8→int32 matmul — exactly the MXU's
native int8 mode (bounded: 255²·block_k < 2^31 for block_k ≤ 32768, so the
int32 accumulation is exact). The seven class sums D_c are then folded
modulo q on the VPU once per output tile: Barrett-reduce D_c and Shoup-
multiply by the constant 2^{8c} mod q.

Grid: (M/bm, N/bn, K/bk); the K dimension accumulates into the uint32
output block (canonical mod-q residues) across grid steps.

VMEM per step (defaults bm=bn=128, bk=512):
    A block 128·512·4 B = 256 KiB, B block 512·128·4 B = 256 KiB,
    out 64 KiB, limb temporaries ≈ 8·(block bytes)/4 — comfortably < 16 MiB.
MXU alignment: bm, bn multiples of 128; bk multiple of 8 (≥ 128 preferred).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.field import shoup_precompute

_NLIMB = 4
_NCLASS = 2 * _NLIMB - 1


def _fold_constants(q: int):
    """(2^{8c} mod q, shoup(2^{8c} mod q)) for c = 0..6."""
    rs = [(1 << (8 * c)) % q for c in range(_NCLASS)]
    pres = [int(shoup_precompute(r, q)) for r in rs]
    return rs, pres


def _barrett(x_i32, q: int):
    """x mod q for 0 <= x < 2^31 given as int32 (kernel-local Barrett).

    t = floor(x * floor(2^32/q) / 2^32) via 16-bit-limb high-mul, then one
    conditional subtract (see field.barrett32; re-implemented here on uint32
    values so the kernel body has no cross-module jnp closures).
    """
    m = (1 << 32) // q
    x = x_i32.astype(jnp.uint32)
    # umulhi32_full(x, m) with m < 2^32
    a1, a0 = x >> 16, x & 0xFFFF
    b1, b0 = jnp.uint32(m >> 16), jnp.uint32(m & 0xFFFF)
    m0 = a0 * b0
    c1 = a0 * b1
    c2 = a1 * b0
    hi2 = a1 * b1
    w = c1 + (m0 >> 16)
    carry = jnp.where(w > jnp.uint32(0xFFFFFFFF) - c2, jnp.uint32(1), jnp.uint32(0))
    w = w + c2
    t = hi2 + (w >> 16) + (carry << 16)
    r = x - t * jnp.uint32(q)
    return jnp.where(r >= q, r - jnp.uint32(q), r)


def _shoup(a_u32, c: int, c_pre: int, q: int):
    """(a * c) mod q for constant c with precomputed Shoup dual."""
    a = a_u32
    a1, a0 = a >> 16, a & 0xFFFF
    b1, b0 = jnp.uint32(c_pre >> 16), jnp.uint32(c_pre & 0xFFFF)
    m0 = a0 * b0
    cc1 = a0 * b1
    cc2 = a1 * b0
    hi2 = a1 * b1
    w = cc1 + (m0 >> 16)
    carry = jnp.where(w > jnp.uint32(0xFFFFFFFF) - cc2, jnp.uint32(1), jnp.uint32(0))
    w = w + cc2
    t = hi2 + (w >> 16) + (carry << 16)
    r = a * jnp.uint32(c) - t * jnp.uint32(q)
    return jnp.where(r >= q, r - jnp.uint32(q), r)


def _gf_matmul_kernel(a_ref, b_ref, out_ref, *, q: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]  # (bm, bk) uint32
    b = b_ref[...]  # (bk, bn) uint32
    a_limbs = [((a >> (8 * i)) & 0xFF).astype(jnp.uint8) for i in range(_NLIMB)]
    b_limbs = [((b >> (8 * j)) & 0xFF).astype(jnp.uint8) for j in range(_NLIMB)]

    rs, pres = _fold_constants(q)
    folded = None
    for c in range(_NCLASS):
        d = None
        for i in range(max(0, c - _NLIMB + 1), min(_NLIMB, c + 1)):
            j = c - i
            # uint8 x uint8 -> int32: the MXU-native integer mode
            prod = jax.lax.dot_general(
                a_limbs[i],
                b_limbs[j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            d = prod if d is None else d + prod
        dq = _barrett(d, q)  # < q
        term = dq if c == 0 else _shoup(dq, rs[c], pres[c], q)
        if folded is None:
            folded = term
        else:
            s = folded + term
            folded = jnp.where(s >= q, s - jnp.uint32(q), s)

    acc = out_ref[...] + folded  # both < q: sum < 2^32
    out_ref[...] = jnp.where(acc >= q, acc - jnp.uint32(q), acc)


@functools.partial(
    jax.jit, static_argnames=("q", "block_m", "block_n", "block_k", "interpret")
)
def gf_matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    q: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = (A @ B) mod q. a: (M, K) uint32, b: (K, N) uint32, shapes must be
    multiples of the block sizes (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        a.shape,
        b.shape,
        (block_m, block_n, block_k),
    )
    assert block_k <= 32768, "int32 limb accumulation bound"
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)
    return pl.pallas_call(
        functools.partial(_gf_matmul_kernel, q=q, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.uint32),
        interpret=interpret,
    )(a, b)
