"""Pure-jnp oracle for the GF(q) matmul kernel: C = (A @ B) mod q.

A: (M, K) uint32, B: (K, N) uint32, canonical representatives < q < 2^31.
Exactness strategy mirrors the device tier: uint32-only limb products
(field.mmul) with modular accumulation — slow (O(MNK) scalar mod-muls) but
bit-exact, used as the allclose oracle for the Pallas kernel.

A fast host oracle (numpy uint64) is also provided for big test shapes.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.field import Field, madd, mmul


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, q: int) -> jnp.ndarray:
    """(A @ B) mod q, pure jnp, uint32-only. a: (..., M, K), b: (..., K, N)."""
    K = a.shape[-1]
    acc = mmul(a[..., :, 0, None], b[..., 0, None, :], q)
    for k in range(1, K):
        acc = madd(acc, mmul(a[..., :, k, None], b[..., k, None, :], q), q)
    return acc


def gf_matmul_host(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """Exact numpy uint64 oracle."""
    f = Field(q)
    return f.matmul(a, b)
