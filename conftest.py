"""Root conftest: make ``import repro`` work from a plain checkout.

Prepends ``src/`` to sys.path so ``python -m pytest`` (and any tooling that
imports test modules) works without the ``PYTHONPATH=src`` incantation or an
editable install. The checkout's ``src/`` deliberately shadows any installed
``repro`` distribution so the tests always test this tree.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
