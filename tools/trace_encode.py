#!/usr/bin/env python
"""Trace one multi-level encode end-to-end (the CI observability smoke).

Forces 8 host devices (unless XLA_FLAGS is already set), builds the
recursive three-level Vandermonde encode on a 2×2×2 pod×slice×chip mesh,
runs it through ``dist.collectives.ir_encode_jit(tracer=...)`` — one span
per CommRound with the α-β prediction stamped next to the measured wall
time — and writes both trace sinks plus the metrics snapshot. The first
traced call compiles the per-round dispatches, so it is discarded as
warmup and only the second call's spans are kept (the calibration-grade
window; see docs/OBSERVABILITY.md).

Usage::

    python tools/trace_encode.py [--out results/traces/encode] \
        [--feed results/BENCH_topology.json] [--drift]

``--feed`` pushes the traced rounds through ``obs.feed.feed_calibration``
(refit α/β, persist into the ``calibration`` block where
``topo.calibrate.load_fitted_costs`` / ``launch.profiles.resolve_profile``
read it). ``--drift`` prints the per-round predicted-vs-measured table.
"""

from __future__ import annotations

import argparse
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/traces/encode",
                    help="output path prefix (writes <out>.trace.json + <out>.jsonl)")
    ap.add_argument("--payload", type=int, default=1 << 14,
                    help="payload elems per source shard")
    ap.add_argument("--feed", default=None, metavar="PATH",
                    help="refit α/β from the trace and persist into PATH's calibration block")
    ap.add_argument("--drift", action="store_true",
                    help="print the per-round predicted-vs-measured drift table")
    args = ap.parse_args(argv)

    import numpy as np
    import jax.numpy as jnp

    from repro.core.field import M31, Field
    from repro.core.matrices import distinct_points, random_vector, vandermonde
    from repro.dist.collectives import ir_encode_jit
    from repro.launch.mesh import make_mesh
    from repro.obs import (
        Tracer,
        get_registry,
        write_chrome_trace,
        write_spans_jsonl,
    )
    from repro.topo import Hierarchy, plan_multilevel

    K = 8
    f = Field(M31)
    A = np.asarray(vandermonde(f, distinct_points(f, K, seed=0)))
    mesh = make_mesh((2, 2, 2), ("pod", "slice", "chip"))
    topo = Hierarchy(levels=(2, 2, 2))
    ir = plan_multilevel(K, 1, (2, 2, 2)).to_ir(A)

    tracer = Tracer()
    fn = ir_encode_jit(mesh, ("pod", "slice", "chip"), ir, tracer=tracer, topo=topo)
    x = jnp.asarray(random_vector(f, (K, args.payload), seed=1).astype(np.uint32))
    fn(x)  # warmup: compiles every per-round dispatch
    n0 = len(tracer.spans)
    out = np.asarray(fn(x))
    spans = tracer.spans[n0:]
    fused = ir_encode_jit(mesh, ("pod", "slice", "chip"), ir)
    assert np.array_equal(out, np.asarray(fused(x))), "traced != fused output"
    comm = [s for s in spans if "comm_round" in s.attrs]
    print(f"traced {len(comm)} comm rounds / {len(spans)} spans "
          f"(schedule: {ir.c1} rounds, {ir.c2} slot-rounds)")
    assert len(comm) == ir.c1, f"expected {ir.c1} round spans, got {len(comm)}"

    chrome = write_chrome_trace(spans, args.out + ".trace.json",
                                process_name="trace_encode")
    jsonl = write_spans_jsonl(spans, args.out + ".jsonl")
    metrics = args.out + ".metrics.json"
    get_registry().write_json(metrics)
    print(f"wrote {chrome}\nwrote {jsonl}\nwrote {metrics}")

    if args.feed:
        from repro.obs import feed_calibration

        fitted = feed_calibration(spans, args.feed)
        print(f"fed calibration -> {args.feed}:")
        for j, c in enumerate(fitted):
            print(f"  level {j}: alpha={c.alpha:.3e}s beta={c.beta:.3e}s/elem")
    if args.drift:
        from repro.launch.perf_report import render_drift

        print()
        print(render_drift(spans))
    return 0


if __name__ == "__main__":
    sys.exit(main())
