#!/usr/bin/env python
"""Schema-check observability artifacts (CI gate for the telemetry layer).

Three kinds, auto-detected from content (or forced with ``--kind``):

* ``trace`` — a Chrome Trace Event file emitted by
  ``repro.obs.export.write_chrome_trace`` (or the JSONL span sink):
  ``traceEvents`` array, each event ``ph`` ∈ {X, B, E, M}, numeric
  ``ts``/``dur`` ≥ 0, events sorted by start time, and every traced
  comm-round span (``args.comm_round``) carrying its α-β prediction
  (``args.predicted_us``) — the attribute the drift report and the live
  calibration feed depend on.
* ``bench`` — ``results/BENCH_topology.json``: the sweep/prediction record
  plus the ``calibration`` block, whose ``samples`` rows must stay
  refit-compatible (``{payload_elems, wall_s, rounds: [{level, msgs,
  elems}]}`` — ``topo.calibrate.fit_level_costs``'s input contract) and
  whose ``fitted_level_costs`` rows must stay loader-compatible
  (``{level, alpha_s, beta_s_per_elem}`` —
  ``topo.calibrate.load_fitted_costs``'s contract).
* ``serve`` — ``results/BENCH_serve.json`` from ``benchmarks/
  bench_serve.py``: fixed-batch vs continuous engine rows on one seeded
  Poisson trace. Beyond the structural schema it enforces the semantic
  invariants the harness guarantees: ``p50 ≤ p99`` in every latency
  block, ``slot_occupancy ∈ [0, 1]``, and the continuous engine's
  prefill compile count bounded by the bucket set
  (``prefill_compiles ≤ len(buckets)``).
* ``coded-serve`` — ``results/BENCH_coded_serve.json`` from
  ``benchmarks/bench_coded_serve.py``: uncoded vs LCC-coded engine rows
  plus fault-injection scenarios. Semantic gates on every scenario:
  ``recoveries ≥ injected_faults`` (no fault goes unrecovered),
  ``recovery_us`` present with ``p50 ≤ p99``, and the decoded-token-
  identity flag ``tokens_identical`` true (the coded run's token
  streams matched the unfailed baseline bit-for-bit).

The validator is a small hand-rolled structural checker (dependency-free on
purpose — ``jsonschema`` is not one of the project's declared deps), with a
declarative schema dialect covering exactly what these two files need:
``{"type": ...}``, ``required``/``properties``, ``items``, ``enum``,
``minimum``. Exits non-zero with a path-qualified error message on the
first violation.

Usage::

    python tools/check_trace.py results/traces/bench_topology.trace.json
    python tools/check_trace.py --kind bench results/BENCH_topology.json
"""

from __future__ import annotations

import argparse
import json
import sys

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
}


def validate(value, schema: dict, path: str = "$") -> list[str]:
    """Structural check of ``value`` against the mini schema dialect.
    Returns a list of human-readable violations (empty = valid)."""
    errs: list[str] = []
    t = schema.get("type")
    if t is not None:
        expected = _TYPES[t]
        ok = isinstance(value, expected)
        if ok and t in ("number", "integer") and isinstance(value, bool):
            ok = False  # bool is an int subclass; never a valid number here
        if not ok:
            return [f"{path}: expected {t}, got {type(value).__name__}"]
    if "enum" in schema and value not in schema["enum"]:
        errs.append(f"{path}: {value!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(value, (int, float)):
        if value < schema["minimum"]:
            errs.append(f"{path}: {value} < minimum {schema['minimum']}")
    if t == "object":
        for key in schema.get("required", ()):
            if key not in value:
                errs.append(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in value:
                errs.extend(validate(value[key], sub, f"{path}.{key}"))
    if t == "array" and "items" in schema:
        for i, item in enumerate(value):
            errs.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errs


#: per-event schema for the Chrome Trace Event Format subset we emit
TRACE_EVENT_SCHEMA = {
    "type": "object",
    "required": ["name", "ph", "pid", "tid"],
    "properties": {
        "name": {"type": "string"},
        "ph": {"type": "string", "enum": ["X", "B", "E", "M"]},
        "pid": {"type": "integer", "minimum": 0},
        "tid": {"type": "integer", "minimum": 0},
        "ts": {"type": "number", "minimum": 0},
        "dur": {"type": "number", "minimum": 0},
        "args": {"type": "object"},
    },
}

TRACE_SCHEMA = {
    "type": "object",
    "required": ["traceEvents"],
    "properties": {
        "traceEvents": {"type": "array", "items": TRACE_EVENT_SCHEMA},
    },
}

_FEATURE_ROW = {
    "type": "object",
    "required": ["level", "msgs", "elems"],
    "properties": {
        "level": {"type": "integer", "minimum": 0},
        "msgs": {"type": "integer", "minimum": 0},
        "elems": {"type": "integer", "minimum": 0},
    },
}

_COST_ROW = {
    "type": "object",
    "required": ["level", "alpha_s", "beta_s_per_elem"],
    "properties": {
        "level": {"type": "integer", "minimum": 0},
        "alpha_s": {"type": "number", "minimum": 0},
        "beta_s_per_elem": {"type": "number", "minimum": 0},
    },
}

_SAMPLE_ROW = {
    "type": "object",
    "required": ["payload_elems", "wall_s", "rounds"],
    "properties": {
        "payload_elems": {"type": "integer", "minimum": 1},
        "wall_s": {"type": "number", "minimum": 0},
        "rounds": {"type": "array", "items": _FEATURE_ROW},
    },
}

BENCH_SCHEMA = {
    "type": "object",
    "required": [
        "K", "p", "payload_elems", "mesh", "topology",
        "autotuner_choice", "measured_us", "measured_s", "predicted",
        "calibration",
    ],
    "properties": {
        "K": {"type": "integer", "minimum": 2},
        "p": {"type": "integer", "minimum": 1},
        "payload_elems": {"type": "integer", "minimum": 1},
        "mesh": {"type": "string"},
        "topology": {"type": "string"},
        "autotuner_choice": {"type": "string"},
        "measured_us": {"type": "object"},
        "measured_s": {"type": "object"},
        "predicted": {"type": "object"},
        "calibration": {
            "type": "object",
            "required": ["samples", "fitted_level_costs"],
            "properties": {
                "samples": {"type": "array", "items": _SAMPLE_ROW},
                "fitted_level_costs": {"type": "array", "items": _COST_ROW},
            },
        },
    },
}


_LATENCY_BLOCK = {
    "type": "object",
    "required": ["p50", "p99"],
    "properties": {
        "p50": {"type": "number", "minimum": 0},
        "p99": {"type": "number", "minimum": 0},
    },
}

_ENGINE_ROW = {
    "type": "object",
    "required": ["tokens_per_s", "ttft_ms", "e2e_ms", "n_requests", "wall_s"],
    "properties": {
        "tokens_per_s": {"type": "number", "minimum": 0},
        "ttft_ms": _LATENCY_BLOCK,
        "e2e_ms": _LATENCY_BLOCK,
        "n_requests": {"type": "integer", "minimum": 1},
        "wall_s": {"type": "number", "minimum": 0},
    },
}

_CONTINUOUS_ROW = {
    "type": "object",
    "required": _ENGINE_ROW["required"]
    + ["slot_occupancy", "prefill_compiles", "decode_steps"],
    "properties": {
        **_ENGINE_ROW["properties"],
        "slot_occupancy": {"type": "number", "minimum": 0},
        "prefill_compiles": {"type": "integer", "minimum": 0},
        "decode_steps": {"type": "integer", "minimum": 0},
    },
}

SERVE_SCHEMA = {
    "type": "object",
    "required": ["workload", "n_slots", "buckets", "engines"],
    "properties": {
        "n_slots": {"type": "integer", "minimum": 1},
        "buckets": {"type": "array", "items": {"type": "integer", "minimum": 1}},
        "workload": {
            "type": "object",
            "required": ["n_requests", "rate_rps", "seed"],
            "properties": {
                "n_requests": {"type": "integer", "minimum": 1},
                "rate_rps": {"type": "number", "minimum": 0},
                "seed": {"type": "integer", "minimum": 0},
            },
        },
        "engines": {
            "type": "object",
            "required": ["fixed_batch", "continuous"],
            "properties": {
                "fixed_batch": _ENGINE_ROW,
                "continuous": _CONTINUOUS_ROW,
            },
        },
    },
}


def check_serve(record: dict) -> list[str]:
    """SERVE_SCHEMA + the harness's semantic invariants: ordered latency
    percentiles, occupancy a fraction, compile count bounded by buckets."""
    errs = validate(record, SERVE_SCHEMA)
    if errs:
        return errs
    for ename, row in record["engines"].items():
        for blk in ("ttft_ms", "e2e_ms"):
            if row[blk]["p50"] > row[blk]["p99"]:
                errs.append(
                    f"$.engines.{ename}.{blk}: p50 {row[blk]['p50']} > "
                    f"p99 {row[blk]['p99']}"
                )
    cont = record["engines"]["continuous"]
    if not (0.0 <= cont["slot_occupancy"] <= 1.0):
        errs.append(
            f"$.engines.continuous.slot_occupancy: "
            f"{cont['slot_occupancy']} outside [0, 1]"
        )
    if cont["prefill_compiles"] > len(record["buckets"]):
        errs.append(
            f"$.engines.continuous.prefill_compiles: "
            f"{cont['prefill_compiles']} > {len(record['buckets'])} buckets "
            "(length bucketing failed to bound recompiles)"
        )
    return errs


_RECOVERY_BLOCK = {
    "type": "object",
    "required": ["K", "R", "n_hosts", "injected_faults", "recoveries",
                 "requests_recovered", "snapshots", "recovery_us"],
    "properties": {
        "K": {"type": "integer", "minimum": 1},
        "R": {"type": "integer", "minimum": 1},
        "n_hosts": {"type": "integer", "minimum": 2},
        "injected_faults": {"type": "integer", "minimum": 0},
        "recoveries": {"type": "integer", "minimum": 0},
        "requests_recovered": {"type": "integer", "minimum": 0},
        "snapshots": {"type": "integer", "minimum": 0},
        "recovery_us": _LATENCY_BLOCK,
    },
}

_SCENARIO_ROW = {
    "type": "object",
    "required": ["kills", "tokens_identical", "tokens_per_s", "coded"],
    "properties": {
        "kills": {"type": "integer", "minimum": 1},
        "tokens_identical": {"type": "boolean"},
        "tokens_per_s": {"type": "number", "minimum": 0},
        "coded": _RECOVERY_BLOCK,
    },
}

CODED_SERVE_SCHEMA = {
    "type": "object",
    "required": ["workload", "n_slots", "buckets", "coded", "engines",
                 "fault_scenarios"],
    "properties": {
        "n_slots": SERVE_SCHEMA["properties"]["n_slots"],
        "buckets": SERVE_SCHEMA["properties"]["buckets"],
        "workload": SERVE_SCHEMA["properties"]["workload"],
        "coded": {
            "type": "object",
            "required": ["K", "R", "n_hosts"],
            "properties": {
                "K": {"type": "integer", "minimum": 1},
                "R": {"type": "integer", "minimum": 1},
                "n_hosts": {"type": "integer", "minimum": 2},
            },
        },
        "engines": {
            "type": "object",
            "required": ["uncoded", "coded"],
            "properties": {
                "uncoded": _CONTINUOUS_ROW,
                "coded": _CONTINUOUS_ROW,
            },
        },
        "fault_scenarios": {"type": "array", "items": _SCENARIO_ROW},
    },
}


def check_coded_serve(record: dict) -> list[str]:
    """CODED_SERVE_SCHEMA + the fault-tolerance invariants: every injected
    fault recovered, ordered recovery percentiles, token identity true."""
    errs = validate(record, CODED_SERVE_SCHEMA)
    if errs:
        return errs
    for ename, row in record["engines"].items():
        for blk in ("ttft_ms", "e2e_ms"):
            if row[blk]["p50"] > row[blk]["p99"]:
                errs.append(
                    f"$.engines.{ename}.{blk}: p50 {row[blk]['p50']} > "
                    f"p99 {row[blk]['p99']}"
                )
        if not (0.0 <= row["slot_occupancy"] <= 1.0):
            errs.append(
                f"$.engines.{ename}.slot_occupancy: "
                f"{row['slot_occupancy']} outside [0, 1]"
            )
    for i, sc in enumerate(record["fault_scenarios"]):
        c = sc["coded"]
        where = f"$.fault_scenarios[{i}]"
        if c["recoveries"] < c["injected_faults"]:
            errs.append(
                f"{where}.coded: recoveries {c['recoveries']} < "
                f"injected_faults {c['injected_faults']} "
                "(a fault went unrecovered)"
            )
        if c["injected_faults"] < sc["kills"]:
            errs.append(
                f"{where}.coded: injected_faults {c['injected_faults']} < "
                f"scheduled kills {sc['kills']}"
            )
        if c["recoveries"] > 0 and c["recovery_us"]["p99"] <= 0:
            errs.append(
                f"{where}.coded.recovery_us: recoveries happened but "
                "p99 is 0 (latency not measured)"
            )
        if c["recovery_us"]["p50"] > c["recovery_us"]["p99"]:
            errs.append(
                f"{where}.coded.recovery_us: p50 "
                f"{c['recovery_us']['p50']} > p99 {c['recovery_us']['p99']}"
            )
        if sc["tokens_identical"] is not True:
            errs.append(
                f"{where}.tokens_identical: false — the coded run's token "
                "streams diverged from the unfailed baseline"
            )
    return errs


def check_trace(record: dict) -> list[str]:
    """TRACE_SCHEMA + the semantic invariants the exporter guarantees:
    start-time-sorted events and predicted_us on every comm-round span."""
    errs = validate(record, TRACE_SCHEMA)
    if errs:
        return errs
    prev_ts = None
    for i, ev in enumerate(record["traceEvents"]):
        if ev["ph"] == "M":
            continue
        if ev["ph"] in ("X", "B") and "ts" not in ev:
            errs.append(f"$.traceEvents[{i}]: {ev['ph']} event without ts")
            continue
        ts = ev.get("ts")
        if prev_ts is not None and ts is not None and ts < prev_ts:
            errs.append(
                f"$.traceEvents[{i}]: ts {ts} < previous {prev_ts} "
                "(events must be start-time sorted)"
            )
        if ts is not None:
            prev_ts = ts
        args = ev.get("args", {})
        if "comm_round" in args and "predicted_us" not in args:
            errs.append(
                f"$.traceEvents[{i}] ({ev['name']}): comm-round span "
                "missing predicted_us (the drift/calibration attribute)"
            )
    return errs


def check_bench(record: dict) -> list[str]:
    return validate(record, BENCH_SCHEMA)


def _jsonl_to_trace(lines: list[dict]) -> dict:
    """Wrap a JSONL span dump as a trace record so one checker serves both
    sink formats (the spans carry the same attrs the chrome args do)."""
    events = []
    for sp in sorted(lines, key=lambda d: d.get("ts_us", 0.0)):
        events.append(
            {
                "name": sp.get("name", ""),
                "ph": "X",
                "pid": 0,
                "tid": 0,
                "ts": float(sp.get("ts_us", 0.0)),
                "dur": max(float(sp.get("dur_us", 0.0)), 0.0),
                "args": sp.get("attrs", {}),
            }
        )
    return {"traceEvents": events}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path")
    ap.add_argument(
        "--kind",
        choices=["trace", "bench", "serve", "coded-serve", "auto"],
        default="auto",
    )
    args = ap.parse_args(argv)
    with open(args.path) as fh:
        text = fh.read()
    if args.path.endswith(".jsonl"):
        record = _jsonl_to_trace(
            [json.loads(l) for l in text.splitlines() if l.strip()]
        )
        kind = "trace"
    else:
        record = json.loads(text)
        kind = args.kind
        if kind == "auto":
            if "traceEvents" in record:
                kind = "trace"
            elif "coded" in record and "fault_scenarios" in record:
                kind = "coded-serve"
            elif "engines" in record:
                kind = "serve"
            else:
                kind = "bench"
    checker = {
        "trace": check_trace,
        "bench": check_bench,
        "serve": check_serve,
        "coded-serve": check_coded_serve,
    }
    errs = checker[kind](record)
    if errs:
        for e in errs:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    if kind == "trace":
        detail = f"{len(record.get('traceEvents', []))} events"
    elif kind == "serve":
        detail = f"{record['workload']['n_requests']} requests"
    elif kind == "coded-serve":
        recov = sum(
            s["coded"]["recoveries"] for s in record["fault_scenarios"]
        )
        detail = (
            f"{len(record['fault_scenarios'])} fault scenarios, "
            f"{recov} recoveries"
        )
    else:
        detail = (
            f"{len(record.get('calibration', {}).get('samples', []))} "
            "calibration samples"
        )
    print(f"OK {args.path}: valid {kind} ({detail})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
