"""Kill-and-recover demo (Remark 1 application): 16 DP replicas hold shards
of a training state; one all-to-all encode (Cauchy generator, universal
prepare-and-shoot: C1=4 rounds, C2=Θ(√K)) builds in-HBM parity; we then kill
up to 8 replicas and rebuild their shards bit-exactly — no disk, no master.

Run:  PYTHONPATH=src python examples/coded_checkpoint_recovery.py
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.coded import build_parity_plan, encode_parity, recover_lost, shard_state_limbs, unshard_state_limbs
from repro.core.bounds import CostModel, allgather_baseline_c1_c2
from repro.core.schedule import counted_c2

K = 16
rng = np.random.default_rng(0)
state = {
    "params": jnp.asarray(rng.normal(size=(1_000_000,)).astype(np.float32)),
    "m": jnp.asarray(rng.normal(size=(1_000_000,)).astype(np.float32)),
    "v": jnp.asarray(abs(rng.normal(size=(1_000_000,))).astype(np.float32)),
    "step": jnp.asarray(1234, jnp.int32),
}

shards, meta = shard_state_limbs(state, K)
plan = build_parity_plan(K, p=1)
print(f"state: {meta.total * 2 / 1e6:.1f} MB as {K} shards of {shards.shape[1] * 2 / 1e6:.2f} MB")
print(f"encode schedule: C1={plan.c1} rounds, C2={counted_c2(plan.ps_plan)} elements/port "
      f"(all-gather baseline: {allgather_baseline_c1_c2(K, 1)[1]})")

t0 = time.time()
parity = np.asarray(jax.jit(lambda s: encode_parity(s, plan))(shards), dtype=np.uint64)
print(f"parity encode: {time.time() - t0:.2f}s "
      f"(modelled ICI time {CostModel().time(plan.c1, counted_c2(plan.ps_plan), shards.shape[1]) * 1e3:.2f} ms)")

sn = np.asarray(shards, dtype=np.uint64)
for n_lost in (1, 4, 8):
    lost = list(rng.choice(K, size=n_lost, replace=False))
    t0 = time.time()
    rec = recover_lost(
        plan, lost,
        {k: sn[k] for k in range(K) if k not in lost},
        {k: parity[k] for k in range(K) if k not in lost},
    )
    ok = all(np.array_equal(rec[k], sn[k]) for k in lost)
    print(f"lost {n_lost:2d} replicas {sorted(lost)}: recovered bit-exact={ok} in {time.time() - t0:.2f}s")

full = sn.copy()
back = unshard_state_limbs(jnp.asarray(full.astype(np.uint32)), meta)
assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)))
print("full state reassembly: bit-exact")
