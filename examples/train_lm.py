"""End-to-end training driver: ~100M-param qwen3-family model, a few hundred
steps on the synthetic pipeline, with coded fault-tolerance active —
a Cauchy parity snapshot of (params, opt state) every 25 steps, a simulated
3-node failure at step 60 recovered bit-exactly from survivors, and a disk
checkpoint at the end.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import build_model
from repro.train import (
    CodedStateGuard,
    OptConfig,
    SyntheticLM,
    init_state,
    make_train_step,
    save_checkpoint,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", type=int, default=60)
    ap.add_argument("--coded-every", type=int, default=50)
    args = ap.parse_args()

    # ~110M params: qwen3 family, reduced depth/width, full qk-norm/GQA/tied-emb
    cfg = get("qwen3-1.7b").replace(
        name="qwen3-110m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=6,
        head_dim=64,
        d_ff=2304,
        vocab_size=32768,
        vocab_padded=0,
        remat="none",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    ocfg = OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    opt_state = init_state(ocfg, params)
    step_fn = jax.jit(make_train_step(model, ocfg))
    ds = SyntheticLM(cfg)
    guard = CodedStateGuard(K=8)

    t0 = time.time()
    for s in range(args.steps):
        batch = ds.batch(s, args.batch, args.seq)
        params, opt_state, metrics = step_fn(
            params, opt_state, {k: jnp.asarray(v) for k, v in batch.items()}
        )
        if s % args.coded_every == 0:
            guard.snapshot({"params": params, "opt": opt_state}, step=s)
            print(
                f"step {s:4d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  lr {float(metrics['lr']):.2e}  "
                f"[coded parity snapshot: C1={guard.plan.c1} rounds]"
            )
        if s == args.fail_at:
            print(f"step {s:4d}  !! simulating loss of replicas {{1, 4, 6}} …")
            state, at = guard.fail_and_recover(lost=[1, 4, 6])
            params, opt_state = state["params"], state["opt"]
            print(f"           recovered bit-exactly from snapshot at step {at}; resuming")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s ({args.steps / dt:.2f} steps/s)")
    save_checkpoint("results/ckpt_train_lm", {"params": params, "opt": opt_state}, args.steps)
    print("final checkpoint: results/ckpt_train_lm")


if __name__ == "__main__":
    main()
