"""Topology planner: which encode algorithm should this scenario run?

Given K, p, a payload size, and a topology, prints the autotuner's candidate
table — per-algorithm C1/C2, α-β predicted time, worst per-link contention —
and its choice.

Run:  PYTHONPATH=src python examples/topology_planner.py \
          --K 16 --p 1 --payload-bytes 65536 --topology two-level --intra 4

Topologies: flat | ring | torus | two-level  (torus/two-level take --intra).
Generators: general | vandermonde | dft  (structured kinds unlock the
specific algorithms; dft needs K compatible with the field).
"""

from __future__ import annotations

import argparse

from repro.core.encode import default_q_for
from repro.topo import autotune, make_topology


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--K", type=int, default=16, help="number of processors")
    ap.add_argument("--p", type=int, default=1, help="ports per processor")
    ap.add_argument("--payload-bytes", type=int, default=65536)
    ap.add_argument(
        "--topology", default="two-level", choices=("flat", "ring", "torus", "two-level")
    )
    ap.add_argument("--intra", type=int, default=None, help="fast-domain size")
    ap.add_argument(
        "--generator", default="general", choices=("general", "vandermonde", "dft")
    )
    ap.add_argument("--q", type=int, default=None, help="field prime (default: auto)")
    args = ap.parse_args()

    q = args.q or default_q_for(args.K, args.p)
    topo = make_topology(args.topology, args.K, k_intra=args.intra)
    result = autotune(
        args.K, args.p, args.payload_bytes, topo, q=q, generator=args.generator
    )

    print(
        f"K={args.K} p={args.p} payload={args.payload_bytes}B "
        f"topology={topo.name} generator={args.generator} q={q}"
    )
    print(f"{'algorithm':<18}{'C1':>4}{'C2':>5}{'time':>12}{'contention':>12}")
    for c in result.candidates:
        mark = " ←" if c is result.chosen else ""
        print(
            f"{c.algorithm:<18}{c.c1:>4}{c.c2:>5}"
            f"{c.predicted_time * 1e6:>10.2f}µs{c.estimate.max_contention:>12}{mark}"
        )
    ch = result.chosen
    print(
        f"\nchosen: {ch.algorithm} — C1={ch.c1} rounds, C2={ch.c2} elements/port, "
        f"predicted {ch.predicted_time * 1e6:.2f} µs"
    )


if __name__ == "__main__":
    main()
