"""Topology planner: which encode algorithm should this scenario run?

Given K, p, a payload size, and a topology, prints the autotuner's candidate
table — per-algorithm C1 (rounds), C2 (elements per port), α-β predicted
time, worst per-link contention — and its choice (marked ``←``).

Run:  PYTHONPATH=src python examples/topology_planner.py \
          --K 16 --p 1 --payload-bytes 65536 --topology two-level --intra 4

      # recursive multi-level hierarchy (chip < slice < pod):
      PYTHONPATH=src python examples/topology_planner.py \
          --K 32 --topology hierarchy --levels 4,4,2

Topologies: flat | ring | torus | two-level | hierarchy.
``torus``/``two-level`` take ``--intra`` (fast-domain size);
``hierarchy`` takes ``--levels`` — comma-separated per-level sizes,
innermost (fastest links) first, multiplying to K (default: a balanced
three-level factorization of K). Generators: general | vandermonde | dft
(structured kinds unlock the specific algorithms; dft needs K compatible
with the field).

Reading the output: on a hierarchy the ``multilevel`` row is the recursive
schedule whose phases align with the topology's levels (gather on the
fastest links, one digit-reduction shoot per level); ``contention`` is the
worst number of messages sharing one link in any round — the quantity the
level-aligned schedules are designed to keep off the slow trunks.
"""

from __future__ import annotations

import argparse

from repro.core.encode import default_q_for
from repro.topo import autotune, make_topology


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--K", type=int, default=16, help="number of processors")
    ap.add_argument("--p", type=int, default=1, help="ports per processor")
    ap.add_argument("--payload-bytes", type=int, default=65536)
    ap.add_argument(
        "--topology",
        default="two-level",
        choices=("flat", "ring", "torus", "two-level", "hierarchy"),
    )
    ap.add_argument(
        "--intra", type=int, default=None, help="fast-domain size (torus/two-level)"
    )
    ap.add_argument(
        "--levels",
        default=None,
        help="hierarchy level sizes, innermost first, comma-separated "
        "(e.g. 4,4,2 = 4 chips < 4 slices < 2 pods; Π levels must equal K)",
    )
    ap.add_argument(
        "--generator", default="general", choices=("general", "vandermonde", "dft")
    )
    ap.add_argument("--q", type=int, default=None, help="field prime (default: auto)")
    args = ap.parse_args()

    q = args.q or default_q_for(args.K, args.p)
    levels = (
        tuple(int(s) for s in args.levels.split(",")) if args.levels else None
    )
    topo = make_topology(args.topology, args.K, k_intra=args.intra, levels=levels)
    result = autotune(
        args.K, args.p, args.payload_bytes, topo, q=q, generator=args.generator
    )

    extra = f" levels={getattr(topo, 'levels', None)}" if args.topology == "hierarchy" else ""
    print(
        f"K={args.K} p={args.p} payload={args.payload_bytes}B "
        f"topology={topo.name}{extra} generator={args.generator} q={q}"
    )
    print(f"{'algorithm':<18}{'C1':>4}{'C2':>5}{'time':>12}{'contention':>12}")
    for c in result.candidates:
        mark = " ←" if c is result.chosen else ""
        print(
            f"{c.algorithm:<18}{c.c1:>4}{c.c2:>5}"
            f"{c.predicted_time * 1e6:>10.2f}µs{c.estimate.max_contention:>12}{mark}"
        )
    ch = result.chosen
    print(
        f"\nchosen: {ch.algorithm} — C1={ch.c1} rounds, C2={ch.c2} elements/port, "
        f"predicted {ch.predicted_time * 1e6:.2f} µs"
    )


if __name__ == "__main__":
    main()
