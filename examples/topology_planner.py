"""Topology planner: which encode algorithm should this scenario run?

Given K, p, a payload size, and a topology, prints the autotuner's candidate
table — per-algorithm C1 (rounds), C2 (elements per port), α-β predicted
time, worst per-link contention — and its choice (marked ``←``).

Run:  PYTHONPATH=src python examples/topology_planner.py \
          --K 16 --p 1 --payload-bytes 65536 --topology two-level --intra 4

      # recursive multi-level hierarchy (chip < slice < pod):
      PYTHONPATH=src python examples/topology_planner.py \
          --K 32 --topology hierarchy --levels 4,4,2

Topologies: flat | ring | torus | torus3d | two-level | hierarchy.
``torus``/``two-level`` take ``--intra`` (fast-domain size);
``hierarchy`` takes ``--levels`` — comma-separated per-level sizes,
innermost (fastest links) first, multiplying to K (default: a balanced
three-level factorization of K); ``torus3d`` reuses ``--levels`` as its
(cols, rows, depth) dims. Generators: general | vandermonde | dft
(structured kinds unlock the specific algorithms; dft needs K compatible
with the field).

Reading the output: a candidate is an (algorithm, pipeline) pair — rows
like ``butterfly+remap-digits`` are a base compile rewritten by a named
``topo.passes`` pipeline (here the Gray-relabeled butterfly whose partners
are torus neighbors). On a hierarchy the ``multilevel`` row is the recursive
schedule whose phases align with the topology's levels (gather on the
fastest links, one digit-reduction shoot per level); ``contention`` is the
worst number of messages sharing one link in any round — the quantity the
level-aligned schedules are designed to keep off the slow trunks.

``--emit-ir`` additionally prints the chosen algorithm's compiled
ScheduleIR: every communication round (port, transfers, elements per
message, example src→dst pairs with their slot selectors) and every local
contraction — the exact schedule the simulator interprets and
``dist.collectives.ir_encode_jit`` executes.

``--pipeline NAME`` applies one named pass pipeline from the
``topo.passes.PIPELINES`` registry to the cheapest base candidate it
applies to and prints the before/after α-β price plus the rewritten IR —
the single-pipeline view of what the autotuner enumerates.
"""

from __future__ import annotations

import argparse

from repro.core.encode import default_q_for
from repro.core.ir import CommRound, round_port_groups
from repro.topo import PIPELINES, autotune, ir_time, make_topology


def emit_ir(ir, max_pairs: int = 4) -> str:
    """Human-readable dump of a compiled ScheduleIR."""
    lines = [
        f"ScheduleIR[{ir.algorithm}] K={ir.K} p={ir.p} "
        f"C1={ir.c1} C2={ir.c2}"
        + (f" placement={list(ir.placement)}" if ir.placement else "")
    ]
    rnd = 0
    for step in ir.steps:
        if isinstance(step, CommRound):
            rnd += 1
            lines.append(f"  round {rnd}:")
            for g in round_port_groups(step):
                pairs = " ".join(f"{s}->{d}" for s, d in g.pairs[:max_pairs])
                more = "" if len(g.pairs) <= max_pairs else f" …(+{len(g.pairs) - max_pairs})"
                slots = ",".join(f"{ss}->{ds}" for ss, ds in g.slots)
                coeff = " coeffs" if g.coeffs_by_dst else ""
                lines.append(
                    f"    port {g.port} [{g.mode}] {len(g.slots)} elem/msg "
                    f"slots[{slots}]{coeff}: {pairs}{more}"
                )
        else:
            shape = (
                "structure-only"
                if step.coeffs is None
                else "x".join(str(s) for s in step.coeffs.shape)
            )
            lines.append(
                f"  local: {len(step.in_slots)} slots -> {len(step.out_slots)} "
                f"slots (coeffs {shape})"
            )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--K", type=int, default=16, help="number of processors")
    ap.add_argument("--p", type=int, default=1, help="ports per processor")
    ap.add_argument("--payload-bytes", type=int, default=65536)
    ap.add_argument(
        "--topology",
        default="two-level",
        choices=("flat", "ring", "torus", "torus3d", "two-level", "hierarchy"),
    )
    ap.add_argument(
        "--intra", type=int, default=None, help="fast-domain size (torus/two-level)"
    )
    ap.add_argument(
        "--levels",
        default=None,
        help="hierarchy level sizes, innermost first, comma-separated "
        "(e.g. 4,4,2 = 4 chips < 4 slices < 2 pods; Π levels must equal K)",
    )
    ap.add_argument(
        "--generator", default="general", choices=("general", "vandermonde", "dft")
    )
    ap.add_argument("--q", type=int, default=None, help="field prime (default: auto)")
    ap.add_argument(
        "--emit-ir",
        action="store_true",
        help="print the chosen algorithm's compiled ScheduleIR "
        "(rounds, transfers, slot selectors, local contractions)",
    )
    ap.add_argument(
        "--pipeline",
        default=None,
        choices=sorted(PIPELINES),
        help="apply one named pass pipeline to the cheapest base candidate "
        "it applies to; print before/after α-β price and the rewritten IR",
    )
    args = ap.parse_args()

    q = args.q or default_q_for(args.K, args.p)
    levels = (
        tuple(int(s) for s in args.levels.split(",")) if args.levels else None
    )
    topo = make_topology(args.topology, args.K, k_intra=args.intra, levels=levels)
    result = autotune(
        args.K, args.p, args.payload_bytes, topo, q=q, generator=args.generator
    )

    extra = f" levels={getattr(topo, 'levels', None)}" if args.topology == "hierarchy" else ""
    print(
        f"K={args.K} p={args.p} payload={args.payload_bytes}B "
        f"topology={topo.name}{extra} generator={args.generator} q={q}"
    )
    w = max(28, max(len(c.algorithm) for c in result.candidates) + 2)
    print(f"{'algorithm':<{w}}{'C1':>4}{'C2':>5}{'time':>12}{'contention':>12}")
    for c in result.candidates:
        mark = " ←" if c is result.chosen else ""
        print(
            f"{c.algorithm:<{w}}{c.c1:>4}{c.c2:>5}"
            f"{c.predicted_time * 1e6:>10.2f}µs{c.estimate.max_contention:>12}{mark}"
        )
    ch = result.chosen
    print(
        f"\nchosen: {ch.algorithm} — C1={ch.c1} rounds, C2={ch.c2} elements/port, "
        f"predicted {ch.predicted_time * 1e6:.2f} µs"
    )
    if args.emit_ir:
        print()
        print(emit_ir(ch.ir))
    if args.pipeline:
        pl = PIPELINES[args.pipeline]
        base = next(
            (
                c
                for c in result.candidates
                if not c.pipeline and pl.applicable(c.ir, topo)
            ),
            None,
        )
        print()
        if base is None:
            print(f"pipeline {pl.name!r}: not applicable to any candidate here")
            return
        pay = max(1, args.payload_bytes // 4)
        rewritten = pl.apply(base.ir, topo, pay)
        t0, t1 = ir_time(base.ir, topo, pay), ir_time(rewritten, topo, pay)
        note = " (no rewrite: already optimal)" if rewritten is base.ir else ""
        print(
            f"pipeline {pl.name!r} on {base.algorithm}: "
            f"{t0 * 1e6:.2f}µs → {t1 * 1e6:.2f}µs{note}"
        )
        print(emit_ir(rewritten))


if __name__ == "__main__":
    main()
