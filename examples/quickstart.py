"""Quickstart: all-to-all encode in 30 lines.

Every one of K=16 processors holds a packet; each wants a distinct linear
combination (a column of A). The universal prepare-and-shoot algorithm does
it in C1 = ⌈log2 K⌉ = 4 rounds moving C2 = 6 elements per port — vs 15 for
an all-gather.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import CostModel, Field, M31, a2a_encode, plan_for
from repro.core.matrices import random_matrix, random_vector
from repro.core.prepare_shoot import encode_oracle

K = 16
f = Field(M31)

A = random_matrix(f, K, seed=0)  # ANY matrix — the universal promise
x = random_vector(f, K, seed=1)

out, report = a2a_encode(jnp.asarray(x.astype(np.uint32)), jnp.asarray(A.astype(np.uint32)), p=1)

assert np.array_equal(np.asarray(out, dtype=np.uint64), encode_oracle(x, A))
print(f"algorithm      : {report.algorithm}")
print(f"rounds C1      : {report.c1}   (lower bound {report.c1_lower} — optimal: {report.c1_optimal})")
print(f"elements C2    : {report.c2}   (vs all-gather baseline {K - 1})")
print(f"modelled time  : {report.time * 1e6:.2f} µs on v5e ICI (β=1µs, τ=4B/50GBps)")

# structured matrices get the specific algorithms (exponentially better C2):
plan = plan_for("dft", K, p=1, q=2013265921)
xq = random_vector(Field(2013265921), K, seed=2)
out2, report2 = a2a_encode(jnp.asarray(xq.astype(np.uint32)), plan=plan)
print(f"\nDFT butterfly  : C1 = C2 = {report2.c2} (strictly optimal, Theorem 2)")
