"""Batched serving demo: build a small model, generate with the batched
engine (greedy + sampled), print throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get
from repro.models import build_model
from repro.serve import Engine


def main():
    cfg = get("qwen3-1.7b").replace(
        name="qwen3-serve-demo",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=768,
        vocab_size=32768,
        vocab_padded=0,
        remat="none",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, max_len=128)

    prompts = [[1, 5, 9, 2], [7, 7, 7], [42], [3, 1, 4, 1, 5, 9, 2, 6]]
    t0 = time.time()
    res = eng.generate(prompts, max_new_tokens=24)
    dt = time.time() - t0
    print(f"batch of {len(prompts)} prompts, {res.steps} decode steps in {dt:.2f}s "
          f"({res.steps * len(prompts) / dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(res.tokens):
        print(f"  seq {i}: {row[:16].tolist()} …")
    res2 = eng.generate(prompts, max_new_tokens=24, greedy=False, seed=7)
    print("sampled variant differs:", not (res.tokens == res2.tokens).all())


if __name__ == "__main__":
    main()
