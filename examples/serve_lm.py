"""Serving demo: continuous batching vs the fixed-batch baseline.

Builds a small model, pushes a seeded Poisson trace of mixed-length
requests through the continuous-batching engine (compiled bucketed
prefill + slot-scheduled decode), prints per-request latencies, then runs
the same prompts through the fixed-batch engine for contrast.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get
from repro.models import build_model
from repro.serve import ContinuousEngine, Engine, LengthBand, poisson_trace


def main():
    cfg = get("qwen3-1.7b").replace(
        name="qwen3-serve-demo",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=768,
        vocab_size=32768,
        vocab_padded=0,
        remat="none",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    reqs = poisson_trace(
        n_requests=8,
        rate_rps=40.0,
        mix=(LengthBand(2, 6, 0.6), LengthBand(7, 16, 0.4)),
        max_new_tokens=12,
        vocab_size=cfg.vocab_size,
        seed=0,
    )

    eng = ContinuousEngine(
        model, params, n_slots=4, max_len=64, buckets=(8, 16, 32),
        max_new_tokens=12,
    )
    rep = eng.serve(reqs, greedy=True)
    print(
        f"continuous: {len(rep.results)} requests, {rep.tokens_per_s:.1f} tok/s, "
        f"ttft p50/p99 {rep.ttft_ms['p50']:.1f}/{rep.ttft_ms['p99']:.1f} ms, "
        f"occupancy {rep.slot_occupancy:.2f}, "
        f"{rep.prefill_compiles} prefill graphs (incl. compile)"
    )
    for r in rep.results[:4]:
        print(f"  {r.id}: {r.tokens[: r.prompt_len]} => "
              f"{r.tokens[r.prompt_len :][:8]} (ttft {r.ttft_s * 1e3:.1f} ms)")

    prompts = [r.prompt for r in reqs[:4]]
    feng = Engine(model, params, max_len=64)
    t0 = time.time()
    res = feng.generate(prompts, max_new_tokens=12)
    dt = time.time() - t0
    gen = int((res.lengths - res.prompt_lens).sum())
    print(f"fixed batch of {len(prompts)}: {res.steps} decode steps, "
          f"{gen} generated tokens in {dt:.2f}s (incl. compile)")
    res2 = feng.generate(prompts, max_new_tokens=12, greedy=False, seed=7)
    print("sampled variant differs:", not (res.tokens == res2.tokens).all())


if __name__ == "__main__":
    main()
